"""End-to-end training driver: train a small LM with the full production
stack — sharded train step, AdamW + cosine schedule, deterministic data
pipeline, async checkpointing, crash recovery.

Default runs a ~7M-parameter qwen-family model for 200 steps on CPU in a
couple of minutes.  ``--arch`` selects any registered architecture;
``--params-100m`` scales to ~100M parameters (the deliverable configuration
— run it on real hardware).

Run:  PYTHONPATH=src python examples/train_tinylm.py [--steps 200]
"""

import argparse

import jax

from repro.configs import base as cb
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--params-100m", action="store_true",
                    help="~100M-param configuration (use real hardware)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tinylm")
    args = ap.parse_args()

    cfg = cb.get(args.arch, smoke=True)
    if args.params_100m:
        cfg = cfg.scaled(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
                         d_ff=2048, vocab_size=32000)
    else:
        cfg = cfg.scaled(d_model=128, d_ff=384, n_layers=4,
                         vocab_size=2048)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    tc = TrainerConfig(total_steps=args.steps, ckpt_every=50, log_every=10,
                       ckpt_dir=args.ckpt_dir)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                            total_steps=args.steps)
    dc = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0)
    trainer = Trainer(cfg, tc, opt_cfg=opt, data_cfg=dc)

    import logging
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    out = trainer.run()
    print(f"\nfinished at step {out['final_step']}; "
          f"loss {float(out['metrics']['loss']):.4f}; "
          f"restarts {out['restarts']}; stragglers {out['stragglers']}")
    trainer.checkpointer.close()


if __name__ == "__main__":
    main()
