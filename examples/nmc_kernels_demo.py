"""Pallas kernel demo: the TPU-native transcriptions of the paper's engines.

1. ``vrf_alu`` — the NM-Carus VPU as a fused vector-program kernel: an
   N-instruction program executes against a VMEM-resident register file in
   ONE pallas_call (one HBM round-trip instead of N), with the program as
   runtime data (the indirect-addressing property: no retrace per program).
2. ``nmc_matmul`` — the W8A8 vmacc loop on the MXU with fused
   dequant+bias+activation epilogue.

Both run here in interpret mode (CPU container); on TPU hardware the same
calls lower to Mosaic.

Run:  PYTHONPATH=src python examples/nmc_kernels_demo.py
"""

import numpy as np
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.nmc_matmul import nmc_matmul
from repro.kernels.vrf_alu import make_prog, vrf_alu


def main():
    rng = np.random.default_rng(0)

    print("vrf_alu: one kernel, arbitrary programs (program = data)")
    vrf = jnp.asarray(rng.integers(-100, 100, (32, 4096)).astype(np.int16))
    # program A: leaky-relu of v1 into v3 via the paper's max(x, x>>2) trick
    prog_a = make_prog([("sra", 2, 0, 1, 2, ref.VRF_MODE_VX),
                        ("max", 3, 2, 1, 0, ref.VRF_MODE_VV)])
    # program B: fused (v1*v4 + v5) ^ v1, then clamp
    prog_b = make_prog([("mul", 6, 4, 1, 0, ref.VRF_MODE_VV),
                        ("add", 6, 5, 6, 0, ref.VRF_MODE_VV),
                        ("xor", 7, 1, 6, 0, ref.VRF_MODE_VV),
                        ("min", 7, 0, 7, 100, ref.VRF_MODE_VX),
                        ("max", 7, 0, 7, -100, ref.VRF_MODE_VX)])
    for name, prog in (("leaky_relu", prog_a), ("fused_chain", prog_b)):
        out = vrf_alu(vrf, prog, block_vl=1024, interpret=True)
        pd = {k: np.asarray(prog[:, i]) for i, k in
              enumerate(("op", "vd", "vs1", "vs2", "scalar", "mode"))}
        exp = ref.vrf_alu(vrf, pd)
        print(f"  {name}: {prog.shape[0]} instrs, one HBM round-trip, "
              f"bit-exact={bool((np.asarray(out) == np.asarray(exp)).all())}")

    print("\nnmc_matmul: W8A8 with fused epilogue (int32 accumulation)")
    m, k, n = 512, 1024, 512
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)) * 0.05
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    wq, sw = ref.quantize_rowwise(w)
    xq, sx = ref.quantize_dynamic(x)
    y = nmc_matmul(xq, wq, sw * sx, None, act="relu", interpret=True)
    exact = jnp.maximum(x @ w, 0)
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    print(f"  {m}x{k}x{n}: relative error vs fp32 {rel:.4f} "
          f"(int8 weights: {k*n/2**20:.1f} MiB vs fp32 {4*k*n/2**20:.1f})")


if __name__ == "__main__":
    main()