"""NMC execution demo: the traced frontend, the bucketed tile scheduler
and the Pallas kernels.

0. ``nmc.kernel`` — author a custom fused kernel as numpy-style Python:
   one decorator gives tracing, engine auto-selection, unified-IR
   lowering, pooled scheduling and sync/async dispatch (DESIGN.md §7).
1. Bucketed multi-tile dispatch — a heterogeneous kernel sweep runs through
   :class:`repro.nmc.BucketedPool`: instruction streams NOP-pad to
   power-of-two buckets, so the whole sweep compiles once per
   ``(engine, sew, bucket)`` instead of once per kernel shape.
2. Resident tile array — :class:`repro.nmc.ResidentPool` keeps tile
   memories on device across dispatches (the paper's memory-mode /
   compute-mode duality): re-dispatching a program moves only instruction
   bytes, never tile state.
3. ``vrf_alu`` — the NM-Carus VPU as a fused vector-program kernel: an
   N-instruction program executes against a VMEM-resident register file in
   ONE pallas_call (one HBM round-trip instead of N), with the program as
   runtime data (the indirect-addressing property: no retrace per program).
4. ``nmc_matmul`` — the W8A8 vmacc loop on the MXU with fused
   dequant+bias+activation epilogue.

The Pallas kernels run here in interpret mode (CPU container); on TPU
hardware the same calls lower to Mosaic.

Run:  PYTHONPATH=src python examples/nmc_kernels_demo.py
"""

import numpy as np
import jax.numpy as jnp

from repro import nmc
from repro.core import programs, timing
from repro.kernels import ref
from repro.kernels.nmc_matmul import nmc_matmul
from repro.kernels.vrf_alu import make_prog, vrf_alu
from repro.nmc import BucketedPool, DispatchQueue, ResidentPool


def frontend_demo():
    rng = np.random.default_rng(1)
    print("nmc.kernel: numpy-style authoring, the whole stack in one call")

    @nmc.kernel
    def leaky_gate(t, x, g):
        xv, gv = t.load(x, bank=0), t.load(g)
        t.store(xv.max(xv >> 2) & gv)        # leaky-relu, gated

    x = rng.integers(-128, 128, 1024, dtype=np.int8)
    g = rng.integers(-128, 128, 1024, dtype=np.int8)
    picked = leaky_gate.select_engine(x, g)
    sync = leaky_gate(x, g)
    futs = [leaky_gate.call_async(x, g, engine=e)
            for e in ("caesar", "carus")]
    agree = all((np.asarray(f.result()) == np.asarray(sync)).all()
                for f in futs)
    oracle_ok = (np.asarray(sync) == leaky_gate.oracle(x, g)).all()
    assert agree and oracle_ok, "frontend sync/async/oracle diverged"
    print(f"  auto-selected engine: {picked}; sync == async(caesar) == "
          f"async(carus) == numpy oracle: {agree and oracle_ok}")

    @nmc.kernel
    def needs_carus(t, x, g):
        t.store(t.load(x).maxu(t.load(g)))
    print(f"  x.maxu(g) auto-selects: {needs_carus.select_engine(x, g)} "
          f"(unsigned compares are xvnmc-only)")


def nmc_scheduler_demo():
    small = {"caesar_bytes": 2048, "carus_bytes": 4096}
    kbs = [programs.build(name, 8, **small)
           for name in ("xor", "mul", "relu", "leaky_relu")]
    # a ragged size: 384 bus ops pad into the same 512 bucket as the others
    kbs.append(programs.build("add", 8, caesar_bytes=1536, carus_bytes=4096))
    builds = [eb for kb in kbs for eb in (kb.caesar, kb.carus)]

    print("bucketed scheduler: heterogeneous sweep, one compile per bucket")
    pool = BucketedPool()
    outs = pool.run_builds(builds)
    exact = all((got.reshape(-1)[: eb.oracle.size]
                 == eb.oracle.reshape(-1)).all()
                for got, eb in zip(outs, builds))
    assert exact, "bucketed sweep diverged from the kernel oracles"
    shapes = {eb.program.shape_key for eb in builds}
    buckets = {eb.program.bucket_key for eb in builds}
    print(f"  {len(builds)} kernel instances, {len(shapes)} exact shapes -> "
          f"{len(buckets)} buckets, {pool.compiles} compiles, "
          f"pad_waste={pool.pad_waste} slots, bit-exact={exact}")

    print("resident tile array: load once, dispatch many (compute mode)")
    rpool = ResidentPool()
    rpool.run_builds(builds[:2])
    loaded = rpool.bytes_moved
    rpool.dispatch([(t, eb.program)
                    for t, eb in zip(rpool.tiles, builds[:2])])
    print(f"  initial load+run moved {loaded} B; re-dispatch moved "
          f"{rpool.bytes_moved - loaded} B (instruction stream only), "
          f"{rpool.compiles} compiles total")

    print("async dispatch queue: double-buffered futures over a 2-tile array")
    queue = DispatchQueue()
    async_outs = queue.run_builds(builds, n_tiles=2)
    async_ok = all((got.reshape(-1)[: eb.oracle.size]
                    == eb.oracle.reshape(-1)).all()
                   for got, eb in zip(async_outs, builds))
    assert async_ok, "async futures diverged from the kernel oracles"
    stages = [timing.stage_cost(eb) for eb in builds]
    ser = timing.dispatch_cycles(stages, "serial")
    ovl = timing.dispatch_cycles(stages, "overlapped")
    print(f"  {queue.submitted} work items in {queue.waves} waves, "
          f"{queue.staged_while_busy} images staged while the tile was "
          f"busy, bit-exact={async_ok}")
    print(f"  modeled dispatch cost: serial {ser:.0f} cyc -> overlapped "
          f"{ovl:.0f} cyc ({ovl / ser:.2f}x, max(dma, compute) per stage)")


def main():
    rng = np.random.default_rng(0)

    frontend_demo()
    print()

    nmc_scheduler_demo()
    print()

    print("vrf_alu: one kernel, arbitrary programs (program = data)")
    vrf = jnp.asarray(rng.integers(-100, 100, (32, 4096)).astype(np.int16))
    # program A: leaky-relu of v1 into v3 via the paper's max(x, x>>2) trick
    prog_a = make_prog([("sra", 2, 0, 1, 2, ref.VRF_MODE_VX),
                        ("max", 3, 2, 1, 0, ref.VRF_MODE_VV)])
    # program B: fused (v1*v4 + v5) ^ v1, then clamp
    prog_b = make_prog([("mul", 6, 4, 1, 0, ref.VRF_MODE_VV),
                        ("add", 6, 5, 6, 0, ref.VRF_MODE_VV),
                        ("xor", 7, 1, 6, 0, ref.VRF_MODE_VV),
                        ("min", 7, 0, 7, 100, ref.VRF_MODE_VX),
                        ("max", 7, 0, 7, -100, ref.VRF_MODE_VX)])
    for name, prog in (("leaky_relu", prog_a), ("fused_chain", prog_b)):
        out = vrf_alu(vrf, prog, block_vl=1024, interpret=True)
        pd = {k: np.asarray(prog[:, i]) for i, k in
              enumerate(("op", "vd", "vs1", "vs2", "scalar", "mode"))}
        exp = ref.vrf_alu(vrf, pd)
        ok = bool((np.asarray(out) == np.asarray(exp)).all())
        assert ok, f"vrf_alu {name} diverged from the reference"
        print(f"  {name}: {prog.shape[0]} instrs, one HBM round-trip, "
              f"bit-exact={ok}")

    print("\nnmc_matmul: W8A8 with fused epilogue (int32 accumulation)")
    m, k, n = 512, 1024, 512
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)) * 0.05
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    wq, sw = ref.quantize_rowwise(w)
    xq, sx = ref.quantize_dynamic(x)
    y = nmc_matmul(xq, wq, sw * sx, None, act="relu", interpret=True)
    exact = jnp.maximum(x @ w, 0)
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    print(f"  {m}x{k}x{n}: relative error vs fp32 {rel:.4f} "
          f"(int8 weights: {k*n/2**20:.1f} MiB vs fp32 {4*k*n/2**20:.1f})")


if __name__ == "__main__":
    main()
