"""Serving with the paper's technique: int8 near-memory (NMC) execution.

Quantizes a model to the W8A8 serving form (per-channel int8 weights,
dynamic int8 activations, int32 accumulation — the NM-Carus vmacc contract)
and serves a stream of requests with continuous batching, comparing output
agreement and weight-memory footprint against the bf16 baseline.  Every
prefill/decode computation is dispatched as queued work through the async
:class:`repro.nmc.DispatchQueue` from the one public ``repro.nmc``
surface (DESIGN.md §5.2/§7), so admission launches overlap on the device
and the host blocks only at future resolution.

Run:  PYTHONPATH=src python examples/serve_nmc.py
"""

import numpy as np
import jax

from repro import nmc
from repro.configs import base as cb
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, quantize_params


def tree_bytes(t):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))


def main():
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = cfg.scaled(nmc_mode="w8a8")
    qparams = quantize_params(params, qcfg)
    print(f"weights: bf16/f32 {tree_bytes(params)/2**20:.1f} MiB -> "
          f"NMC int8 {tree_bytes(qparams)/2**20:.1f} MiB")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 10, 14, 8)]

    outs = {}
    for name, (c, p) in {"bf16": (cfg, params),
                         "nmc-w8a8": (qcfg, qparams)}.items():
        # one dispatch queue per engine so the queued-work counter below is
        # per-run; without the argument both would share the process-wide
        # nmc.default_runtime() queue
        eng = ServeEngine(c, p, n_slots=2, max_len=64,
                          nmc_queue=nmc.DispatchQueue())
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new=8))
        done = sorted(eng.run(), key=lambda r: r.rid)
        outs[name] = [r.out for r in done]
        print(f"{name:9s}: {[o[:6] for o in outs[name]]}  "
              f"({eng.nmc_queue.calls} prefill/decode computations queued "
              f"through the async dispatch runtime)")

    agree = np.mean([np.mean(np.array(a) == np.array(b))
                     for a, b in zip(outs["bf16"], outs["nmc-w8a8"])])
    print(f"\ntoken agreement bf16 vs NMC-int8: {100*agree:.0f}%")


if __name__ == "__main__":
    main()
