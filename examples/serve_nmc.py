"""Serving with the paper's technique: int8 near-memory (NMC) execution.

Quantizes a model to the W8A8 serving form (per-channel int8 weights,
dynamic int8 activations, int32 accumulation — the NM-Carus vmacc contract)
and serves a stream of requests with continuous batching, comparing output
agreement and weight-memory footprint against the bf16 baseline.  Every
prefill/decode computation is dispatched as queued work through the async
:class:`repro.nmc.DispatchQueue` from the one public ``repro.nmc``
surface (DESIGN.md §5.2/§7), so admission launches overlap on the device
and the host blocks only at future resolution.

The second half demos the resident-block serving path (DESIGN.md §12):
one decoder layer's quantized weights are DMA'd onto the simulated tile
array once, then every decoded token runs the whole block — q/k/v/o
projections plus the MLP — as chained partitioned waves against the
resident weights, with :class:`repro.nmc.ResidentPool` counters proving
only activation patches cross the bus after the first step.

Run:  PYTHONPATH=src python examples/serve_nmc.py
"""

import numpy as np
import jax

from repro import nmc
from repro.configs import base as cb
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, quantize_params


def tree_bytes(t):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))


def main():
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qcfg = cfg.scaled(nmc_mode="w8a8")
    qparams = quantize_params(params, qcfg)
    print(f"weights: bf16/f32 {tree_bytes(params)/2**20:.1f} MiB -> "
          f"NMC int8 {tree_bytes(qparams)/2**20:.1f} MiB")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 10, 14, 8)]

    outs = {}
    for name, (c, p) in {"bf16": (cfg, params),
                         "nmc-w8a8": (qcfg, qparams)}.items():
        # one dispatch queue per engine so the queued-work counter below is
        # per-run; without the argument both would share the process-wide
        # nmc.default_runtime() queue
        eng = ServeEngine(c, p, n_slots=2, max_len=64,
                          nmc_queue=nmc.DispatchQueue())
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new=8))
        done = sorted(eng.run(), key=lambda r: r.rid)
        outs[name] = [r.out for r in done]
        print(f"{name:9s}: {[o[:6] for o in outs[name]]}  "
              f"({eng.nmc_queue.calls} prefill/decode computations queued "
              f"through the async dispatch runtime)")

    agree = np.mean([np.mean(np.array(a) == np.array(b))
                     for a, b in zip(outs["bf16"], outs["nmc-w8a8"])])
    print(f"\ntoken agreement bf16 vs NMC-int8: {100*agree:.0f}%")

    # -- resident-block serving (DESIGN.md §12) ------------------------------
    # keep one decoder layer's W8A8 weights resident on the tile array and
    # decode against them: weights DMA once, every later step patches only
    # the activation scalar-tap words
    own = nmc.DispatchQueue(pool=nmc.ResidentPool(
        pool=nmc.default_runtime().bucketed))
    eng = ServeEngine(qcfg, qparams, n_slots=4, max_len=64,
                      nmc_queue=own, nmc_tiles=4)
    blk = eng.resident_block(layer=0, tiles=4)
    x = rng.normal(size=(4, qcfg.d_model)).astype(np.float32)
    xj = x.copy()
    st, stj = blk.init_state(16), blk.init_state(16)
    print(f"\nresident block: {blk.n_shards} tile shards, "
          f"static layout proof: {blk.static}")
    for step in range(3):
        x, st = blk.step(x, st)                    # resident tile array
        xj, stj = blk.step(xj, stj, mm=blk.jax_mm)  # pure-JAX int32 reference
        assert np.array_equal(x, xj), "resident path diverged from reference"
        print(f"  step {step}: loads={own.pool.loads} "
              f"(weight DMAs — constant after step 0), "
              f"patch_bytes={own.pool.patch_bytes} "
              f"(+{blk.patch_bytes_per_call}/step), bit-exact vs JAX: "
              f"{np.array_equal(x, xj)}")
    print(f"steady-state block step: {blk.step_cycles(steady=True):.0f} "
          f"modeled cycles (cold: {blk.step_cycles(steady=False):.0f})")


if __name__ == "__main__":
    main()
