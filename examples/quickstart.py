"""Quickstart: the paper's NMC engines in five minutes.

Runs an 8-bit matrix multiplication three ways — RV32IMC CPU (Table V
baseline model), NM-Caesar (host-streamed micro-ops), NM-Carus (autonomous
xvnmc program) — verifying bit-exactness and reporting the modeled
cycles/energy, then demonstrates full eCPU programmability by assembling
and executing a real RV32E + xvnmc kernel with indirect register addressing.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import alu, carus, ecpu, energy, programs, timing
from repro.core.constants import F_CLK_BENCH_HZ


def main():
    print("=" * 64)
    print("NM-Caesar / NM-Carus quickstart (8-bit matmul A[8,8] x B[8,1024])")
    print("=" * 64)
    kb = programs.build("matmul", 8)
    ok = programs.verify(kb)
    print(f"functional (bit-exact vs quantized oracle): {ok}")

    t = timing.kernel_timing(kb)
    e = energy.kernel_energy(kb)
    print(f"\n{'target':10s} {'cycles':>10s} {'us @250MHz':>11s} "
          f"{'energy nJ':>10s} {'vs CPU':>7s}")
    cpu_cyc = t["cpu"].total_cycles
    for name in ("cpu", "caesar", "carus"):
        cyc = t[name].total_cycles
        outs = kb.n_outputs if name == "cpu" else getattr(kb, name).n_outputs
        speed = (cpu_cyc / kb.n_outputs) / (cyc / outs)
        print(f"{name:10s} {cyc:10.0f} {cyc/F_CLK_BENCH_HZ*1e6:11.1f} "
              f"{e[name].energy_pj/1e3:10.1f} {speed:6.1f}x")

    print("\n" + "=" * 64)
    print("eCPU programmability: assembled RV32E + xvnmc kernel")
    print("=" * 64)
    src = """
        li   a0, 4              # chunks
        li   t0, 1024
        vsetvli t1, t0, e8
        li   t2, 0x00140A00     # packed indices vd=20 vs2=10 vs1=0
        li   a1, 0x00010101     # +1 on each index per iteration
        li   t1, 0
    loop:
        xvnmc.vaddr.vv t2       # indirect-addressed vector add
        add  t2, t2, a1
        addi t1, t1, 1
        blt  t1, a0, loop
        halt
    """
    words = ecpu.assemble(src)
    print(f"assembled {len(words)} instruction words "
          f"(code size independent of data size — Section III-B1)")
    vpu = carus.CarusVPU()
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, 4096, dtype=np.int8)
    b = rng.integers(-128, 128, 4096, dtype=np.int8)
    vrf = np.zeros((32, 256), np.int32)
    for i in range(4):
        vrf[i] = alu.pack_np(a[i * 1024:(i + 1) * 1024])
        vrf[10 + i] = alu.pack_np(b[i * 1024:(i + 1) * 1024])
    cpu = ecpu.ECpu(vpu, jnp.asarray(vrf))
    cpu.load_program(words)
    cpu.run()
    got = np.concatenate([alu.unpack_np(np.asarray(cpu.vrf[20 + i]), np.int8)
                          for i in range(4)])
    print(f"eCPU executed {cpu.scalar_retired} scalar + "
          f"{cpu.vector_retired} vector instructions; "
          f"result correct: {bool((got == a + b).all())}")


if __name__ == "__main__":
    main()