"""Quickstart: the whole NMC stack as one function call (`nmc.jit`).

Write a kernel as numpy-style Python; calling it runs trace -> engine
auto-selection -> unified-IR lowering -> bucketed/resident scheduling ->
dispatch -> extraction, bit-exact against the pure-numpy oracle the
tracer evaluates alongside.  This demo:

1. compiles a fused elementwise kernel and runs it on BOTH engines, sync
   and async, comparing against the oracle and reporting modeled
   cycles/energy;
2. shows engine auto-selection picking NM-Caesar for bus-expressible
   bodies and NM-Carus for bodies the bus ALU cannot express — plus the
   `UnsupportedOnEngine` diagnostic for an explicit bad choice;
3. runs the paper's 8-bit matmul (Table V) through the same traced
   frontend (the kernel library is built on it) against the RV32IMC CPU
   baseline;
4. shards ONE kernel across the tile array (`tiles=N`): the partitioning
   planner splits the matmul's output rows over 4 tiles, the wave runs as
   one batched dispatch, a future-of-gathers reassembles the result
   bit-exactly, and the shared-bus timing model reports the wave speedup;
5. swaps the engine executor under the same kernel (`backend="pallas"`):
   the bucketed instruction stream runs as one fused `pl.pallas_call`
   instead of a per-instruction `lax.scan`, bit-exact and timed against
   the scan reference (DESIGN.md §10).

Run:  PYTHONPATH=src python examples/quickstart.py   (finishes in ~30 s)
"""

import numpy as np

from repro import nmc
from repro.core import energy, programs, timing
from repro.core.constants import F_CLK_BENCH_HZ


def main():
    rng = np.random.default_rng(0)
    print("=" * 64)
    print("1. one kernel, the whole stack: @nmc.kernel -> both engines")
    print("=" * 64)

    @nmc.kernel                       # trace + auto engine selection, SEW 8
    def fused(t, x, y):
        a, b = t.load(x, bank=0), t.load(y)
        t.store(((a * 3) + b).max(0))        # scaled-add + ReLU

    x = rng.integers(-128, 128, 2048, dtype=np.int8)
    y = rng.integers(-128, 128, 2048, dtype=np.int8)
    oracle = fused.oracle(x, y)

    for engine in ("caesar", "carus"):
        out = fused(x, y, engine=engine)            # synchronous call
        fut = fused.call_async(x, y, engine=engine)  # DispatchQueue future
        ok = (np.asarray(out) == oracle).all() and \
            (np.asarray(fut.result()) == np.asarray(out)).all()
        assert ok, f"{engine}: sync/async diverged from the numpy oracle"
        lk = fused.lower(x, y, engine=engine)
        t = timing.program_cycles(lk.program)
        e = energy.program_energy(lk.program)
        print(f"  {engine:6s}: {lk.program.n_instr:5d} instrs, "
              f"{t.total_cycles:7.0f} cyc "
              f"({t.total_cycles / F_CLK_BENCH_HZ * 1e6:5.1f} us @250MHz), "
              f"{e.energy_pj / 1e3:6.1f} nJ, sync==async==oracle: {ok}")

    print()
    print("=" * 64)
    print("2. engine auto-selection + diagnostics")
    print("=" * 64)

    @nmc.kernel
    def bus_friendly(t, x):
        t.store((t.load(x) + 1).max(0))

    @nmc.kernel
    def needs_vector_isa(t, x):
        t.store(t.load(x).maxu(100))         # unsigned max: xvnmc only

    print(f"  (x + 1).relu()  -> {bus_friendly.select_engine(x)}"
          f"   (bus-expressible: host-streamed micro-ops, no eCPU boot)")
    print(f"  x.maxu(100)     -> {needs_vector_isa.select_engine(x)}"
          f"   (the bus ALU has no unsigned compare)")
    try:
        needs_vector_isa.lower(x, engine="caesar")
    except nmc.UnsupportedOnEngine as err:
        print(f"  explicit engine='caesar' raises: {err}")

    # every lower() also runs the static verifier (DESIGN.md §11);
    # corrupt a lowered stream and the checker names the pass, the rule
    # and the offending instruction (with tracer-op provenance)
    from repro.nmc import check
    lk = bus_friendly.lower(x, check="off")
    lk.program.entries["op"][2] = 63          # smash one opcode
    diag = check.verify_lowered(lk).errors[0]
    print(f"  tampered stream  -> {diag}")

    print()
    print("=" * 64)
    print("3. Table V matmul (8-bit) through the same traced frontend")
    print("=" * 64)
    kb = programs.build("matmul", 8)      # kernel library = traced kernels
    ok = programs.verify(kb)
    print(f"  functional (bit-exact vs quantized oracle): {ok}")
    assert all(ok.values()), ok
    t = timing.kernel_timing(kb)
    e = energy.kernel_energy(kb)
    cpu_cyc = t["cpu"].total_cycles
    print(f"  {'target':8s} {'cycles':>9s} {'us @250MHz':>11s} "
          f"{'energy nJ':>10s} {'vs CPU':>7s}")
    for name in ("cpu", "caesar", "carus"):
        cyc = t[name].total_cycles
        outs = kb.n_outputs if name == "cpu" else getattr(kb, name).n_outputs
        speed = (cpu_cyc / kb.n_outputs) / (cyc / outs)
        print(f"  {name:8s} {cyc:9.0f} {cyc/F_CLK_BENCH_HZ*1e6:11.1f} "
              f"{e[name].energy_pj/1e3:10.1f} {speed:6.1f}x")

    print()
    print("=" * 64)
    print("4. tile-parallel partitioned execution (tiles=N, DESIGN.md §9)")
    print("=" * 64)

    @nmc.kernel                       # same kernel; sharding is a kwarg
    def matmul8(t, A, B):
        a = t.consts(A)
        rows = [t.load(B[r]) for r in range(8)]
        for i in range(8):
            acc = None
            for kk in range(8):
                acc = nmc.mac(acc, a[i, kk], rows[kk])
            t.store(acc)

    A = rng.integers(-128, 128, (8, 8), dtype=np.int8)
    B = rng.integers(-128, 128, (8, 256), dtype=np.int8)
    base = matmul8(A, B)                        # single tile
    fut = matmul8.call_async(A, B, tiles=4)     # 4-tile wave (auto: rows)
    out = fut.result()                          # future-of-gathers
    assert (np.asarray(out) == np.asarray(base)).all(), \
        "partitioned result diverged from the single-tile kernel"
    pplan, lks = matmul8.lower_wave(A, B, tiles=4)
    single = timing.stage_cost(matmul8.lower(A, B))
    shards = [timing.stage_cost(lk) for lk in lks]
    speedup = timing.wave_speedup(single, shards, pplan.n_shards)
    print(f"  strategy={pplan.strategy} shards={pplan.n_shards} "
          f"(one {lks[0].program.n_instr}-instr bucket, one compile)")
    print(f"  bit-exact vs single tile: True   modeled wave speedup "
          f"(shared-bus model): {speedup:.2f}x")

    rt = nmc.default_runtime()
    print(f"\n  shared runtime: {rt.bucketed.compiles} XLA compiles, "
          f"{rt.resident.dispatches} dispatches, "
          f"{rt.queue.submitted} queued kernel calls (sync + async + "
          f"partitioned waves share the dispatch queue)")

    print()
    print("=" * 64)
    print("5. Pallas fast-path backend (backend='pallas', DESIGN.md §10)")
    print("=" * 64)
    # same kernel, same runtime, different executor: the whole bucketed
    # instruction stream fuses into one pl.pallas_call (interpret mode on
    # CPU, native kernels on TPU/GPU; backend='auto' picks per device)
    import time

    ref = np.asarray(matmul8(A, B, backend="scan"))
    fast = np.asarray(matmul8(A, B, backend="pallas"))
    assert (fast == ref).all(), "pallas backend diverged from scan"

    def best_of(fn, n=3):
        t = [None] * n
        for i in range(n):
            t0 = time.perf_counter()
            fn()
            t[i] = time.perf_counter() - t0
        return min(t) * 1e6

    lk = matmul8.lower(A, B)
    tile = rt.jit_tile
    us = {bk: best_of(lambda bk=bk: rt.queue.submit(
              tile, lk.program, image=lk.mem, out_slice=lk.out_slice,
              post=lk.post, backend=bk).result())
          for bk in nmc.BACKENDS}
    dev = "CPU interpret mode" if nmc.resolve_backend("auto") == "scan" \
        else "native kernels"
    print("  matmul8 bit-exact scan == pallas: True")
    print(f"  dispatch: scan {us['scan']:8.0f} us   pallas "
          f"{us['pallas']:8.0f} us   ({us['scan'] / us['pallas']:.1f}x, "
          f"{dev})")

    print()
    print("=" * 64)
    print("6. Analysis-driven IR optimizer (opt='O1', DESIGN.md §13)")
    print("=" * 64)
    # a naively-written kernel: the accumulator is loaded like any other
    # operand (forcing a register copy on NM-Carus) and no bank hints are
    # given (landing all operands in one NM-Caesar bank).  opt="O1" —
    # the default — reclaims both, translation-validating every rewrite:
    # each applied rule re-runs the full static verifier AND a numpy
    # oracle differential before the cheaper program is accepted.

    @nmc.kernel
    def axpy(t, c0, w, x):
        t.store(nmc.mac(t.load(c0), t.load(w), t.load(x)))

    c0 = rng.integers(-100, 100, 2048, dtype=np.int8)
    w = rng.integers(-100, 100, 2048, dtype=np.int8)
    x = rng.integers(-100, 100, 2048, dtype=np.int8)
    for eng in ("caesar", "carus"):
        off = axpy.lower(c0, w, x, engine=eng, opt="off")
        o1 = axpy.lower(c0, w, x, engine=eng)       # default: O1
        assert (np.asarray(axpy(c0, w, x, engine=eng))
                == np.asarray(axpy(c0, w, x, engine=eng, opt="off"))).all()
        cyc_off = timing.program_cycles(off.program).cycles
        cyc_o1 = timing.program_cycles(o1.program).cycles
        rep = o1.opt_report
        rules = ",".join(r.rule for r in rep.rewrites) if rep else "-"
        print(f"  {eng:<7} {off.program.n_instr:>5} -> "
              f"{o1.program.n_instr:<5} instrs   {cyc_off:>6.0f} -> "
              f"{cyc_o1:<6.0f} cycles "
              f"(-{100 * (cyc_off - cyc_o1) / cyc_off:.0f}%)   [{rules}]")
    print("  bit-exact vs opt='off' on both engines: True "
          "(every rewrite translation-validated)")


if __name__ == "__main__":
    main()
