"""Table VIII reproduction: matmul A[10,10] x B[10,P] cycles + pJ/MAC vs
BLADE / C-SRAM (their published numbers) and our NM-Caesar / NM-Carus models.
"""

from __future__ import annotations

from repro.core import constants as C
from repro.core import energy, programs, timing
from benchmarks import paper_data as PD


def run() -> list[dict]:
    rows = []
    for sew in (8, 16, 32):
        p = PD.TABLE_VIII_P[sew]
        kb = programs.build_matmul(sew, p=p, seed=7)
        # Table VIII uses A[10,10]; our builder is A[8,8] — scale MAC count
        # and cycles by (10*10*P)/(8*8*P) analytically.
        scale = (10 * 10) / (8 * 8)
        t_caesar = timing.caesar_cycles(kb.caesar).cycles * scale
        t_carus = timing.carus_cycles(kb.carus, sew).cycles * scale
        n_mac = 10 * 10 * p
        e_caesar = energy.caesar_macro_energy_pj(kb) * scale / n_mac
        e_carus = energy.carus_macro_energy_pj(kb) * scale / n_mac
        rows.append({
            "sew": sew, "P": p,
            "caesar_cycles": t_caesar,
            "caesar_cycles_paper": PD.TABLE_VIII_CYCLES["caesar"][sew],
            "carus_cycles": t_carus,
            "carus_cycles_paper": PD.TABLE_VIII_CYCLES["carus"][sew],
            "caesar_pj_mac": e_caesar,
            "caesar_pj_mac_paper": PD.TABLE_VIII_PJ_PER_MAC_65NM["caesar"][sew],
            "carus_pj_mac": e_carus,
            "carus_pj_mac_paper": PD.TABLE_VIII_PJ_PER_MAC_65NM["carus"][sew],
            "blade_multi_cycles": PD.TABLE_VIII_CYCLES["blade_multi"][sew],
            "csram_cycles": PD.TABLE_VIII_CYCLES["csram"][sew],
        })
    return rows


def peak_efficiency_gops_w() -> dict:
    """Carus peak efficiency cross-check (Table VII: 306.7 GOPS/W)."""
    kb = programs.build_matmul(8, p=1024, seed=7)
    e_pj = energy.carus_macro_energy_pj(kb)
    n_ops = 2 * 8 * 8 * 1024          # 1 MAC = 2 ops (paper convention)
    gops_w = n_ops / (e_pj * 1e-12) / 1e9
    return {"model_gops_w": gops_w, "paper_gops_w": C.CARUS_PEAK_GOPS_W,
            "peak_gops_model": C.CARUS_N_LANES * 2 * C.F_CLK_MAX_HZ / 1e9,
            "peak_gops_paper": C.CARUS_PEAK_GOPS}


def main():
    rows = run()
    print(f"{'sew':>4s} {'P':>5s} | {'Caesar kcyc m/p':>16s} |"
          f" {'Carus kcyc m/p':>15s} | {'Caesar pJ/MAC m/p':>18s} |"
          f" {'Carus pJ/MAC m/p':>17s}")
    for r in rows:
        print(f"{r['sew']:4d} {r['P']:5d} |"
              f" {r['caesar_cycles']/1e3:7.1f}/{r['caesar_cycles_paper']/1e3:6.1f} |"
              f" {r['carus_cycles']/1e3:7.1f}/{r['carus_cycles_paper']/1e3:5.1f} |"
              f" {r['caesar_pj_mac']:8.1f}/{r['caesar_pj_mac_paper']:7.1f} |"
              f" {r['carus_pj_mac']:8.1f}/{r['carus_pj_mac_paper']:6.1f}")
    pk = peak_efficiency_gops_w()
    print(f"\nCarus peak efficiency: model {pk['model_gops_w']:.1f} GOPS/W "
          f"vs paper {pk['paper_gops_w']} (macro-level; see EXPERIMENTS.md "
          f"for the system-vs-macro accounting note)")
    return rows


if __name__ == "__main__":
    main()
