"""Benchmark harness: one entry per paper table/figure + the roofline table.

Prints a ``name,us_per_call,derived`` CSV line per benchmark (the harness
contract), followed by each benchmark's detail table.  The NMC engines run
at f_clk = 250 MHz (the paper's benchmarking frequency), so us_per_call is
the modeled wall-clock of the 8-bit matmul kernel on each target.
"""

from __future__ import annotations

import os
import statistics
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def main() -> None:
    from repro.core import constants as C
    from repro.core import energy, programs, timing
    from benchmarks import fig12, table_v, table_vi, table_viii

    lines = []

    # -- Table V ------------------------------------------------------------
    t0 = time.perf_counter()
    rows_v = table_v.run(verify_functional=True)
    errs = []
    for r in rows_v:
        for k in ("thr_caesar_err", "thr_carus_err", "en_caesar_err",
                  "en_carus_err"):
            if not (r["erratum_carus"] and k == "en_carus_err"):
                errs.append(abs(r[k]))
    kb = programs.build("matmul", 8)
    us_caesar = timing.caesar_cycles(kb.caesar).total_cycles \
        / C.F_CLK_BENCH_HZ * 1e6
    us_carus = timing.carus_cycles(kb.carus, 8).total_cycles \
        / C.F_CLK_BENCH_HZ * 1e6
    lines.append(("table_v_matmul8_caesar", us_caesar,
                  f"mean_abs_err_vs_paper={100*statistics.mean(errs):.1f}%"))
    lines.append(("table_v_matmul8_carus", us_carus,
                  f"median_abs_err={100*statistics.median(errs):.1f}%"))

    # -- Table VI -----------------------------------------------------------
    ok = table_vi.functional_demo()
    rows_vi = table_vi.run()
    carus_row = next(r for r in rows_vi if r["config"] == "carus_e20")
    lines.append(("table_vi_anomaly_carus",
                  carus_row["model_cycles"] / C.F_CLK_BENCH_HZ * 1e6,
                  f"functional={'bitexact' if ok else 'FAIL'},"
                  f"cycle_factor={carus_row['model_cycle_factor']:.2f}"
                  f"_vs_paper_{carus_row['paper_cycle_factor']}"))

    # -- Table VIII ---------------------------------------------------------
    rows_viii = table_viii.run()
    pk = table_viii.peak_efficiency_gops_w()
    lines.append(("table_viii_matmul8_carus",
                  rows_viii[0]["carus_cycles"] / C.F_CLK_BENCH_HZ * 1e6,
                  f"pj_per_mac={rows_viii[0]['carus_pj_mac']:.1f}"
                  f"_paper_{rows_viii[0]['carus_pj_mac_paper']}"))
    lines.append(("table_vii_peak_gops_w", 0.0,
                  f"model={pk['model_gops_w']:.1f}_paper="
                  f"{pk['paper_gops_w']}"))

    # -- Fig 12 ---------------------------------------------------------------
    rows_12 = fig12.run()
    sat = rows_12[-1]
    lines.append(("fig12_saturation", 0.0,
                  f"carus_out_per_cyc={sat['carus_out_per_cyc']:.3f}"
                  f"_paper_0.48"))

    # -- Fig 13 ---------------------------------------------------------------
    from benchmarks import fig13
    bd = fig13.run(8)
    vrf_frac = bd["carus"]["vrf"] / sum(bd["carus"].values())
    lines.append(("fig13_power_breakdown", 0.0,
                  f"carus_vrf_share={vrf_frac:.2f}_paper_~0.6"))

    # -- Roofline (reads dry-run artifacts if present) ------------------------
    try:
        from benchmarks import roofline
        rows_rf = roofline.main(out_csv="results/roofline.csv") \
            if os.path.isdir("results/dryrun") else []
        if rows_rf:
            worst = min((r for r in rows_rf if r["shape"] == "train_4k"),
                        key=lambda r: r["mfu_bound"])
            lines.append(("roofline_cells", 0.0,
                          f"n={len(rows_rf)},worst_train_mfu_bound="
                          f"{worst['mfu_bound']:.3f}@{worst['arch']}"))
    except Exception as e:  # roofline needs dry-run artifacts
        lines.append(("roofline_cells", 0.0, f"skipped:{type(e).__name__}"))

    print("\n" + "=" * 60)
    print("name,us_per_call,derived")
    for name, us, derived in lines:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()