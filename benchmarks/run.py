"""Benchmark harness: one entry per paper table/figure + the roofline table.

Prints a ``name,us_per_call,derived`` CSV line per benchmark (the harness
contract), followed by each benchmark's detail table.  The NMC engines run
at f_clk = 250 MHz (the paper's benchmarking frequency), so us_per_call is
the modeled wall-clock of the 8-bit matmul kernel on each target.

The ``nmc_jit_frontend`` line gates the public one-call path (DESIGN.md
§7): a traced ``nmc.kernel`` must auto-select its engine and run bit-exact
vs the tracer's numpy oracle on both engines via both sync and async call
styles.  All functional sweeps dispatch through one shared shape-bucketed
:class:`repro.nmc.BucketedPool` — the jit-cache/compile stats it
reports (and ``table_v.run`` asserts) verify the one-compile-per-bucket
property of the scheduler, and a :class:`repro.nmc.ResidentPool`
re-dispatch demonstrates the residency contract: steady-state dispatches
move only instruction bytes, never tile memories.  The async
:class:`repro.nmc.DispatchQueue` section feeds a 2-tile array a
heterogeneous kernel stream (double-buffered staging, futures) and asserts
bit-exactness vs synchronous dispatch plus the overlapped-DMA timing win.

Run from the repo root as ``PYTHONPATH=src python -m benchmarks.run``
(pytest picks up ``src`` automatically via pyproject.toml).  Pass ``--smoke``
for the reduced CI subset.
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
import time


def main(smoke: bool = False) -> None:
    import numpy as np
    from repro import nmc
    from repro.core import constants as C
    from repro.core import programs, timing
    from repro.nmc import BucketedPool, ResidentPool
    from benchmarks import fig12, table_v, table_vi, table_viii

    pool = BucketedPool()
    lines = []

    # -- Traced frontend (nmc.jit): the public one-call path ------------------
    # A fused kernel authored against the frontend must auto-select, lower,
    # and run bit-exact vs the tracer's numpy oracle on BOTH engines via
    # both call styles — the public-API gate for everything below (the
    # Table V builders themselves are traced kernels).
    rng = np.random.default_rng(3)

    @nmc.kernel
    def fused(t, x, y):
        a, b = t.load(x, bank=0), t.load(y)
        t.store(((a * 3) + b).max(a >> 1))

    fx = rng.integers(-128, 128, 2048, dtype=np.int8)
    fy = rng.integers(-128, 128, 2048, dtype=np.int8)
    assert fused.select_engine(fx, fy) == "caesar"   # bus-expressible body
    oracle = fused.oracle(fx, fy)
    t0 = time.perf_counter()
    jit_ok = True
    for eng in ("caesar", "carus"):
        sync = np.asarray(fused(fx, fy, engine=eng))
        fut = fused.call_async(fx, fy, engine=eng)
        jit_ok &= (sync == oracle).all() and \
            (np.asarray(fut.result()) == sync).all()
    assert jit_ok, "nmc.jit sync/async diverged from the numpy oracle"
    jit_wall_s = time.perf_counter() - t0
    lines.append(("nmc_jit_frontend", jit_wall_s * 1e6 / 4,
                  f"bitexact={jit_ok},auto_engine=caesar,"
                  f"engines=2,call_styles=sync+async"))

    # -- Table V ------------------------------------------------------------
    kernels = ("xor", "matmul", "maxpool") if smoke \
        else programs.TABLE_V_KERNELS
    sews = (8,) if smoke else table_v.ALL_SEWS
    t0 = time.perf_counter()
    # table_v.run asserts compiles <= #buckets on this pool (CI smoke gate)
    rows_v = table_v.run(verify_functional=True, kernels=kernels, sews=sews,
                         pool=pool)
    sweep_wall_s = time.perf_counter() - t0
    # snapshot the pool counters here so the nmc_tile_pool line reports the
    # Table V sweep only (fig12 shares the pool below)
    sweep_stats = (pool.programs_run, pool.dispatches, pool.compiles,
                   len(pool.shape_keys_compiled), pool.pad_waste,
                   pool.bytes_moved)
    errs = []
    for r in rows_v:
        for k in ("thr_caesar_err", "thr_carus_err", "en_caesar_err",
                  "en_carus_err"):
            if not (r["erratum_carus"] and k == "en_carus_err"):
                errs.append(abs(r[k]))
    kb = programs.build("matmul", 8)
    us_caesar = timing.caesar_cycles(kb.caesar).total_cycles \
        / C.F_CLK_BENCH_HZ * 1e6
    us_carus = timing.carus_cycles(kb.carus, 8).total_cycles \
        / C.F_CLK_BENCH_HZ * 1e6
    lines.append(("table_v_matmul8_caesar", us_caesar,
                  f"mean_abs_err_vs_paper={100*statistics.mean(errs):.1f}%"))
    lines.append(("table_v_matmul8_carus", us_carus,
                  f"median_abs_err={100*statistics.median(errs):.1f}%"))

    # -- Fig 12 ---------------------------------------------------------------
    rows_12 = fig12.run(verify=smoke, pool=pool)
    sat = rows_12[-1]
    lines.append(("fig12_saturation", 0.0,
                  f"carus_out_per_cyc={sat['carus_out_per_cyc']:.3f}"
                  f"_paper_0.48"))

    # -- Tile pool (bucketed multi-tile scheduler) ----------------------------
    # Table V sweep only: us_per_call is sweep wall-clock per program, and
    # the counters are the snapshot taken right after that sweep
    (programs_n, dispatches_n, compiles_n, buckets_n, pad_waste_n,
     bytes_moved_n) = sweep_stats
    lines.append(("nmc_tile_pool", sweep_wall_s * 1e6 / max(programs_n, 1),
                  f"programs={programs_n},dispatches={dispatches_n},"
                  f"compiles={compiles_n},buckets={buckets_n},"
                  f"pad_waste={pad_waste_n},bytes_moved={bytes_moved_n}"))

    # -- Resident tile array (memory-mode / compute-mode duality) -------------
    # Load two tiles once, then dispatch the same programs twice: the second
    # compute-mode dispatch must move only instruction bytes (no tile-memory
    # re-upload) and hit the already-traced bucket (no new compile).
    kb8 = programs.build("xor", 8, caesar_bytes=2048, carus_bytes=4096)
    rpool = ResidentPool()
    t0 = time.perf_counter()
    first = rpool.run_builds([kb8.caesar, kb8.carus])
    moved_after_load = rpool.bytes_moved
    compiles_after_load = rpool.compiles
    rpool.dispatch([(t, eb.program) for t, eb in
                    zip(rpool.tiles, (kb8.caesar, kb8.carus))])
    resident_wall_s = time.perf_counter() - t0
    instr_bytes = rpool.bytes_moved - moved_after_load
    assert rpool.compiles == compiles_after_load, "re-dispatch retraced"
    state_bytes = sum(int(rpool.state(t).size) * 4 for t in rpool.tiles)
    assert instr_bytes < state_bytes, (instr_bytes, state_bytes)
    ok_first = all((got.reshape(-1)[: eb.oracle.size]
                    == eb.oracle.reshape(-1)).all()
                   for got, eb in zip(first, (kb8.caesar, kb8.carus)))
    lines.append(("nmc_resident_pool", resident_wall_s * 1e6 / 4,
                  f"bitexact={ok_first},redispatch_bytes={instr_bytes},"
                  f"tile_state_bytes={state_bytes},"
                  f"compiles={rpool.compiles}"))

    # -- Async double-buffered dispatch runtime (DESIGN.md §5.2) ------------
    # A 2-tile array continuously fed with a heterogeneous kernel stream:
    # images stage into shadow buffers while the previous programs run
    # (staged_while_busy > 0), results resolve through futures, and the
    # outputs must be bit-exact vs the synchronous ResidentPool path.
    from repro.nmc import DispatchQueue
    small = dict(caesar_bytes=2048, carus_bytes=4096)
    akbs = [programs.build(n, 8, **small)
            for n in ("xor", "add", "mul", "relu")]
    abuilds = [getattr(kb, e) for kb in akbs for e in ("caesar", "carus")]
    queue = DispatchQueue()
    queue.run_builds(abuilds, n_tiles=2)    # warm-up: trace the buckets
    # snapshot after warm-up: the derived counters below cover the timed
    # run only (same discipline as the nmc_tile_pool sweep_stats above)
    waves0, staged0 = queue.waves, queue.staged_while_busy
    t0 = time.perf_counter()
    async_out = queue.run_builds(abuilds, n_tiles=2)
    async_wall_s = time.perf_counter() - t0
    # the sync reference shares the queue's jit cache: same traces, no
    # recompiles — the comparison isolates the dispatch discipline
    sync_ref = ResidentPool(pool=queue.pool.pool).run_builds(abuilds)
    async_ok = all((np.asarray(a) == np.asarray(b)).all()
                   for a, b in zip(sync_ref, async_out))
    assert async_ok, "async futures diverged from synchronous dispatch"
    assert queue.staged_while_busy > 0, "no double-buffered staging happened"
    # modeled dispatch cost: overlapped-DMA mode must beat the serial mode
    # on the matmul sweep (strictly) and never exceed it
    mm_stages = [timing.stage_cost(getattr(programs.build("matmul", s), e))
                 for s in (8, 16, 32) for e in ("caesar", "carus")]
    ser = timing.dispatch_cycles(mm_stages, "serial")
    ovl = timing.dispatch_cycles(mm_stages, "overlapped")
    assert ovl < ser, (ovl, ser)
    lines.append(("nmc_async_dispatch", async_wall_s * 1e6 / len(abuilds),
                  f"bitexact={async_ok},waves={queue.waves - waves0},"
                  f"staged_while_busy={queue.staged_while_busy - staged0},"
                  f"matmul_overlap_cycle_ratio={ovl / ser:.3f}"))

    # -- Tile-parallel partitioned execution (DESIGN.md §9) -------------------
    # One kernel sharded across the tile array: scaling.run asserts
    # bit-exactness of every partitioned execution (sync + async gathers)
    # vs the single-tile output, the compile bound (pre-padded waves land
    # in one bucket each), and the wave-speedup shape of the shared-bus
    # timing model (monotone to the peak, > 1 at tiles=4 on matmul).
    from benchmarks import scaling
    t0 = time.perf_counter()
    rows_sc = scaling.run(smoke=True) if smoke else scaling.run(
        tiles=(1, 2, 4, 8), sews=(8,),
        kernels=("mul", "matmul", "conv2d"))
    scaling_wall_s = time.perf_counter() - t0
    sc = rows_sc[-1]
    n_cfg = len(rows_sc) - 1
    lines.append(("nmc_scaling", scaling_wall_s * 1e6 / max(n_cfg, 1),
                  f"bitexact=True,configs={n_cfg},"
                  f"compiles={sc['compiles']},buckets={sc['buckets']},"
                  f"matmul_speedup_at4={sc['matmul_speedup_at_4']:.2f}"))

    # -- Wave-scheduler autotuning (DESIGN.md §14) ----------------------------
    # tune_bench.run sweeps the scheduler's (strategy x chunk skew x
    # engine assignment x dispatch order) search: every tuned plan must
    # stay bit-exact vs the uniform plan (sync + async), matmul/conv2d
    # must win >= 5% modeled wave cycles at tiles in {4, 8}, and the
    # heterogeneous qrelu tape must ride one genuinely mixed
    # Caesar+Carus launch wave.
    from benchmarks import tune_bench
    t0 = time.perf_counter()
    rows_tn, mixed = tune_bench.run(sew=8, smoke=smoke)
    tune_wall_s = time.perf_counter() - t0
    fails = tune_bench.gate_failures(rows_tn, mixed, tune_bench.BOUND_PCT)
    assert not fails, "tune gate: " + "; ".join(fails)
    best_tn = max(r["win_vs_uniform_pct"] for r in rows_tn)
    lines.append(("nmc_tune", tune_wall_s * 1e6 / max(len(rows_tn), 1),
                  f"bitexact=True,best_win_pct={best_tn:.2f},"
                  f"mixed_engines={'+'.join(sorted(set(mixed['engines'])))},"
                  f"mixed_one_launch={mixed['one_launch']}"))

    if not smoke:
        # -- Table VI -------------------------------------------------------
        ok = table_vi.functional_demo()
        rows_vi = table_vi.run()
        carus_row = next(r for r in rows_vi if r["config"] == "carus_e20")
        lines.append(("table_vi_anomaly_carus",
                      carus_row["model_cycles"] / C.F_CLK_BENCH_HZ * 1e6,
                      f"functional={'bitexact' if ok else 'FAIL'},"
                      f"cycle_factor={carus_row['model_cycle_factor']:.2f}"
                      f"_vs_paper_{carus_row['paper_cycle_factor']}"))

        # -- Table VIII -------------------------------------------------------
        rows_viii = table_viii.run()
        pk = table_viii.peak_efficiency_gops_w()
        lines.append(("table_viii_matmul8_carus",
                      rows_viii[0]["carus_cycles"] / C.F_CLK_BENCH_HZ * 1e6,
                      f"pj_per_mac={rows_viii[0]['carus_pj_mac']:.1f}"
                      f"_paper_{rows_viii[0]['carus_pj_mac_paper']}"))
        lines.append(("table_vii_peak_gops_w", 0.0,
                      f"model={pk['model_gops_w']:.1f}_paper="
                      f"{pk['paper_gops_w']}"))

        # -- Fig 13 -----------------------------------------------------------
        from benchmarks import fig13
        bd = fig13.run(8)
        vrf_frac = bd["carus"]["vrf"] / sum(bd["carus"].values())
        lines.append(("fig13_power_breakdown", 0.0,
                      f"carus_vrf_share={vrf_frac:.2f}_paper_~0.6"))

        # -- Roofline (reads dry-run artifacts if present) --------------------
        try:
            from benchmarks import roofline
            rows_rf = roofline.main(out_csv="results/roofline.csv") \
                if os.path.isdir("results/dryrun") else []
            if rows_rf:
                worst = min((r for r in rows_rf if r["shape"] == "train_4k"),
                            key=lambda r: r["mfu_bound"])
                lines.append(("roofline_cells", 0.0,
                              f"n={len(rows_rf)},worst_train_mfu_bound="
                              f"{worst['mfu_bound']:.3f}@{worst['arch']}"))
        except Exception as e:  # roofline needs dry-run artifacts
            lines.append(("roofline_cells", 0.0, f"skipped:{type(e).__name__}"))

    print("\n" + "=" * 60)
    print("name,us_per_call,derived")
    for name, us, derived in lines:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    if __package__ in (None, ""):
        # direct-script invocation (`python benchmarks/run.py`): bootstrap
        # the same import roots `python -m benchmarks.run` gets from the
        # repo root + pyproject; a no-op under `-m` or an installed package.
        _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, _root)
        sys.path.insert(0, os.path.join(_root, "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI subset (Table V @ sew=8 + Fig 12)")
    main(smoke=ap.parse_args().smoke)
