"""Table VI reproduction: the MLCommons-tiny Anomaly Detection autoencoder
(10 FC layers + ReLU) end-to-end on CPU cluster vs NM-Caesar vs NM-Carus.

Two parts:
  1. A *functional* reduced autoencoder executed on the Carus engine
     (weights tiled through the 32 KiB VRF exactly as the full app would),
     verified bit-exact against the quantized numpy oracle.
  2. An analytic full-size model (640-128-...-8-...-640, 264k MACs, int8)
     built on the calibrated timing/energy constants:
       * NM-Carus: vmacc matvecs (4 MACs/cyc) + serial weight reload through
         the single-port banks (no overlap: every vector register interleaves
         across all 4 banks, so DMA writes conflict with compute — Fig. 6).
       * NM-Caesar: the 66k-microinstruction stream cannot be precompiled
         (264 KiB of code), so the CV32E20 host assembles commands online at
         ~5 cycles/instruction (Section I: "the CPU [spends] significant
         time encoding such operations at runtime").
       * CPU baseline: the paper's measured 561k cycles (RV32IMCXcv).
"""

from __future__ import annotations

import numpy as np

from repro.core import constants as C
from benchmarks import paper_data as PD

LAYERS = [(640, 128), (128, 128), (128, 128), (128, 128), (128, 8),
          (8, 128), (128, 128), (128, 128), (128, 128), (128, 640)]
E20_ENCODE_CYC_PER_INSTR = 5.0
BASE_CYCLES = PD and 561e3


def model_carus() -> dict:
    compute = 0.0
    vrf_acc = 0
    n_instr = 0
    for din, dout in LAYERS:
        words = -(-dout // 4)
        wpl = -(-words // C.CARUS_N_LANES)
        per_vmacc = max(C.CARUS_ALU_WORD_CYCLES["macc"][8], 3) * wpl
        compute += din * (per_vmacc + 1)       # + emvx of x[k]
        vrf_acc += din * 3 * words
        n_instr += din
        compute += C.CARUS_KERNEL_OVERHEAD_CYCLES
    load = sum(din * dout for din, dout in LAYERS) / 4.0   # 1 word/cycle DMA
    cycles = compute + load
    t = cycles / C.F_CLK_BENCH_HZ
    e_pj = (C.P_CARUS_FIX_MW * 1e-3 * t * 1e12
            + vrf_acc * C.E_CARUS_VRF_ACCESS_PJ
            + load * C.E_CARUS_VRF_ACCESS_PJ           # DMA writes banks
            + C.P_CPU_SYS_MW * 0.4 * 1e-3 * t * 1e12)  # E20 + sys mem share
    return {"cycles": cycles, "energy_uj": e_pj / 1e6}


def model_caesar() -> dict:
    n_instr = sum(din * (-(-dout // 4)) for din, dout in LAYERS)
    compute = 2.0 * n_instr                     # 2 cyc/op, banks split
    encode = E20_ENCODE_CYC_PER_INSTR * n_instr  # online command assembly
    load = sum(din * dout for din, dout in LAYERS) / 4.0
    splats = sum(din for din, _ in LAYERS) * 2.0
    cycles = max(compute, encode) + load + splats
    t = cycles / C.F_CLK_BENCH_HZ
    e_pj = C.P_CAESAR_SYS_MW * 1e-3 * t * 1e12
    return {"cycles": cycles, "energy_uj": e_pj / 1e6}


def run() -> list[dict]:
    rows = []
    ours = {"caesar_e20": model_caesar(), "carus_e20": model_carus()}
    for cfgname, p in PD.TABLE_VI.items():
        row = {"config": cfgname,
               "paper_cycle_factor": p["cycles"],
               "paper_energy_factor": p["energy"],
               "paper_area_factor": p["area"]}
        if cfgname in ours:
            m = ours[cfgname]
            row["model_cycles"] = m["cycles"]
            row["model_cycle_factor"] = PD and 561e3 / m["cycles"]
            row["model_energy_uj"] = m["energy_uj"]
            row["model_energy_factor"] = 13.5 / m["energy_uj"]
        rows.append(row)
    return rows


def functional_demo() -> bool:
    """Reduced autoencoder (fits the 32 KiB VRF) run on the Carus engine."""
    import jax.numpy as jnp
    from repro.core import alu, carus, isa
    from repro.core.carus import trace_entry
    from repro.core.isa import VOp

    rng = np.random.default_rng(3)
    dims = [64, 32, 8, 32, 64]
    ws = [rng.integers(-4, 5, (dims[i], dims[i + 1])).astype(np.int8)
          for i in range(4)]
    x = rng.integers(-8, 9, dims[0]).astype(np.int8)

    # oracle: int8 wrap matvec + relu between layers
    a = x
    for i, w in enumerate(ws):
        a = (a.astype(np.int64) @ w.astype(np.int64)).astype(np.int8)
        if i < 3:
            a = np.maximum(a, 0)
    oracle = a

    vpu = carus.CarusVPU()
    vrf = np.zeros((32, 256), np.int32)
    # v0: activation; weights columns per input: v8+...: W rows packed per k
    cur = x
    act_reg, tmp_reg = 0, 1
    vrf[act_reg, :len(x) // 4] = alu.pack_np(x)
    for li, w in enumerate(ws):
        din, dout = w.shape
        # load weight rows into regs 8.. (host memory-mode writes)
        # executed functionally by poking the VRF between segments
        tr = [trace_entry(VOp.VSETVL, sval1=dout)]
        for k in range(din):
            row = np.pad(w[k].astype(np.int8), (0, (-dout) % 4))
            vrf[8 + k % 16, :len(row) // 4] = alu.pack_np(row)
            op = VOp.VMUL if k == 0 else VOp.VMACC
            tr.append(trace_entry(op, vd=tmp_reg, vs2=8 + k % 16,
                                  sval1=int(cur[k]), mode=isa.MODE_VX))
            if (k % 16 == 15) or k == din - 1:   # flush segment
                out, _, _ = vpu.run_trace(jnp.asarray(vrf),
                                          carus.trace_to_arrays(tr), 8)
                vrf = np.array(out)
                tr = [trace_entry(VOp.VSETVL, sval1=dout)]
        if li < 3:
            tr = [trace_entry(VOp.VSETVL, sval1=dout),
                  trace_entry(VOp.VMAX, vd=tmp_reg, vs2=tmp_reg, sval1=0,
                              mode=isa.MODE_VX)]
            out, _, _ = vpu.run_trace(jnp.asarray(vrf),
                                      carus.trace_to_arrays(tr), 8)
            vrf = np.array(out)
        cur = alu.unpack_np(vrf[tmp_reg], np.int8)[:dout]
        vrf[act_reg] = 0
        vrf[act_reg, : (-(-dout // 4))] = alu.pack_np(
            np.pad(cur, (0, (-dout) % 4)))
    return bool((cur == oracle).all())


def main():
    ok = functional_demo()
    print(f"functional reduced autoencoder on NM-Carus engine: "
          f"{'BIT-EXACT' if ok else 'MISMATCH'}")
    assert ok
    rows = run()
    print(f"\n{'config':14s} {'paper cyc x':>12s} {'model cyc x':>12s} "
          f"{'paper en x':>11s} {'model en x':>11s}")
    for r in rows:
        mc = r.get("model_cycle_factor")
        me = r.get("model_energy_factor")
        print(f"{r['config']:14s} {r['paper_cycle_factor']:12.2f} "
              f"{mc if mc is None else round(mc, 2)!s:>12s} "
              f"{r['paper_energy_factor']:11.2f} "
              f"{me if me is None else round(me, 2)!s:>11s}")
    return rows


if __name__ == "__main__":
    main()
