"""Backend wall-clock comparison: scan interpreters vs the Pallas fast path.

The tentpole claim of DESIGN.md §10 measured: the same bucketed Program
waves executed by the ``lax.scan`` reference interpreters and by the fused
``pl.pallas_call`` backend (interpret mode on CPU, native kernels on
TPU/GPU), over the matmul / conv2d / elementwise traced builders from
``benchmarks/scaling.py`` and the tiles∈{1..16} scaling sweep.

Two numbers per (kernel, tiles, backend) configuration:

* ``dispatch_us`` — pre-lowered programs resubmitted through the shared
  :class:`repro.nmc.runtime.DispatchQueue`: the pure engine-execution
  path (what the backend changes).  The ``--smoke`` gate asserts
  Pallas <= scan on the matmul builder here.
* ``e2e_us``    — full ``CompiledKernel.__call__`` wall-clock including
  per-call tracing/lowering (backend-independent Python work), for
  context on how much of the end-to-end budget the engine is.

Every configuration is also cross-checked bit-exact between the two
backends before it is timed.  Results append to ``BENCH_backends.json``
(one entry per run — the trajectory CI uploads as an artifact).

Run from the repo root: ``PYTHONPATH=src python -m benchmarks.backend_bench``
(``--smoke`` for the reduced CI gate).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

SWEEP_TILES = (1, 2, 4, 8, 16)
SMOKE_TILES = (1, 4)
BACKENDS = ("scan", "pallas")


def _time_calls(fn, repeats: int) -> float:
    """Best-of-N wall-clock of ``fn()`` in microseconds (post warm-up)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_config(kern, args, tiles: int, backend: str, repeats: int):
    """Returns ``(dispatch_us, e2e_us, outputs)`` for one configuration.

    The dispatch loop resubmits pre-lowered shard programs through the
    kernel's runtime queue — same tiles, same jit cache, same padded
    buckets as a real call — isolating executor time from trace time.
    """
    import numpy as np

    rt = kern.runtime
    if tiles == 1:
        lks = [kern.lower(*args)]
        ids = [rt.jit_tile]
    else:
        _, lks = kern.lower_wave(*args, tiles=tiles)
        ids = rt.jit_tiles(len(lks))

    def dispatch_once():
        futs = [rt.queue.submit(t, lk.program, image=lk.mem,
                                out_slice=lk.out_slice, post=lk.post,
                                backend=backend)
                for t, lk in zip(ids, lks)]
        return [np.asarray(f.result()) for f in futs]

    out = dispatch_once()                       # warm-up: compile the bucket
    dispatch_us = _time_calls(dispatch_once, repeats)
    kern(*args, tiles=tiles, backend=backend)   # warm e2e (same cache)
    e2e_us = _time_calls(
        lambda: kern(*args, tiles=tiles, backend=backend), repeats)
    return dispatch_us, e2e_us, out


def run(kernels=("mul", "matmul", "conv2d"), tiles_sweep=SWEEP_TILES,
        sew: int = 8, repeats: int = 5, smoke: bool = False) -> list[dict]:
    import numpy as np
    from repro import nmc
    from benchmarks.scaling import make_kernels

    built = make_kernels(sew, names=kernels)
    rows: list[dict] = []
    for name, (kfn, args, _post) in built.items():
        # one runtime per kernel family: both backends share its bucketed
        # jit cache (keys differ per backend) and its resident tile set
        rt = nmc.NmcRuntime()
        kern = nmc.jit(kfn, sew=sew, runtime=rt)
        engine = kern.select_engine(*args)
        for tiles in tiles_sweep:
            try:
                cfg = {}
                for backend in BACKENDS:
                    dispatch_us, e2e_us, out = bench_config(
                        kern, args, tiles, backend, repeats)
                    cfg[backend] = (dispatch_us, e2e_us, out)
            except nmc.PartitionError as e:
                print(f"# skip {name} tiles={tiles}: {e}")
                continue
            a, b = cfg["scan"][2], cfg["pallas"][2]
            exact = all((x == y).all() for x, y in zip(a, b))
            assert exact, f"{name} tiles={tiles}: backends diverged"
            for backend in BACKENDS:
                dispatch_us, e2e_us, _ = cfg[backend]
                rows.append({"kernel": name, "engine": engine,
                             "backend": backend, "tiles": tiles, "sew": sew,
                             "dispatch_us": round(dispatch_us, 2),
                             "e2e_us": round(e2e_us, 2), "bitexact": exact})
    if smoke:
        # the CI gate: the fused fast path must not lose to the scan
        # interpreter on the matmul builder (pure dispatch wall-clock)
        mm = {r["backend"]: r["dispatch_us"] for r in rows
              if r["kernel"] == "matmul" and r["tiles"] == 1}
        assert mm["pallas"] <= mm["scan"], \
            f"Pallas slower than scan on matmul: {mm}"
    return rows


def main(smoke: bool = False, out_json: str = "BENCH_backends.json") -> None:
    import jax

    t0 = time.perf_counter()
    if smoke:
        rows = run(kernels=("mul", "matmul"), tiles_sweep=SMOKE_TILES,
                   repeats=2, smoke=True)
    else:
        rows = run(smoke=False)
    wall_s = time.perf_counter() - t0

    by_cfg: dict = {}
    for r in rows:
        by_cfg.setdefault((r["kernel"], r["tiles"]), {})[r["backend"]] = r
    print("\n" + "=" * 60)
    print("name,us_per_call,derived")
    for (name, tiles), cfg in sorted(by_cfg.items()):
        s, p = cfg["scan"], cfg["pallas"]
        speedup = s["dispatch_us"] / max(p["dispatch_us"], 1e-9)
        print(f"backend_{name}_t{tiles},{p['dispatch_us']:.2f},"
              f"scan_us={s['dispatch_us']:.2f},"
              f"pallas_us={p['dispatch_us']:.2f},"
              f"speedup={speedup:.2f},bitexact={p['bitexact']}")

    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
             "device": jax.default_backend(), "smoke": smoke,
             "wall_s": round(wall_s, 2), "rows": rows}
    history = []
    if os.path.exists(out_json):
        try:
            with open(out_json) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    with open(out_json, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# wrote {out_json} ({len(history)} run(s))")


if __name__ == "__main__":
    if __package__ in (None, ""):
        _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, _root)
        sys.path.insert(0, os.path.join(_root, "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI gate (mul+matmul, tiles 1/4, asserts "
                         "Pallas <= scan on matmul)")
    ap.add_argument("--out", default="BENCH_backends.json",
                    help="JSON trajectory path")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out)
