"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled dry-run (all per-device quantities from the trip-count-expanded HLO
analysis in repro.launch.hlo_analysis):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16; 394 int8)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = collective_link_bytes / link_bw   (50 GB/s per chip)

Two memory variants are reported:
  * raw          — the compiled XLA program as-is (includes the S^2 score
                   traffic of the chunked-attention XLA fallback),
  * tpu-kernel   — attention-fallback traffic (named_scope-attributed)
                   replaced by the Pallas flash kernel's Q/K/V/O streaming
                   I/O (computed analytically; the kernel keeps scores and
                   softmax stats in VMEM).

MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), N = active params, D = tokens.
Also reported: MODEL/HLO ratio (useful-compute fraction; catches remat and
dispatch waste) and the roofline-limited MFU bound.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get
from repro.core.constants import TPU_V5E


def flash_io_bytes(arch: str, shape_name: str) -> float:
    """Analytic HBM traffic of the Pallas flash-attention kernel for every
    attention site in one step (GLOBAL bytes; divide by devices)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return 0.0   # decode path reads the cache directly (no fallback)
    hd = cfg.head_dim
    bytes_per = 2  # bf16

    def site(sq, skv, hq, hkv, dv=None):
        dv = dv or hd
        q = b * sq * hq * hd * bytes_per
        k = b * skv * hkv * hd * bytes_per
        v = b * skv * hkv * dv * bytes_per
        o = b * sq * hq * dv * bytes_per
        return q + k + v + o

    if cfg.family == "encdec":
        per_pass = (cfg.n_enc_layers * site(cfg.enc_seq, cfg.enc_seq,
                                            cfg.n_heads, cfg.n_kv_heads)
                    + cfg.n_layers * (site(s, s, cfg.n_heads, cfg.n_kv_heads)
                                      + site(s, cfg.enc_seq, cfg.n_heads,
                                             cfg.n_kv_heads)))
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        per_pass = n_attn * site(s, s, cfg.n_heads, cfg.n_kv_heads)
    elif cfg.family == "xlstm":
        per_pass = 0.0
    elif cfg.mla:
        dq = cfg.qk_nope_dim + cfg.qk_rope_dim
        per_pass = cfg.n_layers * (
            b * s * cfg.n_heads * dq * bytes_per * 2      # q + k
            + b * s * cfg.n_heads * cfg.v_head_dim * bytes_per * 2)  # v + o
    else:
        per_pass = cfg.n_layers * site(s, s, cfg.n_heads, cfg.n_kv_heads)
    # fwd = 1 pass; train adds remat-fwd + bwd (dq,dk,dv + reread) ~ 3 more
    passes = 4.0 if shape.kind == "train" else 1.0
    return per_pass * passes


def load_cells(path: str = "results/dryrun") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def roofline_row(rec: dict, spec=TPU_V5E) -> dict:
    n = rec["n_devices"]
    flops = rec["hlo"]["flops"]
    peak = spec.peak_int8_ops if rec.get("nmc_mode") == "w8a8" \
        else spec.peak_bf16_flops
    raw_bytes = rec["hlo"]["hbm_bytes"]
    attn_fb = rec["hlo"].get("attn_fallback_bytes", 0.0)
    fio = flash_io_bytes(rec["arch"], rec["shape"]) / n
    adj_bytes = max(raw_bytes - attn_fb, 0.0) + fio

    t_comp = flops / peak
    t_mem_raw = raw_bytes / spec.hbm_bw
    t_mem = adj_bytes / spec.hbm_bw
    t_coll = rec["hlo"]["collective_link_bytes"] / spec.ici_link_bw

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    est = max(terms.values())

    kind = rec["kind"]
    n_active = rec["active_params"]
    model_flops = (6 if kind == "train" else 2) * n_active * rec["tokens"]
    hlo_global = flops * n
    mfu_bound = (model_flops / (n * spec.peak_bf16_flops)) / est if est else 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "nmc": rec.get("nmc_mode", "none"), "tag": rec.get("tag", ""),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_raw_s": t_mem_raw, "t_collective_s": t_coll,
        "dominant": dominant, "est_step_s": est,
        "model_flops": model_flops, "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "mfu_bound": mfu_bound,
        "peak_hbm_gib": rec["memory"]["peak_bytes"] / 2**30,
        "compile_s": rec["compile_s"],
    }


def dominant_note(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        return "compute-bound: raise useful-flop fraction (remat policy) " \
               "or drop to int8 NMC mode"
    if d == "memory":
        return "memory-bound: fuse attention (Pallas), recompute masks, " \
               "cast residuals bf16"
    return "collective-bound: shrink TP degree / overlap collectives " \
           "with compute"


def main(path: str = "results/dryrun", out_csv: str | None = None):
    rows = [roofline_row(r) for r in load_cells(path)
            if not r.get("tag") and r.get("nmc_mode", "none") == "none"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'comp_s':>9s} "
           f"{'mem_s':>9s} {'coll_s':>9s} {'dom':>10s} {'useful':>7s} "
           f"{'MFUbound':>8s} {'peakGiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['mfu_bound']:8.3f} "
              f"{r['peak_hbm_gib']:8.2f}")
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"\nwrote {out_csv} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    import sys
    main(out_csv=sys.argv[1] if len(sys.argv) > 1 else
         "results/roofline.csv")
