"""Serving-scale sweep: tokens/s vs concurrent users on the tile array.

The resident-block serving claim of DESIGN.md §12 measured: one W8A8
decoder block (qwen1.5-0.5b smoke shapes) kept resident on the NMC tile
array via :class:`repro.serve.block.ResidentBlock`, swept over
``users x nmc_tiles`` — ``users`` concurrent decode rows advance one token
per block step, sharded ``tiles``-wide per projection.

Per configuration:

* **bit-exactness** — three chained steps of the resident path compared
  bit-for-bit against the per-projection
  :meth:`repro.serve.engine.ServeEngine.nmc_project` path and the pure-JAX
  int32 matmul reference (asserted, not just reported);
* **residency** — :class:`repro.nmc.pool.ResidentPool` counters prove the
  quantized weights DMA once (``loads == n_shards`` after the first step,
  unchanged after; later steps add exactly ``patch_bytes_per_call``);
* **modeled throughput** — steady-state block-step cycles through
  :func:`repro.core.timing.chained_wave_cycles` at the paper's benchmark
  clock: ``tok/s = users * F_CLK_BENCH_HZ / steady_cycles``.

Results append to ``BENCH_serving.json`` (one entry per run — the
trajectory CI uploads as an artifact).

Run from the repo root: ``PYTHONPATH=src python -m benchmarks.serving``
(``--smoke`` for the reduced CI gate).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

SWEEP_USERS = (1, 2, 4, 8)
SWEEP_TILES = (2, 4)
# at the qwen smoke shapes (d_ff=128) the MLP up/gate shards outgrow a
# tile's bank at tiles=2 once users>=4, so the smoke curve runs at tiles=4
SMOKE_USERS = (1, 4)
SMOKE_TILES = (4,)
VERIFY_STEPS = 3


def _bench_config(cfg, qparams, users: int, tiles: int) -> dict:
    """One (users, tiles) point: build the resident block on a private
    queue, verify three-way bit-exactness and residency, model tokens/s."""
    import numpy as np
    from repro import nmc
    from repro.core import constants as C
    from repro.serve.engine import ServeEngine

    own = nmc.DispatchQueue(pool=nmc.ResidentPool(
        pool=nmc.default_runtime().bucketed))
    eng = ServeEngine(cfg, qparams, n_slots=users, max_len=32,
                      nmc_queue=own, nmc_tiles=tiles)
    blk = eng.resident_block(layer=0, rows=users, tiles=tiles)
    rng = np.random.default_rng(7)
    x0 = rng.normal(size=(users, cfg.d_model)).astype(np.float32)

    def chain(mm):
        x, st = x0.copy(), blk.init_state(16)
        outs = []
        for _ in range(VERIFY_STEPS):
            x, st = blk.step(x, st, mm=mm)
            outs.append(x.copy())
        return outs

    # resident chain first, under the residency counters
    out_res = chain(None)
    assert blk.static, "value-independence proof failed"
    assert own.pool.loads == blk.n_shards, \
        (own.pool.loads, blk.n_shards)
    loads0, pb0 = own.pool.loads, own.pool.patch_bytes
    t0 = time.perf_counter()
    extra = chain(None)                        # steady-state wall clock
    wall_us = (time.perf_counter() - t0) / VERIFY_STEPS * 1e6
    assert own.pool.loads == loads0, "weights re-crossed the bus"
    assert own.pool.patch_bytes - pb0 \
        == VERIFY_STEPS * blk.patch_bytes_per_call
    assert all(np.array_equal(a, b) for a, b in zip(out_res, extra)), \
        "resident path not deterministic"
    # comparison chains (these reload per projection — after the asserts)
    out_jax = chain(blk.jax_mm)
    out_prj = chain(blk.project_mm(eng))
    exact = all(np.array_equal(a, b) for a, b in zip(out_res, out_jax)) \
        and all(np.array_equal(a, b) for a, b in zip(out_prj, out_jax))
    assert exact, f"users={users} tiles={tiles}: backends diverged"

    steady = blk.step_cycles(steady=True)
    cold = blk.step_cycles(steady=False)
    assert steady < cold, (steady, cold)
    return {"users": users, "tiles": tiles, "n_shards": blk.n_shards,
            "steady_cycles": round(steady, 1), "cold_cycles": round(cold, 1),
            "tok_s": round(users * C.F_CLK_BENCH_HZ / steady, 1),
            "patch_kb_per_step": round(blk.patch_bytes_per_call / 1024, 3),
            "wall_us_per_step": round(wall_us, 1),
            "bitexact": bool(exact), "resident": True}


def run(users_sweep=SWEEP_USERS, tiles_sweep=SWEEP_TILES,
        smoke: bool = False) -> list[dict]:
    import jax
    from repro import nmc
    from repro.configs import base as cb
    from repro.models import lm
    from repro.serve.engine import quantize_params

    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    rows: list[dict] = []
    for tiles in tiles_sweep:
        for users in users_sweep:
            try:
                rows.append(_bench_config(cfg, qparams, users, tiles))
            except (nmc.PartitionError, nmc.LoweringError) as e:
                # a shard that outgrows a tile's SRAM macro at this width
                # is a capacity fact, not a failure — report the skip
                print(f"# skip users={users} tiles={tiles}: {e}")
    assert rows, "every configuration skipped — sweep is vacuous"
    if smoke:
        assert all(r["bitexact"] and r["resident"] for r in rows), rows
    return rows


def main(smoke: bool = False, out_json: str = "BENCH_serving.json") -> None:
    import jax

    t0 = time.perf_counter()
    rows = run(users_sweep=SMOKE_USERS if smoke else SWEEP_USERS,
               tiles_sweep=SMOKE_TILES if smoke else SWEEP_TILES,
               smoke=smoke)
    wall_s = time.perf_counter() - t0

    print("\n" + "=" * 60)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"serving_u{r['users']}_t{r['tiles']},"
              f"{r['wall_us_per_step']:.1f},"
              f"tok_s={r['tok_s']:.1f},"
              f"steady_cyc={r['steady_cycles']:.0f},"
              f"cold_cyc={r['cold_cycles']:.0f},"
              f"patch_kb={r['patch_kb_per_step']},"
              f"bitexact={r['bitexact']},resident={r['resident']}")

    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
             "device": jax.default_backend(), "smoke": smoke,
             "wall_s": round(wall_s, 2), "rows": rows}
    history = []
    if os.path.exists(out_json):
        try:
            with open(out_json) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(entry)
    with open(out_json, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# wrote {out_json} ({len(history)} run(s))")


if __name__ == "__main__":
    if __package__ in (None, ""):
        _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, _root)
        sys.path.insert(0, os.path.join(_root, "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI gate (users 1/4, tiles 4; asserts "
                         "bit-exactness and residency per configuration)")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON trajectory path")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out)
