"""Wave-scheduler autotuning benchmark (DESIGN.md §14 acceptance gate).

Sweeps the scheduler's search — partition strategy × per-tile chunk skew
× per-shard engine assignment × dispatch order, objective
``timing.wave_cycles`` — over the partition-heavy registry kernels and
records, per (kernel, tiles), the modeled wave cycles of the seed
planner, the uniform plan and the tuned plan, plus a functional verdict:
the tuned schedule must reproduce the uniform plan's output bit-exactly
through both the synchronous and the asynchronous dispatch path.

The gate (``--smoke`` / ``--assert``) enforces the PR acceptance
criteria: on **matmul** and **conv2d** at tiles ∈ {4, 8} the tuned plan
is bit-exact *and* models ≥ ``BOUND_PCT``% fewer wave cycles than the
uniform plan; and the heterogeneous **qrelu** tape dispatches a
genuinely mixed Caesar+Carus wave through **one** launch (one
DispatchQueue wave, one resident dispatch call).

Results append to ``BENCH_tune.json``.
Run from the repo root: ``PYTHONPATH=src python -m benchmarks.tune_bench``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

BOUND_PCT = 5.0     # tuned must win this much vs uniform on matmul/conv2d
OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_tune.json")

#: (kernel, tiles) pairs the ≥5% bound applies to.
GATED = tuple((k, t) for k in ("matmul", "conv2d") for t in (4, 8))
#: Ride-along targets reported but not bound (elementwise kernels have
#: little skew headroom — their tuned plan must simply never be worse).
EXTRA = (("mul", 4), ("maxpool", 8))


def _measure(name: str, tiles: int, sew: int, rt) -> dict:
    import numpy as np
    from benchmarks import scaling
    from repro import nmc

    kfn, args, _post = scaling.make_kernels(sew, names=(name,))[name]
    ck = nmc.jit(kfn, tiles=tiles, runtime=rt)
    t0 = time.perf_counter()
    tuned = ck.plan_schedule(*args, schedule="auto")
    tune_ms = (time.perf_counter() - t0) * 1e3   # cold search (cache miss)
    ref = ck(*args, schedule="uniform")
    out_sync = ck(*args, schedule="auto")
    out_async = ck.call_async(*args, schedule="auto").result()
    bitexact = bool(np.array_equal(ref, out_sync)
                    and np.array_equal(ref, out_async))
    win = 100.0 * (tuned.uniform_cycles - tuned.modeled_cycles) \
        / tuned.uniform_cycles
    return {"kernel": name, "tiles": tiles, "sew": sew,
            "strategy": tuned.strategy, "chunks": list(tuned.chunks),
            "engines": list(tuned.engines), "order": list(tuned.order),
            "seed_cycles": float(tuned.seed_cycles),
            "uniform_cycles": float(tuned.uniform_cycles),
            "tuned_cycles": float(tuned.modeled_cycles),
            "win_vs_uniform_pct": round(win, 2),
            "tune_ms": round(tune_ms, 3), "bitexact": bitexact}


def _measure_mixed(sew: int) -> dict:
    """The mixed-engine wave contract on the heterogeneous qrelu tape."""
    import numpy as np
    from repro import nmc
    from repro.core import programs

    kfn, args = programs.qrelu_case(sew)
    rt = nmc.NmcRuntime()               # fresh counters for the assertion
    ck = nmc.jit(kfn, tiles=8, partition="rows", runtime=rt)
    uni = ck.plan_schedule(*args, schedule="uniform")
    tuned = ck.plan_schedule(*args, schedule="auto")
    ref = ck(*args, schedule="uniform")
    q = rt.queue
    w0, m0, d0 = q.waves, q.mixed_engine_waves, rt.resident.dispatch_calls
    out = ck(*args, schedule="auto")
    win = 100.0 * (uni.modeled_cycles - tuned.modeled_cycles) \
        / uni.modeled_cycles
    return {"kernel": "qrelu", "tiles": 8, "sew": sew,
            "engines": list(tuned.engines),
            "mixed": bool(tuned.mixed),
            "uniform_cycles": float(uni.modeled_cycles),
            "tuned_cycles": float(tuned.modeled_cycles),
            "win_vs_uniform_pct": round(win, 2),
            "one_launch": bool(q.waves - w0 == 1
                               and rt.resident.dispatch_calls - d0 == 1),
            "mixed_waves": int(q.mixed_engine_waves - m0),
            "bitexact": bool(np.array_equal(ref, out)
                             and np.array_equal(ref, ck.oracle(*args)))}


def run(sew: int = 8, smoke: bool = False) -> tuple[list, dict]:
    from repro import nmc
    from repro.nmc import schedule as S

    S.clear_plan_cache()
    rt = nmc.NmcRuntime()
    targets = GATED if smoke else GATED + EXTRA
    rows = [_measure(name, tiles, sew, rt) for name, tiles in targets]
    mixed = _measure_mixed(sew)
    return rows, mixed


def gate_failures(rows: list, mixed: dict, bound: float) -> list[str]:
    fails = []
    for r in rows:
        tag = f"{r['kernel']}/tiles={r['tiles']}"
        if not r["bitexact"]:
            fails.append(f"{tag}: tuned schedule not bit-exact")
        gated = (r["kernel"], r["tiles"]) in GATED
        if gated and r["win_vs_uniform_pct"] < bound:
            fails.append(f"{tag}: win {r['win_vs_uniform_pct']:.2f}% "
                         f"< {bound}% bound")
        if not gated and r["tuned_cycles"] > r["uniform_cycles"]:
            fails.append(f"{tag}: tuned models more cycles than uniform")
    if not mixed["bitexact"]:
        fails.append("qrelu: mixed wave not bit-exact")
    if not mixed["mixed"]:
        fails.append("qrelu: tuned plan is not mixed-engine")
    if not mixed["one_launch"] or mixed["mixed_waves"] != 1:
        fails.append("qrelu: mixed wave did not ride one launch")
    return fails


def main(smoke: bool = False, sew: int = 8, bound: float = BOUND_PCT) -> int:
    rows, mixed = run(sew=sew, smoke=smoke)

    print(f"{'kernel':<8} {'tiles':>5} {'strategy':<8} "
          f"{'seed':>8} {'uniform':>8} {'tuned':>8} {'win':>7}  exact")
    for r in rows:
        print(f"{r['kernel']:<8} {r['tiles']:>5} {r['strategy']:<8} "
              f"{r['seed_cycles']:>8.0f} {r['uniform_cycles']:>8.0f} "
              f"{r['tuned_cycles']:>8.0f} "
              f"{r['win_vs_uniform_pct']:>6.2f}%  {r['bitexact']}")
    print(f"qrelu    mixed wave: engines={mixed['engines']} "
          f"win={mixed['win_vs_uniform_pct']:.2f}% "
          f"one_launch={mixed['one_launch']} exact={mixed['bitexact']}")

    history = []
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            history = json.load(f)
    history.append({"ts": time.time(), "sew": sew, "results": rows,
                    "mixed": mixed})
    with open(OUT_JSON, "w") as f:
        json.dump(history, f, indent=1)
    print(f"results appended to {OUT_JSON}")

    failures = gate_failures(rows, mixed, bound)
    if smoke and failures:
        print("TUNE BENCH GATE FAILED:\n  " + "\n  ".join(failures))
        return 1
    if failures:
        print("(informational) " + "; ".join(failures))
    best = max(r["win_vs_uniform_pct"] for r in rows)
    print(f"gate: best win {best:.2f}% (bound {bound}%), "
          f"mixed qrelu wave in one launch")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help=f"enforce the gate: matmul/conv2d tuned plans "
                         f"bit-exact and >= {BOUND_PCT}%% fewer modeled "
                         f"wave cycles than uniform at tiles 4 and 8, "
                         f"plus the one-launch mixed qrelu wave")
    ap.add_argument("--sew", type=int, default=8)
    ap.add_argument("--bound", type=float, default=BOUND_PCT)
    a = ap.parse_args()
    raise SystemExit(main(smoke=a.smoke, sew=a.sew, bound=a.bound))
