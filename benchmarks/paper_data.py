"""The paper's published numbers (ground truth for validation).

Table V: system-level throughput / energy improvement factors vs the
RV32IMC CPU baseline (higher is better), per kernel x bitwidth.
"""

# (caesar, carus) throughput improvement factors
TABLE_V_THROUGHPUT = {
    "xor":        {8: (5.0, 12.7), 16: (5.0, 12.7), 32: (5.0, 12.7)},
    "add":        {8: (8.0, 20.3), 16: (11.0, 27.9), 32: (5.0, 12.7)},
    "mul":        {8: (22.0, 42.0), 16: (11.0, 27.9), 32: (5.0, 12.6)},
    "matmul":     {8: (28.0, 53.9), 16: (14.0, 37.1), 32: (5.6, 11.0)},
    "gemm":       {8: (9.1, 31.6), 16: (6.7, 24.1), 32: (3.3, 7.3)},
    "conv2d":     {8: (16.9, 47.5), 16: (8.3, 29.3), 32: (6.4, 10.0)},
    "relu":       {8: (26.0, 99.6), 16: (12.0, 46.0), 32: (5.0, 19.1)},
    "leaky_relu": {8: (12.0, 26.9), 16: (5.7, 12.9), 32: (2.4, 5.3)},
    "maxpool":    {8: (3.9, 6.3), 16: (3.5, 5.7), 32: (6.1, 3.7)},
}

# (caesar, carus) energy improvement factors
TABLE_V_ENERGY = {
    "xor":        {8: (4.0, 6.6), 16: (4.1, 6.7), 32: (4.7, 7.5)},
    "add":        {8: (6.4, 10.6), 16: (8.9, 14.5), 32: (4.7, 7.5)},
    "mul":        {8: (17.4, 23.7), 16: (9.5, 14.9), 32: (4.7, 7.1)},
    "matmul":     {8: (25.0, 35.6), 16: (13.4, 21.8), 32: (5.8, 7.1)},
    "gemm":       {8: (8.1, 20.7), 16: (6.5, 14.4), 32: (3.4, 4.8)},
    "conv2d":     {8: (14.2, 29.4), 16: (7.6, 17.6), 32: (6.1, 6.3)},
    "relu":       {8: (22.4, 59.3), 16: (11.6, 28.9), 32: (5.1, 2.8)},
    "leaky_relu": {8: (10.3, 17.3), 16: (5.0, 8.6), 32: (2.2, 3.7)},
    "maxpool":    {8: (3.8, 6.7), 16: (3.5, 5.8), 32: (5.8, 3.5)},
}

# Suspected erratum: relu/32-bit Carus energy 2.8x with 19.1x throughput
# would imply the NMC system draws 6.8x the CPU system's power (~42 mW)
# — physically impossible for this macro (peak ~10 mW at 250 MHz); every
# neighbouring cell has energy ~= throughput / 1.5.
SUSPECTED_ERRATA = {("relu", 32, "carus", "energy")}

# Table VIII: matmul A[10,10] x B[10,P] cycle counts (65 nm), P = 1024/512/256
TABLE_VIII_CYCLES = {
    "blade_multi":  {8: 12.8e3, 16: 25.6e3, 32: 51.2e3},
    "blade_single": {8: 204.8e3, 16: 409.6e3, 32: 819.2e3},
    "csram":        {8: 19.2e3, 16: 38.4e3, 32: 76.8e3},
    "caesar":       {8: 51.2e3, 16: 51.2e3, 32: 51.2e3},
    "carus":        {8: 26.6e3, 16: 19.5e3, 32: 26.0e3},
}
TABLE_VIII_PJ_PER_MAC_65NM = {
    "blade_multi":  {8: 7.9, 16: 26.7, 32: 103.0},
    "blade_single": {8: 43.0, 16: 97.1, 32: 320.0},
    "csram":        {8: 150.0, 16: 600.0, 32: 2400.0},
    "caesar":       {8: 16.3, 16: 32.0, 32: 61.8},
    "carus":        {8: 6.8, 16: 12.0, 32: 31.2},
}
TABLE_VIII_P = {8: 1024, 16: 512, 32: 256}

# Fig. 12 saturation values (8-bit matmul, large P)
FIG12_CARUS_SAT_OUT_PER_CYC = 0.48
FIG12_CAESAR_SAT_OUT_PER_CYC = 0.25
FIG12_CARUS_SAT_PJ_PER_OUT = 66.0
FIG12_CAESAR_SAT_PJ_PER_OUT = 175.0

# Table VI (anomaly detection end-to-end)
TABLE_VI = {
    "cv32e40p_1c": {"cycles": 1.0, "energy": 1.0, "area": 1.0},
    "cv32e40p_2c": {"cycles": 2.0, "energy": 1.37, "area": 1.43},
    "cv32e40p_4c": {"cycles": 4.0, "energy": 1.67, "area": 2.29},
    "caesar_e20":  {"cycles": 1.29, "energy": 1.20, "area": 0.90},
    "carus_e20":   {"cycles": 3.55, "energy": 2.36, "area": 1.36},
}
