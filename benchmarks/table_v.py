"""Table V reproduction: kernel throughput/energy improvements vs the CPU.

Runs every kernel x bitwidth functionally (bit-exact check on both engines),
derives cycles/energy from the calibrated mechanistic models, and compares
the improvement factors against the paper's published Table V.

The whole sweep rides the public ``repro.nmc`` stack (DESIGN.md §7): the
kernel builders are traced-frontend kernels (their instruction streams are
emitted by :mod:`repro.nmc.frontend` lowering, their oracles by the
tracer's ``alu.*_np`` evaluation), and the functional sweep dispatches
through a shape-bucketed :class:`repro.nmc.BucketedPool`: all (kernel x
SEW x engine) instances NOP-pad to power-of-two instruction buckets and
run as vmapped multi-tile groups — one XLA compile per ``(engine, sew,
bucket)`` instead of one per kernel instance or exact program shape.
``run`` asserts the compile bound (compiles <= #buckets) on the pool
counters, so the CI smoke subset gates the scheduling property, not just
functional correctness.
"""

from __future__ import annotations

from repro.core import energy, programs, timing
from repro.nmc import BucketedPool, TilePool
from benchmarks import paper_data as PD

ALL_SEWS = (8, 16, 32)


def sweep_buckets(kbs: list) -> set[tuple]:
    """The distinct (engine, sew, instr-bucket) buckets of a kernel sweep —
    the compile-count bound of the bucketed pool."""
    return {getattr(kb, eng).program.bucket_key
            for kb in kbs for eng in ("caesar", "carus")
            if getattr(kb, eng) is not None}


def run(verify_functional: bool = True,
        kernels: tuple = programs.TABLE_V_KERNELS,
        sews: tuple = ALL_SEWS,
        pool: TilePool | None = None) -> list[dict]:
    kbs = [programs.build(name, sew) for name in kernels for sew in sews]
    func_ok: dict = {}
    if verify_functional:
        pool = pool if pool is not None else BucketedPool()
        compiles0 = pool.compiles
        func_ok = programs.verify_sweep(kbs, pool)
        bad = {k: v for k, v in func_ok.items() if not all(v.values())}
        assert not bad, bad
        if isinstance(pool, BucketedPool):
            # the scheduling property of DESIGN.md §5: the whole sweep
            # compiles at most once per (engine, sew, bucket)
            n_buckets = len(sweep_buckets(kbs))
            assert pool.compiles - compiles0 <= n_buckets, \
                (pool.compiles - compiles0, n_buckets)
    rows = []
    for kb in kbs:
        name, sew = kb.name, kb.sew
        ok = func_ok.get((name, sew), {"caesar": None, "carus": None})
        t = timing.kernel_timing(kb)
        e = energy.kernel_energy(kb)
        cpu_cpo = t["cpu"].total_cycles / kb.n_outputs
        cpu_epo = e["cpu"].energy_pj / kb.n_outputs
        row = {"kernel": name, "sew": sew,
               "functional_ok": all(v for v in ok.values() if v
                                    is not None)}
        for eng in ("caesar", "carus"):
            nout = getattr(kb, eng).n_outputs
            thr = cpu_cpo / (t[eng].total_cycles / nout)
            en = cpu_epo / (e[eng].energy_pj / nout)
            p_thr, p_en = (PD.TABLE_V_THROUGHPUT[name][sew],
                           PD.TABLE_V_ENERGY[name][sew])
            i = 0 if eng == "caesar" else 1
            row[f"thr_{eng}"] = thr
            row[f"thr_{eng}_paper"] = p_thr[i]
            row[f"thr_{eng}_err"] = thr / p_thr[i] - 1
            row[f"en_{eng}"] = en
            row[f"en_{eng}_paper"] = p_en[i]
            row[f"en_{eng}_err"] = en / p_en[i] - 1
            row[f"erratum_{eng}"] = (name, sew, eng, "energy") in \
                PD.SUSPECTED_ERRATA
        rows.append(row)
    return rows


def main():
    pool = BucketedPool()
    rows = run(pool=pool)
    print(f"{'kernel':12s} sew | thrC model/paper | thrK model/paper |"
          f" enC model/paper | enK model/paper")
    errs = []
    for r in rows:
        print(f"{r['kernel']:12s} {r['sew']:3d} |"
              f" {r['thr_caesar']:6.1f}/{r['thr_caesar_paper']:6.1f} |"
              f" {r['thr_carus']:6.1f}/{r['thr_carus_paper']:6.1f} |"
              f" {r['en_caesar']:6.1f}/{r['en_caesar_paper']:6.1f} |"
              f" {r['en_carus']:6.1f}/{r['en_carus_paper']:6.1f}"
              + ("  [suspected paper erratum]" if r["erratum_carus"] else ""))
        for k in ("thr_caesar_err", "thr_carus_err", "en_caesar_err",
                  "en_carus_err"):
            if not (r["erratum_carus"] and k == "en_carus_err"):
                errs.append(abs(r[k]))
    import statistics
    print(f"\nvalidation vs Table V ({len(errs)} cells, erratum excluded): "
          f"mean |err| {100*statistics.mean(errs):.1f}%, "
          f"median {100*statistics.median(errs):.1f}%, "
          f"max {100*max(errs):.1f}%")
    print(f"tile pool: {pool.programs_run} programs in {pool.dispatches} "
          f"batched dispatches, {pool.compiles} compiles "
          f"({len(pool.shape_keys_compiled)} buckets, "
          f"pad_waste={pool.pad_waste} instr slots, "
          f"bytes_moved={pool.bytes_moved})")
    return rows


if __name__ == "__main__":
    main()
