"""IR-optimizer benchmark (DESIGN.md §13 acceptance gate).

Sweeps registry kernels through ``repro.nmc.opt.optimize`` and records,
per target, the instruction count and modeled engine cycles before vs
after ``O1`` plus a functional bit-exactness verdict (the optimized
program re-executes on its real engine and must reproduce the registry
oracle).  The optimizer's own translation-validation gate already ran
inside ``optimize`` — this benchmark demonstrates the *win* and
re-checks the *safety* end to end.

Results append to ``BENCH_opt.json``; ``--assert`` enforces the gate:
every target bit-exact, and at least one registry kernel at least
``BOUND_PCT``% cheaper in modeled cycles (the paper's GEMM epilogue
constants sit in the accumulator bank, so bank-aware placement wins
~10% there; the naive ``axpy`` builder wins on both engines).

Run from the repo root: ``PYTHONPATH=src python -m benchmarks.opt_bench``.
"""

from __future__ import annotations

import argparse
import copy
import json
import os
import time

BOUND_PCT = 5.0     # >= one registry kernel must win this much
OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_opt.json")

#: Registry targets with reclaimable slack plus a no-slack control group
#: (the optimizer must be a provable no-op there, not a small regression).
TARGETS = (("gemm", "caesar"), ("axpy", "caesar"), ("axpy", "carus"),
           ("xor", "caesar"), ("relu", "carus"))


def _measure(name: str, engine: str, sew: int) -> dict:
    import numpy as np
    from repro.core import programs, timing
    from repro.nmc import opt

    kb = programs.build(name, sew)
    eb = getattr(kb, engine)
    lk = copy.deepcopy(eb.lowered)      # registry stays opt="off" pristine
    before_c = timing.program_cycles(lk.program).cycles
    before_n = lk.program.n_instr
    t0 = time.perf_counter()
    rep = opt.optimize(lk, "O1")
    opt_ms = (time.perf_counter() - t0) * 1e3
    after_c = timing.program_cycles(lk.program).cycles
    after_n = lk.program.n_instr
    # end-to-end safety: the optimized program on the real engine must
    # reproduce the registry oracle bit-exactly
    from repro.nmc.engine import get_engine
    eng = get_engine(lk.engine)
    final = eng.run(eng.init_state(lk.mem), lk.program)
    got = lk.post(eng.extract(final, lk.out_slice, lk.sew))
    bitexact = bool(np.array_equal(np.asarray(got), eb.oracle))
    return {"kernel": name, "engine": engine, "sew": sew,
            "n_instr_before": int(before_n), "n_instr_after": int(after_n),
            "cycles_before": float(before_c), "cycles_after": float(after_c),
            "cycle_reduction_pct":
                round(100.0 * (before_c - after_c) / before_c, 2),
            "rules": [r.rule for r in rep.rewrites] if rep else [],
            "validated": rep.validated if rep else 0,
            "opt_ms": round(opt_ms, 3), "bitexact": bitexact}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="instrs/cycles before vs after opt='O1' on registry "
                    "kernels, with end-to-end bit-exactness")
    ap.add_argument("--sew", type=int, default=8,
                    help="element width for the sweep")
    ap.add_argument("--assert", dest="enforce", action="store_true",
                    help=f"fail unless every target is bit-exact and at "
                         f"least one registry kernel wins >= {BOUND_PCT}%% "
                         f"modeled cycles")
    ap.add_argument("--bound", type=float, default=BOUND_PCT,
                    help="required best-case cycle reduction in percent")
    args = ap.parse_args()

    results = [_measure(name, engine, args.sew)
               for name, engine in TARGETS]

    print(f"{'kernel':<8} {'engine':<7} {'instrs':>13} {'cycles':>17} "
          f"{'win':>7}  {'rules':<28} exact")
    for r in results:
        print(f"{r['kernel']:<8} {r['engine']:<7} "
              f"{r['n_instr_before']:>6}->{r['n_instr_after']:<6} "
              f"{r['cycles_before']:>8.0f}->{r['cycles_after']:<8.0f} "
              f"{r['cycle_reduction_pct']:>6.2f}%  "
              f"{','.join(r['rules']) or '-':<28} {r['bitexact']}")

    history = []
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            history = json.load(f)
    history.append({"ts": time.time(), "sew": args.sew, "results": results})
    with open(OUT_JSON, "w") as f:
        json.dump(history, f, indent=1)
    print(f"results appended to {OUT_JSON}")

    failures = [f"{r['kernel']}/{r['engine']}: not bit-exact"
                for r in results if not r["bitexact"]]
    best = max(r["cycle_reduction_pct"] for r in results)
    if best < args.bound:
        failures.append(f"best cycle reduction {best:.2f}% "
                        f"< {args.bound}% bound")
    if args.enforce and failures:
        print("OPT BENCH GATE FAILED:\n  " + "\n  ".join(failures))
        return 1
    if failures:
        print("(informational) " + "; ".join(failures))
    print(f"gate: best win {best:.2f}% (bound {args.bound}%), "
          f"{len(results)} targets bit-exact")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
