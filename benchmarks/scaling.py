"""Tile-array scaling sweep: one kernel sharded across tiles (DESIGN.md §9).

The paper's headline property is *scalability* — arrays of identical
NM-Caesar / NM-Carus tiles behind an edge node's SRAM macros.  This
benchmark exercises the partitioning planner end to end: each Table V
kernel family (elementwise, relu, matmul, conv2d, maxpool) is authored as
an ``nmc.jit`` traced kernel and executed at tiles ∈ {1, 2, 4, 8, 16},
asserting three properties exactly where they are claimed:

* **bit-exactness** — every partitioned execution (sync *and* async
  futures-of-gathers) equals the single-tile output, which equals the
  traced numpy oracle;
* **compile discipline** — the whole sweep compiles at most once per
  ``(engine, sew, instr-bucket, tile-bucket)``: shard programs pre-pad to
  one common bucket per wave, so scaling the tile count never multiplies
  XLA compiles (``compiles <= #buckets``);
* **modeled scaling shape** — ``timing.wave_cycles`` (one shared system
  bus serializing DMA against overlapped per-tile compute) yields a wave
  speedup that rises monotonically with the tile count until the bus
  binds, and is strictly > 1 at tiles=4 on the matmul kernel.

Run:  PYTHONPATH=src python -m benchmarks.scaling [--smoke]
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

ALL_TILES = (1, 2, 4, 8, 16)
ALL_SEWS = (8, 16, 32)
ALL_KERNELS = ("mul", "relu", "matmul", "conv2d", "maxpool")


def make_kernels(sew: int, seed: int = 0, names=ALL_KERNELS) -> dict:
    """The five Table V kernel families as traced-kernel closures, sized
    for a quick sweep.  Returns ``{name: (kfn, args, host_post)}`` —
    ``host_post`` is the host-side finishing stage (maxpool's horizontal
    reduction, Section V-B1), applied identically after single-tile and
    gathered multi-tile outputs so it never affects bit-exactness."""
    import numpy as np
    from repro import nmc
    from repro.core import alu

    rng = np.random.default_rng(seed)
    dt = alu.NP_DTYPES[sew]
    info = np.iinfo(dt)

    def rand(shape):
        return rng.integers(info.min, info.max + 1, shape, dtype=dt)

    out: dict = {}
    if "mul" in names:
        def mul(t, x, y):
            t.store(t.load(x, bank=0) * t.load(y))
        out["mul"] = (mul, (rand(1536), rand(1536)), None)
    if "relu" in names:
        def relu(t, x):
            t.store(t.load(x).max(0))
        out["relu"] = (relu, (rand(1536),), None)
    if "matmul" in names:
        def matmul(t, A, B, m=8, k=8):
            a = t.consts(A)
            rows = [t.load(B[r]) for r in range(k)]
            for i in range(m):
                acc = None
                for kk in range(k):
                    acc = nmc.mac(acc, a[i, kk], rows[kk])
                t.store(acc)
        out["matmul"] = (matmul, (rand((8, 8)), rand((8, 256))), None)
    if "conv2d" in names:
        # shape constants bind as defaults: the closures must not read
        # loop-shared names at call time (late binding)
        def conv2d(t, A, F, rows_n=8, nn=128, f=3):
            fw = t.consts(F)
            av = [t.load(A[r]) for r in range(rows_n)]
            sh = {(dj, r): av[r].slide_down(dj)
                  for dj in range(1, f) for r in range(rows_n)}
            for i in range(rows_n - f + 1):
                acc = None
                for di in range(f):
                    for dj in range(f):
                        src = av[i + di] if dj == 0 else sh[(dj, i + di)]
                        acc = nmc.mac(acc, fw[di, dj], src)
                t.store(acc, n=nn - f + 1)
        out["conv2d"] = (conv2d, (rand((8, 128)), rand((3, 3))), None)
    if "maxpool" in names:
        pr, width = 16, 64
        X = rand((pr, width))
        even = np.ascontiguousarray(X[0::2]).reshape(-1)
        odd = np.ascontiguousarray(X[1::2]).reshape(-1)

        def maxpool(t, e, o):           # vertical stage on the tile array
            t.store(t.load(e, bank=0).max(t.load(o)))

        def horiz(v, pr=pr, width=width):   # horizontal stage on the host
            v = np.asarray(v).reshape(pr // 2, width)
            return np.maximum(v[:, 0::2], v[:, 1::2])
        out["maxpool"] = (maxpool, (even, odd), horiz)
    return out


def run(tiles=ALL_TILES, sews=ALL_SEWS, kernels=ALL_KERNELS,
        engines=("caesar", "carus"), smoke: bool = False,
        runtime=None) -> list[dict]:
    from repro import nmc
    from repro.core import timing
    from repro.nmc.pool import tile_bucket

    if smoke:
        tiles = (1, 2, 4)
        sews = (8,)
        kernels = ("mul", "matmul")
    rt = runtime if runtime is not None else nmc.NmcRuntime()
    compiles0 = rt.bucketed.compiles
    pad0, useful0 = rt.bucketed.pad_waste, rt.bucketed.useful_instrs
    expected_keys: set = set()
    rows: list[dict] = []

    for sew in sews:
        for name, (kfn, args, host_post) in make_kernels(sew,
                                                         names=kernels).items():
            kern = nmc.jit(kfn, sew=sew, runtime=rt)
            post = host_post if host_post is not None else np.asarray
            for engine in engines:
                base = np.asarray(post(kern(*args, engine=engine)))
                single = timing.stage_cost(kern.lower(*args, engine=engine))
                for n in tiles:
                    pplan, lks = kern.lower_wave(*args, engine=engine,
                                                 tiles=n)
                    progs = [lk.program for lk in lks]
                    assert len({p.bucket_key for p in progs}) == 1, \
                        "wave shards straddle instruction buckets"
                    expected_keys.add((*progs[0].bucket_key,
                                       tile_bucket(len(progs))))
                    sync = np.asarray(post(kern(*args, engine=engine,
                                                tiles=n)))
                    fut = kern.call_async(*args, engine=engine, tiles=n)
                    asyn = np.asarray(post(fut.result()))
                    ok = (sync == base).all() and (asyn == base).all()
                    assert ok, (name, sew, engine, n)
                    stages = [timing.stage_cost(lk) for lk in lks]
                    rows.append({
                        "kernel": name, "sew": sew, "engine": engine,
                        "tiles_requested": n, "shards": pplan.n_shards,
                        "strategy": pplan.strategy, "bitexact": bool(ok),
                        "wave_cycles": timing.wave_cycles(stages,
                                                          pplan.n_shards),
                        "single_cycles": timing.wave_cycles([single], 1),
                    })
    compiled = rt.bucketed.compiles - compiles0
    # the scheduling property: scaling the tile count costs at most one
    # XLA compile per (engine, sew, instr-bucket, tile-bucket)
    assert compiled <= len(expected_keys), (compiled, len(expected_keys))

    # modeled scaling shape on the matmul kernel (NM-Caesar): within each
    # contiguous run of one partition strategy, speedup rises monotonically
    # to its peak, then the serialized bus binds.  A strategy switch (rows
    # -> axis once the 8 output rows stop dividing the tile count) restarts
    # the curve: axis shards slice B instead of replicating it, so the bus
    # stream shrinks and the speedup jumps.
    mm = [r for r in rows
          if r["kernel"] == "matmul" and r["engine"] == engines[0]
          and r["sew"] == sews[0]]
    speedups = [r["single_cycles"] / r["wave_cycles"] for r in mm]
    strategies = [r["strategy"] for r in mm]
    i = 0
    while i < len(mm):
        j = i
        while j + 1 < len(mm) and strategies[j + 1] == strategies[i]:
            j += 1
        seg = speedups[i:j + 1]
        peak = max(range(len(seg)), key=seg.__getitem__)
        assert all(a <= b + 1e-9 for a, b in zip(seg[:peak],
                                                 seg[1:peak + 1])), speedups
        i = j + 1
    at4 = next(r["single_cycles"] / r["wave_cycles"] for r in mm
               if r["tiles_requested"] == 4)
    assert at4 > 1.0, at4
    for r in rows:
        r["wave_speedup"] = r["single_cycles"] / r["wave_cycles"]
    # ragged-tail waste visibility: every dispatch above (base calls,
    # partitioned sync waves, async gathers) reported its NOP padding into
    # the runtime's bucketed counters — surface and bound it here.  The
    # power-of-two bucket rule guarantees < 1x waste per program stream
    # and replicated padding lanes only appear at non-power-of-two shard
    # counts, so total waste must stay under 2x the useful instructions.
    pad_waste = rt.bucketed.pad_waste - pad0
    useful = rt.bucketed.useful_instrs - useful0
    if smoke:
        assert pad_waste < 2 * useful, (pad_waste, useful)
    r0 = {"compiles": compiled, "buckets": len(expected_keys),
          "matmul_speedup_at_4": at4, "pad_waste": pad_waste,
          "useful_instrs": useful}
    rows.append({"kernel": "_summary", **r0})
    return rows


def main(smoke: bool = False):
    rows = run(smoke=smoke)
    summary = rows.pop()
    print(f"{'kernel':8s} sew engine tiles shards strat  bitexact "
          f"wave-speedup")
    for r in rows:
        print(f"{r['kernel']:8s} {r['sew']:3d} {r['engine']:6s} "
              f"{r['tiles_requested']:5d} {r['shards']:6d} "
              f"{r['strategy']:6s} {str(r['bitexact']):8s} "
              f"{r['wave_speedup']:6.2f}x")
    print(f"\ncompiles={summary['compiles']} <= buckets="
          f"{summary['buckets']}; matmul wave speedup @4 tiles = "
          f"{summary['matmul_speedup_at_4']:.2f}x")
    print(f"pad_waste={summary['pad_waste']} instr slots over "
          f"useful={summary['useful_instrs']} "
          f"({summary['pad_waste'] / max(summary['useful_instrs'], 1):.2f}x"
          f" bucketing overhead)")
    return rows


if __name__ == "__main__":
    if __package__ in (None, ""):
        _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, _root)
        sys.path.insert(0, os.path.join(_root, "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI subset (mul+matmul @ sew=8, tiles<=4)")
    main(smoke=ap.parse_args().smoke)
