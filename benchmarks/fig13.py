"""Fig. 13 reproduction: average system power breakdown (8-/32-bit conv2d).

Checks the paper's three qualitative claims about the power structure:
  1. CPU system: memory accesses ~ the CPU's own power,
  2. NM-Caesar: ~70 % of power in memory, half of it instruction fetch,
  3. NM-Carus: VRF banks ~60 % of total, eCPU negligible.
"""

from __future__ import annotations

from repro.core import energy, programs, timing


def run(sew: int = 8) -> dict:
    kb = programs.build("conv2d", sew)
    tr = timing.carus_cycles(kb.carus, sew)
    acc = timing.carus_vrf_accesses(kb.carus, sew)
    acc_rate = acc / tr.cycles
    out = {
        "cpu": energy.power_breakdown_mw("cpu"),
        "caesar": energy.power_breakdown_mw("caesar"),
        "carus": energy.power_breakdown_mw("carus", acc_rate),
    }
    return out


def main():
    for sew in (8, 32):
        bd = run(sew)
        print(f"--- conv2d {sew}-bit: average power breakdown (mW) ---")
        for eng, comps in bd.items():
            total = sum(comps.values())
            parts = ", ".join(f"{k} {v:.2f} ({100*v/total:.0f}%)"
                              for k, v in comps.items())
            print(f"{eng:8s} total {total:5.2f} mW: {parts}")
        cpu = bd["cpu"]
        assert abs(cpu["system_mem"] / cpu["host_cpu"] - 1) < 0.15, \
            "claim 1: CPU-system memory ~ CPU power"
        cz = bd["caesar"]
        mem_frac = (cz["instr_fetch"] + cz["system_mem"] + cz["nmc_mem"]) \
            / sum(cz.values())
        assert 0.6 < mem_frac < 0.8, f"claim 2: {mem_frac}"
        ka = bd["carus"]
        vrf_frac = ka["vrf"] / sum(ka.values())
        assert 0.45 < vrf_frac < 0.7, f"claim 3: {vrf_frac}"
        assert ka["ecpu"] / sum(ka.values()) < 0.06, "eCPU negligible"
    print("\nFig. 13 qualitative structure reproduced "
          "(claims 1-3 of Section V-B1).")


if __name__ == "__main__":
    main()
