"""Static-verifier overhead benchmark (DESIGN.md §11 acceptance gate).

``nmc.jit(fn, check="error")`` — the default — verifies every lowering.
The verifier is numpy-vectorized (one in-place event-key sort, no
per-instruction Python loop) and memoizes the verdict on a content
fingerprint of the lowered program, so repeated lowerings of the same
kernel/signature pay one 64 KiB hash, not the pass pipeline.  This
benchmark measures both regimes: paired, interleaved
``lower(check="off")`` vs ``lower(check="error")`` timings over the
quickstart-style kernels on both engines give the steady-state overhead
(paired medians cancel machine drift, which on shared CI runners dwarfs
the effect being measured), and a ``clear_memo()``-per-iteration loop
gives the cold verify cost per configuration.

Results append to ``BENCH_check.json``; ``--assert`` enforces the
acceptance gate.  The gate is dual-bound: the relative bound (default
5%) applies to configurations whose baseline lowering takes at least
``REL_FLOOR_MS`` — the quickstart path (engine auto-selection picks
NM-Caesar, whose per-word bus programs run thousands of instructions
through the verifier).  NM-Carus lowers the same kernels to a handful
of vector instructions in ~0.2 ms, so a percentage there only measures
the verifier's fixed numpy dispatch floor; those configurations are
instead held to an absolute ceiling of ``ABS_BOUND_MS`` added latency.

Run from the repo root: ``PYTHONPATH=src python -m benchmarks.check_bench``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

BOUND_PCT = 5.0     # relative bound for substantial lowerings
REL_FLOOR_MS = 1.0  # below this baseline, a percentage is meaningless
ABS_BOUND_MS = 0.6  # absolute added-latency ceiling for tiny lowerings
OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_check.json")


def _paired_overhead(kern, args, engine: str, pairs: int) -> dict:
    """Interleaved off/error lowering timings -> median paired stats."""
    for _ in range(3):  # warm both paths (imports, caches)
        kern.lower(*args, engine=engine, check="off")
        kern.lower(*args, engine=engine, check="error")
    offs, deltas = [], []
    for _ in range(pairs):
        t0 = time.perf_counter()
        kern.lower(*args, engine=engine, check="off")
        t1 = time.perf_counter()
        kern.lower(*args, engine=engine, check="error")
        t2 = time.perf_counter()
        offs.append(t1 - t0)
        deltas.append((t2 - t1) - (t1 - t0))
    # median of the per-pair deltas: each delta is taken under the same
    # instantaneous machine load, so drift cancels where independently
    # sorted medians would not
    offs.sort()
    deltas.sort()
    off_ms = offs[len(offs) // 2] * 1e3
    delta_ms = deltas[len(deltas) // 2] * 1e3

    from repro.nmc import check
    lk = kern.lower(*args, engine=engine, check="off")
    colds = []
    for _ in range(max(pairs // 2, 10)):
        check.clear_memo()
        t0 = time.perf_counter()
        check.verify_lowered(lk)
        colds.append(time.perf_counter() - t0)
    colds.sort()
    return {"off_ms": round(off_ms, 4),
            "error_ms": round(off_ms + delta_ms, 4),
            "delta_ms": round(delta_ms, 4),
            "overhead_pct": round(100.0 * delta_ms / off_ms, 2),
            "cold_verify_ms": round(colds[len(colds) // 2] * 1e3, 4)}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="lowering-time overhead of check='error' vs 'off'")
    ap.add_argument("--pairs", type=int, default=40,
                    help="interleaved off/error timing pairs per config")
    ap.add_argument("--n", type=int, default=4096,
                    help="elements per input vector")
    ap.add_argument("--assert", dest="enforce", action="store_true",
                    help=f"fail if any config with a >= {REL_FLOOR_MS} ms "
                         f"baseline exceeds {BOUND_PCT}%% overhead, or any "
                         f"smaller one adds > {ABS_BOUND_MS} ms")
    ap.add_argument("--bound", type=float, default=BOUND_PCT,
                    help="relative overhead bound in percent for --assert")
    ap.add_argument("--abs-bound", type=float, default=ABS_BOUND_MS,
                    help="absolute delta bound in ms for sub-floor configs")
    args = ap.parse_args()

    import numpy as np
    from repro import nmc

    @nmc.kernel
    def fused(t, x, y):
        t.store((t.load(x) * 3 + t.load(y)).max(0))

    @nmc.kernel
    def scaled(t, x):
        t.store(t.load(x) * 3 + 1)

    rng = np.random.default_rng(0)
    xs = rng.integers(-100, 100, args.n).astype(np.int8)
    ys = rng.integers(-100, 100, args.n).astype(np.int8)

    configs = [("fused", fused, (xs, ys), "caesar"),
               ("fused", fused, (xs, ys), "carus"),
               ("scaled", scaled, (xs,), "caesar"),
               ("scaled", scaled, (xs,), "carus")]
    results = []
    print(f"{'kernel':<8} {'engine':<7} {'off ms':>9} {'error ms':>9} "
          f"{'overhead':>9} {'cold ms':>8}")
    for name, kern, kargs, engine in configs:
        r = _paired_overhead(kern, kargs, engine, args.pairs)
        r.update(kernel=name, engine=engine, n=args.n)
        results.append(r)
        print(f"{name:<8} {engine:<7} {r['off_ms']:>9.3f} "
              f"{r['error_ms']:>9.3f} {r['overhead_pct']:>8.2f}% "
              f"{r['cold_verify_ms']:>8.3f}")

    history = []
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            history = json.load(f)
    history.append({"ts": time.time(), "results": results})
    with open(OUT_JSON, "w") as f:
        json.dump(history, f, indent=1)
    print(f"results appended to {OUT_JSON}")

    failures = []
    for r in results:
        tag = f"{r['kernel']}/{r['engine']}"
        if r["off_ms"] >= REL_FLOOR_MS:
            if r["overhead_pct"] > args.bound:
                failures.append(f"{tag}: {r['overhead_pct']:.2f}% "
                                f"> {args.bound:.1f}% relative bound")
        elif r["delta_ms"] > args.abs_bound:
            failures.append(f"{tag}: +{r['delta_ms']:.3f} ms "
                            f"> {args.abs_bound:.2f} ms absolute bound")
    rel = [r["overhead_pct"] for r in results if r["off_ms"] >= REL_FLOOR_MS]
    if rel:
        print(f"worst relative overhead (baselines >= {REL_FLOOR_MS} ms): "
              f"{max(rel):.2f}% (bound {args.bound:.1f}%)")
    if failures:
        print("gate:", "FAIL" if args.enforce else "would fail (no --assert)")
        for line in failures:
            print(" ", line)
        return 1 if args.enforce else 0
    print("gate: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
