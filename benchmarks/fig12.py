"""Fig. 12 reproduction: matmul throughput/energy scaling with matrix shape.

Sweeps P for the A[8,8] x B[8,P] kernel on both engines and checks the two
published saturation points (8-bit): NM-Carus 0.48 outputs/cycle and
~66 pJ/output; NM-Caesar 0.25 outputs/cycle and ~175 pJ/output, plus the
crossover (Caesar beats Carus at small P because of the eCPU bootstrap).
"""

from __future__ import annotations

from repro.core import energy, programs, timing
from repro.nmc.pool import BucketedPool, TilePool
from benchmarks import paper_data as PD


def run(sew: int = 8, verify: bool = False,
        pool: TilePool | None = None) -> list[dict]:
    kbs = [programs.build_matmul(sew, p=p, seed=11)
           for p in (8, 16, 32, 64, 128, 256, 512, 1024)]
    if verify:
        # whole P-sweep through the shape-bucketed tile pool, bit-exact;
        # the P-sweep's ragged instruction counts share power-of-two buckets
        res = programs.verify_sweep(kbs, pool or BucketedPool())
        assert all(all(v.values()) for v in res.values()), res
    rows = []
    for p, kb in zip((8, 16, 32, 64, 128, 256, 512, 1024), kbs):
        t = timing.kernel_timing(kb)
        e = energy.kernel_energy(kb)
        rows.append({
            "P": p,
            "caesar_out_per_cyc": kb.caesar.n_outputs /
            t["caesar"].total_cycles,
            "carus_out_per_cyc": kb.carus.n_outputs / t["carus"].total_cycles,
            "cpu_out_per_cyc": kb.n_outputs / t["cpu"].total_cycles,
            "caesar_pj_per_out": e["caesar"].energy_pj / kb.caesar.n_outputs,
            "carus_pj_per_out": e["carus"].energy_pj / kb.carus.n_outputs,
        })
    return rows


def main():
    rows = run()
    print(f"{'P':>6s} {'CPU out/cyc':>12s} {'Caesar':>8s} {'Carus':>8s} "
          f"{'Caesar pJ/out':>14s} {'Carus pJ/out':>13s}")
    for r in rows:
        print(f"{r['P']:6d} {r['cpu_out_per_cyc']:12.4f} "
              f"{r['caesar_out_per_cyc']:8.3f} {r['carus_out_per_cyc']:8.3f} "
              f"{r['caesar_pj_per_out']:14.1f} {r['carus_pj_per_out']:13.1f}")
    sat = rows[-1]
    print(f"\nsaturation checks (paper): Carus {sat['carus_out_per_cyc']:.3f}"
          f" vs {PD.FIG12_CARUS_SAT_OUT_PER_CYC} out/cyc; "
          f"Caesar {sat['caesar_out_per_cyc']:.3f} vs "
          f"{PD.FIG12_CAESAR_SAT_OUT_PER_CYC}; "
          f"Carus {sat['carus_pj_per_out']:.0f} vs "
          f"{PD.FIG12_CARUS_SAT_PJ_PER_OUT} pJ/out; "
      f"Caesar {sat['caesar_pj_per_out']:.0f} vs "
          f"{PD.FIG12_CAESAR_SAT_PJ_PER_OUT} pJ/out")
    small = rows[0]
    print(f"crossover check: at P=8 Caesar ({small['caesar_out_per_cyc']:.3f}"
          f" out/cyc) should beat Carus ({small['carus_out_per_cyc']:.3f}) "
          f"— eCPU bootstrap overhead (Fig. 12 discussion)")
    return rows


if __name__ == "__main__":
    main()
