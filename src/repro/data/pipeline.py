"""Deterministic, resumable data pipeline.

Production shape: every host reads only its shard of the global batch
(``host_batch = global_batch / n_hosts``), and batches are a pure function
of (seed, step) — so restart-from-checkpoint reproduces the exact token
stream with no data-loader state to persist beyond the step counter, and
elastic re-sharding (different host count after a resize) re-partitions the
same global stream.

Two sources:
  * ``SyntheticLM`` — seeded-PRNG token stream (benchmarks / tests / CI).
  * ``PackedFileDataset`` — memory-mapped uint16/uint32 token file, packed
    into fixed-length rows, sharded by host then by step (real corpora).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Deterministic synthetic LM batches: batch = f(seed, step, host)."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.mc = model_cfg

    def batch_at(self, step: int) -> dict:
        c, mc = self.cfg, self.mc
        out = {}
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        fam = mc.family
        s_text = c.seq_len - (mc.n_img_tokens if fam == "vlm" else 0)
        out["tokens"] = rng.integers(0, mc.vocab_size,
                                     (c.host_batch, s_text), dtype=np.int32)
        if fam == "encdec":
            out["frames"] = rng.normal(
                size=(c.host_batch, mc.enc_seq, mc.d_model)).astype(np.float32)
        if fam == "vlm":
            out["images"] = rng.normal(
                size=(c.host_batch, mc.n_img_tokens, mc.d_model)
            ).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PackedFileDataset:
    """Memory-mapped token file -> fixed-length packed rows.

    Deterministic assignment: row r of the epoch permutation goes to
    (step, slot) = divmod(r, global_batch); each host takes its contiguous
    slot range.  The permutation is seeded, so any (host count, step) pair
    addresses the same global stream."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.n_rows = len(self.tokens) // (cfg.seq_len + 1)
        assert self.n_rows >= cfg.global_batch, "dataset smaller than a batch"

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        epoch, within = divmod(step * c.global_batch, self.n_rows)
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, epoch]))
        perm = rng.permutation(self.n_rows)
        lo = within + c.host_id * c.host_batch
        rows = perm[(lo + np.arange(c.host_batch)) % self.n_rows]
        L = c.seq_len + 1
        toks = np.stack([self.tokens[r * L:(r + 1) * L] for r in rows])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(kind: str, data_cfg: DataConfig, model_cfg: ModelConfig,
                 path: Optional[str] = None):
    if kind == "synthetic":
        return SyntheticLM(data_cfg, model_cfg)
    if kind == "file":
        assert path, "file dataset needs --data-path"
        return PackedFileDataset(path, data_cfg)
    raise KeyError(kind)
