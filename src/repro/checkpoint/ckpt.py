"""Checkpointing: atomic, versioned, restart- and resize-safe.

Format: one directory per step (``step_000123/``) holding
  * ``tree.json``  — pytree structure + leaf metadata (shape/dtype),
  * ``leaf_XXXXX.npy`` — one file per leaf (written via a temp dir + rename,
    so a torn write never corrupts the latest checkpoint),
  * ``DONE``       — commit marker; restore only considers committed steps.

Multi-host: each host writes its addressable shards (here: single-host
writes everything); restore reshards onto the *current* mesh by sharded
``jax.device_put``, so a checkpoint taken on N hosts restores on M — the
elastic-resize path (runtime/elastic.py) relies on this.

A background thread handles async saves (the train loop never blocks on
disk); ``wait()`` drains pending writes before exit.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    meta = {"step": step, "treedef": str(treedef),
            "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if "bfloat16" in logical:
            arr = arr.view(np.uint16)   # numpy can't serialize ml_dtypes
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": logical})
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(path, d, "DONE")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any, shardings=None) -> Any:
    """Restore into the structure of `like`; reshard onto `shardings`
    (a matching pytree of NamedShardings) when given."""
    import ml_dtypes
    d = os.path.join(path, f"step_{step:08d}")
    assert os.path.exists(os.path.join(d, "DONE")), f"uncommitted ckpt {d}"
    with open(os.path.join(d, "tree.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like)
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if "bfloat16" in meta["leaves"][i]["dtype"]:
            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {leaf.shape}"
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with a bounded queue."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.path, step, tree)
                self._gc()
            except BaseException as e:   # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        all_steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def submit(self, step: int, tree: Any):
        if self._err:
            raise self._err
        # materialize on host *now* so the train loop can donate buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join(timeout=10)
