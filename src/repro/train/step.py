"""Train / eval step builders: loss -> grads -> AdamW, with microbatched
gradient accumulation, optional int8 gradient compression on the cross-pod
all-reduce, and donated (in-place) parameter/optimizer buffers — the
memory/compute-mode duality of the paper applied to training state."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed import compress as gc
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_loss(cfg: ModelConfig):
    def loss(params, batch):
        return lm.loss_fn(params, batch, cfg)
    return loss


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Microbatching: the global batch is split on axis 0 and
    accumulated with a lax.scan (constant-memory in n_microbatches)."""
    loss_fn = make_loss(cfg)

    def grads_of(params, batch):
        (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return l, met, g

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]), batch)

            def acc_fn(acc, mbatch):
                l, met, g = grads_of(params, mbatch)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), met

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, total_l), mets = jax.lax.scan(
                acc_fn, (zero_g, jnp.float32(0)), mb)
            g = jax.tree.map(lambda x: x / n_microbatches, g)
            loss = total_l / n_microbatches
            metrics = jax.tree.map(lambda m: m[-1], mets)
        else:
            loss, metrics, g = grads_of(params, batch)

        if compress_grads:
            # int8 + error feedback; the error state lives in opt_state
            cg, new_err = gc.compress_tree(g, opt_state["grad_err"])
            g = gc.decompress_tree(cg)
            opt_state = dict(opt_state, grad_err=new_err)

        err = opt_state.get("grad_err")
        core = {k: opt_state[k] for k in ("m", "v", "step")}
        new_params, new_core, opt_metrics = adamw.apply_updates(
            params, g, core, opt_cfg)
        new_state = dict(new_core)
        if err is not None:
            new_state["grad_err"] = err
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, compress_grads: bool = False):
    params = lm.init_params(key, cfg)
    opt_state = adamw.init_state(params)
    if compress_grads:
        opt_state["grad_err"] = gc.init_error_state(params)
    return params, opt_state


def make_eval_step(cfg: ModelConfig):
    loss_fn = make_loss(cfg)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)
    return eval_step
