"""Fault-tolerant training loop.

Production behaviours (exercised in tests/test_fault_tolerance.py):
  * periodic async checkpoints (never blocks the step loop),
  * automatic restart-from-latest on crash (any exception in a step triggers
    restore + replay; the data pipeline is a pure function of step, so the
    token stream is identical after restore),
  * straggler detection: a rolling median of step times flags outliers
    (> straggler_factor x median); mitigation hook logs and (at scale)
    would trigger hot-spare swap — here it records the event,
  * elastic resize: on a device-count change the loop re-builds the mesh,
    re-shards state from the last checkpoint and continues (simulated by
    runtime/elastic.py since this container has one device).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, make_dataset
from repro.distributed import sharding
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import step as step_lib

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    n_microbatches: int = 1
    compress_grads: bool = False
    straggler_factor: float = 3.0
    max_restarts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 data_cfg: Optional[DataConfig] = None, mesh=None):
        self.cfg = cfg
        self.tc = tc
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=tc.total_steps)
        self.data_cfg = data_cfg or DataConfig(global_batch=8, seq_len=128,
                                               seed=tc.seed)
        self.mesh = mesh
        self.dataset = make_dataset("synthetic", self.data_cfg, cfg)
        self.checkpointer = ckpt.AsyncCheckpointer(tc.ckpt_dir)
        self.step_times: list[float] = []
        self.straggler_events: list[int] = []
        self.restarts = 0

        fn = step_lib.make_train_step(cfg, self.opt_cfg,
                                      tc.n_microbatches, tc.compress_grads)
        if mesh is not None:
            self._jit_step = None  # built lazily with shardings
            self._raw_step = fn
        else:
            self._jit_step = jax.jit(fn, donate_argnums=(0, 1))
            self._raw_step = fn

    # -- state ---------------------------------------------------------------
    def init_state(self):
        params, opt_state = step_lib.init_train_state(
            jax.random.PRNGKey(self.tc.seed), self.cfg,
            self.tc.compress_grads)
        if self.mesh is not None:
            pshard = sharding.param_shardings(params, self.mesh)
            params = jax.device_put(params, pshard)
        return params, opt_state, 0

    def maybe_restore(self, params, opt_state):
        last = ckpt.latest_step(self.tc.ckpt_dir)
        if last is None:
            return params, opt_state, 0
        log.info("restoring from step %d", last)
        tree = {"params": params, "opt": opt_state}
        restored = ckpt.restore(self.tc.ckpt_dir, last, tree)
        return restored["params"], restored["opt"], last

    # -- loop ----------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None) -> dict:
        """Train to total_steps.  `fail_at` injects a crash once (tests)."""
        params, opt_state, start = self.init_state()
        params, opt_state, start = self.maybe_restore(params, opt_state)
        step = start
        metrics = {}
        failed_once = False
        while step < self.tc.total_steps:
            try:
                if fail_at is not None and step == fail_at and not failed_once:
                    failed_once = True
                    raise RuntimeError("injected node failure")
                t0 = time.perf_counter()
                batch = {k: jax.numpy.asarray(v) for k, v in
                         self.dataset.batch_at(step).items()}
                params, opt_state, metrics = self._step(params, opt_state,
                                                        batch)
                dt = time.perf_counter() - t0
                self._straggler_check(step, dt)
                step += 1
                if step % self.tc.ckpt_every == 0 or \
                        step == self.tc.total_steps:
                    self.checkpointer.submit(
                        step, {"params": params, "opt": opt_state})
                if step % self.tc.log_every == 0:
                    log.info("step %d loss %.4f (%.0f ms)", step,
                             float(metrics["loss"]), dt * 1e3)
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.restarts += 1
                if self.restarts > self.tc.max_restarts:
                    raise
                log.warning("step %d failed (%s); restarting from ckpt",
                            step, e)
                self.checkpointer.wait()
                params, opt_state, step = self.init_state()
                params, opt_state, step = self.maybe_restore(params,
                                                             opt_state)
        self.checkpointer.wait()
        return {"final_step": step, "metrics": metrics,
                "restarts": self.restarts,
                "stragglers": list(self.straggler_events)}

    def _step(self, params, opt_state, batch):
        if self._jit_step is None:
            with self.mesh:
                return jax.jit(self._raw_step, donate_argnums=(0, 1))(
                    params, opt_state, batch)
        return self._jit_step(params, opt_state, batch)

    def _straggler_check(self, step: int, dt: float):
        self.step_times.append(dt)
        hist = self.step_times[-50:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if dt > self.tc.straggler_factor * med:
                self.straggler_events.append(step)
                log.warning("straggler at step %d: %.0f ms vs median %.0f ms"
                            " — would trigger hot-spare mitigation", step,
                            dt * 1e3, med * 1e3)
