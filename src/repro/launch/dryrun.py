import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline inputs.

For each cell this produces a JSON record with:
  * memory_analysis()  — per-device HBM (argument/output/temp/peak), proving
    the sharded program fits the 16 GiB v5e budget,
  * cost_analysis()    — HLO FLOPs + bytes accessed,
  * the collective mix parsed from the post-SPMD optimized HLO
    (op kind, count, per-device link bytes under ring algorithms),
together with the roofline terms derived in benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import gzip
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get
from repro.distributed import context, sharding
from repro.launch import hlo_analysis
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVE_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Sum per-device link bytes of every collective in optimized HLO.

    Ring-algorithm accounting per device (shapes in post-SPMD HLO are
    per-shard): all-reduce ~ 2x result bytes; all-gather ~ result bytes
    (each device receives (k-1)/k ~ 1x); reduce-scatter ~ operand bytes
    ~ result bytes (we see the op result: scattered shard => k x result;
    use result bytes as the conservative per-device estimate); all-to-all
    and collective-permute ~ result bytes."""
    stats: dict[str, dict] = {}
    for m in _COLLECTIVE_RE.finditer(hlo):
        tuple_part, dtype, dims, kind = m.groups()
        if "-done(" in m.group(0):
            continue  # async pair: count only the -start / sync form
        if tuple_part is not None:
            size = 0
            for t in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", tuple_part):
                size += _shape_bytes(*t)
        else:
            size = _shape_bytes(dtype, dims)
        factor = 2.0 if kind == "all-reduce" else 1.0
        st = stats.setdefault(kind, {"count": 0, "link_bytes": 0.0,
                                     "result_bytes": 0})
        st["count"] += 1
        st["result_bytes"] += size
        st["link_bytes"] += factor * size
    return stats


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             nmc_mode: str = "none", extra_tag: str = "",
             cfg_override=None, hlo_out: str | None = None,
             **cfg_kw) -> dict:
    cfg = cfg_override or get(arch)
    if cfg_kw:
        cfg = cfg.scaled(**cfg_kw)
    if nmc_mode != "none":
        cfg = cfg.scaled(nmc_mode=nmc_mode)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with context.use_mesh(mesh):
        fn, args, donate = S.cell_fn_and_inputs(cfg, shape)
        in_shardings = _shardings_for(args, cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
        hlo_text = compiled.as_text()
        ana = hlo_analysis.analyze(hlo_text)   # trip-count-expanded
        coll = parse_collectives(hlo_text)     # raw (body-once) census
    if hlo_out:
        with gzip.open(hlo_out, "wt") as f:
            f.write(hlo_text)
    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "nmc_mode": nmc_mode, "tag": extra_tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # NOTE: xla cost_analysis counts while bodies once; the `hlo` block
        # holds the trip-count-expanded numbers used for the roofline.
        "xla_flops_body_once": cost.get("flops", 0.0),
        "xla_bytes_body_once": cost.get("bytes accessed", 0.0),
        "hlo": ana,                            # per-device, expanded
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "collectives_body_once": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": shape.global_batch * (shape.seq_len
                                        if shape.kind != "decode" else 1),
    }
    return rec


def _shardings_for(args, cfg, shape, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def batch_sh(tree):
        return sharding.batch_shardings(tree, mesh)

    if shape.kind == "train":
        params, opt, batch = args
        return (sharding.param_shardings(params, mesh),
                sharding.opt_state_shardings(opt, params, mesh),
                batch_sh(batch))
    if shape.kind == "prefill":
        params, batch = args
        return (sharding.param_shardings(params, mesh), batch_sh(batch))
    params, tokens, caches, cache_len = args
    return (sharding.param_shardings(params, mesh),
            batch_sh(tokens),
            sharding.cache_shardings(caches, mesh, shape.global_batch),
            NamedSharding(mesh, P()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--nmc-mode", default="none",
                    choices=["none", "w8", "w8a8"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in applicable_shapes(get(arch)):
                cells.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    for arch, sh in cells:
        for mp in meshes:
            tag = f"{arch}__{sh}__{'pod2' if mp else 'pod1'}"
            if args.nmc_mode != "none":
                tag += f"__{args.nmc_mode}"
            if args.tag:
                tag += f"__{args.tag}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                hlo_out = os.path.join(args.out, tag + ".hlo.gz") \
                    if args.save_hlo else None
                kw = {"seq_parallel": True} if args.seq_parallel else {}
                if args.remat_policy != "full":
                    kw["remat_policy"] = args.remat_policy
                if args.kv_int8:
                    kw["kv_cache_dtype"] = "int8"
                rec = run_cell(arch, sh, mp, args.nmc_mode, args.tag,
                               hlo_out=hlo_out, **kw)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                ncoll = sum(c["count"] for c in
                            rec["hlo"]["collectives"].values())
                print(f"  ok: {rec['compile_s']}s compile, "
                      f"flops/dev={rec['hlo']['flops']:.3e}, "
                      f"peak={rec['memory']['peak_bytes']/2**30:.2f} GiB, "
                      f"coll_bytes/dev={rec['hlo']['collective_link_bytes']:.3e} "
                      f"({ncoll:.0f} ops)", flush=True)
            except Exception as e:
                print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
                with open(out_path + ".err", "w") as f:
                    f.write(traceback.format_exc())


if __name__ == "__main__":
    main()
