"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, \
        f"need {n} devices, have {len(devices)} — run under dryrun.py " \
        f"(XLA_FLAGS=--xla_force_host_platform_device_count=512)"
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes,
                axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    devices = jax.devices()
    mp = max(1, min(model_parallel, len(devices)))
    dp = len(devices) // mp
    dev = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return Mesh(dev, ("data", "model"),
                axis_types=(AxisType.Auto, AxisType.Auto))