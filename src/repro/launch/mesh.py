"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.

JAX version support: ``jax.sharding.AxisType`` only exists on newer JAX
(>= 0.5); on 0.4.x meshes are built without ``axis_types`` (every axis is
implicitly "auto", which is exactly what ``AxisType.Auto`` requests).
:func:`make_mesh` is the single version-compat constructor — everything in
the repo (and the subprocess test scripts) builds meshes through it.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.sharding
from jax.sharding import Mesh


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,) * n`` when the installed JAX has
    AxisType, else nothing (0.4.x behavior is implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(devices, axes: tuple[str, ...]) -> Mesh:
    """Version-compat Mesh constructor: all axes auto-sharded."""
    devices = np.asarray(devices)
    return Mesh(devices, axes, **_axis_types_kw(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, \
        f"need {n} devices, have {len(devices)} — run under dryrun.py " \
        f"(XLA_FLAGS=--xla_force_host_platform_device_count=512)"
    return make_mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    devices = jax.devices()
    mp = max(1, min(model_parallel, len(devices)))
    dp = len(devices) // mp
    dev = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return make_mesh(dev, ("data", "model"))
