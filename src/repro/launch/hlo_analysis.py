"""Post-optimization HLO text analyzer with while-loop trip-count expansion.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so any scan-over-layers program under-reports FLOPs/bytes/collectives by a
factor of the layer count.  This analyzer parses the optimized HLO text,
builds the computation call graph (fusions, while bodies, conditionals) and
accumulates

  * dot/convolution FLOPs,
  * HBM traffic: operand + result bytes of fusion-BOUNDARY ops (ops inside
    fusion computations stay in registers/VMEM and are not counted),
  * collective link bytes by kind (ring accounting: all-reduce 2x, others
    1x result bytes per device),

multiplying while bodies by their ``known_trip_count`` backend_config
(emitted by XLA when the trip count is static — always true for lax.scan).

Shapes in post-SPMD HLO are per-shard, so all results are PER-DEVICE.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict


def normalize_cost_analysis(cost) -> dict:
    """Version-compat view of ``compiled.cost_analysis()``.

    JAX 0.4.x returns a one-element *list* of dicts (one per computation);
    newer JAX returns the dict directly.  Everything in the repo reads the
    result through this helper so both shapes work (fields like ``"flops"``
    and ``"bytes accessed"`` are then plain ``dict.get`` lookups)."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost) if cost else {}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w\-.]+)\s*\(.*->.*\{\s*$")
_TRIP = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"?(\d+)"?')
_OPLINE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\-.]+\s*=\s*(.*)$")
_KIND = re.compile(r"^(?:\([^)]*\)|(?:[a-z0-9]+\[[0-9,]*\])\S*)\s+"
                   r"([a-z0-9\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "iota",
               "after-all", "partition-id", "replica-id"}


def _bytes_of(text: str) -> int:
    tot = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        tot += n * _DTYPE_BYTES[dt]
    return tot


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\-.]+)\s*=\s*(\([^)]+\)|[a-z0-9]+\[[0-9,]*\])")
_OPERANDS = re.compile(r"%([\w\-.]+)")


def _dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(shape_text: str) -> int:
    n = 1
    for d in _dims(shape_text):
        n *= d
    return n


def _dot_flops(line: str, symtab: dict) -> float:
    m = re.match(r".*?=\s*([a-z0-9]+\[[0-9,]*\])\S*\s+dot\(([^)]*)\)", line)
    if not m:
        return 0.0
    out = _elems(m.group(1))
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    ops = _OPERANDS.findall(m.group(2))
    if cm is None or not ops or ops[0] not in symtab:
        return 0.0
    lhs = _dims(symtab[ops[0]])
    contract = 1
    for ci in cm.group(1).split(","):
        if ci and int(ci) < len(lhs):
            contract *= lhs[int(ci)]
    return 2.0 * out * contract


def _conv_flops(line: str, symtab: dict) -> float:
    m = re.match(r".*?=\s*([a-z0-9]+\[[0-9,]*\])\S*\s+convolution\(([^)]*)\)",
                 line)
    if not m:
        return 0.0
    res = _elems(m.group(1))
    ops = _OPERANDS.findall(m.group(2))
    if len(ops) < 2 or ops[1] not in symtab:
        return 0.0
    return 2.0 * res * max(_elems(symtab[ops[1]]), 1)


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    attn_fallback_bytes: float = 0.0   # ops inside named_scope
                                       # "flashattn_fallback" — replaced by
                                       # the fused Pallas kernel on TPU
    coll: dict = dataclasses.field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "link_bytes": 0.0}))

    def add(self, o: "Totals", mult: float = 1.0):
        self.flops += o.flops * mult
        self.bytes += o.bytes * mult
        self.attn_fallback_bytes += o.attn_fallback_bytes * mult
        for k, v in o.coll.items():
            self.coll[k]["count"] += v["count"] * mult
            self.coll[k]["link_bytes"] += v["link_bytes"] * mult

    @property
    def coll_link_bytes(self):
        return sum(v["link_bytes"] for v in self.coll.values())


def split_computations(hlo: str):
    comps, entry = {}, None
    cur, buf = None, []
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_START.match(line)
            if m and line.rstrip().endswith("{"):
                if m.group(1):
                    entry = m.group(2)
                cur = m.group(2)
                buf = []
        else:
            if line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps, entry


def analyze(hlo: str) -> dict:
    comps, entry = split_computations(hlo)
    if entry is None:
        entry = next((n for n in comps if "main" in n), None) \
            or next(iter(comps))
    memo: dict[tuple, Totals] = {}
    _tagged: dict[str, bool] = {}

    def comp_tagged(name: str) -> bool:
        """A computation counts as attention-fallback if any of its ops
        carries the named_scope tag (fusions erase per-op metadata at the
        call site, so we look inside)."""
        if name not in _tagged:
            _tagged[name] = any("flashattn_fallback" in l
                                for l in comps.get(name, ()))
        return _tagged[name]

    symtabs: dict[str, dict] = {}

    def symtab(name: str) -> dict:
        if name not in symtabs:
            tab = {}
            for line in comps.get(name, ()):
                dm = _DEF_RE.match(line)
                if dm:
                    tab[dm.group(1)] = dm.group(2)
            symtabs[name] = tab
        return symtabs[name]

    def _io_bytes(line: str, body: str, tab: dict) -> int:
        """Operand + result bytes of one op (fusion-boundary HBM traffic).

        In-place ops get realistic accounting instead of full-buffer I/O:
        dynamic-update-slice ~ 2x update bytes; dynamic-slice ~ 2x result;
        scatter ~ 2x updates + indices (XLA executes these in place)."""
        result = _bytes_of(body.split("(")[0])
        am = re.search(r"\(([^)]*)\)", body)
        operands = []
        if am:
            operands = [_bytes_of(tab[op]) for op in
                        _OPERANDS.findall(am.group(1)) if op in tab]
        if "dynamic-update-slice" in line or "dynamic_update_slice" in line:
            small = [b for b in operands if 0 < b < result]
            upd = max(small) if small else min(operands, default=result)
            return 2 * upd
        if "dynamic-slice" in line or "dynamic_slice" in line:
            return 2 * result
        if " scatter(" in body or "scatter-add" in line:
            small = sorted(b for b in operands if b < result) or [result]
            return 2 * small[-1] + sum(small[:-1])
        return result + sum(operands)

    def comp_totals(name: str, in_fusion: bool, stack=()) -> Totals:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return Totals()
        tot = Totals()
        tab = symtab(name)
        for line in comps[name]:
            m = _OPLINE.match(line)
            if not m:
                continue
            body = m.group(1)
            km = _KIND.match(body)
            kind = km.group(1) if km else ""

            if kind == "fusion":
                cm = re.search(r"calls=%?([\w\-.]+)", line)
                if cm:
                    tot.add(comp_totals(cm.group(1), True,
                                        stack + (name,)))
                if not in_fusion:
                    nbytes = _io_bytes(line, body, tab)
                    tot.bytes += nbytes
                    if "flashattn_fallback" in line or \
                            (cm and comp_tagged(cm.group(1))):
                        tot.attn_fallback_bytes += nbytes
                continue
            if kind == "while":
                bm = re.search(r"body=%?([\w\-.]+)", line)
                cm = re.search(r"condition=%?([\w\-.]+)", line)
                tm = _TRIP.search(line)
                mult = float(tm.group(1)) if tm else 1.0
                if bm:
                    tot.add(comp_totals(bm.group(1), False,
                                        stack + (name,)), mult)
                if cm:
                    tot.add(comp_totals(cm.group(1), False,
                                        stack + (name,)), mult + 1)
                continue
            if kind == "conditional":
                for g in re.findall(r"branch_computations=\{([^}]*)\}", line):
                    for cn in g.split(","):
                        tot.add(comp_totals(cn.strip().lstrip("%"),
                                            in_fusion, stack + (name,)))
                continue
            if kind in ("call", "async-start"):
                cm = re.search(r"(?:to_apply|calls)=%?([\w\-.]+)", line)
                if cm:
                    tot.add(comp_totals(cm.group(1), in_fusion,
                                        stack + (name,)))
                continue

            if kind == "dot":
                tot.flops += _dot_flops(line, tab)
            elif kind == "convolution":
                tot.flops += _conv_flops(line, tab)

            coll = next((c for c in COLLECTIVES
                         if kind.startswith(c) and not
                         kind.endswith("-done")), None)
            if coll:
                result = body.split("(")[0]
                nbytes = _bytes_of(result)
                if kind.endswith("-start") and result.startswith("("):
                    nbytes //= 2          # async tuple repeats the buffer
                factor = 2.0 if coll == "all-reduce" else 1.0
                tot.coll[coll]["count"] += 1
                tot.coll[coll]["link_bytes"] += factor * nbytes

            if not in_fusion and kind not in _SKIP_BYTES:
                nbytes = _io_bytes(line, body, tab)
                tot.bytes += nbytes
                if "flashattn_fallback" in line:
                    tot.attn_fallback_bytes += nbytes
        memo[key] = tot
        return tot

    t = comp_totals(entry, False)
    return {
        "flops": t.flops,
        "hbm_bytes": t.bytes,
        "attn_fallback_bytes": t.attn_fallback_bytes,
        "collective_link_bytes": t.coll_link_bytes,
        "collectives": {k: dict(v) for k, v in t.coll.items()},
    }
