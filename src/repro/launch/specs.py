"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
for every (architecture x shape) cell — weak-type-correct, shardable, zero
device allocation."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import step as step_lib


def abstract_params(cfg: ModelConfig, serve: bool = False):
    p = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if serve and cfg.nmc_mode != "none":
        # the paper's technique: serving params are int8-quantized (w_q +
        # per-channel scales), produced once by serve.quantize_params
        from repro.models import layers as L
        p = jax.eval_shape(L.quantize_tree, p)
        return p
    if serve:  # baseline serving runs bf16 weights
        p = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if x.dtype == jnp.float32 else x, p)
    return p


def abstract_opt_state(cfg: ModelConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(adamw.init_state, params)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch / serving inputs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train" or shape.kind == "prefill":
        batch = {}
        s_text = s - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
        batch["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq,
                                                    cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["images"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache_len": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Sliding-window archs keep a ring cache of `window` slots."""
    if cfg.window is not None:
        return min(seq_len, cfg.window)
    return seq_len


def abstract_caches(cfg: ModelConfig, shape: ShapeSpec):
    params = abstract_params(cfg, serve=True)
    return jax.eval_shape(
        lambda: lm.init_caches(params, cfg, shape.global_batch,
                               cache_len_for(cfg, shape.seq_len),
                               dtype=jnp.bfloat16))


def cell_fn_and_inputs(cfg: ModelConfig, shape: ShapeSpec,
                       opt_cfg: adamw.AdamWConfig | None = None):
    """Returns (step_fn, abstract_args (tuple), donate_argnums)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    if shape.kind == "train":
        fn = step_lib.make_train_step(cfg, opt_cfg)
        args = (abstract_params(cfg), abstract_opt_state(cfg),
                input_specs(cfg, shape))
        return fn, args, (0, 1)
    if shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(params, batch, cfg, shape.seq_len)
        return fn, (abstract_params(cfg, serve=True),
                    input_specs(cfg, shape)), ()
    # decode
    def fn(params, tokens, caches, cache_len):
        return lm.decode_step(params, tokens, caches, cache_len, cfg)
    io = input_specs(cfg, shape)
    args = (abstract_params(cfg, serve=True), io["tokens"],
            abstract_caches(cfg, shape), io["cache_len"])
    return fn, args, (2,)
