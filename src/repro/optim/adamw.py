"""AdamW with decoupled weight decay, global-norm clipping and schedules.

Self-contained (no optax): state is a plain pytree {m, v} in f32 ("master"
moments regardless of param dtype) + a scalar step.  The update is fully
jit-compatible and shards trivially — every moment inherits its parameter's
sharding (same tree structure).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    return lr


def init_state(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, grads, state: dict, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
