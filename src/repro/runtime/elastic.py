"""Elastic scaling + node-failure runtime.

On real clusters this sits on top of the coordination service: it watches
device health, and on membership change (i) drains in-flight steps,
(ii) rebuilds the mesh over the surviving/new devices, (iii) restores the
last committed checkpoint *resharded onto the new mesh* (checkpoint/ckpt.py
restores by host array + device_put, so mesh shape changes are free), and
(iv) resumes — the data pipeline is a pure function of step, so no stream
state needs migration.

This container has one device, so the membership watcher is simulated; the
re-mesh + reshard + resume path itself is real and tested
(tests/test_fault_tolerance.py::test_elastic_reshard).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
from jax.sharding import Mesh
import numpy as np

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class ClusterView:
    n_devices: int
    generation: int = 0


class MembershipWatcher:
    """Simulated membership: tests script resize events by step index."""

    def __init__(self, events: Optional[dict[int, int]] = None):
        self.events = events or {}
        self.view = ClusterView(n_devices=len(jax.devices()))

    def poll(self, step: int) -> Optional[ClusterView]:
        if step in self.events:
            self.view = ClusterView(self.events[step],
                                    self.view.generation + 1)
            return self.view
        return None


def make_mesh_for(n_devices: int, model_parallel: int = 1,
                  devices=None) -> Mesh:
    """Best-effort (data, model) mesh over the given device count."""
    devices = devices if devices is not None else jax.devices()[:n_devices]
    mp = min(model_parallel, n_devices)
    while n_devices % mp:
        mp -= 1
    dp = n_devices // mp
    dev = np.asarray(devices).reshape(dp, mp)
    return Mesh(dev, ("data", "model"))


def reshard_state(state, new_mesh: Mesh, spec_fn: Callable):
    """Move a pytree onto a new mesh via host round-trip-free device_put
    (spec_fn: params -> NamedSharding tree for the new mesh)."""
    shardings = spec_fn(state, new_mesh)
    return jax.device_put(state, shardings)


class HeartbeatMonitor:
    """Tracks per-step liveness; at scale this would be fed by the pod
    coordinator.  A missed deadline marks a suspected node failure and
    triggers the trainer's restart path."""

    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self.last_beat: Optional[float] = None
        self.failures: int = 0

    def beat(self, now: float):
        if self.last_beat is not None and \
                now - self.last_beat > self.timeout_s:
            self.failures += 1
            log.warning("heartbeat gap %.1fs > %.1fs: suspected failure",
                        now - self.last_beat, self.timeout_s)
        self.last_beat = now
