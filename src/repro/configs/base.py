"""Config registry: ``--arch <id>`` resolution + assigned input shapes.

Every architecture module registers its full config (exact dims from the
assignment) and a ``smoke`` reduction of the same family for CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from repro.models.config import ModelConfig

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = (
    "zamba2-2.7b", "h2o-danube-1.8b", "qwen1.5-0.5b", "mistral-nemo-12b",
    "phi3-medium-14b", "xlstm-125m", "whisper-tiny", "moonshot-v1-16b-a3b",
    "deepseek-v2-lite-16b", "pixtral-12b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        importlib.import_module(_MODULES[name])
    return (_SMOKE if smoke else _REGISTRY)[name]()


# ---------------------------------------------------------------------------
# Assigned input shapes (identical across LM archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
