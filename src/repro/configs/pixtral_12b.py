"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB: input_specs() provides
precomputed patch embeddings) + mistral-nemo backbone: 40L d_model=5120
32H (kv=8, head_dim=128) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=131072, vlm=True, n_img_tokens=1024, rope_theta=1e9)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
        vlm=True, n_img_tokens=8, remat=False)


base.register("pixtral-12b", full, smoke)
