"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=131072, 128k ctx.  [hf:mistralai/Mistral-Nemo-Base-2407]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=131072, rope_theta=1e6)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemo-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
        remat=False)


base.register("mistral-nemo-12b", full, smoke)
