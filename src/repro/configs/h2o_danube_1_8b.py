"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.  [arXiv:2401.16818; hf]
Sub-quadratic via SWA (window 4096) -> runs long_500k."""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
        n_heads=32, n_kv_heads=8, d_ff=6912, vocab_size=32000,
        window=4096, rope_theta=10000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, window=32,
        remat=False)


base.register("h2o-danube-1.8b", full, smoke)
