"""Architecture configs (one module per assigned arch) + shape registry."""

from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, get

__all__ = ["ARCH_IDS", "SHAPES", "applicable_shapes", "get"]
