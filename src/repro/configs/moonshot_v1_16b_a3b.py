"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=163840,
        moe=True, n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        capacity_factor=1.25, rope_theta=50000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=256, moe=True,
        n_experts=8, top_k=2, moe_d_ff=96, n_shared_experts=2,
        capacity_factor=2.0, remat=False)


base.register("moonshot-v1-16b-a3b", full, smoke)
