"""xlstm-125m [ssm]: 12L d_model=768 4H vocab=50304 — sLSTM + mLSTM blocks
(sLSTM at layers 3 and 9, others mLSTM; ~[7:1] mix of arXiv:2405.04517)."""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="xlstm", n_layers=12, d_model=768,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
        slstm_layers=(3, 9), ssm_chunk=128)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
        slstm_layers=(1,), ssm_chunk=16, remat=False)


base.register("xlstm-125m", full, smoke)
