"""whisper-tiny [audio]: enc-dec, 4+4L d_model=384 6H d_ff=1536 vocab=51865.
Conv frontend is a STUB per assignment: input_specs() provides precomputed
frame embeddings (B, enc_seq, d_model).  [arXiv:2212.04356]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec", n_layers=4, n_enc_layers=4,
        d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
        encdec=True, learned_pos=True, enc_seq=1500, act="gelu")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec", n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        encdec=True, learned_pos=True, enc_seq=32, act="gelu", remat=False)


base.register("whisper-tiny", full, smoke)
