"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
MoE: 2 shared + 64 routed experts top-6, expert d_ff=1408, vocab=102400,
first layer dense (d_ff=10944).  [arXiv:2405.04434; hf]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=102400,
        mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128, moe=True, n_experts=64, top_k=6, moe_d_ff=1408,
        n_shared_experts=2, first_dense_layers=1, dense_d_ff=10944,
        capacity_factor=1.25, rope_theta=10000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=256, mla=True,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        moe=True, n_experts=8, top_k=2, moe_d_ff=96, n_shared_experts=2,
        first_dense_layers=1, dense_d_ff=128, capacity_factor=2.0,
        remat=False)


base.register("deepseek-v2-lite-16b", full, smoke)
