"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE SwiGLU GQA.  [arXiv:2404.14219]"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab_size=100352,
        rope_theta=10000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", family="dense", n_layers=2, d_model=80,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=256, remat=False)


base.register("phi3-medium-14b", full, smoke)
