"""zamba2-2.7b [hybrid]: 54 Mamba2 layers + a shared attention/MLP block
applied every 6 layers.  54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  [arXiv:2411.15242; hf]

The shared block uses a 4096 sliding window (Zamba2's training context),
which also keeps the arch sub-quadratic for long_500k (DESIGN.md §4).
"""

from repro.configs import base
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
        attn_every=6, window=4096, rope_theta=10000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16, attn_every=2, window=64, remat=False)


base.register("zamba2-2.7b", full, smoke)
