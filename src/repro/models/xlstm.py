"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential scan), following Beck et al. 2024 (arXiv:2405.04517).

mLSTM trains with a chunkwise formulation (intra-chunk quadratic attention
with log-gate decays + inter-chunk state recurrence — the same SSD shape as
Mamba2, MXU-friendly).  sLSTM is inherently sequential (its recurrent gate
input breaks parallelization) and runs as a ``lax.scan`` over time.  Both use
exponential gating with the max-stabilizer state m_t.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = int(d * cfg.proj_factor_mlstm)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": L.rmsnorm_init(d),
        "up": L.linear_init(ks[0], d, di),
        "up_gate": L.linear_init(ks[1], d, di),
        "wq": L.linear_init(ks[2], di, di),
        "wk": L.linear_init(ks[3], di, di),
        "wv": L.linear_init(ks[4], di, di),
        "wi": L.linear_init(ks[5], di, h),        # input gate (per head)
        "wf": L.linear_init(ks[6], di, h),        # forget gate (per head)
        "out_norm": L.rmsnorm_init(di),
        "down": L.linear_init(ks[7], di, d),
    }


def _mlstm_core_chunked(q, k, v, log_i, log_f, chunk: int):
    """q,k,v: (B,S,H,P); log_i/log_f: (B,S,H).  Stabilized chunkwise mLSTM.
    Returns y (B,S,H,P) and final (C, n, m) state."""
    B_, S, H, P = q.shape
    nc = max(S // chunk, 1)
    qc = q.reshape(B_, nc, chunk, H, P)
    kc = k.reshape(B_, nc, chunk, H, P) / np.sqrt(P)
    vc = v.reshape(B_, nc, chunk, H, P)
    li = log_i.reshape(B_, nc, chunk, H).astype(jnp.float32)
    lf = log_f.reshape(B_, nc, chunk, H).astype(jnp.float32)

    cum_f = jnp.cumsum(lf, axis=2)                    # (B,nc,q,H)
    total_f = cum_f[:, :, -1, :]                      # (B,nc,H)

    # intra-chunk log weights: D[i,j] = (cum_f_i - cum_f_j) + li_j, j <= i
    # (decay from j to i is sum_{l=j+1..i} lf_l = cum_f_i - cum_f_j)
    dmat = cum_f[:, :, :, None, :] - cum_f[:, :, None, :, :] \
        + li[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(causal[None, None, :, :, None], dmat, -jnp.inf)

    def scan_fn(carry, inp):
        (C, n, m) = carry                             # (B,H,P,P),(B,H,P),(B,H)
        qb, kb, vb, lib, cfb, tfb, db = inp
        # stabilizer for this chunk: running m
        a_j = tfb[:, None, :] - cfb + lib             # (B,q,H) contribution lw
        m_new = jnp.maximum(tfb + m, jnp.max(a_j, axis=1))      # (B,H)
        # inter contribution to outputs: logits_i = cum_f_i + m - m_ref
        inter_w = jnp.exp(cfb + m[:, None, :] - m_new[:, None, :])
        y_inter = jnp.einsum("bqhp,bhpo,bqh->bqho", qb, C, inter_w)
        n_inter = jnp.einsum("bqhp,bhp,bqh->bqh", qb, n, inter_w)
        # intra contribution (stabilized by m_new)
        w_intra = jnp.exp(db - m_new[:, None, None, :])         # (B,q,q,H)
        s = jnp.einsum("bqhp,bjhp->bqjh", qb, kb)
        y_intra = jnp.einsum("bqjh,bqjh,bjhp->bqhp", s, w_intra, vb)
        n_intra = jnp.einsum("bqjh,bqjh->bqh", s, w_intra)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra),
                            jnp.exp(-m_new)[:, None, :]) + 1e-6
        y = (y_inter + y_intra) / denom[..., None]
        # state update to chunk end
        upd_w = jnp.exp(a_j - m_new[:, None, :])                # (B,q,H)
        C_new = C * jnp.exp(tfb + m - m_new)[:, :, None, None] + \
            jnp.einsum("bqh,bqhp,bqho->bhpo", upd_w, kb, vb)
        n_new = n * jnp.exp(tfb + m - m_new)[:, :, None] + \
            jnp.einsum("bqh,bqhp->bhp", upd_w, kb)
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B_, H, P, P), jnp.float32)
    n0 = jnp.zeros((B_, H, P), jnp.float32)
    m0 = jnp.full((B_, H), -1e30, jnp.float32)
    (C, n, m), ys = jax.lax.scan(
        scan_fn, (C0, n0, m0),
        (qc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         kc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         vc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         li.transpose(1, 0, 2, 3), cum_f.transpose(1, 0, 2, 3),
         total_f.transpose(1, 0, 2), dmat.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, S, H, P)
    return y.astype(q.dtype), (C, n, m)


def mlstm_apply(p, x, cfg: ModelConfig, state=None, return_state=False):
    """x: (B,S,D).  state (decode): (C, n, m)."""
    b_, s, d = x.shape
    di = int(d * cfg.proj_factor_mlstm)
    h = cfg.n_heads
    pdim = di // h
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    u = L.linear(p["up"], xn, nmc_mode=cfg.nmc_mode)
    g = L.linear(p["up_gate"], xn, nmc_mode=cfg.nmc_mode, act="silu")
    q = L.linear(p["wq"], u, nmc_mode=cfg.nmc_mode).reshape(b_, s, h, pdim)
    k = L.linear(p["wk"], u, nmc_mode=cfg.nmc_mode).reshape(b_, s, h, pdim)
    v = L.linear(p["wv"], u, nmc_mode=cfg.nmc_mode).reshape(b_, s, h, pdim)
    log_i = L.linear(p["wi"], u).astype(jnp.float32)          # (B,S,H)
    log_f = jax.nn.log_sigmoid(L.linear(p["wf"], u).astype(jnp.float32))

    if state is not None:                       # decode: single step
        y, new_state = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   log_i[:, 0], log_f[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = _mlstm_core_chunked(q, k, v, log_i, log_f,
                                           min(cfg.ssm_chunk or 64, s))
    y = y.reshape(b_, s if state is None else 1, di)
    y = L.rmsnorm(p["out_norm"], y, cfg.norm_eps) * g
    out = x + L.linear(p["down"], y, nmc_mode=cfg.nmc_mode)
    if return_state or state is not None:
        return out, new_state
    return out


def _mlstm_step(q, k, v, log_i, log_f, state):
    """Single-token mLSTM update.  q/k/v: (B,H,P); gates: (B,H)."""
    C, n, m = state
    pdim = q.shape[-1]
    kf = k.astype(jnp.float32) / np.sqrt(pdim)
    m_new = jnp.maximum(log_f + m, log_i)
    C_new = C * jnp.exp(log_f + m - m_new)[..., None, None] + \
        jnp.exp(log_i - m_new)[..., None, None] * \
        jnp.einsum("bhp,bho->bhpo", kf, v.astype(jnp.float32))
    n_new = n * jnp.exp(log_f + m - m_new)[..., None] + \
        jnp.exp(log_i - m_new)[..., None] * kf
    num = jnp.einsum("bhp,bhpo->bho", q.astype(jnp.float32), C_new)
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", q.astype(jnp.float32), n_new))
    den = jnp.maximum(den, jnp.exp(-m_new)) + 1e-6
    y = (num / den[..., None]).astype(q.dtype)
    return y, (C_new, n_new, m_new)


def mlstm_state_init(cfg: ModelConfig, batch: int):
    di = int(cfg.d_model * cfg.proj_factor_mlstm)
    h = cfg.n_heads
    pdim = di // h
    return (jnp.zeros((batch, h, pdim, pdim), jnp.float32),
            jnp.zeros((batch, h, pdim), jnp.float32),
            jnp.full((batch, h), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    df = int(d * cfg.proj_factor_slstm)
    ks = jax.random.split(key, 10)
    p = {"norm": L.rmsnorm_init(d),
         "ffn_norm": L.rmsnorm_init(d),
         "up": L.linear_init(ks[8], d, 2 * df),
         "down": L.linear_init(ks[9], df, d)}
    for i, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = L.linear_init(ks[i], d, d)
        # block-diagonal recurrent weights: (H, P, P)
        h = cfg.n_heads
        pdim = d // h
        p[f"r_{gate}"] = 0.1 * jax.random.normal(ks[4 + i], (h, pdim, pdim),
                                                 jnp.float32)
    return p


def slstm_apply(p, x, cfg: ModelConfig, state=None, return_state=False):
    """x: (B,S,D); sequential scan over time (sLSTM is not parallelizable —
    its recurrent gate input depends on h_{t-1})."""
    b_, s, d = x.shape
    h = cfg.n_heads
    pdim = d // h
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    zx = L.linear(p["w_z"], xn).astype(jnp.float32)
    ix = L.linear(p["w_i"], xn).astype(jnp.float32)
    fx = L.linear(p["w_f"], xn).astype(jnp.float32)
    ox = L.linear(p["w_o"], xn).astype(jnp.float32)

    def gate_rec(r, hprev):                       # (H,P,P) x (B,H,P)
        return jnp.einsum("hpo,bhp->bho", r, hprev)

    def step(carry, inp):
        c, n, m, hprev = carry
        zxt, ixt, fxt, oxt = inp                  # (B,D) each
        hp = hprev.reshape(b_, h, pdim)
        z = jnp.tanh(zxt + gate_rec(p["r_z"], hp).reshape(b_, d))
        i_raw = ixt + gate_rec(p["r_i"], hp).reshape(b_, d)
        f_raw = fxt + gate_rec(p["r_f"], hp).reshape(b_, d)
        o = jax.nn.sigmoid(oxt + gate_rec(p["r_o"], hp).reshape(b_, d))
        log_f = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(log_f + m, i_raw)
        c_new = c * jnp.exp(log_f + m - m_new) + jnp.exp(i_raw - m_new) * z
        n_new = n * jnp.exp(log_f + m - m_new) + jnp.exp(i_raw - m_new)
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    if state is None:
        state = slstm_state_init(cfg, b_)
    (c, n, m, hl), hs = jax.lax.scan(
        step, state, (zx.transpose(1, 0, 2), ix.transpose(1, 0, 2),
                      fx.transpose(1, 0, 2), ox.transpose(1, 0, 2)))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    out = x + y
    # post-FFN (GEGLU-style up/down)
    xf = L.rmsnorm(p["ffn_norm"], out, cfg.norm_eps)
    u = L.linear(p["up"], xf, nmc_mode=cfg.nmc_mode)
    df = u.shape[-1] // 2
    out = out + L.linear(p["down"],
                         jax.nn.gelu(u[..., :df]) * u[..., df:],
                         nmc_mode=cfg.nmc_mode)
    if return_state:
        return out, (c, n, m, hl)
    return out


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.full((batch, d), -1e30, jnp.float32),
            jnp.zeros((batch, d), jnp.float32))
