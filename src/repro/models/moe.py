"""Mixture-of-Experts with expert parallelism over the `model` mesh axis.

Design (DESIGN.md §8): activations enter the MoE block replicated across the
`model` axis (the attention output all-reduce already paid for that), and
each model shard owns E / model_size experts.  Dispatch is therefore fully
local — a capacity-bounded scatter into an (E_local, cap, d) buffer — and the
only collective is one psum over `model` to combine expert outputs: the same
collective a dense TP FFN needs.  No all_to_all, no GSPMD-surprising gathers,
deterministic HLO.  (A reduce-scatter + all2all variant is evaluated in the
§Perf hillclimb.)

Runs inside ``context.shard_map`` (the version-compat wrapper over
``jax.shard_map`` / ``jax.experimental.shard_map``) when a mesh is active;
degrades to a
single-shard call otherwise (unit tests).  Capacity-dropped tokens fall back
to zero contribution from routed experts (shared experts still apply),
standard top-k capacity semantics.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import context
from repro.models import layers as L
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    p = {
        "router": L.linear_init(ks[0], d, e),
        "wi": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "wg": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], (e, f, d), jnp.float32) / jnp.sqrt(
            jnp.float32(f)),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d,
                                 cfg.moe_d_ff * cfg.n_shared_experts, "silu")
    return p


def _expert_ffn(buf, wi, wg, wo, dtype):
    """buf: (E_loc, cap, d) -> (E_loc, cap, d); SwiGLU per expert."""
    h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dtype))
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype)))
    return jnp.einsum("ecf,efd->ecd", h * g, wo.astype(dtype))


def _moe_local(x, wr, wi, wg, wo, *, cfg: ModelConfig, axis: Optional[str]):
    """Token dispatch + expert FFN on one shard.  x: (t, d) local tokens
    (replicated over `axis`); wi/wg/wo: (E_local, ...) local expert slice."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = wi.shape[0]
    cap = max(int(t * k * cfg.capacity_factor / e), 1)
    dtype = x.dtype

    logits = (x.astype(jnp.float32) @ wr.astype(jnp.float32))     # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (t, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # aux load-balancing loss (Switch-style), computed on local tokens
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))

    flat_e = top_e.reshape(-1)                                    # (t*k,)
    flat_p = top_p.reshape(-1).astype(dtype)
    tok_ix = jnp.repeat(jnp.arange(t), k)

    shard = 0 if axis is None else jax.lax.axis_index(axis)
    e0 = shard * e_loc
    local = (flat_e >= e0) & (flat_e < e0 + e_loc)
    le = jnp.clip(flat_e - e0, 0, e_loc - 1)

    # position of each assignment within its expert's capacity buffer
    onehot = jax.nn.one_hot(jnp.where(local, le, e_loc), e_loc + 1,
                            dtype=jnp.int32)                      # (t*k, E+1)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.sum(pos * onehot, axis=-1)                         # (t*k,)
    keep = local & (slot < cap)
    slot_c = jnp.clip(slot, 0, cap - 1)

    buf = jnp.zeros((e_loc, cap, d), dtype)
    buf = buf.at[le, slot_c].add(
        jnp.where(keep, 1.0, 0.0).astype(dtype)[:, None] * x[tok_ix])

    out_buf = _expert_ffn(buf, wi, wg, wo, dtype)                 # (E,cap,d)

    contrib = out_buf[le, slot_c] * jnp.where(keep, flat_p, 0.0)[:, None]
    y = jnp.zeros((t, d), dtype).at[tok_ix].add(contrib)
    if axis is not None:
        y = jax.lax.psum(y, axis)
        aux = jax.lax.pmean(aux, axis)
    return y, aux


def _expert_weights(p, dtype):
    """Dense or NMC-quantized (w8) expert banks -> bf16 compute form.
    int8 banks halve expert HBM bytes — the dominant weights in MoE decode."""
    if "wi_q" in p:
        return tuple((p[f"{k}_q"].astype(dtype)
                      * p[f"{k}_s"].astype(dtype)[..., None, :])
                     for k in ("wi", "wg", "wo"))
    return p["wi"], p["wg"], p["wo"]


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss).  Routed experts (EP over `model`) +
    shared experts (plain TP MLP, computed once)."""
    b, s, d = x.shape
    mesh = context.get_mesh()
    x2 = x.reshape(-1, d)
    quant = "wi_q" in p
    wkeys = (("wi_q", "wi_s", "wg_q", "wg_s", "wo_q", "wo_s") if quant
             else ("wi", "wg", "wo"))
    wargs = [p[k] for k in wkeys]
    rw = p["router"].get("w")
    if rw is None:   # quantized router
        rw = (p["router"]["w_q"].astype(x.dtype)
              * p["router"]["scale"].astype(x.dtype)[None, :])

    def local_fn(xx, wr, *ws):
        if quant:
            pw = {k: v for k, v in zip(wkeys, ws)}
            wi, wg, wo = _expert_weights(pw, xx.dtype)
        else:
            wi, wg, wo = ws
        axis = context.MODEL_AXIS if mesh is not None and \
            context.has_model_axis() else None
        return _moe_local(xx, wr, wi, wg, wo, cfg=cfg, axis=axis)

    if mesh is not None and context.has_model_axis():
        dax = context.data_axes()
        espec = [P(context.MODEL_AXIS, *([None] * (w.ndim - 1)))
                 for w in wargs]
        y2, aux = context.shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(dax if dax else None, None), P(None, None),
                      *espec),
            out_specs=(P(dax if dax else None, None), P()),
        )(x2, rw, *wargs)
    else:
        y2, aux = local_fn(x2, rw, *wargs)

    y = y2.reshape(b, s, d)
    if "shared" in p:
        y = y + L.mlp(p["shared"], x, nmc_mode=cfg.nmc_mode)
    return y, aux.astype(jnp.float32)
