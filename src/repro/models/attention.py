"""Attention layers: GQA (RoPE, optional bias/sliding-window) and MLA.

Three execution modes, shared across all transformer families:
  * train/prefill: blocked flash attention (Pallas on TPU, chunked-lax
    fallback — never materializes S x S);
  * decode: one-token attention against a donated KV cache;
  * MLA keeps the *compressed* (kv_lora + rope) cache and uses the absorbed
    formulation for decode — the cache stays (S, kv_lora+rope_dim) per token
    instead of (S, H * (nope+v)), DeepSeek-V2's core memory win.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": L.linear_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": L.linear_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": L.linear_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": L.linear_init(ks[3], cfg.n_heads * hd, d),
    }


def gqa_qkv(p, x, cfg: ModelConfig, positions):
    """x: (B, S, D) -> q (B,H,S,hd), k/v (B,KV,S,hd), rope applied."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = L.linear(p["wq"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, s, cfg.n_heads, hd)
    k = L.linear(p["wk"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, s, cfg.n_kv_heads, hd)
    if not cfg.learned_pos:
        cos, sin = L.rope_table(positions, hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def gqa_apply(p, x, cfg: ModelConfig, *, causal=True, q_offset=0,
              kv=None) -> jax.Array:
    """Train/prefill path.  `kv` overrides K/V (cross-attention)."""
    b, s, _ = x.shape
    positions = jnp.arange(s) + q_offset
    q, k, v = gqa_qkv(p, x, cfg, positions)
    if kv is not None:
        k, v = kv
    o = kops.attention(q, k, v, causal=causal, window=cfg.window,
                       q_offset=q_offset)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return L.linear(p["wo"], o, nmc_mode=cfg.nmc_mode)


def gqa_decode(p, x, cfg: ModelConfig, cache: dict, cache_len) -> tuple:
    """One-token decode.  cache: {"k","v"}: (B, KV, S_cache, hd); cache_len
    (B,) absolute lengths.  Sliding-window archs use a RING cache with
    S_cache == window: slots hold the last `window` tokens (insertion at
    (len-1) mod S_cache; softmax is permutation-invariant so slot order is
    irrelevant, and RoPE is applied with absolute positions before insert).
    Returns (out (B,1,D), new cache)."""
    b = x.shape[0]
    positions = cache_len[:, None] - 1 + jnp.zeros((b, 1), jnp.int32)
    q = L.linear(p["wq"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, 1, cfg.n_heads, cfg.head_dim)
    k = L.linear(p["wk"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = L.linear(p["wv"], x, nmc_mode=cfg.nmc_mode).reshape(
        b, 1, cfg.n_kv_heads, cfg.head_dim)
    if not cfg.learned_pos:
        cos, sin = L.rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = L.apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k = L.apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    q = q.transpose(0, 2, 1, 3)
    s_cache = cache["k"].shape[2]
    ring = cfg.window is not None and s_cache <= cfg.window
    idx = (cache_len - 1) % s_cache                         # (B,)
    new_cache = {}
    if "k_s" in cache:                 # int8 quantized cache
        kq, ks = _quant_kv(k.transpose(0, 2, 1, 3))
        vq, vs = _quant_kv(v.transpose(0, 2, 1, 3))
        new_cache["k"] = _cache_insert(cache["k"], kq, idx)
        new_cache["v"] = _cache_insert(cache["v"], vq, idx)
        new_cache["k_s"] = _cache_insert(cache["k_s"], ks, idx)
        new_cache["v_s"] = _cache_insert(cache["v_s"], vs, idx)
        kc = _dequant_kv(new_cache["k"], new_cache["k_s"], x.dtype)
        vc = _dequant_kv(new_cache["v"], new_cache["v_s"], x.dtype)
    else:
        kc = _cache_insert(cache["k"], k.transpose(0, 2, 1, 3), idx)
        vc = _cache_insert(cache["v"], v.transpose(0, 2, 1, 3), idx)
        new_cache = {"k": kc, "v": vc}
    if ring:
        # every resident slot is within the window; mask only warmup slots
        o = kops.decode_attention(q, kc, vc,
                                  jnp.minimum(cache_len, s_cache),
                                  window=None)
    else:
        o = kops.decode_attention(q, kc, vc, cache_len, window=cfg.window)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    out = L.linear(p["wo"], o, nmc_mode=cfg.nmc_mode)
    return out, new_cache


def _cache_insert(cache, new, idx):
    """cache (B,H,S,d) <- new (B,H,1,d) at per-batch position idx (B,)."""
    b, h, s, d = cache.shape
    oh = jax.nn.one_hot(idx, s, dtype=cache.dtype)          # (B, S)
    return cache * (1 - oh[:, None, :, None]) + \
        new.astype(cache.dtype) * oh[:, None, :, None]


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        # beyond-paper NMC extension: quantized decode state.  Per-token
        # per-head scales; cache bytes halve vs bf16 (scales are hd x smaller)
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.bfloat16),
                "v_s": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quant_kv(x):
    """(..., hd) -> int8 values + (..., 1) scale (symmetric per token/head)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s.astype(jnp.bfloat16)


def _dequant_kv(q, s, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * s.astype(jnp.float32)).astype(dtype)


def gqa_prefill(p, x, cfg: ModelConfig, max_len: int) -> tuple:
    """Prefill: full attention over the prompt AND build the cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = gqa_qkv(p, x, cfg, positions)
    o = kops.attention(q, k, v, causal=True, window=cfg.window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = L.linear(p["wo"], o, nmc_mode=cfg.nmc_mode)
    pad = max_len - s
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_kv(kp)
        vq, vs = _quant_kv(vp)
        return out, {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
    cache = {"k": kp.astype(x.dtype), "v": vp.astype(x.dtype)}
    return out, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    return {
        "wq": L.linear_init(ks[0], d, h * (dn + dr)),
        "w_dkv": L.linear_init(ks[1], d, r),            # compress
        "w_krope": L.linear_init(ks[2], d, dr),         # shared rope key
        "w_uk": L.linear_init(ks[3], r, h * dn),        # decompress K
        "w_uv": L.linear_init(ks[4], r, h * dv),        # decompress V
        "wo": L.linear_init(ks[5], h * dv, d),
        "norm_ckv": L.rmsnorm_init(r),
    }


def mla_apply(p, x, cfg: ModelConfig, *, q_offset=0) -> jax.Array:
    """Train/prefill: expanded (flash-compatible) formulation."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.arange(s) + q_offset
    cos, sin = L.rope_table(positions, dr, cfg.rope_theta)

    q = L.linear(p["wq"], x, nmc_mode=cfg.nmc_mode).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, cos, sin)

    ckv = L.rmsnorm(p["norm_ckv"],
                    L.linear(p["w_dkv"], x, nmc_mode=cfg.nmc_mode))
    k_nope = L.linear(p["w_uk"], ckv, nmc_mode=cfg.nmc_mode).reshape(
        b, s, h, dn)
    v = L.linear(p["w_uv"], ckv, nmc_mode=cfg.nmc_mode).reshape(b, s, h, dv)
    k_rope = L.apply_rope(
        L.linear(p["w_krope"], x, nmc_mode=cfg.nmc_mode)[:, :, None, :],
        cos, sin)
    k_rope = jnp.broadcast_to(k_rope, (b, s, h, dr))

    q_full = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k_full = jnp.concatenate([k_nope, k_rope], -1).transpose(0, 2, 1, 3)
    v_t = v.transpose(0, 2, 1, 3)
    o = kops.attention(q_full, k_full, v_t, causal=True, q_offset=q_offset)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    return L.linear(p["wo"], o, nmc_mode=cfg.nmc_mode)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}


def mla_prefill(p, x, cfg: ModelConfig, max_len: int) -> tuple:
    b, s, _ = x.shape
    out = mla_apply(p, x, cfg)
    positions = jnp.arange(s)
    cos, sin = L.rope_table(positions, cfg.qk_rope_dim, cfg.rope_theta)
    ckv = L.rmsnorm(p["norm_ckv"],
                    L.linear(p["w_dkv"], x, nmc_mode=cfg.nmc_mode))
    krope = L.apply_rope(
        L.linear(p["w_krope"], x, nmc_mode=cfg.nmc_mode)[:, :, None, :],
        cos, sin)[:, :, 0, :]
    pad = max_len - s
    cache = {
        "ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))).astype(x.dtype),
        "krope": jnp.pad(krope, ((0, 0), (0, pad), (0, 0))).astype(x.dtype),
    }
    return out, cache


def mla_decode(p, x, cfg: ModelConfig, cache: dict, cache_len) -> tuple:
    """Absorbed decode: attention runs in the compressed latent space —
    per-token cache cost is kv_lora_rank + rope_dim, not H*(nope+v)."""
    b = x.shape[0]
    h = cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    positions = (cache_len - 1)[:, None]
    cos, sin = L.rope_table(positions, dr, cfg.rope_theta)

    q = L.linear(p["wq"], x, nmc_mode=cfg.nmc_mode).reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, cos[:, :, None, :], sin[:, :, None, :])

    ckv_new = L.rmsnorm(p["norm_ckv"],
                        L.linear(p["w_dkv"], x, nmc_mode=cfg.nmc_mode))
    krope_new = L.apply_rope(
        L.linear(p["w_krope"], x, nmc_mode=cfg.nmc_mode)[:, :, None, :],
        cos[:, :, None, :], sin[:, :, None, :])[:, :, 0, :]

    idx = cache_len - 1
    oh = jax.nn.one_hot(idx, cache["ckv"].shape[1], dtype=cache["ckv"].dtype)
    ckv_c = cache["ckv"] * (1 - oh[..., None]) + \
        ckv_new.astype(cache["ckv"].dtype) * oh[..., None]
    krope_c = cache["krope"] * (1 - oh[..., None]) + \
        krope_new.astype(cache["krope"].dtype) * oh[..., None]

    # absorb W_uk into q: q_lat (B,H,r) = q_nope @ W_uk(per head)
    w_uk = p["w_uk"]["w"].reshape(r, h, dn) if "w" in p["w_uk"] else (
        p["w_uk"]["w_q"].astype(jnp.float32)
        * p["w_uk"]["scale"][None, :]).reshape(r, h, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(dn + dr)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat,
                         ckv_c.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                           krope_c.astype(jnp.float32))) * scale
    mask = jnp.arange(ckv_c.shape[1])[None, :] < cache_len[:, None]
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, ckv_c.astype(jnp.float32))
    w_uv = p["w_uv"]["w"].reshape(r, h, dv) if "w" in p["w_uv"] else (
        p["w_uv"]["w_q"].astype(jnp.float32)
        * p["w_uv"]["scale"][None, :]).reshape(r, h, dv)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    out = L.linear(p["wo"], o, nmc_mode=cfg.nmc_mode)
    return out, {"ckv": ckv_c, "krope": krope_c}
