"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int | None = None      # sliding-window attention
    learned_pos: bool = False      # learned absolute positions (whisper)

    # MLA (DeepSeek)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0
    dense_d_ff: int = 0            # d_ff of the first dense layers

    # SSM (Mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0            # hybrid: shared attention block period

    # xLSTM
    slstm_layers: tuple = ()       # layer indices running sLSTM (others mLSTM)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0

    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500

    # VLM (pixtral)
    vlm: bool = False
    n_img_tokens: int = 1024

    act: str = "silu"
    norm_eps: float = 1e-5

    # the paper's technique as a framework feature: quantized near-memory
    # execution of projections (none | w8 | w8a8)
    nmc_mode: str = "none"
    # beyond-paper extension of the same idea to decode state: int8 KV cache
    # with per-token-per-head scales (bf16 | int8)
    kv_cache_dtype: str = "bf16"

    dtype: Any = jnp.bfloat16

    # distribution / training knobs
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs — trades
                                 # recompute flops/traffic for residency)
    scan_layers: bool = True
    seq_parallel: bool = False   # Megatron-SP residual stream: x sharded on
                                 # sequence over `model` between blocks
                                 # (§Perf hillclimb; shards norm/elementwise
                                 # traffic 1/TP at equal collective bytes)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return (self.family in ("hybrid", "xlstm")
                or self.window is not None)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS in §Roofline)."""
        d, v = self.d_model, self.vocab_size
        n = v * d * 2                               # embed + head
        hd = self.head_dim
        if self.family == "xlstm":
            for i in range(self.n_layers):
                if i in self.slstm_layers:
                    di = int(self.d_model * self.proj_factor_slstm)
                    n += 4 * d * d + 2 * d * di     # r/z/i/f gates + up/down
                else:
                    di = int(self.d_model * self.proj_factor_mlstm)
                    n += 2 * d * di + 3 * di * di + di * d  # up/gate + qkv + down
            return n
        if self.family == "hybrid":
            di = self.d_inner
            per_mamba = d * (2 * di) + di * d + di * (2 * self.ssm_state) \
                + di  # in/out proj + BC proj + dt
            n += self.n_layers * per_mamba
            n_attn_blocks = 1  # shared weights
            n += n_attn_blocks * (d * (self.n_heads + 2 * self.n_kv_heads) * hd
                                  + self.n_heads * hd * d + 3 * d * self.d_ff)
            return n
        # attention
        if self.mla:
            per_attn = (d * self.kv_lora_rank + d * self.qk_rope_dim
                        + self.kv_lora_rank * self.n_heads
                        * (self.qk_nope_dim + self.v_head_dim)
                        + d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                        + self.n_heads * self.v_head_dim * d)
        else:
            per_attn = (d * (self.n_heads + 2 * self.n_kv_heads) * hd
                        + self.n_heads * hd * d)
        n_dec = self.n_layers
        if self.moe:
            per_ffn_moe = 3 * d * self.moe_d_ff * (self.n_experts
                                                   + self.n_shared_experts) \
                + d * self.n_experts
            n_moe = self.n_layers - self.first_dense_layers
            n += n_moe * (per_attn + per_ffn_moe)
            n += self.first_dense_layers * (per_attn + 3 * d *
                                            (self.dense_d_ff or self.d_ff))
            return n
        per_ffn = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        n += n_dec * (per_attn + per_ffn)
        if self.encdec:
            n += self.n_enc_layers * (per_attn + per_ffn)
            n += self.n_layers * per_attn          # cross-attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = 3 * d * self.moe_d_ff * self.n_experts \
            * (self.n_layers - self.first_dense_layers)
        active = 3 * d * self.moe_d_ff * self.top_k \
            * (self.n_layers - self.first_dense_layers)
        return full - all_experts + active
