"""Mamba2 (SSD) blocks — the MXU-friendly chunked matmul formulation.

Train/prefill run the chunked *state-space dual* algorithm: within-chunk
work is batched matmuls (quadratic in the chunk length only), across-chunk
state is a short ``lax.scan`` — this is the TPU-native adaptation of the
Mamba2 scan (no sequential per-token work, MXU-dominated).  Decode is the
O(1) recurrent update against a donated (B, H, P, N) state.

Tensor-parallel layout (DESIGN.md): every head owns an independent state
slice — the same bank-per-lane independence NM-Carus exploits (Fig. 6).
The z/x projections are column-sharded over `model` (heads local to shard),
B/C/dt are small and replicated, the out-projection is row-sharded with one
psum.  Projections are kept as separate linears so each shards cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def mamba2_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 9)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "in_z": L.linear_init(ks[0], d, di),
        "in_x": L.linear_init(ks[1], d, di),
        "in_b": L.linear_init(ks[2], d, n),
        "in_c": L.linear_init(ks[3], d, n),
        "in_dt": L.linear_init(ks[4], d, h),
        "conv_x": {"w": 0.1 * jax.random.normal(ks[5], (cfg.ssm_conv, di),
                                                jnp.float32),
                   "b": jnp.zeros((di,), jnp.float32)},
        "conv_bc": {"w": 0.1 * jax.random.normal(ks[6], (cfg.ssm_conv, 2 * n),
                                                 jnp.float32),
                    "b": jnp.zeros((2 * n,), jnp.float32)},
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[7], (h,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))),
        "norm": L.rmsnorm_init(di),
        "out_proj": L.linear_init(ks[8], di, d),
    }


def _proj(p, x, cfg: ModelConfig):
    nmc = cfg.nmc_mode
    z = L.shard_hidden(L.linear(p["in_z"], x, nmc_mode=nmc))
    xs = L.shard_hidden(L.linear(p["in_x"], x, nmc_mode=nmc))
    b = L.linear(p["in_b"], x, nmc_mode=nmc)
    c = L.linear(p["in_c"], x, nmc_mode=nmc)
    dt = L.linear(p["in_dt"], x, nmc_mode=nmc)
    return z, xs, b, c, dt


def _conv_full(cp, u: jax.Array, k: int) -> jax.Array:
    """Causal depthwise conv over (B, S, C), silu."""
    b, s, c = u.shape
    w = cp["w"].astype(u.dtype)                 # (k, C)
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i:i + s, :] * w[i]
    return jax.nn.silu(out + cp["b"].astype(u.dtype))


def _conv_step(cp, window: jax.Array) -> jax.Array:
    """window: (B, k, C) -> (B, 1, C)."""
    w = cp["w"].astype(window.dtype)
    return jax.nn.silu((window * w[None]).sum(axis=1, keepdims=True)
                       + cp["b"].astype(window.dtype))


def _ssd_chunked(xh, dt, A, b, c, chunk: int):
    """SSD over chunks.  xh: (B,S,H,P); dt: (B,S,H) (post-softplus);
    A: (H,) negative; b, c: (B,S,N) (single group, broadcast over heads).
    Returns y (B,S,H,P) and the final state (B,H,P,N)."""
    B_, S, H, P = xh.shape
    N = b.shape[-1]
    nc = S // chunk
    q = chunk
    f32 = jnp.float32

    xc = xh.reshape(B_, nc, q, H, P)
    dtc = dt.reshape(B_, nc, q, H).astype(f32)
    bc = b.reshape(B_, nc, q, N).astype(f32)
    cc = c.reshape(B_, nc, q, N).astype(f32)
    dA = dtc * A.astype(f32)                               # (B,nc,q,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                           # (B,nc,q,H)

    # within-chunk ("diagonal") term
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)             # (B,nc,q,q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,q,q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = (cb[..., None] * decay *
              dtc[:, :, None, :, :]).astype(xh.dtype)      # (B,nc,q,q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # per-chunk final states
    wj = (jnp.exp(cum[:, :, -1:, :] - cum) * dtc).astype(xh.dtype)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", wj, bc.astype(xh.dtype), xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(carry, inp):
        s_c, dec = inp                                     # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None].astype(carry.dtype) + s_c
        return new, carry                                  # emit state *before*

    init = jnp.zeros((B_, H, P, N), xh.dtype)
    final, states_in = jax.lax.scan(
        scan_fn, init, (s_chunk.transpose(1, 0, 2, 3, 4),
                        chunk_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)         # (B,nc,H,P,N)

    # off-chunk contribution
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cc.astype(xh.dtype),
                       states_in, jnp.exp(cum).astype(xh.dtype))
    y = (y_diag + y_off).reshape(B_, S, H, P)
    return y, final


def mamba2_apply(p, x, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence (train/prefill).  x: (B,S,D)."""
    b_, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs_raw, bm_raw, cm_raw, dt = _proj(p, x, cfg)
    xs = _conv_full(p["conv_x"], xs_raw, cfg.ssm_conv)
    bc = _conv_full(p["conv_bc"], jnp.concatenate([bm_raw, cm_raw], -1),
                    cfg.ssm_conv)
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(b_, s, h, pdim)
    y, state = _ssd_chunked(xh, dt, A, bmat, cmat, min(cfg.ssm_chunk, s))
    y = y + (p["D"].astype(y.dtype)[None, None, :, None] * xh)
    y = y.reshape(b_, s, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y, nmc_mode=cfg.nmc_mode)
    if return_state:
        k = cfg.ssm_conv - 1
        conv_cache_x = xs_raw[:, -k:]
        conv_cache_bc = jnp.concatenate([bm_raw, cm_raw], -1)[:, -k:]
        return out, {"ssm": state, "conv_x": conv_cache_x,
                     "conv_bc": conv_cache_bc}
    return out


def mamba2_state_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                             dtype),
    }


def mamba2_decode(p, x, cfg: ModelConfig, state: dict):
    """One-token recurrent step.  x: (B,1,D)."""
    b_ = x.shape[0]
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xs_raw, bm_raw, cm_raw, dt = _proj(p, x, cfg)
    bc_raw = jnp.concatenate([bm_raw, cm_raw], -1)
    win_x = jnp.concatenate([state["conv_x"].astype(x.dtype), xs_raw], axis=1)
    win_bc = jnp.concatenate([state["conv_bc"].astype(x.dtype), bc_raw],
                             axis=1)
    xs = _conv_step(p["conv_x"], win_x)
    bc = _conv_step(p["conv_bc"], win_bc)
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                    # (B,H)
    xh = xs.reshape(b_, h, pdim)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(xh.dtype),
                     bmat[:, 0], xh)
    s_new = state["ssm"] * dA[..., None, None].astype(state["ssm"].dtype) + dbx
    y = jnp.einsum("bhpn,bn->bhp", s_new, cmat[:, 0])
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b_, 1, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y, nmc_mode=cfg.nmc_mode)
    return out, {"ssm": s_new, "conv_x": win_x[:, 1:],
                 "conv_bc": win_bc[:, 1:]}
