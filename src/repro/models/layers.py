"""Core layers: norms, embeddings, RoPE, (NMC-quantizable) linears, MLPs.

Functional style: every layer is an ``init(key, ...) -> params`` plus an
``apply(params, x, ...) -> y`` pair over plain dict pytrees — no framework
dependency, fully inspectable, shard-mappable.

The paper's technique surfaces here as :func:`linear`'s NMC modes:
  * ``none``  — bf16 dense (baseline)
  * ``w8``    — int8 weights dequantized on the fly (halves weight HBM bytes)
  * ``w8a8``  — int8 x int8 -> int32 MXU path with fused dequant epilogue
                (the NM-Carus vmacc loop; Pallas kernel on TPU)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

Params = dict


def shard_hidden(x: jax.Array, axis: int = -1) -> jax.Array:
    """Constrain the trailing (feature) axis of an activation to the `model`
    mesh axis when a mesh is active — used to steer GSPMD toward head/ffn
    tensor parallelism.  No-op without a mesh."""
    from repro.distributed import context
    spec = context.hidden_spec(x.ndim, axis, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def shard_batch_only(x: jax.Array) -> jax.Array:
    """Constrain an activation to batch-only (pod/data) sharding — used at
    residual-stream junction points to keep the feature axis replicated."""
    from repro.distributed import context
    spec = context.batch_spec(x.ndim)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def shard_seq(x: jax.Array) -> jax.Array:
    """Sequence-parallel residual stream (Megatron-SP): shard dim 1 (seq)
    over `model`.  GSPMD then emits reduce-scatter after row-sharded
    projections and all-gather before column-sharded ones — same link bytes
    as the all-reduce it replaces, but every norm/residual/elementwise op in
    between touches 1/TP of the bytes."""
    from repro.distributed import context
    mesh = context.get_mesh()
    if mesh is None or not context.has_model_axis() or x.ndim < 3 \
            or x.shape[1] % mesh.shape[context.MODEL_AXIS]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    ax = context.data_axes()
    spec = NamedSharding(mesh, P(ax if ax else None, context.MODEL_AXIS,
                                 *([None] * (x.ndim - 2))))
    return jax.lax.with_sharding_constraint(x, spec)


def _init_dense(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# Linear (+ NMC quantized execution)
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": _init_dense(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_quantize(p: Params) -> Params:
    """Convert a trained linear to its NMC (int8) serving form.  Handles
    stacked (scan-over-layers) weights of shape (L, d_in, d_out)."""
    w = p["w"]
    if w.ndim == 3:
        wq, s = jax.vmap(lambda wl: kref.quantize_rowwise(wl, axis=0))(w)
    else:
        wq, s = kref.quantize_rowwise(w, axis=0)
    q = {"w_q": wq, "scale": s}
    if "b" in p:
        q["b"] = p["b"]
    return q


def linear(p: Params, x: jax.Array, *, nmc_mode: str = "none",
           act: str = "none", dtype=None) -> jax.Array:
    """y = act(x @ W + b), honouring the NMC execution mode.

    Accepts arbitrary leading batch dims; contraction over the last."""
    dtype = dtype or x.dtype
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    x2 = x.reshape(-1, d_in)
    if "w_q" in p:                               # quantized serving params
        if nmc_mode == "w8a8":
            xq, sx = kref.quantize_dynamic(x2)
            y = kops.nmc_matmul(xq, p["w_q"], p["scale"] * sx,
                                p.get("b"), act=act, out_dtype=dtype)
            return y.reshape(*lead, -1)
        # w8: dequantize weights, bf16 matmul (weight bytes halved in HBM)
        w = (p["w_q"].astype(dtype) * p["scale"].astype(dtype)[None, :])
    else:
        w = p["w"].astype(dtype)
    y = x2.astype(dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(dtype)
    y = kref.apply_act(y, act).astype(dtype)
    return y.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["g"]).astype(dt)


def layernorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, dim: int, theta: float) -> tuple:
    """cos/sin tables for given positions: (..., dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2) or broadcastable."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    if cos.ndim == 2:                       # (S, D/2) -> (S, 1, D/2)
        cos, sin = cos[:, None, :], sin[:, None, :]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    return jnp.concatenate([rot1, rot2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int) -> Params:
    return {"table": _init_dense(key, (vocab, d), scale=0.02)}


def embed(p: Params, ids: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[ids]


def lm_head_init(key, d: int, vocab: int) -> Params:
    return linear_init(key, d, vocab)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str = "silu") -> Params:
    ks = jax.random.split(key, 3)
    if act == "silu":                          # gated (SwiGLU)
        return {"wi": linear_init(ks[0], d, d_ff),
                "wg": linear_init(ks[1], d, d_ff),
                "wo": linear_init(ks[2], d_ff, d)}
    return {"wi": linear_init(ks[0], d, d_ff),
            "wo": linear_init(ks[2], d_ff, d)}


def mlp(p: Params, x: jax.Array, act: str = "silu",
        nmc_mode: str = "none") -> jax.Array:
    if "wg" in p:
        h = linear(p["wi"], x, nmc_mode=nmc_mode) * \
            linear(p["wg"], x, nmc_mode=nmc_mode, act="silu")
        h = shard_hidden(h)
    else:
        h = shard_hidden(linear(p["wi"], x, nmc_mode=nmc_mode, act=act))
    return linear(p["wo"], h, nmc_mode=nmc_mode)


def _quantize_expert_bank(w):
    """(…, E, d_in, d_out) expert weights -> int8 + per-(expert, out) scale."""
    amax = jnp.max(jnp.abs(w), axis=-2)                     # (…, E, d_out)
    s = jnp.maximum(amax, 1e-8) / 127.0
    wq = jnp.clip(jnp.round(w / s[..., None, :]), -127, 127).astype(jnp.int8)
    return wq, s


def quantize_tree(params, path_filter=None):
    """Recursively convert every linear ({'w': ...}) and MoE expert bank
    (router + wi/wg/wo arrays) in a param tree to its int8 NMC form.  Norm
    gains / embeddings / biases are left untouched (the paper never
    quantizes accumulators or normalization state)."""
    if isinstance(params, dict):
        if "w" in params and params["w"].ndim in (2, 3):
            return linear_quantize(params)
        if "router" in params and "wi" in params:           # MoE expert bank
            # router stays full precision: its logit margins decide top-k
            # routing and are tiny relative to int8 noise (standard practice)
            out = {k: (v if k == "router" else quantize_tree(v))
                   for k, v in params.items()
                   if k not in ("wi", "wg", "wo")}
            for k in ("wi", "wg", "wo"):
                wq, s = _quantize_expert_bank(params[k])
                out[f"{k}_q"], out[f"{k}_s"] = wq, s
            return out
        return {k: quantize_tree(v) for k, v in params.items()}
    return params
