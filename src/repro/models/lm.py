"""Unified language model: one entry point for all ten architecture families.

Families and their block stacks (all scanned where homogeneous — HLO size is
O(1) in depth, required for 512-device dry-run compiles):

  dense / vlm    : [attn + MLP] x L                       (scan)
  moe            : first_dense x k (unrolled) + [attn + MoE] x (L-k)  (scan)
  hybrid (zamba2): [[mamba2 x attn_every] + shared attn/MLP block] x G
                   (outer scan over groups, inner scan over mamba layers;
                    the attention block's weights are SHARED across groups)
  xlstm          : mLSTM / sLSTM blocks (unrolled; tiny)
  encdec (whisper): encoder [attn + MLP] x Le (scan, non-causal)
                    + decoder [self + cross + MLP] x L (scan)

Three entry points per family: ``forward`` (teacher-forced training),
``prefill`` (build KV caches / recurrent states), ``decode_step``
(one token, donated caches).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# decoder block (dense & moe & vlm)
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, use_moe: bool, d_ff: int | None = None):
    ks = jax.random.split(key, 2)
    attn_init = A.mla_init if cfg.mla else A.gqa_init
    p = {"ln1": L.rmsnorm_init(cfg.d_model),
         "attn": attn_init(ks[0], cfg),
         "ln2": L.rmsnorm_init(cfg.d_model)}
    if use_moe:
        p["moe"] = MOE.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, d_ff or cfg.d_ff, cfg.act)
    return p


def _block_apply(p, x, cfg: ModelConfig, use_moe: bool):
    sp = L.shard_seq if cfg.seq_parallel else (lambda t: t)
    x = sp(x)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn = A.mla_apply if cfg.mla else A.gqa_apply
    x = sp(x + attn(p["attn"], h, cfg))
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, aux = MOE.moe_apply(p["moe"], h, cfg)
        return x + y, aux
    return x + L.mlp(p["mlp"], h, cfg.act, nmc_mode=cfg.nmc_mode), jnp.float32(0)


def _block_prefill(p, x, cfg: ModelConfig, use_moe: bool, max_len: int):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    pre = A.mla_prefill if cfg.mla else A.gqa_prefill
    y, cache = pre(p["attn"], h, cfg, max_len)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, _ = MOE.moe_apply(p["moe"], h, cfg)
        return x + y, cache
    return x + L.mlp(p["mlp"], h, cfg.act, nmc_mode=cfg.nmc_mode), cache


def _block_decode(p, x, cfg: ModelConfig, use_moe: bool, cache, cache_len):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    dec = A.mla_decode if cfg.mla else A.gqa_decode
    y, cache = dec(p["attn"], h, cfg, cache, cache_len)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, _ = MOE.moe_apply(p["moe"], h, cfg)
        return x + y, cache
    return x + L.mlp(p["mlp"], h, cfg.act, nmc_mode=cfg.nmc_mode), cache


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "head": L.lm_head_init(ks[1], cfg.d_model, cfg.vocab_size),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(
            lambda k: _block_init(k, cfg, use_moe=False), ks[2], cfg.n_layers)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            p["dense_layers"] = _stack_init(
                lambda k: _block_init(k, cfg, False,
                                      cfg.dense_d_ff or cfg.d_ff),
                ks[3], nd)
        p["layers"] = _stack_init(
            lambda k: _block_init(k, cfg, use_moe=True), ks[2],
            cfg.n_layers - nd)
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        p["mamba"] = jax.vmap(
            lambda k: _stack_init(lambda kk: SSM.mamba2_init(kk, cfg),
                                  k, cfg.attn_every)
        )(jax.random.split(ks[2], groups))
        p["shared_attn"] = _block_init(ks[3], cfg, use_moe=False)
    elif fam == "xlstm":
        p["blocks"] = []
        bkeys = jax.random.split(ks[2], cfg.n_layers)
        for i in range(cfg.n_layers):
            if i in cfg.slstm_layers:
                p["blocks"].append(XL.slstm_init(bkeys[i], cfg))
            else:
                p["blocks"].append(XL.mlstm_init(bkeys[i], cfg))
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(
            lambda k: _enc_block_init(k, cfg), ks[2], cfg.n_enc_layers)
        p["layers"] = _stack_init(
            lambda k: _dec_block_init(k, cfg), ks[3], cfg.n_layers)
        p["pos_dec"] = {"table": 0.02 * jax.random.normal(
            ks[4], (32768, cfg.d_model), jnp.float32)}
        p["enc_norm"] = L.rmsnorm_init(cfg.d_model)
    else:
        raise ValueError(fam)
    if fam == "vlm":
        p["img_proj"] = L.linear_init(ks[5], cfg.d_model, cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# forward (training) per family
# ---------------------------------------------------------------------------

def _embed_in(p, tokens, cfg):
    x = L.embed(p["embed"], tokens, cfg.dtype)
    return x


def _lm_logits(p, x, cfg):
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = L.linear(p["head"], x, nmc_mode=cfg.nmc_mode)
    return L.shard_hidden(logits)         # vocab sharded over `model`


def forward(params, batch: dict, cfg: ModelConfig):
    """Teacher-forced forward.  Returns (logits, aux_loss)."""
    fam = cfg.family
    if fam == "encdec":
        return _forward_encdec(params, batch, cfg)
    if fam == "vlm":
        x = _vlm_embed(params, batch, cfg)
    else:
        x = _embed_in(params, batch["tokens"], cfg)
    aux = jnp.float32(0)

    if fam in ("dense", "vlm"):
        def body(h, lp):
            h, a = _block_apply(lp, h, cfg, use_moe=False)
            return h, a
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    elif fam == "moe":
        if cfg.first_dense_layers:
            def bodyd(h, lp):
                h, a = _block_apply(lp, h, cfg, use_moe=False)
                return h, a
            x, _ = jax.lax.scan(_maybe_remat(bodyd, cfg), x,
                                params["dense_layers"])

        def body(h, lp):
            h, a = _block_apply(lp, h, cfg, use_moe=True)
            return h, a
        x, auxs = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        aux = jnp.sum(auxs)
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, glp):
            def inner(hh, lp):
                return hh + SSM.mamba2_apply(lp, hh, cfg), None
            h, _ = jax.lax.scan(inner, h, glp)
            h, _ = _block_apply(shared, h, cfg, use_moe=False)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(group, cfg), x, params["mamba"])
    elif fam == "xlstm":
        for i, bp in enumerate(params["blocks"]):
            if i in cfg.slstm_layers:
                x = XL.slstm_apply(bp, x, cfg)
            else:
                x = XL.mlstm_apply(bp, x, cfg)
    return _lm_logits(params, x, cfg), aux


def _vlm_embed(params, batch, cfg):
    """Concat projected (stub) image patch embeddings with text embeddings.
    The result must be batch-sharded only: any model-axis sharding on the
    feature dim here poisons the residual stream for every layer."""
    img = L.linear(params["img_proj"], batch["images"].astype(cfg.dtype),
                   nmc_mode=cfg.nmc_mode)
    txt = _embed_in(params, batch["tokens"], cfg)
    return L.shard_batch_only(jnp.concatenate([img, txt], axis=1))


# ---------------------------------------------------------------------------
# whisper-style encoder-decoder
# ---------------------------------------------------------------------------

def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": L.layernorm_init(cfg.d_model),
            "attn": A.gqa_init(ks[0], cfg),
            "ln2": L.layernorm_init(cfg.d_model),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu")}


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": L.layernorm_init(cfg.d_model),
            "attn": A.gqa_init(ks[0], cfg),
            "lnx": L.layernorm_init(cfg.d_model),
            "xattn": A.gqa_init(ks[1], cfg),
            "ln2": L.layernorm_init(cfg.d_model),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu")}


def _encode(params, frames, cfg):
    x = frames.astype(cfg.dtype)

    def body(h, lp):
        hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
        h = h + A.gqa_apply(lp["attn"], hn, cfg, causal=False)
        hn = L.layernorm(lp["ln2"], h, cfg.norm_eps)
        return h + L.mlp(lp["mlp"], hn, "gelu", nmc_mode=cfg.nmc_mode), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block_apply(lp, h, enc_kv, cfg):
    hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
    h = h + A.gqa_apply(lp["attn"], hn, cfg, causal=True)
    hn = L.layernorm(lp["lnx"], h, cfg.norm_eps)
    h = h + A.gqa_apply(lp["xattn"], hn, cfg, causal=False, kv=enc_kv)
    hn = L.layernorm(lp["ln2"], h, cfg.norm_eps)
    return h + L.mlp(lp["mlp"], hn, "gelu", nmc_mode=cfg.nmc_mode)


def _cross_kv(lp, enc, cfg):
    b, se, _ = enc.shape
    hd = cfg.head_dim
    k = L.linear(lp["xattn"]["wk"], enc).reshape(b, se, cfg.n_kv_heads, hd)
    v = L.linear(lp["xattn"]["wv"], enc).reshape(b, se, cfg.n_kv_heads, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _forward_encdec(params, batch, cfg):
    enc = _encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = _embed_in(params, tokens, cfg) + \
        params["pos_dec"]["table"][:s].astype(cfg.dtype)

    def body(h, lp):
        enc_kv = _cross_kv(lp, enc, cfg)
        return _dec_block_apply(lp, h, enc_kv, cfg), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    return _lm_logits(params, x, cfg), jnp.float32(0)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(params, batch: dict, cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE aux).  batch["tokens"] supervises;
    for VLM only the text positions are supervised."""
    logits, aux = forward(params, batch, cfg)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        logits = logits[:, batch["images"].shape[1]:]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    true_logit = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - true_logit)
    mask = batch.get("mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def init_caches(params, cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        n_scan = cfg.n_layers - (cfg.first_dense_layers if fam == "moe" else 0)
        one = (A.mla_cache_init(cfg, batch, max_len, dtype) if cfg.mla
               else A.gqa_cache_init(cfg, batch, max_len, dtype))
        stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one)
        caches = {"layers": stack}
        if fam == "moe" and cfg.first_dense_layers:
            caches["dense_layers"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (cfg.first_dense_layers,) + x.shape), one)
        return caches
    if fam == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        ms = SSM.mamba2_state_init(cfg, batch, dtype)
        mstack = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (groups, cfg.attn_every) + x.shape), ms)
        ac = A.gqa_cache_init(cfg, batch, max_len, dtype)
        astack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (groups,) + x.shape), ac)
        return {"mamba": mstack, "attn": astack}
    if fam == "xlstm":
        states = []
        for i in range(cfg.n_layers):
            states.append(XL.slstm_state_init(cfg, batch)
                          if i in cfg.slstm_layers
                          else XL.mlstm_state_init(cfg, batch))
        return {"blocks": states}
    if fam == "encdec":
        one = A.gqa_cache_init(cfg, batch, max_len, dtype)
        stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
        ek = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, cfg.enc_seq,
                        cfg.head_dim), dtype)
        return {"layers": stack, "cross_k": ek, "cross_v": ek}
    raise ValueError(fam)


def cache_batch_axes(cfg: ModelConfig, caches: dict) -> dict:
    """Explicit batch-axis metadata for a cache pytree from
    :func:`init_caches`: the same tree structure with an int axis per leaf.

    Per-slot cache writes (e.g. ``repro.serve.engine.ServeEngine``
    admission) need to know each leaf's batch axis.  Leaves stacked with a
    leading layer/group axis carry batch at position 1 (position 2 for the
    hybrid family's mamba states, stacked ``(groups, attn_every, B, ...)``);
    un-stacked leaves carry it at position 0.  Shape sniffing cannot
    recover this — a size-1 layer axis is indistinguishable from a size-1
    batch axis (single-layer configs) — so the family knowledge lives
    here, next to the ``init_caches`` stacking rules it mirrors."""
    fam = cfg.family

    def const(tree, ax: int):
        return jax.tree.map(lambda _: ax, tree)

    if fam in ("dense", "vlm", "moe", "encdec"):
        # every entry ("layers", "dense_layers", "cross_k/v") is stacked
        # with one leading layer axis -> batch at 1
        return {k: const(v, 1) for k, v in caches.items()}
    if fam == "hybrid":
        return {"mamba": const(caches["mamba"], 2),
                "attn": const(caches["attn"], 1)}
    if fam == "xlstm":                 # per-layer list, batch leading
        return {"blocks": const(caches["blocks"], 0)}
    raise ValueError(fam)


def decode_step(params, tokens, caches: dict, cache_len, cfg: ModelConfig):
    """One decode step.  tokens: (B, 1) int32 (the *new* token ids);
    cache_len: (B,) lengths INCLUDING the new token.  Returns
    (logits (B, vocab), new caches)."""
    fam = cfg.family
    x = _embed_in(params, tokens, cfg)
    if fam in ("dense", "vlm", "moe"):
        use_moe = fam == "moe"
        if use_moe and cfg.first_dense_layers:
            def bodyd(h, inp):
                lp, c = inp
                h, nc = _block_decode(lp, h, cfg, False, c, cache_len)
                return h, nc
            x, ncd = jax.lax.scan(bodyd, x, (params["dense_layers"],
                                             caches["dense_layers"]))

        def body(h, inp):
            lp, c = inp
            h, nc = _block_decode(lp, h, cfg, use_moe, c, cache_len)
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new = {"layers": nc}
        if use_moe and cfg.first_dense_layers:
            new["dense_layers"] = ncd
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, inp):
            glp, gms, gac = inp

            def inner(hh, inp2):
                lp, st = inp2
                y, nst = SSM.mamba2_decode(lp, hh, cfg, st)
                return hh + y, nst
            h, nms = jax.lax.scan(inner, h, (glp, gms))
            h, nac = _block_decode(shared, h, cfg, False, gac, cache_len)
            return h, (nms, nac)
        x, (nm, na) = jax.lax.scan(
            group, x, (params["mamba"], caches["mamba"], caches["attn"]))
        new = {"mamba": nm, "attn": na}
    elif fam == "xlstm":
        states = []
        for i, bp in enumerate(params["blocks"]):
            st = caches["blocks"][i]
            if i in cfg.slstm_layers:
                x, ns = XL.slstm_apply(bp, x, cfg, state=st,
                                       return_state=True)
            else:
                x, ns = XL.mlstm_apply(bp, x, cfg, state=st)
            states.append(ns)
        new = {"blocks": states}
    elif fam == "encdec":
        pos = jnp.clip(cache_len - 1, 0, params["pos_dec"]["table"].shape[0]
                       - 1)
        x = x + params["pos_dec"]["table"][pos][:, None, :].astype(cfg.dtype)

        def body(h, inp):
            lp, c, ck, cv = inp
            hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
            y, nc = A.gqa_decode(lp["attn"], hn, cfg, c, cache_len)
            h = h + y
            hn = L.layernorm(lp["lnx"], h, cfg.norm_eps)
            h = h + A.gqa_apply(lp["xattn"], hn, cfg, causal=False,
                                kv=(ck, cv))
            hn = L.layernorm(lp["ln2"], h, cfg.norm_eps)
            h = h + L.mlp(lp["mlp"], hn, "gelu", nmc_mode=cfg.nmc_mode)
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["layers"], caches["layers"],
                                       caches["cross_k"], caches["cross_v"]))
        new = {"layers": nc, "cross_k": caches["cross_k"],
               "cross_v": caches["cross_v"]}
    else:
        raise ValueError(fam)
    logits = _lm_logits(params, x, cfg)[:, 0]
    return logits, new


def prefill(params, batch: dict, cfg: ModelConfig, max_len: int):
    """Process the prompt, return (last-position logits, caches)."""
    fam = cfg.family
    if fam == "encdec":
        enc = _encode(params, batch["frames"], cfg)
        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = _embed_in(params, tokens, cfg) + \
            params["pos_dec"]["table"][:s].astype(cfg.dtype)

        def body(h, lp):
            enc_kv = _cross_kv(lp, enc, cfg)
            hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
            y, cache = A.gqa_prefill(lp["attn"], hn, cfg, max_len)
            h = h + y
            hn = L.layernorm(lp["lnx"], h, cfg.norm_eps)
            h = h + A.gqa_apply(lp["xattn"], hn, cfg, causal=False,
                                kv=enc_kv)
            hn = L.layernorm(lp["ln2"], h, cfg.norm_eps)
            h = h + L.mlp(lp["mlp"], hn, "gelu", nmc_mode=cfg.nmc_mode)
            return h, (cache, enc_kv)
        x, (caches, enc_kvs) = jax.lax.scan(_maybe_remat(body, cfg), x,
                                            params["layers"])
        new = {"layers": caches, "cross_k": enc_kvs[0], "cross_v": enc_kvs[1]}
        return _lm_logits(params, x, cfg)[:, -1], new

    if fam == "vlm":
        x = _vlm_embed(params, batch, cfg)
    else:
        x = _embed_in(params, batch["tokens"], cfg)

    if fam in ("dense", "vlm", "moe"):
        use_moe = fam == "moe"
        caches = {}
        if use_moe and cfg.first_dense_layers:
            def bodyd(h, lp):
                return _block_prefill(lp, h, cfg, False, max_len)
            x, cd = jax.lax.scan(_maybe_remat(bodyd, cfg), x,
                                 params["dense_layers"])
            caches["dense_layers"] = cd

        def body(h, lp):
            return _block_prefill(lp, h, cfg, use_moe, max_len)
        x, cs = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        caches["layers"] = cs
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, glp):
            def inner(hh, lp):
                y, st = SSM.mamba2_apply(lp, hh, cfg, return_state=True)
                return hh + y, st
            h, sts = jax.lax.scan(inner, h, glp)
            hn = L.rmsnorm(shared["ln1"], h, cfg.norm_eps)
            y, ac = A.gqa_prefill(shared["attn"], hn, cfg, max_len)
            h = h + y
            hn = L.rmsnorm(shared["ln2"], h, cfg.norm_eps)
            h = h + L.mlp(shared["mlp"], hn, cfg.act, nmc_mode=cfg.nmc_mode)
            return h, (sts, ac)
        x, (ms, ac) = jax.lax.scan(_maybe_remat(group, cfg), x,
                                   params["mamba"])
        caches = {"mamba": ms, "attn": ac}
    elif fam == "xlstm":
        states = []
        for i, bp in enumerate(params["blocks"]):
            if i in cfg.slstm_layers:
                x, st = XL.slstm_apply(bp, x, cfg, return_state=True)
            else:
                x, st = XL.mlstm_apply(bp, x, cfg, return_state=True)
            states.append(st)
        caches = {"blocks": states}
    else:
        raise ValueError(fam)
    return _lm_logits(params, x, cfg)[:, -1], caches
