"""Engine protocol: one interface over both NMC tiles (DESIGN.md §5).

An :class:`Engine` knows how to *lower* a unified-IR
:class:`repro.nmc.program.Program` to the arrays its scan consumes, *run* it
against a tile state (Caesar: flat memory words; Carus: the VRF), *extract*
output elements from a final state, and *cost* it through the mechanistic
timing/energy models.  The two implementations wrap the existing functional
simulators — the scans themselves are unchanged and stay bit-exact.

``scan_fn(sew)`` returns the raw ``(state, arrays) -> state`` callable the
:class:`repro.nmc.pool.TilePool` maps over tiles with ``jax.vmap``.  The
bucketed scheduler feeds NOP-padded streams through the same callable:
``CaesarOp.NOP`` / ``VOp.VNOP`` entries leave the carried state bit-exactly
unchanged inside both scans, so a padded program's final state equals the
unpadded one's (property-tested in ``tests/test_nmc_ir.py``).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import alu
from repro.core.caesar import CaesarConfig, CaesarEngine
from repro.core.carus import CarusConfig, CarusVPU
from repro.nmc.program import Program


@runtime_checkable
class Engine(Protocol):
    name: str

    def lower(self, program: Program) -> dict: ...
    def init_state(self, image) -> jnp.ndarray: ...
    def run(self, state, program: Program): ...
    def scan_fn(self, sew: int): ...
    def extract(self, state, out_slice: tuple[int, int], sew: int): ...
    def cost(self, program: Program, host_cycles: float = 0.0): ...


class _EngineBase:
    def lower(self, program: Program) -> dict:
        assert program.engine == self.name, (program.engine, self.name)
        return program.lower()

    def extract(self, state, out_slice: tuple[int, int], sew: int
                ) -> np.ndarray:
        """Final tile state -> output elements (host-side view)."""
        start, nw = out_slice
        flat = np.asarray(state).reshape(-1)
        return alu.unpack_np(flat[start:start + nw], alu.NP_DTYPES[sew])

    def cost(self, program: Program, host_cycles: float = 0.0):
        from repro.core import timing
        return timing.program_cycles(program, host_cycles)

    def energy(self, program: Program, host_cycles: float = 0.0):
        from repro.core import energy
        return energy.program_energy(program, host_cycles)


class CaesarTile(_EngineBase):
    """NM-Caesar tile: state is the flat 8192-word 2-bank memory image."""

    name = "caesar"

    def __init__(self, config: CaesarConfig | None = None):
        self.sim = CaesarEngine(config)

    def init_state(self, image) -> jnp.ndarray:
        return jnp.asarray(image, jnp.int32).reshape(-1)

    def run(self, state, program: Program):
        mem, _, _ = self.sim.run_program(state, program)
        return mem

    def scan_fn(self, sew: int):
        def run_one(mem, arrays):
            out, _, _ = self.sim.run_stream(mem, arrays, sew)
            return out
        return run_one


class CarusTile(_EngineBase):
    """NM-Carus tile: state is the (n_regs, reg_words) VRF."""

    name = "carus"

    def __init__(self, config: CarusConfig | None = None):
        self.sim = CarusVPU(config)

    def init_state(self, image) -> jnp.ndarray:
        cfg = self.sim.cfg
        return jnp.asarray(image, jnp.int32).reshape(cfg.n_regs,
                                                     cfg.reg_words)

    def run(self, state, program: Program):
        vrf, _, _ = self.sim.run_program(state, program)
        return vrf

    def scan_fn(self, sew: int):
        def run_one(vrf, arrays):
            out, _, _ = self.sim.run_trace(vrf, arrays, sew)
            return out
        return run_one


#: Execution backends implementing the Engine protocol.  "scan" is the
#: ``lax.scan`` reference interpreter; "pallas" is the fused-kernel fast
#: path (``repro.nmc.pallas_engine``), auto-falling back to interpret
#: mode on CPU.
BACKENDS = ("scan", "pallas")

_DEFAULT_ENGINES: dict[tuple[str, str], Engine] = {}


def resolve_backend(backend: str) -> str:
    """Map ``"auto"`` to the fast path on accelerators, scan elsewhere."""
    if backend == "auto":
        import jax
        return "pallas" if jax.default_backend() in ("tpu", "gpu") \
            else "scan"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}: valid backends are "
            f"{BACKENDS + ('auto',)}")
    return backend


def get_engine(name: str, backend: str = "scan") -> Engine:
    """Default (paper-configuration) engine instances, shared per process.

    ``backend`` selects the implementation: ``"scan"`` (reference
    interpreters), ``"pallas"`` (fused kernels), or ``"auto"``.
    """
    backend = resolve_backend(backend)
    key = (name, backend)
    if key not in _DEFAULT_ENGINES:
        if name not in ("caesar", "carus"):
            raise KeyError(name)
        if backend == "scan":
            cls = CaesarTile if name == "caesar" else CarusTile
        else:
            from repro.nmc.pallas_engine import (PallasCaesarEngine,
                                                 PallasCarusEngine)
            cls = PallasCaesarEngine if name == "caesar" \
                else PallasCarusEngine
        _DEFAULT_ENGINES[key] = cls()
    return _DEFAULT_ENGINES[key]


def implementations() -> tuple[tuple[str, str], ...]:
    """All registered ``(engine, backend)`` variants — the conformance
    matrix ``tests/test_engines.py`` sweeps."""
    return tuple((n, b) for n in ("caesar", "carus") for b in BACKENDS)
