"""Async double-buffered NMC dispatch runtime (DESIGN.md §5.2).

The paper's system-level speedups depend on the memory-mode/compute-mode
duality: the host DMA streams the next image into one tile's memory while
another tile (or the same tile's shadow buffer) computes, so data movement
and execution overlap instead of serializing.  :class:`DispatchQueue` makes
that duality executable on top of :class:`repro.nmc.pool.ResidentPool`:

* ``submit(tile, program, image, out_slice)`` returns an :class:`NMCFuture`
  immediately.  The image — if any — is *staged* onto the device as early
  as the tile's single shadow buffer allows (``init_state`` issues the
  async host->device copy, the memory-mode DMA): at submit time when the
  shadow is free — even while the tile's previous program is still in
  flight — otherwise when the item's launch wave is assembled, right after
  the preceding wave dispatched.  Depth-2 double buffering, matching the
  load-ahead of ``timing.dispatch_cycles``; nothing blocks until the
  future is resolved.
* work items launch in *waves*: at each flush the head-of-line item of every
  pending tile installs its staged shadow buffer (buffer swap) and the wave
  dispatches through the shared bucketed jit cache as one batched
  ``ResidentPool.dispatch`` per bucket.  Per-tile FIFO order is preserved;
  chained programs on one tile land in consecutive waves.
* :meth:`NMCFuture.result` is the only synchronization point: it
  ``jax.block_until_ready``\\ s the captured final state, extracts the output
  slice (memory-mode read, counted in the pool's ``bytes_moved``), applies
  the build's host-side ``post`` stage, and caches the result.

Two schedulers are pluggable via ``mode``:

* ``"overlapped"`` (default) — eager staging + lazy batched waves: the
  double-buffered pipeline whose modeled cost is
  ``timing.dispatch_cycles(stages, mode="overlapped")`` (max(dma, compute)
  per steady-state stage instead of their sum).
* ``"inorder"`` — the serial reference: each submit blocks on the tile's
  previous work before staging, then launches a single-item wave.  Results
  are bit-exact equal between the two modes (and to synchronous
  ``ResidentPool.dispatch``); only the overlap counters differ.

``submit_call(fn, *args)`` is the generic device-work flavor of the same
contract: it launches any JAX computation (already asynchronously dispatched
by the runtime) and wraps the result pytree in a :class:`DeviceFuture`, so
host-side consumers (e.g. :class:`repro.serve.engine.ServeEngine` admission)
adopt the same block-only-at-resolution discipline.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

import jax
import numpy as np

from repro.nmc.engine import get_engine
from repro.nmc.pool import WORD_BYTES, ResidentPool
from repro.nmc.program import Program

SCHEDULERS = ("inorder", "overlapped")


class NMCFuture:
    """Handle to one queued (tile, program) work item.

    ``result()`` resolves lazily: it flushes the queue if the item has not
    launched yet, blocks until the tile's captured final state is ready, and
    extracts/post-processes the output elements exactly like the synchronous
    ``ResidentPool`` load/dispatch/store path (bit-exact, same accounting).
    """

    def __init__(self, queue: "DispatchQueue", tile, program: Program,
                 out_slice: Optional[tuple[int, int]],
                 post: Optional[Callable]):
        self.queue = queue
        self.tile = tile
        self.program = program
        self.out_slice = out_slice
        self.post = post
        self._final = None          # device array captured at launch
        self._out = None            # host elements, cached after resolution
        self._resolved = False
        self._done = False
        self._seq = None            # key in the queue's outstanding book

    @property
    def launched(self) -> bool:
        return self._final is not None

    @property
    def done(self) -> bool:
        """The item's computation is known-complete (it was blocked on)."""
        return self._done

    @property
    def resolved(self) -> bool:
        return self._resolved

    def state(self):
        """The tile's final device state for this item (launches if needed,
        blocks until the computation is done)."""
        if self._final is None:
            self.queue.flush()
        out = jax.block_until_ready(self._final)
        self._done = True
        return out

    def result(self) -> Optional[np.ndarray]:
        """Output elements (memory-mode read).  ``None`` when the item was
        submitted without an ``out_slice`` (state stays resident)."""
        if not self._resolved:
            final = self.state()
            if self.out_slice is not None:
                elems = get_engine(self.program.engine).extract(
                    final, self.out_slice, self.program.sew)
                self.queue._account_store(self.out_slice)
                self._out = self.post(elems) if self.post else elems
            self._resolved = True
            self.queue.resolved += 1
            # resolved futures leave the queue's books: only callers who
            # keep the future (or the pool's residency) pin device state
            self.queue._outstanding.pop(self._seq, None)
        return self._out


class GatherFuture:
    """Future over a partitioned kernel wave (DESIGN.md §9): one
    :class:`NMCFuture` per tile shard plus the partition plan's ``gather``
    closure.  ``result()`` resolves every shard (the first resolution
    flushes the queue, launching the whole wave batched) and reassembles
    the caller's array — bit-exact vs the single-tile path by
    construction (tests/test_partition.py)."""

    def __init__(self, futures, gather: Callable):
        self.futures = list(futures)
        self._gather = gather
        self._out = None
        self._resolved = False

    @property
    def launched(self) -> bool:
        return all(f.launched for f in self.futures)

    @property
    def done(self) -> bool:
        return all(f.done for f in self.futures)

    @property
    def resolved(self) -> bool:
        return self._resolved

    def result(self) -> np.ndarray:
        if not self._resolved:
            self._out = self._gather([f.result() for f in self.futures])
            self._resolved = True
        return self._out


class DeviceFuture:
    """Future over an already-launched JAX computation (async dispatch):
    ``result()`` blocks until the value pytree is ready."""

    def __init__(self, value):
        self._value = value
        self._ready = False

    @property
    def value(self):
        """The launched result pytree *without* blocking — JAX arrays are
        themselves futures, so consumers that only force part of the tree
        (e.g. argmax on logits) can keep the rest in flight."""
        return self._value

    def result(self):
        if not self._ready:
            jax.block_until_ready(self._value)
            self._ready = True
        return self._value


@dataclasses.dataclass
class _WorkItem:
    tile: object
    program: Program
    image: object                   # host image awaiting staging | None
    staged: object                  # staged device state (shadow buffer) | None
    engine: str
    future: NMCFuture
    prev: Optional[NMCFuture]       # preceding future on this tile, if any
    backend: Optional[str] = None   # executor override for this item's wave
    patch: object = None            # (word_start, words) spans applied onto
                                    # the resident state at launch — the
                                    # steady-state serving path (weights
                                    # resident, activations patched per call)


class DispatchQueue:
    """Asynchronous double-buffered dispatch over a resident tile array.

    Counters (asserted by tests/benchmarks):

    * ``submitted`` / ``launched`` / ``resolved`` — work-item lifecycle.
    * ``waves`` — batched launch rounds (>= 1 ``ResidentPool.dispatch``
      each; one per distinct bucket in the wave).
    * ``staged_while_busy`` — images staged into a tile's shadow buffer
      while its previous program was still unresolved: the double-buffering
      overlap events.  Always 0 under the ``inorder`` scheduler.
    * ``calls`` — generic device computations launched via ``submit_call``.
    """

    def __init__(self, pool: ResidentPool | None = None,
                 mode: str = "overlapped"):
        assert mode in SCHEDULERS, mode
        self.pool = pool if pool is not None else ResidentPool()
        self.mode = mode
        self._queued: list[_WorkItem] = []
        self._last: dict[object, NMCFuture] = {}  # tile -> FIFO tail
        self._outstanding: dict[int, NMCFuture] = {}   # pruned at result()
        self._seq = itertools.count()
        self._staged_pending: dict[object, int] = {}  # tile -> staged count
        self.submitted = 0
        self.launched = 0
        self.resolved = 0
        self.waves = 0
        self.mixed_engine_waves = 0     # waves mixing Caesar+Carus shards
        self.staged_while_busy = 0
        self.calls = 0

    # -- submission ----------------------------------------------------------
    def submit(self, tile, program: Program, image=None,
               out_slice: Optional[tuple[int, int]] = None,
               post: Optional[Callable] = None,
               backend: Optional[str] = None,
               patch: Optional[list] = None) -> NMCFuture:
        """Queue one work item; returns its future immediately.

        ``image`` (optional) is the host image to stage into the tile's
        shadow buffer, installed as the tile's resident state when the item
        launches.  Staging is double-buffered: it happens as early as
        possible — at submit when the tile's single shadow buffer is free,
        otherwise when the item's launch wave is assembled (right after the
        previous wave dispatched, so the transfer overlaps the in-flight
        compute either way).  Without an image the program chains against
        the tile's current resident state.  ``backend`` (optional) pins the
        item to an executor ("scan"/"pallas"); waves group per backend at
        launch, default follows the pool.

        ``patch`` (optional) is a list of ``(word_start, words)`` spans
        applied onto the tile's resident state when the item launches
        (after any image install): the steady-state resident-serving path —
        weights stay on the tile, only the per-call activation words move
        (``ResidentPool.patch`` accounting)."""
        from repro.nmc.check import assert_submittable
        # last-line structural floor of the static checking contract
        # (DESIGN.md §11): full verification belongs at lowering time
        assert_submittable(program)
        prev = self._last.get(tile)
        if image is not None and self.mode == "inorder" \
                and prev is not None and not prev.done:
            prev.state()            # serial DMA: wait before staging
        fut = NMCFuture(self, tile, program, out_slice, post)
        item = _WorkItem(tile, program, image, None, program.engine, fut,
                         prev, backend, patch)
        # depth-2 double buffering: at most one staged shadow buffer per
        # tile ahead of the resident (possibly computing) state
        if image is not None and not self._staged_pending.get(tile):
            self._stage(item)
        self._queued.append(item)
        self._last[tile] = fut
        fut._seq = next(self._seq)
        self._outstanding[fut._seq] = fut
        self.submitted += 1
        if self.mode == "inorder":
            self.flush()
        return fut

    def _stage(self, item: _WorkItem) -> None:
        """Start the async host->device copy into the tile's shadow buffer
        (memory-mode DMA); counted as overlapped when the tile's previous
        program is still unresolved at this moment."""
        item.staged = get_engine(item.engine).init_state(item.image)
        item.image = None
        self._staged_pending[item.tile] = \
            self._staged_pending.get(item.tile, 0) + 1
        if item.prev is not None and not item.prev.done:
            self.staged_while_busy += 1

    def submit_call(self, fn: Callable, *args, **kwargs) -> DeviceFuture:
        """Launch a generic JAX computation as queued device work (the
        runtime's async dispatch does the overlapping); block only at
        ``result()``."""
        self.calls += 1
        return DeviceFuture(fn(*args, **kwargs))

    # -- launching -----------------------------------------------------------
    def flush(self) -> None:
        """Launch every queued item, wave by wave (per-tile FIFO preserved:
        each wave takes the head-of-line item of every pending tile)."""
        while self._queued:
            wave, rest, seen = [], [], set()
            for it in self._queued:
                (rest if it.tile in seen else wave).append(it)
                seen.add(it.tile)
            self._queued = rest
            self._launch_wave(wave)

    def _launch_wave(self, wave: list[_WorkItem]) -> None:
        for it in wave:             # buffer swap: shadow -> resident state
            if it.image is not None:
                self._stage(it)     # deferred staging (shadow was occupied)
            if it.staged is not None:
                self.pool.install(it.tile, it.engine, it.staged)
                self._staged_pending[it.tile] -= 1
            if it.patch is not None:
                # partial memory-mode write on top of the resident state
                # (after any install, so patch words win over image words)
                self.pool.patch(it.tile, it.patch)
        by_backend: dict[str, list[_WorkItem]] = {}
        for it in wave:
            by_backend.setdefault(it.backend, []).append(it)
        for backend, items in by_backend.items():
            self.pool.dispatch([(it.tile, it.program) for it in items],
                               backend=backend)
        for it in wave:             # capture this wave's final state per item
            it.future._final = self.pool.state(it.tile)
        self.launched += len(wave)
        self.waves += 1
        if len({it.program.engine for it in wave}) > 1:
            # a heterogeneous wave (DESIGN.md §14): Caesar and Carus
            # shards launched together; the pool batches per engine
            # bucket group inside the one dispatch
            self.mixed_engine_waves += 1

    def drain(self) -> None:
        """Flush and resolve every outstanding future (chained per-tile
        futures included, not just the FIFO tails)."""
        self.flush()
        for fut in list(self._outstanding.values()):
            fut.result()            # each pops itself from the book

    # -- convenience ---------------------------------------------------------
    def run_builds(self, builds: list,
                   n_tiles: Optional[int] = None) -> list[np.ndarray]:
        """EngineBuild list -> output elements through the async path:
        submit everything (staging all images up front), then resolve —
        bit-exact equal to ``ResidentPool.run_builds``.

        ``n_tiles`` feeds the builds round-robin through a fixed array of
        that many tiles (the paper's continuously-fed tile array): item
        ``k`` stages into tile ``k % n_tiles``'s shadow buffer while the
        tile's previous program is still in flight — the double-buffering
        the ``staged_while_busy`` counter measures.  Default (``None``)
        gives every build its own fresh tile."""
        futs = []
        for k, eb in enumerate(builds):
            # fresh tile ids draw from the wrapped pool's counter so they
            # can never collide with ResidentPool.run_builds (or another
            # queue) allocating on the same pool
            tile = (("lane", k % n_tiles) if n_tiles
                    else ("build", next(self.pool._ids)))
            futs.append(self.submit(tile, eb.program, image=eb.mem,
                                    out_slice=eb.out_slice, post=eb.post))
        return [f.result() for f in futs]

    # -- accounting ----------------------------------------------------------
    def _account_store(self, out_slice: tuple[int, int]) -> None:
        # mirrors ResidentPool.store: word-granular (n_words * 4), so
        # sub-word element tails at SEW 8/16 cost their whole last word
        self.pool.stores += 1
        self.pool.bytes_moved += int(out_slice[1]) * WORD_BYTES
