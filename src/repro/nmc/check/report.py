"""Diagnostics containers shared by every verifier pass (DESIGN.md §11).

:class:`Diagnostic` / :class:`CheckReport` / :class:`VerificationError` are
the public result types of :func:`repro.nmc.check.verify_program` and
friends; :class:`_Ctx` is the internal pass context (emission helpers,
per-verification cache) threaded through the structural / dataflow /
resource / partition / residency passes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

#: Verification modes accepted by ``nmc.jit(fn, check=...)``.
CHECK_MODES = ("error", "warn", "off")

SEVERITIES = ("error", "warning", "info")
PASSES = ("structural", "dataflow", "resource", "partition", "residency")

#: Diagnostics reported per (pass, rule) before summarizing — a corrupted
#: 8k-instruction stream should not produce 8k records.
MAX_PER_RULE = 8


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding, with enough provenance to locate the defect:
    the pass and rule that fired, the instruction index in the lowered
    stream, and (when the program came from the traced frontend) the
    tracer-op index it lowered from."""

    severity: str               # "error" | "warning" | "info"
    pass_name: str              # "structural" | "dataflow" | ...
    rule: str                   # stable slug, e.g. "read-before-write"
    message: str
    kernel: Optional[str] = None
    instr: Optional[int] = None       # instruction index in the stream
    op_index: Optional[int] = None    # tracer node index (provenance)

    def __str__(self) -> str:
        where = self.kernel or "<program>"
        if self.instr is not None:
            where += f" instr#{self.instr}"
        if self.op_index is not None:
            where += f" (traced op#{self.op_index})"
        return (f"{self.severity}[{self.pass_name}/{self.rule}] "
                f"{where}: {self.message}")

    def as_dict(self) -> dict:
        """JSON-ready record (the CLI ``--report`` schema — stable keys)."""
        return {"severity": self.severity, "pass": self.pass_name,
                "rule": self.rule, "message": self.message,
                "kernel": self.kernel, "instr": self.instr,
                "op_index": self.op_index}


@dataclasses.dataclass
class CheckReport:
    """All diagnostics of one verification run."""

    target: str                       # what was verified (kernel / plan)
    diagnostics: List[Diagnostic]

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        """No errors (warnings and infos allowed)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """No errors and no warnings (infos allowed)."""
        return not self.errors and not self.warnings

    def by_rule(self, rule: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.rule == rule]

    def render(self) -> str:
        if not self.diagnostics:
            return f"{self.target}: clean"
        lines = [f"{self.target}: {len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def raise_if_errors(self) -> "CheckReport":
        if self.errors:
            raise VerificationError(self)
        return self

    def extend(self, other: "CheckReport") -> "CheckReport":
        self.diagnostics.extend(other.diagnostics)
        return self


class VerificationError(Exception):
    """A program failed static verification (``check="error"``)."""

    def __init__(self, report: CheckReport):
        self.report = report
        super().__init__(report.render())


# ---------------------------------------------------------------------------
# Pass context + emission helpers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Ctx:
    kernel: Optional[str]
    out_slice: Optional[Tuple[int, int]]
    init_spans: Optional[Sequence[Tuple[int, int]]]   # image-defined words
    used_words: int
    prov: Optional[Sequence[int]]
    diags: List[Diagnostic]
    cache: dict = dataclasses.field(default_factory=dict)

    def op_index(self, instr: Optional[int]) -> Optional[int]:
        if instr is None or self.prov is None or instr >= len(self.prov):
            return None
        return self.prov[instr]

    def emit(self, severity: str, pass_name: str, rule: str, message: str,
             instr: Optional[int] = None) -> None:
        self.diags.append(Diagnostic(
            severity, pass_name, rule, message, kernel=self.kernel,
            instr=None if instr is None else int(instr),
            op_index=self.op_index(instr)))

    def emit_rows(self, severity: str, pass_name: str, rule: str,
                  rows: np.ndarray, fmt: Callable[[int], str]) -> None:
        """Emit one diagnostic per flagged instruction row, capped at
        :data:`MAX_PER_RULE` with a summarizing tail record."""
        rows = np.asarray(rows)
        for i in rows[:MAX_PER_RULE]:
            self.emit(severity, pass_name, rule, fmt(int(i)), instr=int(i))
        if len(rows) > MAX_PER_RULE:
            self.emit(severity, pass_name, rule,
                      f"... and {len(rows) - MAX_PER_RULE} more "
                      f"'{rule}' findings")


def _defined_words(ctx: _Ctx, capacity: int) -> Optional[np.ndarray]:
    """Boolean image-defined map, or None when unknown (hand-built
    programs verify structurally but skip init-sensitive dataflow)."""
    if ctx.init_spans is None:
        return None
    defined = np.zeros(capacity, bool)
    for start, nw in ctx.init_spans:
        lo = max(0, int(start))
        defined[lo:min(capacity, int(start) + int(nw))] = True
    return defined
