"""Partition-safety pass: shard plans and lowered waves.

Shard store pieces must exactly partition the parent store set (no gap,
no overlap), axis-shard loads must carry a sufficient slide halo, and a
lowered wave must agree on one instruction bucket with verifier-neutral
NOP tails.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.nmc.check.report import CheckReport, _Ctx


def verify_plan(parent, plan, kernel: Optional[str] = None) -> CheckReport:
    """Partition-safety pass over a :class:`repro.nmc.partition.
    PartitionPlan`: the shards' store pieces must exactly partition every
    parent store's element range (no gap, no overlap), and axis shards'
    loads must carry the full slide halo."""
    from repro.nmc.partition import slide_halo
    target = kernel or getattr(parent, "name", None) or "<plan>"
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=None, diags=[])
    per_store: dict = {si: [] for si in range(len(plan.store_trims))}
    for shard, pieces in enumerate(plan.pieces):
        for si, lo, hi in pieces:
            if si not in per_store:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"shard {shard} references store #{si}, but the "
                         f"parent tape has {len(plan.store_trims)} stores")
                continue
            per_store[si].append((lo, hi, shard))
    for si, trim in enumerate(plan.store_trims):
        ivs = sorted(per_store[si])
        pos = 0
        for lo, hi, shard in ivs:
            if lo > pos:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"store #{si}: elements [{pos}, {lo}) are covered "
                         f"by no shard")
            elif lo < pos:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"store #{si}: elements [{lo}, {min(pos, hi)}) "
                         f"are covered twice (shard {shard} overlaps)")
            pos = max(pos, hi)
        if pos < trim:
            ctx.emit("error", "partition", "store-not-partitioned",
                     f"store #{si}: elements [{pos}, {trim}) are covered "
                     f"by no shard")
    # halo sufficiency: axis shards replay every load sliced [lo, end);
    # end must reach hi + the tape's max cumulative slide read-ahead
    if plan.strategy in ("axis", "single") and plan.pieces:
        halo = slide_halo(parent)
        parent_loads = [n for n in parent.nodes if n.op == "load"]
        for shard, (b, pieces) in enumerate(zip(plan.builders, plan.pieces)):
            if not pieces:
                continue
            lo = min(p[1] for p in pieces)
            hi = max(p[2] for p in pieces)
            shard_loads = [n for n in b.nodes if n.op == "load"]
            for pl, sl in zip(parent_loads, shard_loads):
                required = min(hi + halo, pl.ne) - lo
                if sl.ne < required:
                    ctx.emit(
                        "error", "partition", "insufficient-halo",
                        f"shard {shard} load (traced op#{sl.idx}) carries "
                        f"{sl.ne} elements for piece [{lo}, {hi}) but "
                        f"slides read ahead {halo}: needs "
                        f"{required}")
    return CheckReport(target, ctx.diags)


def verify_wave(parent, plan, lks: Sequence,
                kernel: Optional[str] = None) -> CheckReport:
    """Partition safety + per-shard verification of a lowered wave,
    including the common-bucket padding contract: every shard program of
    one *engine group* must sit at one shared instruction count with
    verifier-neutral NOP tails (the structural nop-not-neutral rule
    covers the tails).  A mixed-engine wave (DESIGN.md §14) legitimately
    carries one bucket per engine — Caesar and Carus programs never share
    a compile bucket — so agreement is checked per group."""
    # facade-level import: verify_lowered (and its memo) live in the
    # package __init__, which re-exports this module — defer to avoid the
    # cycle
    from repro.nmc.check import verify_lowered
    target = kernel or getattr(parent, "name", None) or "<wave>"
    report = verify_plan(parent, plan, kernel=target)
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=None, diags=report.diagnostics)
    by_engine: dict = {}
    for lk in lks:
        by_engine.setdefault(lk.engine, set()).add(lk.program.n_instr)
    for eng, sizes in sorted(by_engine.items()):
        if len(sizes) > 1:
            ctx.emit("error", "partition", "wave-bucket-mismatch",
                     f"{eng} shard programs pad to different instruction "
                     f"counts {sorted(sizes)} — the engine group would "
                     f"split into several compile buckets")
    for i, lk in enumerate(lks):
        report.extend(verify_lowered(lk, kernel=f"{target}[shard {i}]"))
    return report
