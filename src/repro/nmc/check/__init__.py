"""Static IR verifier + dataflow analysis for NMC programs (DESIGN.md §11).

The stack hands ``Program`` arrays across four layers (tracer -> lowering ->
partitioner -> bucketed pools -> scan/Pallas engines), and a malformed
stream — an out-of-range Carus register, a read of a never-written Caesar
word, a shard wave that misses part of the output store set — executes
silently and computes garbage.  This package is the correctness *tooling*
layer that rejects such programs before they reach an engine:

* :func:`verify_program` — composable static passes over one unified-IR
  :class:`repro.nmc.program.Program`, returning a :class:`CheckReport` of
  structured :class:`Diagnostic` records (severity, pass name, rule,
  instruction index, tracer-op provenance).  Passes:

  - **structural** (:mod:`repro.nmc.check.structural`) — opcode valid for
    the engine, register/address ranges (Carus VRF bounds, Caesar word
    addresses vs the 32 KiB image), SEW-legal modes, Caesar entries
    structurally zero in Carus-only fields, padding NOPs truly neutral.
  - **dataflow** (:mod:`repro.nmc.check.dataflow`) — def-use liveness:
    read-before-write against the image-defined spans, MAC/DOT
    accumulator chains (use-before-init, never-stored), dead writes
    (overwritten or never read), in-place VMACC hazards on Carus, and
    store coverage (every word of ``out_slice`` written or
    image-defined).
  - **resource** (:mod:`repro.nmc.check.resource`) — allocator high-water
    vs engine capacity, plus an independent bank-conflict / instruction
    count estimate cross-checked against :mod:`repro.core.timing` (drift
    between the verifier's and the cost model's view of a program is
    itself an error).

* :func:`verify_lowered` — the same passes over a frontend
  :class:`repro.nmc.frontend.LoweredKernel`, using its recorded metadata
  (image-defined spans, per-instruction tracer provenance, kernel name).
* :func:`verify_plan` / :func:`verify_wave`
  (:mod:`repro.nmc.check.partition`) — **partition safety**: shard store
  pieces exactly partition the parent store set, axis-shard loads carry a
  sufficient slide halo, and the common-bucket padding of a lowered wave
  is verifier-neutral.
* :func:`verify_resident` / :func:`verify_chained_waves`
  (:mod:`repro.nmc.check.residency`) — **residency hazards**: patch spans
  never alias resident weight spans, no program write mutates an
  image-defined span, and chained waves are tile-disjoint (no WAR hazard
  across dependent submissions).
* :func:`assert_wave` / :func:`assert_submittable` — the cheap O(entries)
  subset the hot scheduler layers (:class:`repro.nmc.pool.BucketedPool`,
  :class:`repro.nmc.runtime.DispatchQueue`) assert on every dispatch.

``python -m repro.nmc.check --all`` sweeps every registry kernel x engine
x SEW (plus partitioned waves) and prints a report — the CI lint gate.
``--report PATH`` writes the same sweep as stable-schema JSON.

The passes are numpy-vectorized (event sort over def/use streams, not a
per-instruction Python loop) and :func:`verify_lowered` memoizes its
verdict on a content fingerprint of the program, so
``nmc.jit(fn, check="error")`` — the default — verifies every lowering
at a few percent overhead (``benchmarks/check_bench.py`` is the gate).

These analyses are also the substrate of the IR optimizer
(:mod:`repro.nmc.opt`): every rewrite re-runs them as its
translation-validation gate.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import alu
from repro.nmc.program import ENGINES, PROG_DTYPE, Program
from repro.nmc.registry import engine_op_ids

# Split modules re-exported verbatim: the 1005-line monolith became
# report / structural / dataflow / resource / partition / residency, and
# every pre-split import path (`from repro.nmc.check import X`,
# `check.X`) keeps working through this facade.
from repro.nmc.check.report import (CHECK_MODES, MAX_PER_RULE, PASSES,
                                    SEVERITIES, CheckReport, Diagnostic,
                                    VerificationError, _Ctx, _defined_words)
from repro.nmc.check.structural import (_CAESAR_BANK_WORDS,
                                        _CAESAR_MEM_WORDS, _CARUS_N_REGS,
                                        _CARUS_REG_WORDS, _NOP_C, _NOP_K,
                                        _caesar_code, _carus_operands,
                                        _carus_regs, _carus_uses, _class_lut,
                                        _columns, _member, check_structural)
from repro.nmc.check.dataflow import (_chain_check, _event_analysis,
                                      check_dataflow)
from repro.nmc.check.resource import check_resource
from repro.nmc.check.partition import verify_plan, verify_wave
from repro.nmc.check.residency import verify_chained_waves, verify_resident

__all__ = [
    "CHECK_MODES", "SEVERITIES", "PASSES", "MAX_PER_RULE",
    "Diagnostic", "CheckReport", "VerificationError",
    "check_structural", "check_dataflow", "check_resource",
    "verify_program", "verify_lowered", "clear_memo",
    "verify_plan", "verify_wave",
    "verify_resident", "verify_chained_waves",
    "assert_submittable", "assert_wave", "main",
]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

_PASS_FNS = {"structural": check_structural, "dataflow": check_dataflow,
             "resource": check_resource}


def verify_program(prog: Program, *, kernel: Optional[str] = None,
                   out_slice: Optional[Tuple[int, int]] = None,
                   init_spans: Optional[Sequence[Tuple[int, int]]] = None,
                   used_words: int = 0,
                   prov: Optional[Sequence[int]] = None,
                   passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Run the static passes over one unified-IR program.

    ``init_spans`` lists the image-defined word spans (loads, constant
    pools) — without it the read-before-write and store-coverage checks
    are skipped (a bare program has no image to check against).  ``prov``
    maps instruction index -> tracer op index for provenance."""
    target = kernel or f"<{prog.engine} program>"
    ctx = _Ctx(kernel=target, out_slice=out_slice, init_spans=init_spans,
               used_words=int(used_words), prov=prov, diags=[])
    if prog.engine not in ENGINES:
        ctx.emit("error", "structural", "bad-engine",
                 f"unknown engine {prog.engine!r}")
        return CheckReport(target, ctx.diags)
    if prog.sew not in alu.SEWS:
        ctx.emit("error", "structural", "bad-sew",
                 f"sew={prog.sew!r} is not one of {sorted(alu.SEWS)}")
        return CheckReport(target, ctx.diags)
    for name in (passes or ("structural", "dataflow", "resource")):
        _PASS_FNS[name](prog, ctx)
    return CheckReport(target, ctx.diags)


# Verification is a pure function of (program bytes, lowering metadata),
# and the synchronous call path re-lowers an identical program on every
# invocation — memoize the verdict on a content fingerprint so repeated
# lowerings pay one 64 KiB hash, not the full pass pipeline.  In-place
# corruption of `entries` changes the fingerprint, so tampering is never
# masked by the cache.  The OrderedDict is LRU-bounded at ``_MEMO_CAP``
# entries so unbounded registry sweeps cannot grow it without limit;
# eviction only costs a re-verification on the next identical lowering.
_MEMO_CAP = 256
_report_memo: "OrderedDict[tuple, CheckReport]" = OrderedDict()


def clear_memo() -> None:
    """Drop the ``verify_lowered`` verdict cache (benchmarks, tests)."""
    _report_memo.clear()


def _spans_key(spans) -> Optional[tuple]:
    return None if spans is None else tuple((int(s), int(n)) for s, n in spans)


def _lowered_key(lk, kernel: str, passes) -> Optional[tuple]:
    prog = lk.program
    e = prog.entries
    if not e.flags.c_contiguous:
        e = np.ascontiguousarray(e)
    h = hashlib.blake2b(e, digest_size=16)
    prov = getattr(lk, "prov", None)
    if prov is not None:
        try:
            h.update(np.ascontiguousarray(prov, dtype=np.int64))
        except (TypeError, ValueError):
            return None                 # unhashable provenance: skip memo
    out_slice = getattr(lk, "out_slice", None)
    return (h.digest(), prog.engine, prog.sew, kernel,
            None if out_slice is None else tuple(map(int, out_slice)),
            _spans_key(getattr(lk, "init_spans", None)),
            int(getattr(lk, "used_words", 0) or 0),
            None if passes is None else tuple(passes))


def verify_lowered(lk, kernel: Optional[str] = None,
                   passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Verify a frontend :class:`repro.nmc.frontend.LoweredKernel` (or any
    duck-typed build) using its recorded lowering metadata."""
    prog = lk.program
    kernel = kernel or getattr(lk, "kernel", None) \
        or f"<{prog.engine} kernel>"
    key = _lowered_key(lk, kernel, passes)
    if key is not None:
        cached = _report_memo.get(key)
        if cached is not None:
            _report_memo.move_to_end(key)
            return cached
    report = verify_program(
        prog, kernel=kernel,
        out_slice=getattr(lk, "out_slice", None),
        init_spans=getattr(lk, "init_spans", None),
        used_words=getattr(lk, "used_words", 0) or 0,
        prov=getattr(lk, "prov", None), passes=passes)
    if key is not None:
        _report_memo[key] = report
        while len(_report_memo) > _MEMO_CAP:
            _report_memo.popitem(last=False)
    return report


# ---------------------------------------------------------------------------
# Cheap dispatch-time asserts (pool / runtime hot path)
# ---------------------------------------------------------------------------

def assert_submittable(program: Program) -> None:
    """O(n) structural floor checked at every queue submit: right dtype,
    known engine/sew, opcodes within the engine's id space.  Full
    verification belongs at lowering time (``nmc.jit(check=...)``) — this
    is the last-line invariant of the runtime boundary."""
    assert isinstance(program, Program), type(program)
    assert program.engine in ENGINES, program.engine
    assert program.sew in alu.SEWS, program.sew
    assert program.entries.dtype == PROG_DTYPE, program.entries.dtype
    if program.n_instr:
        op = program.entries["op"]
        lo, hi = int(op.min()), int(op.max())
        max_id = max(engine_op_ids(program.engine))
        assert 0 <= lo and hi <= max_id, \
            f"opcode {lo if lo < 0 else hi} outside the {program.engine} " \
            f"id space [0, {max_id}]"


def assert_wave(programs: Sequence[Program]) -> None:
    """Wave-level invariants asserted by the bucketed schedulers after
    padding: a non-empty wave of same-(engine, sew, n_instr) programs,
    each individually submittable."""
    assert programs, "empty wave"
    keys = {p.shape_key for p in programs}
    assert len(keys) == 1, f"mixed shape keys in one wave: {sorted(keys)}"
    for p in programs:
        assert_submittable(p)


# ---------------------------------------------------------------------------
# CLI: sweep every registry kernel x engine x SEW (the CI lint gate)
# ---------------------------------------------------------------------------

def _sweep_rows(kernels: Sequence[str], sews: Sequence[int],
                engines: Sequence[str]) -> list:
    from repro.core import programs as P
    rows = []
    for name in kernels:
        for sew in sews:
            kb = P.build(name, sew)
            for engine in engines:
                eb = getattr(kb, engine, None)
                if eb is None:
                    continue
                lk = getattr(eb, "lowered", None)
                rep = verify_lowered(lk, kernel=f"{name}/{engine}/sew{sew}") \
                    if lk is not None else verify_program(
                        eb.program, kernel=f"{name}/{engine}/sew{sew}")
                rows.append((name, sew, engine, eb.program.n_instr, rep))
    return rows


def _wave_rows(sews: Sequence[int]) -> list:
    """Partition-safety coverage for the sweep: one axis wave (with a
    slide halo) and one rows wave, verified end to end."""
    from repro.nmc import frontend

    def slide_sum(t, x):
        a = t.load(x)
        t.store(a + a.slide_down(2), n=a.ne - 2)

    def row_quad(t, *cols):
        for c in cols:
            t.store(t.load(c) * 3 + 1)

    rng = np.random.default_rng(0)
    rows = []
    for sew in sews:
        n = 64 * (32 // sew)
        x = rng.integers(-100, 100, n)
        k = frontend.jit(slide_sum, sew=sew, check="off")
        plan, lks = k.lower_wave(x, tiles=2)
        rep = verify_wave(k.trace(x), plan, lks, kernel=f"axis-wave/sew{sew}")
        rows.append(("axis-wave", sew, lks[0].engine,
                     lks[0].program.n_instr, rep))
        cols = [rng.integers(-100, 100, n) for _ in range(4)]
        k = frontend.jit(row_quad, sew=sew, check="off")
        plan, lks = k.lower_wave(*cols, tiles=2)
        rep = verify_wave(k.trace(*cols), plan, lks,
                          kernel=f"rows-wave/sew{sew}")
        rows.append(("rows-wave", sew, lks[0].engine,
                     lks[0].program.n_instr, rep))
    return rows


#: ``--report`` JSON schema version: bump only on breaking key changes.
REPORT_SCHEMA = 1


def _report_json(rows: Sequence, strict: bool) -> dict:
    """The sweep as a stable-schema JSON document (the CI artifact).

    Top-level keys: ``schema`` (int), ``strict`` (bool), ``targets``
    (list of per-target records with ``kernel``/``sew``/``engine``/
    ``n_instr``/``errors``/``warnings``/``status``/``diagnostics``), and
    ``summary`` (``targets``/``errors``/``warnings``/``status``).
    Diagnostic records use :meth:`Diagnostic.as_dict` keys."""
    targets = []
    n_err = n_warn = 0
    for name, sew, engine, n_instr, rep in rows:
        e, w = len(rep.errors), len(rep.warnings)
        n_err += e
        n_warn += w
        targets.append({
            "kernel": name, "sew": int(sew), "engine": engine,
            "n_instr": int(n_instr), "errors": e, "warnings": w,
            "status": "fail" if e or (strict and w) else "ok",
            "diagnostics": [d.as_dict() for d in rep.diagnostics
                            if d.severity != "info"],
        })
    return {
        "schema": REPORT_SCHEMA,
        "strict": bool(strict),
        "targets": targets,
        "summary": {
            "targets": len(targets), "errors": n_err, "warnings": n_warn,
            "status": "fail" if n_err or (strict and n_warn) else "ok",
        },
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import json
    from repro.core import programs as P

    ap = argparse.ArgumentParser(
        prog="python -m repro.nmc.check",
        description="Static verification sweep over the kernel registry.")
    ap.add_argument("--all", action="store_true",
                    help="sweep every registry kernel x engine x SEW "
                         "(default when no --kernel is given)")
    ap.add_argument("--kernel", action="append", default=None,
                    help="restrict to one registry kernel (repeatable)")
    ap.add_argument("--sew", action="append", type=int, default=None,
                    help="restrict to one element width (repeatable)")
    ap.add_argument("--engine", action="append", default=None,
                    choices=list(ENGINES), help="restrict to one engine")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the sweep as JSON to PATH "
                         "(CI artifact, schema v%d)" % REPORT_SCHEMA)
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not just errors")
    ap.add_argument("--no-waves", action="store_true",
                    help="skip the partitioned-wave checks")
    args = ap.parse_args(argv)

    kernels = args.kernel or list(P.ALL_KERNELS)
    sews = args.sew or sorted(alu.SEWS)
    engines = args.engine or list(ENGINES)
    rows = _sweep_rows(kernels, sews, engines)
    if not args.no_waves:
        rows += _wave_rows(sews)

    lines = [f"{'kernel':<12} {'sew':>3} {'engine':<7} {'instrs':>7} "
             f"{'errors':>6} {'warns':>5}  status"]
    n_err = n_warn = 0
    details = []
    for name, sew, engine, n_instr, rep in rows:
        e, w = len(rep.errors), len(rep.warnings)
        n_err += e
        n_warn += w
        status = "FAIL" if e or (args.strict and w) else "ok"
        lines.append(f"{name:<12} {sew:>3} {engine:<7} {n_instr:>7} "
                     f"{e:>6} {w:>5}  {status}")
        if e or w:
            details.append(rep.render())
    lines.append(f"\n{len(rows)} targets verified: {n_err} error(s), "
                 f"{n_warn} warning(s)")
    if details:
        lines.append("\n" + "\n".join(details))
    print("\n".join(lines))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(_report_json(rows, args.strict), f, indent=2)
            f.write("\n")
        print(f"report written to {args.report}")
    return 1 if n_err or (args.strict and n_warn) else 0
