"""Static IR verifier + dataflow analysis for NMC programs (DESIGN.md §11).

The stack hands ``Program`` arrays across four layers (tracer -> lowering ->
partitioner -> bucketed pools -> scan/Pallas engines), and a malformed
stream — an out-of-range Carus register, a read of a never-written Caesar
word, a shard wave that misses part of the output store set — executes
silently and computes garbage.  This module is the correctness *tooling*
layer that rejects such programs before they reach an engine:

* :func:`verify_program` — composable static passes over one unified-IR
  :class:`repro.nmc.program.Program`, returning a :class:`CheckReport` of
  structured :class:`Diagnostic` records (severity, pass name, rule,
  instruction index, tracer-op provenance).  Passes:

  - **structural** — opcode valid for the engine, register/address ranges
    (Carus VRF bounds, Caesar word addresses vs the 32 KiB image),
    SEW-legal modes, Caesar entries structurally zero in Carus-only
    fields, padding NOPs truly neutral.
  - **dataflow** — def-use liveness: read-before-write against the
    image-defined spans, MAC/DOT accumulator chains (use-before-init,
    never-stored), dead writes (overwritten or never read), in-place
    VMACC hazards on Carus, and store coverage (every word of
    ``out_slice`` written or image-defined).
  - **resource** — allocator high-water vs engine capacity, plus an
    independent bank-conflict / instruction count estimate cross-checked
    against :mod:`repro.core.timing` (drift between the verifier's and
    the cost model's view of a program is itself an error).

* :func:`verify_lowered` — the same passes over a frontend
  :class:`repro.nmc.frontend.LoweredKernel`, using its recorded metadata
  (image-defined spans, per-instruction tracer provenance, kernel name).
* :func:`verify_plan` / :func:`verify_wave` — **partition safety**: shard
  store pieces exactly partition the parent store set, axis-shard loads
  carry a sufficient slide halo, and the common-bucket padding of a
  lowered wave is verifier-neutral.
* :func:`assert_wave` / :func:`assert_submittable` — the cheap O(entries)
  subset the hot scheduler layers (:class:`repro.nmc.pool.BucketedPool`,
  :class:`repro.nmc.runtime.DispatchQueue`) assert on every dispatch.

``python -m repro.nmc.check --all`` sweeps every registry kernel x engine
x SEW (plus partitioned waves) and prints a report — the CI lint gate.

The passes are numpy-vectorized (event sort over def/use streams, not a
per-instruction Python loop) and :func:`verify_lowered` memoizes its
verdict on a content fingerprint of the program, so
``nmc.jit(fn, check="error")`` — the default — verifies every lowering
at a few percent overhead (``benchmarks/check_bench.py`` is the gate).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc.program import ENGINES, NOP_OP_ID, PROG_DTYPE, Program
from repro.nmc.registry import engine_op_ids

#: Verification modes accepted by ``nmc.jit(fn, check=...)``.
CHECK_MODES = ("error", "warn", "off")

SEVERITIES = ("error", "warning", "info")
PASSES = ("structural", "dataflow", "resource", "partition")

#: Diagnostics reported per (pass, rule) before summarizing — a corrupted
#: 8k-instruction stream should not produce 8k records.
MAX_PER_RULE = 8

_CAESAR_MEM_WORDS = C.CAESAR_MEM_BYTES // C.WORD_BYTES
_CAESAR_BANK_WORDS = _CAESAR_MEM_WORDS // C.CAESAR_N_BANKS
_CARUS_REG_WORDS = C.CARUS_REG_WORDS
_CARUS_N_REGS = C.CARUS_N_VREGS

_NOP_C = NOP_OP_ID["caesar"]
_NOP_K = NOP_OP_ID["carus"]

# Caesar opcode classes, as boolean lookup tables over the (small) opcode
# space — `lut[clip(op)] & in-range` beats np.isin on the hot verify path
_LUT_N = 64


def _class_lut(ids) -> np.ndarray:
    lut = np.zeros(_LUT_N, bool)
    lut[np.array(sorted(int(i) for i in ids))] = True
    return lut


def _member(op: np.ndarray, lut: np.ndarray) -> np.ndarray:
    """Vectorized set membership; ids outside [0, _LUT_N) are non-members."""
    return lut[np.clip(op, 0, _LUT_N - 1)] & (op >= 0) & (op < _LUT_N)


_N_FIELDS = len(PROG_DTYPE.names)
_COL = {name: i for i, name in enumerate(PROG_DTYPE.names)}


def _columns(e: np.ndarray) -> np.ndarray:
    """The entries as a [n, 8] int32 matrix: column slices are much
    cheaper than repeated structured-field extraction on the hot path."""
    if not e.flags.c_contiguous:
        e = np.ascontiguousarray(e)
    return e.view(np.int32).reshape(len(e), _N_FIELDS)


def _caesar_code(ctx: _Ctx, op: np.ndarray) -> np.ndarray:
    """Per-op combined class code (see :data:`_C_CODE`), computed once per
    verification and shared between the structural and dataflow passes."""
    code = ctx.cache.get("ccode")
    if code is None:
        code = _C_CODE[np.clip(op, 0, _LUT_N - 1)]   # fancy index: a copy
        if len(op) and int(op.min()) < 0:
            code[op < 0] = 0
        ctx.cache["ccode"] = code
    return code


_C_STORE = _class_lut(isa.CAESAR_STORE_OPS)
_C_READ = _class_lut(o for o in CaesarOp
                     if o not in (CaesarOp.CSRW, CaesarOp.NOP))
_C_VALID = _class_lut(engine_op_ids("caesar"))

# combined per-op class code (bit0 read, bit1 store, bit2 valid, bit3
# MAC/DOT chain) — one lookup serves the structural and dataflow passes
_C_CODE = (_C_READ * 1 + _C_STORE * 2 + _C_VALID * 4
           + _class_lut([CaesarOp.MAC_INIT, CaesarOp.MAC,
                         CaesarOp.MAC_STORE, CaesarOp.DOT_INIT,
                         CaesarOp.DOT, CaesarOp.DOT_STORE]) * 8
           ).astype(np.int8)

# Carus compact-id classes
_K_ID = isa.COMPACT_ID
_K_ARITH = _class_lut(_K_ID[v] for v in isa.ARITH_OPS)
_K_MACC = _K_ID[VOp.VMACC]
_K_MV = _K_ID[VOp.VMV]
_K_SLIDES = _class_lut([_K_ID[VOp.VSLIDEUP], _K_ID[VOp.VSLIDEDOWN]])
_K_EMVV, _K_EMVX = _K_ID[VOp.EMVV], _K_ID[VOp.EMVX]
_K_SETVL = _K_ID[VOp.VSETVL]
_K_MODE_BITS = 0x3 | isa.MODE_INDIRECT | isa.MODE_SLIDE1


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding, with enough provenance to locate the defect:
    the pass and rule that fired, the instruction index in the lowered
    stream, and (when the program came from the traced frontend) the
    tracer-op index it lowered from."""

    severity: str               # "error" | "warning" | "info"
    pass_name: str              # "structural" | "dataflow" | ...
    rule: str                   # stable slug, e.g. "read-before-write"
    message: str
    kernel: Optional[str] = None
    instr: Optional[int] = None       # instruction index in the stream
    op_index: Optional[int] = None    # tracer node index (provenance)

    def __str__(self) -> str:
        where = self.kernel or "<program>"
        if self.instr is not None:
            where += f" instr#{self.instr}"
        if self.op_index is not None:
            where += f" (traced op#{self.op_index})"
        return (f"{self.severity}[{self.pass_name}/{self.rule}] "
                f"{where}: {self.message}")


@dataclasses.dataclass
class CheckReport:
    """All diagnostics of one verification run."""

    target: str                       # what was verified (kernel / plan)
    diagnostics: List[Diagnostic]

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        """No errors (warnings and infos allowed)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """No errors and no warnings (infos allowed)."""
        return not self.errors and not self.warnings

    def by_rule(self, rule: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.rule == rule]

    def render(self) -> str:
        if not self.diagnostics:
            return f"{self.target}: clean"
        lines = [f"{self.target}: {len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def raise_if_errors(self) -> "CheckReport":
        if self.errors:
            raise VerificationError(self)
        return self

    def extend(self, other: "CheckReport") -> "CheckReport":
        self.diagnostics.extend(other.diagnostics)
        return self


class VerificationError(Exception):
    """A program failed static verification (``check="error"``)."""

    def __init__(self, report: CheckReport):
        self.report = report
        super().__init__(report.render())


# ---------------------------------------------------------------------------
# Pass context + emission helpers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Ctx:
    kernel: Optional[str]
    out_slice: Optional[Tuple[int, int]]
    init_spans: Optional[Sequence[Tuple[int, int]]]   # image-defined words
    used_words: int
    prov: Optional[Sequence[int]]
    diags: List[Diagnostic]
    cache: dict = dataclasses.field(default_factory=dict)

    def op_index(self, instr: Optional[int]) -> Optional[int]:
        if instr is None or self.prov is None or instr >= len(self.prov):
            return None
        return self.prov[instr]

    def emit(self, severity: str, pass_name: str, rule: str, message: str,
             instr: Optional[int] = None) -> None:
        self.diags.append(Diagnostic(
            severity, pass_name, rule, message, kernel=self.kernel,
            instr=None if instr is None else int(instr),
            op_index=self.op_index(instr)))

    def emit_rows(self, severity: str, pass_name: str, rule: str,
                  rows: np.ndarray, fmt: Callable[[int], str]) -> None:
        """Emit one diagnostic per flagged instruction row, capped at
        :data:`MAX_PER_RULE` with a summarizing tail record."""
        rows = np.asarray(rows)
        for i in rows[:MAX_PER_RULE]:
            self.emit(severity, pass_name, rule, fmt(int(i)), instr=int(i))
        if len(rows) > MAX_PER_RULE:
            self.emit(severity, pass_name, rule,
                      f"... and {len(rows) - MAX_PER_RULE} more "
                      f"'{rule}' findings")


def _defined_words(ctx: _Ctx, capacity: int) -> Optional[np.ndarray]:
    """Boolean image-defined map, or None when unknown (hand-built
    programs verify structurally but skip init-sensitive dataflow)."""
    if ctx.init_spans is None:
        return None
    defined = np.zeros(capacity, bool)
    for start, nw in ctx.init_spans:
        lo = max(0, int(start))
        defined[lo:min(capacity, int(start) + int(nw))] = True
    return defined


# ---------------------------------------------------------------------------
# Structural pass
# ---------------------------------------------------------------------------

def _structural_caesar(e: np.ndarray, ctx: _Ctx) -> None:
    m = _columns(e)
    op = m[:, 0]
    code = _caesar_code(ctx, op)
    bad = (code & 4) == 0
    ctx.emit_rows("error", "structural", "bad-opcode", np.flatnonzero(bad),
                  lambda i: f"opcode {int(op[i])} is not an NM-Caesar "
                            f"bus micro-op")
    addrs = m[:, 1:4]                   # dest / src1 / src2
    oob_any = (addrs < 0) | (addrs >= _CAESAR_MEM_WORDS)
    if oob_any.any():                   # clean programs skip the per-field walk
        real = ~bad & (op != _NOP_C)
        for c, f in enumerate(("dest", "src1", "src2")):
            v = addrs[:, c]
            ctx.emit_rows(
                "error", "structural", "oob-address",
                np.flatnonzero(real & oob_any[:, c]),
                lambda i, f=f, v=v: f"{f}={int(v[i])} outside the "
                f"{_CAESAR_MEM_WORDS}-word (32 KiB) macro")
    carus_f = m[:, 4:]                  # sval1 / sval2 / imm / mode
    junk = None
    if carus_f.any():
        junk = carus_f.any(axis=1)
        ctx.emit_rows(
            "error", "structural", "nonzero-carus-field",
            np.flatnonzero(junk),
            lambda i: "Caesar entries must be structurally zero in the "
            "Carus-only fields (sval1/sval2/imm/mode); Program.from_entries "
            "normalizes them")
    nops = op == _NOP_C
    if nops.any():
        nop_bad = nops & addrs.any(axis=1)
        if junk is not None:
            nop_bad &= ~junk
        ctx.emit_rows(
            "error", "structural", "nop-not-neutral",
            np.flatnonzero(nop_bad),
            lambda i: "padding NOP carries non-zero operand fields — not a "
            "neutral bucket filler")


def _carus_regs(e: np.ndarray) -> tuple:
    """Resolved (vd, vs2, vs1) operand indices per entry: direct fields,
    or the bytes of ``sval2`` under MODE_INDIRECT (the engine resolves
    these at runtime and silently wraps modulo n_regs — exactly the bug
    class the bounds check below catches statically)."""
    ind = (e["mode"] & isa.MODE_INDIRECT) != 0
    s2 = e["sval2"]
    vd = np.where(ind, (s2 >> 16) & 0xFF, e["dest"])
    vs2 = np.where(ind, (s2 >> 8) & 0xFF, e["src2"])
    vs1 = np.where(ind, s2 & 0xFF, e["src1"])
    return vd, vs2, vs1


def _carus_uses(e: np.ndarray) -> tuple:
    """Boolean (uses_vd, reads_vd, uses_vs2, uses_vs1, writes_vd) masks
    from the engine's operand semantics per opcode and mode."""
    op, opmode = e["op"], e["mode"] & 0x3
    arith = _member(op, _K_ARITH)
    macc = op == _K_MACC
    mv = op == _K_MV
    slide = _member(op, _K_SLIDES)
    vv = opmode == isa.MODE_VV
    writes_vd = arith | macc | mv | slide | (op == _K_EMVV)
    reads_vd = macc | (op == _K_EMVV)      # in-place accumulate / RMW lane
    uses_vs2 = arith | macc | slide | (op == _K_EMVX)
    uses_vs1 = (arith | macc | mv) & vv    # .vv second operand (VMV copies)
    return writes_vd | reads_vd, reads_vd, uses_vs2, uses_vs1, writes_vd


def _carus_operands(ctx: _Ctx, e: np.ndarray) -> tuple:
    """(regs, uses) for the program, cached on the ctx: both the
    structural and the dataflow pass need them, and on the tiny programs
    carus lowers to, the numpy-call count is the whole verify cost."""
    ops = ctx.cache.get("kops")
    if ops is None:
        ops = (_carus_regs(e), _carus_uses(e))
        ctx.cache["kops"] = ops
    return ops


def _structural_carus(e: np.ndarray, ctx: _Ctx, sew: int) -> None:
    op = e["op"]
    bad = (op < 0) | (op >= len(isa.VOP_COMPACT))
    ctx.emit_rows("error", "structural", "bad-opcode", np.flatnonzero(bad),
                  lambda i: f"opcode {int(op[i])} is outside the xvnmc "
                            f"compact-id space [0, {len(isa.VOP_COMPACT)})")
    ok = ~bad
    mode = e["mode"]
    bad_mode = ok & (((mode & ~_K_MODE_BITS) != 0) | ((mode & 0x3) == 0x3))
    ctx.emit_rows("error", "structural", "bad-mode",
                  np.flatnonzero(bad_mode),
                  lambda i: f"mode={int(mode[i])} is not a legal "
                            f"vv/vx/vi (+indirect/slide1) encoding")
    (vd, vs2, vs1), (uses_vd, _, uses_vs2, uses_vs1, _) = \
        _carus_operands(ctx, e)
    for name, idxs, used in (("vd", vd, uses_vd), ("vs2", vs2, uses_vs2),
                             ("vs1", vs1, uses_vs1)):
        oob = ok & used & ((idxs < 0) | (idxs >= _CARUS_N_REGS))
        ctx.emit_rows(
            "error", "structural", "oob-register", np.flatnonzero(oob),
            lambda i, name=name, idxs=idxs: f"{name}=v{int(idxs[i])} "
            f"outside the {_CARUS_N_REGS}-register VRF (the engine would "
            f"silently wrap modulo {_CARUS_N_REGS})")
    setvl = ok & (op == _K_SETVL)
    vlmax = _CARUS_REG_WORDS * (32 // sew)
    sval1 = e["sval1"]
    ctx.emit_rows(
        "warning", "structural", "vl-clamped",
        np.flatnonzero(setvl & (sval1 > vlmax)),
        lambda i: f"VSETVL requests vl={int(sval1[i])} > VLMAX({sew})="
        f"{vlmax}; the engine clamps")
    ctx.emit_rows(
        "warning", "structural", "vl-empty",
        np.flatnonzero(setvl & (sval1 <= 0)),
        lambda i: f"VSETVL requests vl={int(sval1[i])}: every following "
        f"vector op writes nothing")
    nop_bad = (op == _NOP_K) & (
        (e["dest"] | e["src1"] | e["src2"] | e["sval1"] | e["sval2"]
         | e["imm"] | e["mode"]) != 0)
    ctx.emit_rows(
        "error", "structural", "nop-not-neutral", np.flatnonzero(nop_bad),
        lambda i: "padding VNOP carries non-zero fields — not a neutral "
        "bucket filler")


def check_structural(prog: Program, ctx: _Ctx) -> None:
    if prog.engine == "caesar":
        _structural_caesar(prog.entries, ctx)
    else:
        _structural_carus(prog.entries, ctx, prog.sew)


# ---------------------------------------------------------------------------
# Dataflow pass: event-sorted def-use analysis
# ---------------------------------------------------------------------------

def _event_analysis(ctx: _Ctx, capacity: int, unit: str,
                    r_loc: np.ndarray, r_row: np.ndarray,
                    w_loc: np.ndarray, w_row: np.ndarray,
                    out_range: Optional[Tuple[int, int]],
                    acc_read_rows: Optional[np.ndarray] = None) -> None:
    """Shared def-use core for both engines: sort (location, row, kind)
    events — reads before writes at the same instruction, so an in-place
    update reads its old value first — then flag reads whose location's
    first event is that read (read-before-write, against the image-defined
    map), writes whose next same-location event is another write
    (dead-write / WAW), final writes that fall outside the output window,
    and output words never written nor image-defined."""
    defined = _defined_words(ctx, capacity)
    nr, nw = len(r_loc), len(w_loc)
    if nr + nw:
        # pack each event into one int64 key (loc, then row, then
        # read<write) and sort it IN PLACE — row and kind are recovered by
        # decoding the key, so no permutation array, no gathers, and no
        # 3-key lexsort on the <5% lowering-overhead hot path
        mr = int(r_row.max()) if nr else 0
        mw = int(w_row.max()) if nw else 0
        # power-of-two span: decode is a shift/mask, not an int division
        # (arithmetic right shift floors, so negative garbage locs from
        # corrupted programs still decode and sort consistently)
        shift = (2 * max(mr, mw) + 1).bit_length()
        key = np.empty(nr + nw, np.int64)
        key[:nr] = (r_loc << shift) + 2 * r_row
        key[nr:] = (w_loc << shift) + 2 * w_row + 1
        key.sort()
        loc = key >> shift
        kind = key & 1
    else:
        loc = kind = np.zeros(0, np.int64)
        shift = 1

    def row_at(p: int) -> int:
        # rows only matter at finding positions — decode lazily per hit
        return (int(key[p]) & ((1 << shift) - 1)) >> 1

    first = np.empty(len(loc), bool)
    if len(loc):
        first[0] = True
        first[1:] = loc[1:] != loc[:-1]

    if defined is not None and len(loc):
        cand = np.flatnonzero(first & (kind == 0))
        pos = cand[~defined[np.clip(loc[cand], 0, capacity - 1)]]
        acc_rows = set() if acc_read_rows is None else set(
            int(r) for r in acc_read_rows)
        for p in pos[:MAX_PER_RULE]:
            extra = (" (in-place VMACC accumulator)"
                     if row_at(p) in acc_rows else "")
            ctx.emit("error", "dataflow", "read-before-write",
                     f"reads {unit} {int(loc[p])} before any write "
                     f"(not image-defined either){extra}",
                     instr=row_at(p))
        if len(pos) > MAX_PER_RULE:
            ctx.emit("error", "dataflow", "read-before-write",
                     f"... and {len(pos) - MAX_PER_RULE} more "
                     f"'read-before-write' findings")

    if len(loc):
        nxt_same = np.empty(len(loc), bool)
        nxt_same[-1] = False
        nxt_same[:-1] = loc[1:] == loc[:-1]
        waw = np.zeros(len(loc), bool)
        waw[:-1] = (kind[:-1] == 1) & nxt_same[:-1] & (kind[1:] == 1)
        pos = np.flatnonzero(waw)
        for p in pos[:MAX_PER_RULE]:
            ctx.emit("warning", "dataflow", "dead-write",
                     f"{unit} {int(loc[p])} is overwritten at "
                     f"instr#{row_at(p + 1)} before any read",
                     instr=row_at(p))
        if len(pos) > MAX_PER_RULE:
            ctx.emit("warning", "dataflow", "dead-write",
                     f"... and {len(pos) - MAX_PER_RULE} more "
                     f"'dead-write' findings")
        if out_range is not None:
            lo, hi = out_range
            final = (kind == 1) & ~nxt_same
            dead_final = final & ((loc < lo) | (loc >= hi))
            pos = np.flatnonzero(dead_final)
            for p in pos[:MAX_PER_RULE]:
                ctx.emit("warning", "dataflow", "dead-write",
                         f"{unit} {int(loc[p])} is written, never read, "
                         f"and outside the output window [{lo}, {hi})",
                         instr=row_at(p))
            if len(pos) > MAX_PER_RULE:
                ctx.emit("warning", "dataflow", "dead-write",
                         f"... and {len(pos) - MAX_PER_RULE} more "
                         f"'dead-write' findings")

    # store coverage: every output location written or image-defined
    if out_range is not None and defined is not None:
        lo, hi = out_range
        covered = defined.copy()
        if len(w_loc):
            covered[np.clip(w_loc, 0, capacity - 1)] = True
        missing = np.flatnonzero(~covered[lo:hi]) + lo
        for m in missing[:MAX_PER_RULE]:
            ctx.emit("error", "dataflow", "uncovered-store",
                     f"output {unit} {int(m)} is never written and not "
                     f"image-defined — the extracted result would be "
                     f"uninitialized zeros")
        if len(missing) > MAX_PER_RULE:
            ctx.emit("error", "dataflow", "uncovered-store",
                     f"... and {len(missing) - MAX_PER_RULE} more "
                     f"uncovered output {unit}s")


def _chain_check(ctx: _Ctx, op: np.ndarray, init_id: int, body_id: int,
                 store_id: int, label: str) -> None:
    """Accumulator-chain protocol (MAC_INIT/MAC/MAC_STORE and the DOT
    triple): body/store ops require a live chain; INIT while live (and a
    chain that never stores) are dead accumulations."""
    chain = (op == init_id) | (op == body_id) | (op == store_id)
    if not chain.any():
        return
    rows = np.flatnonzero(chain)
    kinds = op[rows]
    t = np.where(kinds == init_id, 1, np.where(kinds == store_id, -1, 0))
    nz = np.flatnonzero(t != 0)
    last = np.full(len(rows), -1)
    if len(nz):
        marks = np.full(len(rows), -1)
        marks[nz] = nz
        last = np.maximum.accumulate(marks)
    prev = np.concatenate([[-1], last[:-1]])
    live_before = (prev >= 0) & (t[np.clip(prev, 0, None)] == 1)
    use_dead = ((kinds == body_id) | (kinds == store_id)) & ~live_before
    ctx.emit_rows(
        "error", "dataflow", "acc-use-before-init",
        rows[np.flatnonzero(use_dead)],
        lambda i: f"{label} accumulator used with no live "
        f"{label}_INIT chain")
    reinit = (kinds == init_id) & live_before
    ctx.emit_rows(
        "warning", "dataflow", "dead-accumulator",
        rows[np.flatnonzero(reinit)],
        lambda i: f"{label}_INIT while the previous chain was never "
        f"stored — the pending accumulation is dead")
    if last[-1] >= 0 and t[last[-1]] == 1:
        ctx.emit("warning", "dataflow", "dead-accumulator",
                 f"{label} chain never reaches {label}_STORE — the "
                 f"accumulation is dead", instr=int(rows[last[-1]]))


def _dataflow_caesar(prog: Program, ctx: _Ctx) -> None:
    m = _columns(prog.entries)
    op = m[:, 0]
    code = _caesar_code(ctx, op)
    ridx = np.flatnonzero(code & 1)
    widx = np.flatnonzero(code & 2)
    r_loc = m[ridx, 2:4].T.reshape(-1)          # src1 then src2 reads
    r_row = np.concatenate([ridx, ridx])
    out = None
    if ctx.out_slice is not None:
        out = (int(ctx.out_slice[0]), int(ctx.out_slice[0])
               + int(ctx.out_slice[1]))
    _event_analysis(ctx, _CAESAR_MEM_WORDS, "word",
                    r_loc.astype(np.int64), r_row,
                    m[widx, 1].astype(np.int64), widx, out)
    if (code & 8).any():                        # any MAC/DOT chain ops
        _chain_check(ctx, op, int(CaesarOp.MAC_INIT), int(CaesarOp.MAC),
                     int(CaesarOp.MAC_STORE), "MAC")
        _chain_check(ctx, op, int(CaesarOp.DOT_INIT), int(CaesarOp.DOT),
                     int(CaesarOp.DOT_STORE), "DOT")


def _dataflow_carus(prog: Program, ctx: _Ctx) -> None:
    e = prog.entries
    rows = np.arange(len(e))
    (vd, vs2, vs1), (_, reads_vd, uses_vs2, uses_vs1, writes_vd) = \
        _carus_operands(ctx, e)
    # match the engine's wrap so the dataflow stays well-indexed even when
    # the structural pass already flagged an out-of-range register
    vd, vs2, vs1 = (vd % _CARUS_N_REGS, vs2 % _CARUS_N_REGS,
                    vs1 % _CARUS_N_REGS)
    r_loc = np.concatenate([vs2[uses_vs2], vs1[uses_vs1], vd[reads_vd]])
    r_row = np.concatenate([rows[uses_vs2], rows[uses_vs1], rows[reads_vd]])
    out = None
    if ctx.out_slice is not None:
        lo, nw = int(ctx.out_slice[0]), int(ctx.out_slice[1])
        out = (lo // _CARUS_REG_WORDS,
               -(-(lo + nw) // _CARUS_REG_WORDS))
    # register-granular init map: a load/cpool block defines its registers
    reg_ctx = ctx
    if ctx.init_spans is not None:
        reg_spans = [(s // _CARUS_REG_WORDS,
                      -(-(s + n) // _CARUS_REG_WORDS) - s // _CARUS_REG_WORDS)
                     for s, n in ctx.init_spans]
        reg_ctx = dataclasses.replace(ctx, init_spans=reg_spans)
    _event_analysis(reg_ctx, _CARUS_N_REGS, "register",
                    r_loc.astype(np.int64), r_row,
                    vd[writes_vd].astype(np.int64), rows[writes_vd], out,
                    acc_read_rows=rows[reads_vd])


def check_dataflow(prog: Program, ctx: _Ctx) -> None:
    if prog.engine == "caesar":
        _dataflow_caesar(prog, ctx)
    else:
        _dataflow_carus(prog, ctx)


# ---------------------------------------------------------------------------
# Resource pass
# ---------------------------------------------------------------------------

def check_resource(prog: Program, ctx: _Ctx) -> None:
    from repro.core import timing
    cap = _CAESAR_MEM_WORDS if prog.engine == "caesar" \
        else _CARUS_N_REGS * _CARUS_REG_WORDS
    if ctx.used_words:
        if ctx.used_words > cap:
            ctx.emit("error", "resource", "capacity",
                     f"allocator high-water {ctx.used_words} words exceeds "
                     f"the {cap}-word tile capacity")
        else:
            ctx.emit("info", "resource", "mem-highwater",
                     f"{ctx.used_words}/{cap} words "
                     f"({100.0 * ctx.used_words / cap:.1f}%) of tile "
                     f"memory occupied")
    try:
        report = timing.program_cycles(prog)
    except Exception as exc:  # corrupted stream: the cost model rejects it
        ctx.emit("error", "resource", "timing-drift",
                 f"timing.program_cycles rejects the program outright "
                 f"({type(exc).__name__}: {exc})")
        return
    n_real = prog.n_instr - prog.n_nops
    if report.n_instrs != n_real:
        ctx.emit("error", "resource", "timing-drift",
                 f"timing model costs {report.n_instrs} instructions, the "
                 f"verifier counts {n_real} non-NOP entries — the cost "
                 f"model and the IR disagree")
    if prog.engine == "caesar":
        m = _columns(prog.entries)
        real = m[:, 0] != _NOP_C
        same = int(np.count_nonzero(
            real & (m[:, 2] // _CAESAR_BANK_WORDS
                    == m[:, 3] // _CAESAR_BANK_WORDS)))
        modeled = report.detail.get("same_bank_ops")
        if modeled != same:
            ctx.emit("error", "resource", "timing-drift",
                     f"static bank-conflict estimate ({same} same-bank "
                     f"ops) disagrees with timing.program_cycles "
                     f"({modeled})")
        elif same:
            ctx.emit("info", "resource", "bank-conflicts",
                     f"{same}/{n_real} ops fetch both operands from one "
                     f"bank (+{C.CAESAR_SAME_BANK_CYCLES - C.CAESAR_CYCLES_PER_OP} "
                     f"cycle each, Section III-A2)")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

_PASS_FNS = {"structural": check_structural, "dataflow": check_dataflow,
             "resource": check_resource}


def verify_program(prog: Program, *, kernel: Optional[str] = None,
                   out_slice: Optional[Tuple[int, int]] = None,
                   init_spans: Optional[Sequence[Tuple[int, int]]] = None,
                   used_words: int = 0,
                   prov: Optional[Sequence[int]] = None,
                   passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Run the static passes over one unified-IR program.

    ``init_spans`` lists the image-defined word spans (loads, constant
    pools) — without it the read-before-write and store-coverage checks
    are skipped (a bare program has no image to check against).  ``prov``
    maps instruction index -> tracer op index for provenance."""
    target = kernel or f"<{prog.engine} program>"
    ctx = _Ctx(kernel=target, out_slice=out_slice, init_spans=init_spans,
               used_words=int(used_words), prov=prov, diags=[])
    if prog.engine not in ENGINES:
        ctx.emit("error", "structural", "bad-engine",
                 f"unknown engine {prog.engine!r}")
        return CheckReport(target, ctx.diags)
    if prog.sew not in alu.SEWS:
        ctx.emit("error", "structural", "bad-sew",
                 f"sew={prog.sew!r} is not one of {sorted(alu.SEWS)}")
        return CheckReport(target, ctx.diags)
    for name in (passes or ("structural", "dataflow", "resource")):
        _PASS_FNS[name](prog, ctx)
    return CheckReport(target, ctx.diags)


# Verification is a pure function of (program bytes, lowering metadata),
# and the synchronous call path re-lowers an identical program on every
# invocation — memoize the verdict on a content fingerprint so repeated
# lowerings pay one 64 KiB hash, not the full pass pipeline.  In-place
# corruption of `entries` changes the fingerprint, so tampering is never
# masked by the cache.
_MEMO_CAP = 256
_report_memo: "OrderedDict[tuple, CheckReport]" = OrderedDict()


def clear_memo() -> None:
    """Drop the ``verify_lowered`` verdict cache (benchmarks, tests)."""
    _report_memo.clear()


def _spans_key(spans) -> Optional[tuple]:
    return None if spans is None else tuple((int(s), int(n)) for s, n in spans)


def _lowered_key(lk, kernel: str, passes) -> Optional[tuple]:
    prog = lk.program
    e = prog.entries
    if not e.flags.c_contiguous:
        e = np.ascontiguousarray(e)
    h = hashlib.blake2b(e, digest_size=16)
    prov = getattr(lk, "prov", None)
    if prov is not None:
        try:
            h.update(np.ascontiguousarray(prov, dtype=np.int64))
        except (TypeError, ValueError):
            return None                 # unhashable provenance: skip memo
    out_slice = getattr(lk, "out_slice", None)
    return (h.digest(), prog.engine, prog.sew, kernel,
            None if out_slice is None else tuple(map(int, out_slice)),
            _spans_key(getattr(lk, "init_spans", None)),
            int(getattr(lk, "used_words", 0) or 0),
            None if passes is None else tuple(passes))


def verify_lowered(lk, kernel: Optional[str] = None,
                   passes: Optional[Sequence[str]] = None) -> CheckReport:
    """Verify a frontend :class:`repro.nmc.frontend.LoweredKernel` (or any
    duck-typed build) using its recorded lowering metadata."""
    prog = lk.program
    kernel = kernel or getattr(lk, "kernel", None) \
        or f"<{prog.engine} kernel>"
    key = _lowered_key(lk, kernel, passes)
    if key is not None:
        cached = _report_memo.get(key)
        if cached is not None:
            _report_memo.move_to_end(key)
            return cached
    report = verify_program(
        prog, kernel=kernel,
        out_slice=getattr(lk, "out_slice", None),
        init_spans=getattr(lk, "init_spans", None),
        used_words=getattr(lk, "used_words", 0) or 0,
        prov=getattr(lk, "prov", None), passes=passes)
    if key is not None:
        _report_memo[key] = report
        while len(_report_memo) > _MEMO_CAP:
            _report_memo.popitem(last=False)
    return report


# ---------------------------------------------------------------------------
# Partition safety
# ---------------------------------------------------------------------------

def verify_plan(parent, plan, kernel: Optional[str] = None) -> CheckReport:
    """Partition-safety pass over a :class:`repro.nmc.partition.
    PartitionPlan`: the shards' store pieces must exactly partition every
    parent store's element range (no gap, no overlap), and axis shards'
    loads must carry the full slide halo."""
    from repro.nmc.partition import slide_halo
    target = kernel or getattr(parent, "name", None) or "<plan>"
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=None, diags=[])
    per_store: dict = {si: [] for si in range(len(plan.store_trims))}
    for shard, pieces in enumerate(plan.pieces):
        for si, lo, hi in pieces:
            if si not in per_store:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"shard {shard} references store #{si}, but the "
                         f"parent tape has {len(plan.store_trims)} stores")
                continue
            per_store[si].append((lo, hi, shard))
    for si, trim in enumerate(plan.store_trims):
        ivs = sorted(per_store[si])
        pos = 0
        for lo, hi, shard in ivs:
            if lo > pos:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"store #{si}: elements [{pos}, {lo}) are covered "
                         f"by no shard")
            elif lo < pos:
                ctx.emit("error", "partition", "store-not-partitioned",
                         f"store #{si}: elements [{lo}, {min(pos, hi)}) "
                         f"are covered twice (shard {shard} overlaps)")
            pos = max(pos, hi)
        if pos < trim:
            ctx.emit("error", "partition", "store-not-partitioned",
                     f"store #{si}: elements [{pos}, {trim}) are covered "
                     f"by no shard")
    # halo sufficiency: axis shards replay every load sliced [lo, end);
    # end must reach hi + the tape's max cumulative slide read-ahead
    if plan.strategy in ("axis", "single") and plan.pieces:
        halo = slide_halo(parent)
        parent_loads = [n for n in parent.nodes if n.op == "load"]
        for shard, (b, pieces) in enumerate(zip(plan.builders, plan.pieces)):
            if not pieces:
                continue
            lo = min(p[1] for p in pieces)
            hi = max(p[2] for p in pieces)
            shard_loads = [n for n in b.nodes if n.op == "load"]
            for pl, sl in zip(parent_loads, shard_loads):
                required = min(hi + halo, pl.ne) - lo
                if sl.ne < required:
                    ctx.emit(
                        "error", "partition", "insufficient-halo",
                        f"shard {shard} load (traced op#{sl.idx}) carries "
                        f"{sl.ne} elements for piece [{lo}, {hi}) but "
                        f"slides read ahead {halo}: needs "
                        f"{required}")
    return CheckReport(target, ctx.diags)


def verify_wave(parent, plan, lks: Sequence,
                kernel: Optional[str] = None) -> CheckReport:
    """Partition safety + per-shard verification of a lowered wave,
    including the common-bucket padding contract: every shard program must
    sit at one shared instruction count with verifier-neutral NOP tails
    (the structural nop-not-neutral rule covers the tails)."""
    target = kernel or getattr(parent, "name", None) or "<wave>"
    report = verify_plan(parent, plan, kernel=target)
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=None, diags=report.diagnostics)
    sizes = {lk.program.n_instr for lk in lks}
    if len(sizes) > 1:
        ctx.emit("error", "partition", "wave-bucket-mismatch",
                 f"shard programs pad to different instruction counts "
                 f"{sorted(sizes)} — the wave would split into several "
                 f"compile buckets")
    for i, lk in enumerate(lks):
        report.extend(verify_lowered(lk, kernel=f"{target}[shard {i}]"))
    return report


# ---------------------------------------------------------------------------
# Cheap dispatch-time asserts (pool / runtime hot path)
# ---------------------------------------------------------------------------

def assert_submittable(program: Program) -> None:
    """O(n) structural floor checked at every queue submit: right dtype,
    known engine/sew, opcodes within the engine's id space.  Full
    verification belongs at lowering time (``nmc.jit(check=...)``) — this
    is the last-line invariant of the runtime boundary."""
    assert isinstance(program, Program), type(program)
    assert program.engine in ENGINES, program.engine
    assert program.sew in alu.SEWS, program.sew
    assert program.entries.dtype == PROG_DTYPE, program.entries.dtype
    if program.n_instr:
        op = program.entries["op"]
        lo, hi = int(op.min()), int(op.max())
        max_id = max(engine_op_ids(program.engine))
        assert 0 <= lo and hi <= max_id, \
            f"opcode {lo if lo < 0 else hi} outside the {program.engine} " \
            f"id space [0, {max_id}]"


def assert_wave(programs: Sequence[Program]) -> None:
    """Wave-level invariants asserted by the bucketed schedulers after
    padding: a non-empty wave of same-(engine, sew, n_instr) programs,
    each individually submittable."""
    assert programs, "empty wave"
    keys = {p.shape_key for p in programs}
    assert len(keys) == 1, f"mixed shape keys in one wave: {sorted(keys)}"
    for p in programs:
        assert_submittable(p)


# ---------------------------------------------------------------------------
# CLI: sweep every registry kernel x engine x SEW (the CI lint gate)
# ---------------------------------------------------------------------------

def _sweep_rows(kernels: Sequence[str], sews: Sequence[int],
                engines: Sequence[str]) -> list:
    from repro.core import programs as P
    rows = []
    for name in kernels:
        for sew in sews:
            kb = P.build(name, sew)
            for engine in engines:
                eb = getattr(kb, engine, None)
                if eb is None:
                    continue
                lk = getattr(eb, "lowered", None)
                rep = verify_lowered(lk, kernel=f"{name}/{engine}/sew{sew}") \
                    if lk is not None else verify_program(
                        eb.program, kernel=f"{name}/{engine}/sew{sew}")
                rows.append((name, sew, engine, eb.program.n_instr, rep))
    return rows


def _wave_rows(sews: Sequence[int]) -> list:
    """Partition-safety coverage for the sweep: one axis wave (with a
    slide halo) and one rows wave, verified end to end."""
    from repro.nmc import frontend

    def slide_sum(t, x):
        a = t.load(x)
        t.store(a + a.slide_down(2), n=a.ne - 2)

    def row_quad(t, *cols):
        for c in cols:
            t.store(t.load(c) * 3 + 1)

    rng = np.random.default_rng(0)
    rows = []
    for sew in sews:
        n = 64 * (32 // sew)
        x = rng.integers(-100, 100, n)
        k = frontend.jit(slide_sum, sew=sew, check="off")
        plan, lks = k.lower_wave(x, tiles=2)
        rep = verify_wave(k.trace(x), plan, lks, kernel=f"axis-wave/sew{sew}")
        rows.append(("axis-wave", sew, lks[0].engine,
                     lks[0].program.n_instr, rep))
        cols = [rng.integers(-100, 100, n) for _ in range(4)]
        k = frontend.jit(row_quad, sew=sew, check="off")
        plan, lks = k.lower_wave(*cols, tiles=2)
        rep = verify_wave(k.trace(*cols), plan, lks,
                          kernel=f"rows-wave/sew{sew}")
        rows.append(("rows-wave", sew, lks[0].engine,
                     lks[0].program.n_instr, rep))
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    from repro.core import programs as P

    ap = argparse.ArgumentParser(
        prog="python -m repro.nmc.check",
        description="Static verification sweep over the kernel registry.")
    ap.add_argument("--all", action="store_true",
                    help="sweep every registry kernel x engine x SEW "
                         "(default when no --kernel is given)")
    ap.add_argument("--kernel", action="append", default=None,
                    help="restrict to one registry kernel (repeatable)")
    ap.add_argument("--sew", action="append", type=int, default=None,
                    help="restrict to one element width (repeatable)")
    ap.add_argument("--engine", action="append", default=None,
                    choices=list(ENGINES), help="restrict to one engine")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the report to PATH (CI artifact)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not just errors")
    ap.add_argument("--no-waves", action="store_true",
                    help="skip the partitioned-wave checks")
    args = ap.parse_args(argv)

    kernels = args.kernel or list(P.ALL_KERNELS)
    sews = args.sew or sorted(alu.SEWS)
    engines = args.engine or list(ENGINES)
    rows = _sweep_rows(kernels, sews, engines)
    if not args.no_waves:
        rows += _wave_rows(sews)

    lines = [f"{'kernel':<12} {'sew':>3} {'engine':<7} {'instrs':>7} "
             f"{'errors':>6} {'warns':>5}  status"]
    n_err = n_warn = 0
    details = []
    for name, sew, engine, n_instr, rep in rows:
        e, w = len(rep.errors), len(rep.warnings)
        n_err += e
        n_warn += w
        status = "FAIL" if e or (args.strict and w) else "ok"
        lines.append(f"{name:<12} {sew:>3} {engine:<7} {n_instr:>7} "
                     f"{e:>6} {w:>5}  {status}")
        if e or w:
            details.append(rep.render())
    lines.append(f"\n{len(rows)} targets verified: {n_err} error(s), "
                 f"{n_warn} warning(s)")
    if details:
        lines.append("\n" + "\n".join(details))
    text = "\n".join(lines)
    print(text)
    if args.report:
        with open(args.report, "w") as f:
            f.write(text + "\n")
        print(f"report written to {args.report}")
    return 1 if n_err or (args.strict and n_warn) else 0
