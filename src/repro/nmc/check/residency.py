"""Residency hazard pass: resident-weight kernels and chained waves.

The PR 8 serving path keeps weight images resident on private tiles and
patches only the activation constant-pool words between calls
(:class:`repro.nmc.serve.block.ResidentProjection`).  That contract has
two static hazards the per-program passes cannot see:

* a **patch span** (cpool words rewritten every call) that aliases a
  **weight span** (words DMA'd once at construction) would corrupt the
  resident image on the first submit, and every later call computes
  against garbage weights;
* a program **write** landing inside any image-defined span mutates
  state the projection assumes immutable across calls — correct on call
  one, silently wrong on call two (a WAR hazard stretched across
  submissions);
* two **chained waves** touching the same tile would overlap DMA-out of
  wave *k* with DMA-in of wave *k+1* on that tile (a WAR hazard across
  the four dependent waves of a transformer block step).

:func:`verify_resident` proves the first two per lowered shard;
:func:`verify_chained_waves` proves tile-disjointness across a chained
wave schedule.  Both are wired into the serving layer at construction
time — the hazards are static properties of the layout, so one check at
build covers every future call.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.nmc.check.report import CheckReport, _Ctx
from repro.nmc.check.structural import _caesar_code, _columns


def _overlap(a: Tuple[int, int], b: Tuple[int, int]) -> bool:
    (s1, n1), (s2, n2) = a, b
    return s1 < s2 + n2 and s2 < s1 + n1


def verify_resident(lk, kernel: Optional[str] = None) -> CheckReport:
    """Prove a lowered kernel safe for weight residency: patch spans
    (``cpool_spans``) never alias the once-DMA'd weight spans, and no
    program write lands inside any image-defined span."""
    target = kernel or getattr(lk, "kernel", None) or "<resident>"
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=getattr(lk, "prov", None), diags=[])
    prog = lk.program
    if prog.engine != "caesar":
        ctx.emit("error", "residency", "engine-not-resident",
                 f"engine {prog.engine!r} embeds operand values in the "
                 f"instruction stream (EMVX scalars) — only caesar "
                 f"programs support the patch-only residency contract")
        return CheckReport(target, ctx.diags)
    cpools = [(int(s), int(n)) for s, n in (lk.cpool_spans or ())]
    weights = [sp for sp in ((int(s), int(n)) for s, n in
                             (lk.init_spans or ()))
               if sp not in cpools]
    for p in cpools:
        for w in weights:
            if _overlap(p, w):
                ctx.emit("error", "residency", "patch-aliases-weights",
                         f"patch span [{p[0]}, {p[0] + p[1]}) overlaps "
                         f"resident weight span [{w[0]}, {w[0] + w[1]}) — "
                         f"the first submit would corrupt the resident "
                         f"image")
    m = _columns(prog.entries)
    op = m[:, 0]
    writes = (_caesar_code(ctx, op) & 2) != 0
    if writes.any():
        dest = m[:, 1]
        spans = cpools + weights
        starts = np.array([s for s, _ in spans], np.int64)
        ends = np.array([s + n for s, n in spans], np.int64)
        hit = np.zeros(len(dest), bool)
        for lo, hi in zip(starts, ends):
            hit |= writes & (dest >= lo) & (dest < hi)
        ctx.emit_rows(
            "error", "residency", "resident-write-hazard",
            np.flatnonzero(hit),
            lambda i: f"writes word {int(dest[i])} inside an image-defined "
            f"span — the span DMAs in once at construction, so the write "
            f"corrupts state the next call reads (WAR across submits)")
    return CheckReport(target, ctx.diags)


def verify_chained_waves(wave_tiles: Sequence[Sequence],
                         kernel: Optional[str] = None) -> CheckReport:
    """Prove a chained wave schedule WAR-hazard-free: no tile appears
    twice within one wave (two programs racing one tile) and no tile
    appears in two different waves (wave *k*'s DMA-out overlapping wave
    *k+1*'s DMA-in on the shared tile).  Tile IDs are any hashable —
    ints for planner tiles, the serving layer's ``("resident", uid, j)``
    tuples alike."""
    target = kernel or "<chained-waves>"
    ctx = _Ctx(kernel=target, out_slice=None, init_spans=None,
               used_words=0, prov=None, diags=[])
    seen: dict = {}
    for wi, tiles in enumerate(wave_tiles):
        tl = list(tiles)
        dup = sorted({t for t in tl if tl.count(t) > 1})
        for t in dup:
            ctx.emit("error", "residency", "war-hazard",
                     f"wave {wi} submits tile {t} twice — two programs "
                     f"race one tile's memory within a wave")
        for t in set(tl):
            if t in seen and seen[t] != wi:
                ctx.emit("error", "residency", "war-hazard",
                         f"tile {t} appears in wave {seen[t]} and wave "
                         f"{wi} — wave {wi}'s DMA-in would race wave "
                         f"{seen[t]}'s DMA-out on the shared tile")
            else:
                seen[t] = wi
    return CheckReport(target, ctx.diags)
