"""Resource pass: capacity + cost-model cross-checks.

Allocator high-water vs engine capacity, plus an independent
bank-conflict / instruction count estimate cross-checked against
:mod:`repro.core.timing` — drift between the verifier's and the cost
model's view of a program is itself an error.
"""

from __future__ import annotations

import numpy as np

from repro.core import constants as C
from repro.nmc.program import Program

from repro.nmc.check.report import _Ctx
from repro.nmc.check.structural import (_CAESAR_BANK_WORDS,
                                        _CAESAR_MEM_WORDS, _CARUS_N_REGS,
                                        _CARUS_REG_WORDS, _NOP_C, _columns)


def check_resource(prog: Program, ctx: _Ctx) -> None:
    from repro.core import timing
    cap = _CAESAR_MEM_WORDS if prog.engine == "caesar" \
        else _CARUS_N_REGS * _CARUS_REG_WORDS
    if ctx.used_words:
        if ctx.used_words > cap:
            ctx.emit("error", "resource", "capacity",
                     f"allocator high-water {ctx.used_words} words exceeds "
                     f"the {cap}-word tile capacity")
        else:
            ctx.emit("info", "resource", "mem-highwater",
                     f"{ctx.used_words}/{cap} words "
                     f"({100.0 * ctx.used_words / cap:.1f}%) of tile "
                     f"memory occupied")
    try:
        report = timing.program_cycles(prog)
    except Exception as exc:  # corrupted stream: the cost model rejects it
        ctx.emit("error", "resource", "timing-drift",
                 f"timing.program_cycles rejects the program outright "
                 f"({type(exc).__name__}: {exc})")
        return
    n_real = prog.n_instr - prog.n_nops
    if report.n_instrs != n_real:
        ctx.emit("error", "resource", "timing-drift",
                 f"timing model costs {report.n_instrs} instructions, the "
                 f"verifier counts {n_real} non-NOP entries — the cost "
                 f"model and the IR disagree")
    if prog.engine == "caesar":
        m = _columns(prog.entries)
        real = m[:, 0] != _NOP_C
        same = int(np.count_nonzero(
            real & (m[:, 2] // _CAESAR_BANK_WORDS
                    == m[:, 3] // _CAESAR_BANK_WORDS)))
        modeled = report.detail.get("same_bank_ops")
        if modeled != same:
            ctx.emit("error", "resource", "timing-drift",
                     f"static bank-conflict estimate ({same} same-bank "
                     f"ops) disagrees with timing.program_cycles "
                     f"({modeled})")
        elif same:
            ctx.emit("info", "resource", "bank-conflicts",
                     f"{same}/{n_real} ops fetch both operands from one "
                     f"bank (+{C.CAESAR_SAME_BANK_CYCLES - C.CAESAR_CYCLES_PER_OP} "
                     f"cycle each, Section III-A2)")
