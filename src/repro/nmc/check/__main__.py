"""``python -m repro.nmc.check`` — the static-verification sweep CLI."""

from repro.nmc.check import main

if __name__ == "__main__":
    raise SystemExit(main())
