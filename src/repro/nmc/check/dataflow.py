"""Dataflow pass: event-sorted def-use analysis over one program.

Read-before-write against the image-defined spans, MAC/DOT accumulator
chains (use-before-init, never-stored), dead writes (overwritten or
never read), in-place VMACC hazards on Carus, and store coverage (every
word of ``out_slice`` written or image-defined).

The def/use event machinery here (one sorted int64 key stream per
verification) is also the substrate of the IR optimizer
(:mod:`repro.nmc.opt`): the same events that *diagnose* a dead write are
what licenses its removal.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.isa import CaesarOp
from repro.nmc.program import Program

from repro.nmc.check.report import MAX_PER_RULE, _Ctx, _defined_words
from repro.nmc.check.structural import (_CAESAR_MEM_WORDS, _CARUS_N_REGS,
                                        _CARUS_REG_WORDS, _caesar_code,
                                        _carus_operands, _columns)


def _event_analysis(ctx: _Ctx, capacity: int, unit: str,
                    r_loc: np.ndarray, r_row: np.ndarray,
                    w_loc: np.ndarray, w_row: np.ndarray,
                    out_range: Optional[Tuple[int, int]],
                    acc_read_rows: Optional[np.ndarray] = None) -> None:
    """Shared def-use core for both engines: sort (location, row, kind)
    events — reads before writes at the same instruction, so an in-place
    update reads its old value first — then flag reads whose location's
    first event is that read (read-before-write, against the image-defined
    map), writes whose next same-location event is another write
    (dead-write / WAW), final writes that fall outside the output window,
    and output words never written nor image-defined."""
    defined = _defined_words(ctx, capacity)
    nr, nw = len(r_loc), len(w_loc)
    if nr + nw:
        # pack each event into one int64 key (loc, then row, then
        # read<write) and sort it IN PLACE — row and kind are recovered by
        # decoding the key, so no permutation array, no gathers, and no
        # 3-key lexsort on the <5% lowering-overhead hot path
        mr = int(r_row.max()) if nr else 0
        mw = int(w_row.max()) if nw else 0
        # power-of-two span: decode is a shift/mask, not an int division
        # (arithmetic right shift floors, so negative garbage locs from
        # corrupted programs still decode and sort consistently)
        shift = (2 * max(mr, mw) + 1).bit_length()
        key = np.empty(nr + nw, np.int64)
        key[:nr] = (r_loc << shift) + 2 * r_row
        key[nr:] = (w_loc << shift) + 2 * w_row + 1
        key.sort()
        loc = key >> shift
        kind = key & 1
    else:
        loc = kind = np.zeros(0, np.int64)
        shift = 1

    def row_at(p: int) -> int:
        # rows only matter at finding positions — decode lazily per hit
        return (int(key[p]) & ((1 << shift) - 1)) >> 1

    first = np.empty(len(loc), bool)
    if len(loc):
        first[0] = True
        first[1:] = loc[1:] != loc[:-1]

    if defined is not None and len(loc):
        cand = np.flatnonzero(first & (kind == 0))
        pos = cand[~defined[np.clip(loc[cand], 0, capacity - 1)]]
        acc_rows = set() if acc_read_rows is None else set(
            int(r) for r in acc_read_rows)
        for p in pos[:MAX_PER_RULE]:
            extra = (" (in-place VMACC accumulator)"
                     if row_at(p) in acc_rows else "")
            ctx.emit("error", "dataflow", "read-before-write",
                     f"reads {unit} {int(loc[p])} before any write "
                     f"(not image-defined either){extra}",
                     instr=row_at(p))
        if len(pos) > MAX_PER_RULE:
            ctx.emit("error", "dataflow", "read-before-write",
                     f"... and {len(pos) - MAX_PER_RULE} more "
                     f"'read-before-write' findings")

    if len(loc):
        nxt_same = np.empty(len(loc), bool)
        nxt_same[-1] = False
        nxt_same[:-1] = loc[1:] == loc[:-1]
        waw = np.zeros(len(loc), bool)
        waw[:-1] = (kind[:-1] == 1) & nxt_same[:-1] & (kind[1:] == 1)
        pos = np.flatnonzero(waw)
        for p in pos[:MAX_PER_RULE]:
            ctx.emit("warning", "dataflow", "dead-write",
                     f"{unit} {int(loc[p])} is overwritten at "
                     f"instr#{row_at(p + 1)} before any read",
                     instr=row_at(p))
        if len(pos) > MAX_PER_RULE:
            ctx.emit("warning", "dataflow", "dead-write",
                     f"... and {len(pos) - MAX_PER_RULE} more "
                     f"'dead-write' findings")
        if out_range is not None:
            lo, hi = out_range
            final = (kind == 1) & ~nxt_same
            dead_final = final & ((loc < lo) | (loc >= hi))
            pos = np.flatnonzero(dead_final)
            for p in pos[:MAX_PER_RULE]:
                ctx.emit("warning", "dataflow", "dead-write",
                         f"{unit} {int(loc[p])} is written, never read, "
                         f"and outside the output window [{lo}, {hi})",
                         instr=row_at(p))
            if len(pos) > MAX_PER_RULE:
                ctx.emit("warning", "dataflow", "dead-write",
                         f"... and {len(pos) - MAX_PER_RULE} more "
                         f"'dead-write' findings")

    # store coverage: every output location written or image-defined
    if out_range is not None and defined is not None:
        lo, hi = out_range
        covered = defined.copy()
        if len(w_loc):
            covered[np.clip(w_loc, 0, capacity - 1)] = True
        missing = np.flatnonzero(~covered[lo:hi]) + lo
        for m in missing[:MAX_PER_RULE]:
            ctx.emit("error", "dataflow", "uncovered-store",
                     f"output {unit} {int(m)} is never written and not "
                     f"image-defined — the extracted result would be "
                     f"uninitialized zeros")
        if len(missing) > MAX_PER_RULE:
            ctx.emit("error", "dataflow", "uncovered-store",
                     f"... and {len(missing) - MAX_PER_RULE} more "
                     f"uncovered output {unit}s")


def _chain_check(ctx: _Ctx, op: np.ndarray, init_id: int, body_id: int,
                 store_id: int, label: str) -> None:
    """Accumulator-chain protocol (MAC_INIT/MAC/MAC_STORE and the DOT
    triple): body/store ops require a live chain; INIT while live (and a
    chain that never stores) are dead accumulations."""
    chain = (op == init_id) | (op == body_id) | (op == store_id)
    if not chain.any():
        return
    rows = np.flatnonzero(chain)
    kinds = op[rows]
    t = np.where(kinds == init_id, 1, np.where(kinds == store_id, -1, 0))
    nz = np.flatnonzero(t != 0)
    last = np.full(len(rows), -1)
    if len(nz):
        marks = np.full(len(rows), -1)
        marks[nz] = nz
        last = np.maximum.accumulate(marks)
    prev = np.concatenate([[-1], last[:-1]])
    live_before = (prev >= 0) & (t[np.clip(prev, 0, None)] == 1)
    use_dead = ((kinds == body_id) | (kinds == store_id)) & ~live_before
    ctx.emit_rows(
        "error", "dataflow", "acc-use-before-init",
        rows[np.flatnonzero(use_dead)],
        lambda i: f"{label} accumulator used with no live "
        f"{label}_INIT chain")
    reinit = (kinds == init_id) & live_before
    ctx.emit_rows(
        "warning", "dataflow", "dead-accumulator",
        rows[np.flatnonzero(reinit)],
        lambda i: f"{label}_INIT while the previous chain was never "
        f"stored — the pending accumulation is dead")
    if last[-1] >= 0 and t[last[-1]] == 1:
        ctx.emit("warning", "dataflow", "dead-accumulator",
                 f"{label} chain never reaches {label}_STORE — the "
                 f"accumulation is dead", instr=int(rows[last[-1]]))


def _dataflow_caesar(prog: Program, ctx: _Ctx) -> None:
    m = _columns(prog.entries)
    op = m[:, 0]
    code = _caesar_code(ctx, op)
    ridx = np.flatnonzero(code & 1)
    widx = np.flatnonzero(code & 2)
    r_loc = m[ridx, 2:4].T.reshape(-1)          # src1 then src2 reads
    r_row = np.concatenate([ridx, ridx])
    out = None
    if ctx.out_slice is not None:
        out = (int(ctx.out_slice[0]), int(ctx.out_slice[0])
               + int(ctx.out_slice[1]))
    _event_analysis(ctx, _CAESAR_MEM_WORDS, "word",
                    r_loc.astype(np.int64), r_row,
                    m[widx, 1].astype(np.int64), widx, out)
    if (code & 8).any():                        # any MAC/DOT chain ops
        _chain_check(ctx, op, int(CaesarOp.MAC_INIT), int(CaesarOp.MAC),
                     int(CaesarOp.MAC_STORE), "MAC")
        _chain_check(ctx, op, int(CaesarOp.DOT_INIT), int(CaesarOp.DOT),
                     int(CaesarOp.DOT_STORE), "DOT")


def _dataflow_carus(prog: Program, ctx: _Ctx) -> None:
    e = prog.entries
    rows = np.arange(len(e))
    (vd, vs2, vs1), (_, reads_vd, uses_vs2, uses_vs1, writes_vd) = \
        _carus_operands(ctx, e)
    # match the engine's wrap so the dataflow stays well-indexed even when
    # the structural pass already flagged an out-of-range register
    vd, vs2, vs1 = (vd % _CARUS_N_REGS, vs2 % _CARUS_N_REGS,
                    vs1 % _CARUS_N_REGS)
    r_loc = np.concatenate([vs2[uses_vs2], vs1[uses_vs1], vd[reads_vd]])
    r_row = np.concatenate([rows[uses_vs2], rows[uses_vs1], rows[reads_vd]])
    out = None
    if ctx.out_slice is not None:
        lo, nw = int(ctx.out_slice[0]), int(ctx.out_slice[1])
        out = (lo // _CARUS_REG_WORDS,
               -(-(lo + nw) // _CARUS_REG_WORDS))
    # register-granular init map: a load/cpool block defines its registers
    reg_ctx = ctx
    if ctx.init_spans is not None:
        reg_spans = [(s // _CARUS_REG_WORDS,
                      -(-(s + n) // _CARUS_REG_WORDS) - s // _CARUS_REG_WORDS)
                     for s, n in ctx.init_spans]
        reg_ctx = dataclasses.replace(ctx, init_spans=reg_spans)
    _event_analysis(reg_ctx, _CARUS_N_REGS, "register",
                    r_loc.astype(np.int64), r_row,
                    vd[writes_vd].astype(np.int64), rows[writes_vd], out,
                    acc_read_rows=rows[reads_vd])


def check_dataflow(prog: Program, ctx: _Ctx) -> None:
    if prog.engine == "caesar":
        _dataflow_caesar(prog, ctx)
    else:
        _dataflow_carus(prog, ctx)
