"""Structural pass: per-entry well-formedness of a unified-IR program.

Opcode valid for the engine, register/address ranges (Carus VRF bounds,
Caesar word addresses vs the 32 KiB macro), SEW-legal modes, Caesar
entries structurally zero in the Carus-only fields, and padding NOPs
truly neutral.

This module also owns the shared IR-decoding machinery (opcode class
LUTs, the column view, resolved Carus operand masks) that the dataflow,
resource and optimizer layers reuse — decode once per verification, not
once per pass.
"""

from __future__ import annotations

import numpy as np

from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc.program import NOP_OP_ID, PROG_DTYPE, Program
from repro.nmc.registry import engine_op_ids

from repro.nmc.check.report import _Ctx

_CAESAR_MEM_WORDS = C.CAESAR_MEM_BYTES // C.WORD_BYTES
_CAESAR_BANK_WORDS = _CAESAR_MEM_WORDS // C.CAESAR_N_BANKS
_CARUS_REG_WORDS = C.CARUS_REG_WORDS
_CARUS_N_REGS = C.CARUS_N_VREGS

_NOP_C = NOP_OP_ID["caesar"]
_NOP_K = NOP_OP_ID["carus"]

# Caesar opcode classes, as boolean lookup tables over the (small) opcode
# space — `lut[clip(op)] & in-range` beats np.isin on the hot verify path
_LUT_N = 64


def _class_lut(ids) -> np.ndarray:
    lut = np.zeros(_LUT_N, bool)
    lut[np.array(sorted(int(i) for i in ids))] = True
    return lut


def _member(op: np.ndarray, lut: np.ndarray) -> np.ndarray:
    """Vectorized set membership; ids outside [0, _LUT_N) are non-members."""
    return lut[np.clip(op, 0, _LUT_N - 1)] & (op >= 0) & (op < _LUT_N)


_N_FIELDS = len(PROG_DTYPE.names)
_COL = {name: i for i, name in enumerate(PROG_DTYPE.names)}


def _columns(e: np.ndarray) -> np.ndarray:
    """The entries as a [n, 8] int32 matrix: column slices are much
    cheaper than repeated structured-field extraction on the hot path."""
    if not e.flags.c_contiguous:
        e = np.ascontiguousarray(e)
    return e.view(np.int32).reshape(len(e), _N_FIELDS)


def _caesar_code(ctx: _Ctx, op: np.ndarray) -> np.ndarray:
    """Per-op combined class code (see :data:`_C_CODE`), computed once per
    verification and shared between the structural and dataflow passes."""
    code = ctx.cache.get("ccode")
    if code is None:
        code = _C_CODE[np.clip(op, 0, _LUT_N - 1)]   # fancy index: a copy
        if len(op) and int(op.min()) < 0:
            code[op < 0] = 0
        ctx.cache["ccode"] = code
    return code


_C_STORE = _class_lut(isa.CAESAR_STORE_OPS)
_C_READ = _class_lut(o for o in CaesarOp
                     if o not in (CaesarOp.CSRW, CaesarOp.NOP))
_C_VALID = _class_lut(engine_op_ids("caesar"))
_C_CHAIN = _class_lut([CaesarOp.MAC_INIT, CaesarOp.MAC, CaesarOp.MAC_STORE,
                       CaesarOp.DOT_INIT, CaesarOp.DOT, CaesarOp.DOT_STORE])

# combined per-op class code (bit0 read, bit1 store, bit2 valid, bit3
# MAC/DOT chain) — one lookup serves the structural and dataflow passes
_C_CODE = (_C_READ * 1 + _C_STORE * 2 + _C_VALID * 4 + _C_CHAIN * 8
           ).astype(np.int8)

# Carus compact-id classes
_K_ID = isa.COMPACT_ID
_K_ARITH = _class_lut(_K_ID[v] for v in isa.ARITH_OPS)
_K_MACC = _K_ID[VOp.VMACC]
_K_MV = _K_ID[VOp.VMV]
_K_SLIDES = _class_lut([_K_ID[VOp.VSLIDEUP], _K_ID[VOp.VSLIDEDOWN]])
_K_EMVV, _K_EMVX = _K_ID[VOp.EMVV], _K_ID[VOp.EMVX]
_K_SETVL = _K_ID[VOp.VSETVL]
_K_MODE_BITS = 0x3 | isa.MODE_INDIRECT | isa.MODE_SLIDE1


def _structural_caesar(e: np.ndarray, ctx: _Ctx) -> None:
    m = _columns(e)
    op = m[:, 0]
    code = _caesar_code(ctx, op)
    bad = (code & 4) == 0
    ctx.emit_rows("error", "structural", "bad-opcode", np.flatnonzero(bad),
                  lambda i: f"opcode {int(op[i])} is not an NM-Caesar "
                            f"bus micro-op")
    addrs = m[:, 1:4]                   # dest / src1 / src2
    oob_any = (addrs < 0) | (addrs >= _CAESAR_MEM_WORDS)
    if oob_any.any():                   # clean programs skip the per-field walk
        real = ~bad & (op != _NOP_C)
        for c, f in enumerate(("dest", "src1", "src2")):
            v = addrs[:, c]
            ctx.emit_rows(
                "error", "structural", "oob-address",
                np.flatnonzero(real & oob_any[:, c]),
                lambda i, f=f, v=v: f"{f}={int(v[i])} outside the "
                f"{_CAESAR_MEM_WORDS}-word (32 KiB) macro")
    carus_f = m[:, 4:]                  # sval1 / sval2 / imm / mode
    junk = None
    if carus_f.any():
        junk = carus_f.any(axis=1)
        ctx.emit_rows(
            "error", "structural", "nonzero-carus-field",
            np.flatnonzero(junk),
            lambda i: "Caesar entries must be structurally zero in the "
            "Carus-only fields (sval1/sval2/imm/mode); Program.from_entries "
            "normalizes them")
    nops = op == _NOP_C
    if nops.any():
        nop_bad = nops & addrs.any(axis=1)
        if junk is not None:
            nop_bad &= ~junk
        ctx.emit_rows(
            "error", "structural", "nop-not-neutral",
            np.flatnonzero(nop_bad),
            lambda i: "padding NOP carries non-zero operand fields — not a "
            "neutral bucket filler")


def _carus_regs(e: np.ndarray) -> tuple:
    """Resolved (vd, vs2, vs1) operand indices per entry: direct fields,
    or the bytes of ``sval2`` under MODE_INDIRECT (the engine resolves
    these at runtime and silently wraps modulo n_regs — exactly the bug
    class the bounds check below catches statically)."""
    ind = (e["mode"] & isa.MODE_INDIRECT) != 0
    s2 = e["sval2"]
    vd = np.where(ind, (s2 >> 16) & 0xFF, e["dest"])
    vs2 = np.where(ind, (s2 >> 8) & 0xFF, e["src2"])
    vs1 = np.where(ind, s2 & 0xFF, e["src1"])
    return vd, vs2, vs1


def _carus_uses(e: np.ndarray) -> tuple:
    """Boolean (uses_vd, reads_vd, uses_vs2, uses_vs1, writes_vd) masks
    from the engine's operand semantics per opcode and mode."""
    op, opmode = e["op"], e["mode"] & 0x3
    arith = _member(op, _K_ARITH)
    macc = op == _K_MACC
    mv = op == _K_MV
    slide = _member(op, _K_SLIDES)
    vv = opmode == isa.MODE_VV
    writes_vd = arith | macc | mv | slide | (op == _K_EMVV)
    reads_vd = macc | (op == _K_EMVV)      # in-place accumulate / RMW lane
    uses_vs2 = arith | macc | slide | (op == _K_EMVX)
    uses_vs1 = (arith | macc | mv) & vv    # .vv second operand (VMV copies)
    return writes_vd | reads_vd, reads_vd, uses_vs2, uses_vs1, writes_vd


def _carus_operands(ctx: _Ctx, e: np.ndarray) -> tuple:
    """(regs, uses) for the program, cached on the ctx: both the
    structural and the dataflow pass need them, and on the tiny programs
    carus lowers to, the numpy-call count is the whole verify cost."""
    ops = ctx.cache.get("kops")
    if ops is None:
        ops = (_carus_regs(e), _carus_uses(e))
        ctx.cache["kops"] = ops
    return ops


def _structural_carus(e: np.ndarray, ctx: _Ctx, sew: int) -> None:
    op = e["op"]
    bad = (op < 0) | (op >= len(isa.VOP_COMPACT))
    ctx.emit_rows("error", "structural", "bad-opcode", np.flatnonzero(bad),
                  lambda i: f"opcode {int(op[i])} is outside the xvnmc "
                            f"compact-id space [0, {len(isa.VOP_COMPACT)})")
    ok = ~bad
    mode = e["mode"]
    bad_mode = ok & (((mode & ~_K_MODE_BITS) != 0) | ((mode & 0x3) == 0x3))
    ctx.emit_rows("error", "structural", "bad-mode",
                  np.flatnonzero(bad_mode),
                  lambda i: f"mode={int(mode[i])} is not a legal "
                            f"vv/vx/vi (+indirect/slide1) encoding")
    (vd, vs2, vs1), (uses_vd, _, uses_vs2, uses_vs1, _) = \
        _carus_operands(ctx, e)
    for name, idxs, used in (("vd", vd, uses_vd), ("vs2", vs2, uses_vs2),
                             ("vs1", vs1, uses_vs1)):
        oob = ok & used & ((idxs < 0) | (idxs >= _CARUS_N_REGS))
        ctx.emit_rows(
            "error", "structural", "oob-register", np.flatnonzero(oob),
            lambda i, name=name, idxs=idxs: f"{name}=v{int(idxs[i])} "
            f"outside the {_CARUS_N_REGS}-register VRF (the engine would "
            f"silently wrap modulo {_CARUS_N_REGS})")
    setvl = ok & (op == _K_SETVL)
    vlmax = _CARUS_REG_WORDS * (32 // sew)
    sval1 = e["sval1"]
    ctx.emit_rows(
        "warning", "structural", "vl-clamped",
        np.flatnonzero(setvl & (sval1 > vlmax)),
        lambda i: f"VSETVL requests vl={int(sval1[i])} > VLMAX({sew})="
        f"{vlmax}; the engine clamps")
    ctx.emit_rows(
        "warning", "structural", "vl-empty",
        np.flatnonzero(setvl & (sval1 <= 0)),
        lambda i: f"VSETVL requests vl={int(sval1[i])}: every following "
        f"vector op writes nothing")
    nop_bad = (op == _NOP_K) & (
        (e["dest"] | e["src1"] | e["src2"] | e["sval1"] | e["sval2"]
         | e["imm"] | e["mode"]) != 0)
    ctx.emit_rows(
        "error", "structural", "nop-not-neutral", np.flatnonzero(nop_bad),
        lambda i: "padding VNOP carries non-zero fields — not a neutral "
        "bucket filler")


def check_structural(prog: Program, ctx: _Ctx) -> None:
    if prog.engine == "caesar":
        _structural_caesar(prog.entries, ctx)
    else:
        _structural_carus(prog.entries, ctx, prog.sew)
