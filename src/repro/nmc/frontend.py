"""Traced kernel frontend: numpy-style Python -> the whole NMC stack
(DESIGN.md §7).

Write a kernel as ordinary Python over traced values; calling it runs the
full pipeline — trace, engine selection, lowering to the unified IR,
bucketed/resident scheduling, sync or async dispatch — in one call::

    from repro import nmc

    @nmc.kernel                        # trace + engine auto-selection
    def fused(t, x, y):
        a = t.load(x)                  # host array -> tile memory
        b = t.load(y)
        t.store(((a * 3) + b).max(0))  # ints broadcast; max(x, 0) = ReLU

    out = fused(xs, ys)                # sync: lower, schedule, run, extract
    fut = fused.call_async(xs, ys)     # async via the DispatchQueue
    assert (fut.result() == out).all() # bit-exact either way

    mm = nmc.jit(my_matmul, engine="carus", sew=16)   # explicit target

The contract, layer by layer:

* **Tracing** — the kernel function receives a :class:`TileContext` ``t``
  plus its host numpy arrays.  ``t.load`` / ``t.consts`` bring data into
  tile memory; arithmetic on :class:`NmcValue` (``+ - * ^ & | << >>``,
  ``min/max/minu/maxu``, :func:`mac`, ``slide_down``, scalar broadcast)
  records ops into a :class:`ProgramBuilder` tape *and* eagerly evaluates
  them through the pure-numpy oracle mirrors (``alu.lane_binop_np`` /
  ``alu.trunc_lanes_np``, two's complement, wrap at SEW) — so every traced
  kernel carries its own bit-exact reference output.
* **Engine selection** — ``engine="auto"`` picks NM-Caesar when every
  traced op is bus-expressible (the :data:`repro.nmc.registry.BINOPS`
  table) and NM-Carus otherwise; an explicit engine that cannot express
  the body raises :class:`UnsupportedOnEngine` naming the offending op.
* **Lowering** — the tape lowers to a unified-IR
  :class:`repro.nmc.program.Program` per engine.  NM-Caesar lowering is
  word-major: elementwise chains fuse through a rotating scratch window,
  ``mul``→``mac`` chains become MAC_INIT/MAC/MAC_STORE accumulator runs,
  scalars splat into constant words, and operand regions are placed in
  opposite banks (loads default to bank 1, constants/outputs/temporaries
  to bank 0 — the Section III-A2 one-op-per-2-cycles placement).
  NM-Carus lowering chunks vectors across registers with the indirect
  register-addressing template, reads ``t.consts`` scalars through
  EMVX + ``.vx`` ops, reuses dead registers in place (VMACC accumulates
  into its destination), and tracks VSETVL.
* **Execution** — ``CompiledKernel(...)`` runs synchronously through the
  shared :class:`repro.nmc.registry.NmcRuntime` resident pool;
  ``call_async`` submits to its :class:`repro.nmc.runtime.DispatchQueue`
  and returns a future.  Both paths share one bucketed jit cache (one XLA
  compile per ``(engine, sew, instr-bucket, tile-bucket)``) and are
  bit-exact equal to each other and to the traced oracle.

Re-tracing happens per call (programs embed ``t.consts`` scalar values,
faithfully modeling the eCPU reading taps at runtime); XLA compilation
does not — lowered programs hit the shared bucketed compile cache.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc.program import Program, caesar_entry, carus_entry, instr_bucket
from repro.nmc.registry import BINOPS, NmcRuntime, default_runtime

ENGINES = ("caesar", "carus")
PARTITIONS = ("auto", "rows", "axis")

_CAESAR_MEM_WORDS = C.CAESAR_MEM_BYTES // C.WORD_BYTES
_CAESAR_BANK_WORDS = _CAESAR_MEM_WORDS // C.CAESAR_N_BANKS
_CAESAR_SCRATCH_WINDOW = 16        # rotating scratch words per fused group


class UnsupportedOnEngine(Exception):
    """A traced op cannot be expressed on the requested engine."""

    def __init__(self, op: str, engine: str, reason: str = "",
                 kernel: Optional[str] = None,
                 op_index: Optional[int] = None):
        self.op = op
        self.engine = engine
        self.kernel = kernel
        self.op_index = op_index
        where = f"op '{op}'"
        if op_index is not None:
            where += f" (traced op#{op_index})"
        if kernel:
            where += f" in kernel '{kernel}'"
        msg = f"{where} is not expressible on engine '{engine}'"
        if reason:
            msg = f"{msg}: {reason}"
        super().__init__(msg)


class LoweringError(Exception):
    """The traced program is valid but this lowering cannot realize it
    (capacity, layout or scheduling limitation with a named cause)."""


def splat_word(val: int, sew: int) -> int:
    """Replicate a SEW-bit value across a 32-bit word (host-side helper
    for NM-Caesar scalar constants)."""
    v = int(np.int64(val) & ((1 << sew) - 1))
    w = 0
    for k in range(32 // sew):
        w |= v << (sew * k)
    w &= 0xFFFFFFFF
    return w - (1 << 32) if w >= (1 << 31) else w


def _wrap_scalar(v, sew: int) -> int:
    """Wrap a Python scalar to SEW bits, sign-extended — the value the
    engines see (Caesar: splat word; Carus: eCPU GPR operand)."""
    return int(alu.trunc_lanes_np(np.int64(int(v)), sew))


# ---------------------------------------------------------------------------
# Tape
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class _Node:
    """One traced value: a load/const pool or a recorded vector op."""

    idx: int
    op: str                 # "load" | "cpool" | BINOPS name | "mac" | "slide_down"
    args: tuple = ()        # operand _Nodes / _ConstScalar / wrapped ints
    val: np.ndarray | None = None   # int64 lanes, wrapped at SEW (the oracle)
    ne: int = 0             # logical element count
    bank: Optional[int] = None      # NM-Caesar placement hint (loads)
    amount: int = 0         # slide offset

    def __repr__(self):
        return f"<{self.op}#{self.idx} ne={self.ne}>"


@dataclasses.dataclass(frozen=True)
class _ConstScalar:
    """One element of a ``t.consts`` pool: a scalar tap living in tile
    memory (Caesar: its own splat word; Carus: EMVX-read from the pool
    registers)."""

    pool: _Node
    index: int
    value: int              # wrapped to SEW


class ProgramBuilder:
    """Records traced ops; one instance per trace.  Kernel functions see
    it through :class:`TileContext`; lowerings walk ``nodes``/``stores``."""

    def __init__(self, sew: int, name: str = "kernel"):
        assert sew in alu.SEWS, sew
        self.sew = sew
        self.name = name          # diagnostic provenance (kernel name)
        self.nodes: list[_Node] = []
        self.stores: list[tuple[_Node, int]] = []   # (node, trimmed ne)

    def _where(self) -> str:
        """Provenance prefix for trace-time diagnostics: the kernel name
        and the index the op being recorded would get."""
        return f"{self.name} (traced op#{len(self.nodes)})"

    # -- node construction ---------------------------------------------------
    def _new(self, op: str, args: tuple = (), **kw) -> _Node:
        node = _Node(idx=len(self.nodes), op=op, args=args, **kw)
        self.nodes.append(node)
        return node

    def load(self, array, bank: Optional[int] = None) -> _Node:
        arr = np.asarray(array).reshape(-1)
        val = alu.trunc_lanes_np(arr.astype(np.int64), self.sew)
        return self._new("load", val=val, ne=int(arr.size), bank=bank)

    def cpool(self, array) -> _Node:
        arr = np.asarray(array).reshape(-1)
        val = alu.trunc_lanes_np(arr.astype(np.int64), self.sew)
        return self._new("cpool", val=val, ne=int(arr.size))

    def binop(self, name: str, a: _Node, b) -> _Node:
        assert name in BINOPS, name
        b_val = b.value if isinstance(b, _ConstScalar) \
            else (b.val if isinstance(b, _Node) else _wrap_scalar(b, self.sew))
        if isinstance(b, _Node) and a.ne != b.ne:
            raise LoweringError(
                f"{self._where()}: operand length mismatch for "
                f"'{name}': {a.ne} vs {b.ne}")
        val = alu.trunc_lanes_np(
            alu.lane_binop_np(name, a.val, b_val, self.sew), self.sew)
        return self._new(name, (a, b), val=val, ne=a.ne)

    def mac(self, acc, a, b) -> _Node:
        """acc + a * b elementwise; ``acc=None`` starts a chain (a mul)."""
        x, y = a, b
        vecs = [v for v in (x, y) if isinstance(v, _Node)]
        if not vecs:
            raise LoweringError(
                f"{self._where()}: mac needs at least one vector operand")
        ne = vecs[0].ne
        if any(v.ne != ne for v in vecs) or \
                (isinstance(acc, _Node) and acc.ne != ne):
            raise LoweringError(
                f"{self._where()}: mac operand length mismatch")
        xv = x.val if isinstance(x, _Node) else _scalar_val(x, self.sew)
        yv = y.val if isinstance(y, _Node) else _scalar_val(y, self.sew)
        if acc is None:
            return self._new(
                "mul", (x, y),
                val=alu.trunc_lanes_np(np.int64(xv) * yv, self.sew), ne=ne)
        val = alu.trunc_lanes_np(acc.val + np.int64(xv) * yv, self.sew)
        return self._new("mac", (acc, x, y), val=val, ne=ne)

    def slide_down(self, a: _Node, amount: int) -> _Node:
        amount = int(amount)
        assert amount >= 0, amount
        k = min(amount, a.ne)
        val = np.concatenate([a.val[k:], np.zeros(k, np.int64)])
        return self._new("slide_down", (a,), val=val, ne=a.ne, amount=amount)

    def store(self, node: _Node, n: Optional[int] = None) -> None:
        trim = int(n) if n is not None else node.ne
        assert 0 < trim <= node.ne, (trim, node.ne)
        if node.op in ("load", "cpool"):
            raise LoweringError(
                f"{self.name} (traced op#{node.idx}): storing a loaded "
                f"value directly is not supported — apply at least one op "
                f"(tile memory outputs are compute results)")
        self.stores.append((node, trim))

    # -- analysis ------------------------------------------------------------
    def compute_nodes(self) -> list[_Node]:
        return [n for n in self.nodes
                if n.op in BINOPS or n.op in ("mac", "slide_down")]

    def consumers(self) -> dict[int, list[_Node]]:
        cons: dict[int, list[_Node]] = {n.idx: [] for n in self.nodes}
        for n in self.nodes:
            for a in n.args:
                if isinstance(a, _Node):
                    cons[a.idx].append(n)
                elif isinstance(a, _ConstScalar):
                    cons[a.pool.idx].append(n)
        return cons

    def oracle(self):
        """Reference output: the stored values, trimmed and shaped exactly
        like the executed kernel's post-processed result."""
        dt = alu.NP_DTYPES[self.sew]
        parts = [node.val[:trim].astype(dt) for node, trim in self.stores]
        return _shape_parts(parts)


def _scalar_val(v, sew: int) -> int:
    return v.value if isinstance(v, _ConstScalar) else _wrap_scalar(v, sew)


def _shape_parts(parts: list[np.ndarray]) -> np.ndarray:
    if len(parts) == 1:
        return parts[0]
    if len({p.size for p in parts}) == 1:
        return np.stack(parts)
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# User-facing trace values
# ---------------------------------------------------------------------------

class NmcValue:
    """A traced vector living in tile memory.  Supports numpy-style
    arithmetic (recorded into the tape, evaluated eagerly through the
    ``alu.*_np`` oracle mirrors) and scalar broadcast of Python ints and
    ``t.consts`` elements."""

    __array_priority__ = 1000   # keep numpy from hijacking ndarray op value

    def __init__(self, builder: ProgramBuilder, node: _Node):
        self._b = builder
        self._node = node

    # -- introspection -------------------------------------------------------
    @property
    def ne(self) -> int:
        return self._node.ne

    @property
    def value(self) -> np.ndarray:
        """The traced (oracle) value: wrapped SEW-wide lanes."""
        return self._node.val.astype(alu.NP_DTYPES[self._b.sew])

    def __repr__(self):
        return f"NmcValue({self._node!r}, sew={self._b.sew})"

    # -- op recording --------------------------------------------------------
    def _bin(self, name: str, other, reverse: bool = False) -> "NmcValue":
        if isinstance(other, NmcValue):
            other = other._node
        elif isinstance(other, np.ndarray):
            raise TypeError("load host arrays with t.load()/t.consts() "
                            "before using them in traced arithmetic")
        if reverse and name in ("sub", "sll", "srl", "sra"):
            raise TypeError(f"scalar {name} with a traced vector on the "
                            f"right is not supported — rewrite the kernel "
                            f"with the vector on the left")
        return NmcValue(self._b, self._b.binop(name, self._node, other))

    def __add__(self, o):
        return self._bin("add", o)
    __radd__ = __add__

    def __sub__(self, o):
        return self._bin("sub", o)

    def __rsub__(self, o):
        return self._bin("sub", o, reverse=True)

    def __mul__(self, o):
        return self._bin("mul", o)
    __rmul__ = __mul__

    def __xor__(self, o):
        return self._bin("xor", o)
    __rxor__ = __xor__

    def __and__(self, o):
        return self._bin("and", o)
    __rand__ = __and__

    def __or__(self, o):
        return self._bin("or", o)
    __ror__ = __or__

    def __rshift__(self, o):
        return self._bin("sra", o)      # arithmetic: values are signed lanes

    def __lshift__(self, o):
        return self._bin("sll", o)

    def sra(self, o):
        return self._bin("sra", o)

    def srl(self, o):
        return self._bin("srl", o)

    def sll(self, o):
        return self._bin("sll", o)

    def min(self, o):
        return self._bin("min", o)

    def max(self, o):
        return self._bin("max", o)

    def minu(self, o):
        return self._bin("minu", o)

    def maxu(self, o):
        return self._bin("maxu", o)

    def relu(self) -> "NmcValue":
        return self.max(0)

    def slide_down(self, amount: int) -> "NmcValue":
        """``out[i] = self[i + amount]``, zero-filled at the tail.  Lowers
        to VSLIDEDOWN on NM-Carus; on NM-Caesar it is realized as a
        host-prepared shifted data replica — hence only slides of *loaded*
        values are bus-expressible (the Table VII data-replication trick)."""
        return NmcValue(self._b, self._b.slide_down(self._node, amount))


class ConstView:
    """Indexable view of a ``t.consts`` pool: ``view[i, j]`` is a scalar
    tap usable wherever a Python int scalar is (mac taps, `*`, …)."""

    def __init__(self, builder: ProgramBuilder, node: _Node, shape: tuple):
        self._b = builder
        self._node = node
        self._shape = shape

    def __getitem__(self, key) -> _ConstScalar:
        flat = int(np.ravel_multi_index(key, self._shape)) \
            if isinstance(key, tuple) else int(key)
        if flat < 0:                    # pythonic negatives, normalized so
            flat += self._node.ne       # the lowered pool address matches
        if not 0 <= flat < self._node.ne:
            raise IndexError(f"consts index {key} out of range for shape "
                             f"{self._shape}")
        return _ConstScalar(self._node, flat, int(self._node.val[flat]))


class TileContext:
    """The trace context a kernel function receives as its first argument."""

    def __init__(self, builder: ProgramBuilder):
        self.builder = builder

    @property
    def sew(self) -> int:
        return self.builder.sew

    def load(self, array, bank: Optional[int] = None) -> NmcValue:
        """Bring a host array into tile memory as a traced vector.  ``bank``
        is an NM-Caesar placement hint (default bank 1; constants, outputs
        and temporaries live in bank 0, so vector/scalar op operands land
        in opposite banks — the 1-op-per-2-cycles placement)."""
        return NmcValue(self.builder, self.builder.load(array, bank=bank))

    def consts(self, array) -> ConstView:
        """Load an array of scalar taps (e.g. matmul A entries, conv filter
        weights).  Element reads model the hardware path: EMVX from the
        pool registers on NM-Carus, dedicated splat words on NM-Caesar."""
        arr = np.asarray(array)
        return ConstView(self.builder, self.builder.cpool(arr), arr.shape)

    def store(self, value: NmcValue, n: Optional[int] = None) -> None:
        """Mark a traced value as a kernel output; ``n`` trims the logical
        length (e.g. a convolution's valid width)."""
        self.builder.store(value._node, n=n)


def mac(acc: Optional[NmcValue], a, b) -> NmcValue:
    """Elementwise multiply-accumulate: ``acc + a * b`` (wrap at SEW).
    ``acc=None`` starts an accumulation chain.  Chains of ``mac`` lower to
    MAC_INIT/MAC/MAC_STORE accumulator runs on NM-Caesar and in-place
    VMUL/VMACC on NM-Carus."""
    vec = next((v for v in (acc, a, b) if isinstance(v, NmcValue)), None)
    if vec is None:
        raise TypeError("mac needs at least one traced operand")
    if acc is not None and not isinstance(acc, NmcValue):
        raise TypeError(f"mac accumulator must be a traced vector or None "
                        f"(chain start), got {type(acc).__name__} — add a "
                        f"scalar with `mac(None, a, b) + c` instead")
    b_ = vec._b
    node = b_.mac(acc._node if isinstance(acc, NmcValue) else None,
                  a._node if isinstance(a, NmcValue) else a,
                  b._node if isinstance(b, NmcValue) else b)
    return NmcValue(b_, node)


# ---------------------------------------------------------------------------
# Engine selection
# ---------------------------------------------------------------------------

def engine_diagnosis(builder: ProgramBuilder,
                     engine: str) -> Optional[UnsupportedOnEngine]:
    """Why this tape cannot lower to ``engine`` — or None if it can."""
    lanes = 32 // builder.sew
    name = getattr(builder, "name", None)
    for n in builder.compute_nodes():
        if engine == "caesar":
            if n.op in BINOPS and not BINOPS[n.op].on_caesar:
                return UnsupportedOnEngine(
                    n.op, "caesar", "the bus ALU has no such micro-op "
                    "(Section III-A2); use engine='carus'",
                    kernel=name, op_index=n.idx)
            if n.op == "slide_down" and n.args[0].op != "load":
                return UnsupportedOnEngine(
                    "slide_down", "caesar", "NM-Caesar realizes slides as "
                    "host-side shifted data replicas, so only loaded "
                    "values can slide; computed values need NM-Carus's "
                    "VSLIDEDOWN", kernel=name, op_index=n.idx)
        else:
            n_words = -(-n.ne // lanes)
            if n.op == "slide_down" and \
                    -(-n_words // C.CARUS_REG_WORDS) > 1:
                return UnsupportedOnEngine(
                    "slide_down", "carus", "VSLIDEDOWN operates within one "
                    "vector register; the vector spans multiple registers",
                    kernel=name, op_index=n.idx)
    return None


def select_engine(builder: ProgramBuilder) -> str:
    """``auto`` rule: NM-Caesar for bus-op-expressible bodies (host-
    streamed micro-ops, no eCPU bootstrap), NM-Carus otherwise."""
    if engine_diagnosis(builder, "caesar") is None:
        return "caesar"
    bad = engine_diagnosis(builder, "carus")
    if bad is not None:
        raise bad
    return "carus"


# ---------------------------------------------------------------------------
# Lowered artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredKernel:
    """A traced kernel lowered for one engine: the unified-IR program, the
    initial tile-memory image, the output window and the host-side
    extraction stage.  Duck-type compatible with
    :class:`repro.core.programs.EngineBuild` (pools, runtime, timing and
    energy all accept it directly)."""

    engine: str
    sew: int
    stream: list                    # PROG_DTYPE entries
    mem: np.ndarray                 # initial memory / VRF image
    out_slice: tuple[int, int]      # (word_start, n_words)
    post: Callable                  # raw elements -> shaped logical output
    oracle: np.ndarray              # traced reference output (shaped)
    host_cycles: float = 0.0
    ecpu_instrs: int = 0
    used_words: int = 0             # allocator high-water: words the tile
                                    # image actually occupies (drives the
                                    # DMA legs of the multi-tile bus model)
    kernel: str = ""                # traced kernel name (diagnostics)
    init_spans: tuple = ()          # image-defined (word_start, n_words)
                                    # spans — what the static verifier may
                                    # treat as defined before instr #0
    prov: Optional[list] = None     # instruction index -> tracer op index
    cpool_spans: tuple = ()         # (word_start, n_elems) per ``t.consts``
                                    # pool, trace order — the value-dependent
                                    # image words.  On NM-Caesar each element
                                    # owns one splat word, so a resident-
                                    # weights caller (serve/block.py) can
                                    # patch exactly these words per call and
                                    # keep everything else on the tile.
    opt_report: Optional[object] = None  # repro.nmc.opt.OptReport when the
                                    # optimizer rewrote this lowering
                                    # (None: opt="off" or nothing fired)
    _prog: Optional[Program] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def program(self) -> Program:
        if self._prog is None:
            self._prog = Program.from_entries(self.engine, self.sew,
                                              self.stream)
        return self._prog

    @property
    def n_outputs(self) -> int:
        return int(self.oracle.size)

    def pad_to(self, n_instr: int) -> None:
        """NOP-pad the lowered program to ``n_instr`` entries — the
        wave-level bucket alignment of partitioned shards (bit-exact and
        zero-cost by the padding contract of :meth:`Program.pad_to`).
        ``stream`` keeps the unpadded tape; ``program`` reflects the pad."""
        self._prog = self.program.pad_to(n_instr)


def _make_post(spans: list[tuple[int, int]], lanes: int, dtype) -> Callable:
    """Extraction stage: slice each store's elements out of the flat
    extracted window (skipping inter-store padding) and shape the result."""

    def post(elems: np.ndarray) -> np.ndarray:
        flat = np.asarray(elems).reshape(-1)
        parts = [flat[off * lanes: off * lanes + ne].astype(dtype, copy=False)
                 for off, ne in spans]
        return _shape_parts(parts)

    return post


# ---------------------------------------------------------------------------
# NM-Caesar lowering: word-major fused groups over a 2-bank memory
# ---------------------------------------------------------------------------

class _CaesarLowering:
    def __init__(self, builder: ProgramBuilder):
        bad = engine_diagnosis(builder, "caesar")
        if bad is not None:
            raise bad
        self.b = builder
        self.sew = builder.sew
        self.lanes = 32 // self.sew

    def words(self, ne: int) -> int:
        return -(-ne // self.lanes)

    def lower(self) -> LoweredKernel:
        b = self.b
        nodes = b.nodes
        consumers = b.consumers()
        stored: dict[int, list[int]] = {}
        for node, trim in b.stores:
            stored.setdefault(node.idx, []).append(trim)
        compute = b.compute_nodes()
        compute_set = {n.idx for n in compute}

        # -- demanded word counts (store trims propagate up the cone) -------
        demand: dict[int, int] = {}
        for n in reversed(compute):
            d = max((self.words(t) for t in stored.get(n.idx, ())),
                    default=0)
            for c in consumers[n.idx]:
                if c.idx in compute_set:
                    d = max(d, demand.get(c.idx, 0))
            demand[n.idx] = d if d else self.words(n.ne)

        # -- fused word-major groups (equal full word counts) ----------------
        groups: list[list[_Node]] = []
        for n in compute:
            if n.op == "slide_down":
                continue                       # host-side data replica
            if groups and self.words(groups[-1][0].ne) == self.words(n.ne):
                groups[-1].append(n)
            else:
                groups.append([n])
        group_of = {n.idx: gi for gi, g in enumerate(groups) for n in g}

        # -- streaming: single-use intermediates never touch a full region --
        streamed: set[int] = set()
        chain_into: dict[int, int] = {}        # producer -> consumer mac
        for n in compute:
            if n.op == "slide_down" or n.idx in stored:
                continue
            cons = [c for c in consumers[n.idx]]
            if len(cons) == 1 and cons[0].idx in group_of \
                    and group_of.get(n.idx) == group_of[cons[0].idx]:
                streamed.add(n.idx)
                c = cons[0]
                if c.op == "mac" and n.op in ("mul", "mac") \
                        and c.args[0] is n:
                    chain_into[n.idx] = c.idx

        # -- allocation ------------------------------------------------------
        b0, b1 = _Cursor(0, _CAESAR_BANK_WORDS, b.name), \
            _Cursor(_CAESAR_BANK_WORDS, _CAESAR_MEM_WORDS, b.name)
        region: dict[int, int] = {}            # node idx -> base word addr
        const_addr: dict = {}                  # wrapped int value -> addr
        cpool_base: dict[int, int] = {}        # cpool node idx -> base

        def const_word(v: int) -> int:
            if v not in const_addr:
                const_addr[v] = b0.take(1, "constant")
            return const_addr[v]

        for n in nodes:                        # constants, first-use order
            if n.op == "cpool":
                cpool_base[n.idx] = b0.take(n.ne, "consts pool")
            for a in n.args:
                if not isinstance(a, (_Node, _ConstScalar)):
                    const_word(_wrap_scalar(a, self.sew))

        spans: list[tuple[int, int]] = []
        region_words: dict[int, int] = {}      # node idx -> allocated words
        out_base = b0.pos
        for node, trim in b.stores:            # outputs: contiguous window
            if node.idx not in region:
                region[node.idx] = b0.take(demand[node.idx], "output")
                region_words[node.idx] = demand[node.idx]
            spans.append((region[node.idx] - out_base, trim))
        out_words = max(r + self.words(t) for (r, t) in spans) if spans else 0

        for n in nodes:                        # loads + replicas, then temps
            if n.idx in region:
                continue                       # a stored slide replica lands
                                               # directly in the output window
            if n.op == "load":
                cur = b0 if n.bank == 0 else b1
                region[n.idx] = cur.take(self.words(n.ne), "load")
                region_words[n.idx] = self.words(n.ne)
            elif n.op == "slide_down":
                src = n.args[0]
                cur = b0 if src.bank == 0 else b1
                region[n.idx] = cur.take(self.words(n.ne), "slide replica")
                region_words[n.idx] = self.words(n.ne)
        for n in compute:
            if n.op != "slide_down" and n.idx not in region \
                    and n.idx not in streamed:
                region[n.idx] = b0.take(demand[n.idx], "temporary")
        scratch: dict[int, int] = {}
        slot_base = b0.pos
        n_slots = 0
        for n in compute:
            if n.idx in streamed and n.idx not in chain_into:
                scratch[n.idx] = n_slots
                n_slots += 1
        if n_slots:
            b0.take(n_slots, "scratch window")
        mac_tmp = None                         # lazy: generic vector-acc mac

        # -- memory image ----------------------------------------------------
        mem = np.zeros(_CAESAR_MEM_WORDS, np.int32)
        dt = alu.NP_DTYPES[self.sew]
        init_spans: list[tuple[int, int]] = []     # image-defined words
        for n in nodes:
            if n.op in ("load", "slide_down"):
                # a stored slide's region is its (demand-sized) output
                # window slot — never write past the allocation
                nw = min(self.words(n.ne), region_words[n.idx])
                padded = np.zeros(nw * self.lanes, dt)
                padded[:min(n.ne, nw * self.lanes)] = \
                    n.val[:nw * self.lanes].astype(dt)
                mem[region[n.idx]:region[n.idx] + nw] = alu.pack_np(padded)
                init_spans.append((region[n.idx], nw))
            elif n.op == "cpool":
                base = cpool_base[n.idx]
                for i, v in enumerate(n.val):
                    mem[base + i] = splat_word(int(v), self.sew)
                init_spans.append((base, int(n.ne)))
        for v, addr in const_addr.items():
            mem[addr] = splat_word(v, self.sew)
            init_spans.append((addr, 1))

        # -- emission --------------------------------------------------------
        def wref(x, w: int) -> int:
            if isinstance(x, _ConstScalar):
                return cpool_base[x.pool.idx] + x.index
            if isinstance(x, _Node):
                if x.idx in scratch:
                    return slot_base + scratch[x.idx]
                return region[x.idx] + w
            return const_addr[_wrap_scalar(x, self.sew)]

        def wdest(n: _Node, w: int) -> int:
            if n.idx in scratch:
                return slot_base + scratch[n.idx]
            return region[n.idx] + w

        stream: list = []
        prov: list[int] = []                   # instr index -> tracer op idx

        def emit(idx: int, entry) -> None:
            stream.append(entry)
            prov.append(idx)

        for g in groups:
            gmax = max(demand[n.idx] for n in g)
            for w in range(gmax):
                acc_owner = None
                for n in g:
                    if w >= demand[n.idx]:
                        continue
                    if n.op == "mac":
                        acc, x, y = n.args
                        s1, s2 = wref(x, w), wref(y, w)
                        if isinstance(acc, _Node) \
                                and chain_into.get(acc.idx) == n.idx:
                            if acc_owner != acc.idx:
                                raise LoweringError(
                                    f"{b.name} (traced op#{n.idx}): "
                                    "interleaved MAC chains: NM-Caesar has "
                                    "one packed accumulator — keep each "
                                    "mul/mac chain contiguous in the trace")
                            if n.idx in chain_into:
                                emit(n.idx, caesar_entry(
                                    CaesarOp.MAC, 0, s1, s2))
                                acc_owner = n.idx
                            else:
                                emit(n.idx, caesar_entry(
                                    CaesarOp.MAC_STORE, wdest(n, w), s1, s2))
                                acc_owner = None
                        else:               # vector accumulator: mul + add
                            if mac_tmp is None:
                                mac_tmp = b0.take(1, "mac temporary")
                            emit(n.idx, caesar_entry(
                                CaesarOp.MUL, mac_tmp, s1, s2))
                            emit(n.idx, caesar_entry(
                                CaesarOp.ADD, wdest(n, w), wref(acc, w),
                                mac_tmp))
                    elif n.op == "mul" and n.idx in chain_into:
                        x, y = n.args
                        emit(n.idx, caesar_entry(
                            CaesarOp.MAC_INIT, 0, wref(x, w), wref(y, w)))
                        acc_owner = n.idx
                    else:
                        x, y = n.args
                        emit(n.idx, caesar_entry(
                            BINOPS[n.op].caesar_op, wdest(n, w),
                            wref(x, w), wref(y, w)))

        post = _make_post(spans, self.lanes, dt)
        used = b0.pos + (b1.pos - _CAESAR_BANK_WORDS)
        # the value-dependent image words, trace order: one splat word per
        # consts-pool element (the activation taps a resident-weights
        # caller patches per call — serve/block.py)
        cspans = tuple((cpool_base[n.idx], int(n.ne))
                       for n in nodes if n.op == "cpool")
        return LoweredKernel("caesar", self.sew, stream, mem,
                             (out_base, out_words), post, b.oracle(),
                             used_words=used, kernel=b.name,
                             init_spans=tuple(init_spans), prov=prov,
                             cpool_spans=cspans)


class _Cursor:
    """Bump allocator over one memory bank with capacity diagnostics."""

    def __init__(self, base: int, limit: int, kernel: str = "kernel"):
        self.base, self.pos, self.limit = base, base, limit
        self.kernel = kernel

    def take(self, n_words: int, what: str) -> int:
        addr = self.pos
        self.pos += n_words
        if self.pos > self.limit:
            raise LoweringError(
                f"{self.kernel}: NM-Caesar bank overflow allocating "
                f"{n_words} words for {what}: "
                f"{self.pos - self.base}/{self.limit - self.base} "
                f"words used")
        return addr


# ---------------------------------------------------------------------------
# NM-Carus lowering: chunked registers, indirect addressing, in-place reuse
# ---------------------------------------------------------------------------

class _CarusLowering:
    def __init__(self, builder: ProgramBuilder):
        bad = engine_diagnosis(builder, "carus")
        if bad is not None:
            raise bad
        self.b = builder
        self.sew = builder.sew
        self.lanes = 32 // self.sew
        self.rw = C.CARUS_REG_WORDS
        self.vlmax = self.rw * self.lanes

    def words(self, ne: int) -> int:
        return -(-ne // self.lanes)

    def chunks(self, ne: int) -> int:
        return max(1, -(-self.words(ne) // self.rw))

    def vl_of(self, n: _Node) -> int:
        """Single-register values run at their exact element count;
        register-spanning values use the vlmax indirect-loop template
        (exactly the paper's Section III-B1 kernel structure)."""
        return n.ne if self.chunks(n.ne) == 1 else self.vlmax

    def lower(self) -> LoweredKernel:
        b = self.b
        nodes = b.nodes
        consumers = b.consumers()
        compute_set = {n.idx for n in b.compute_nodes()}
        stored_first: dict[int, int] = {}
        for si, (node, _t) in enumerate(b.stores):
            if node.idx in stored_first:
                raise LoweringError(
                    f"{b.name} (traced op#{node.idx}): storing one value "
                    f"twice is not supported on NM-Carus")
            stored_first[node.idx] = si

        # -- output blocks (contiguous registers, store order) ---------------
        reg = 0
        spans: list[tuple[int, int]] = []
        out_words = 0
        home: dict[int, int] = {}       # node idx -> destination base reg
        for node, trim in b.stores:
            home[node.idx] = reg
            spans.append((reg * self.rw, trim))
            out_words = reg * self.rw + self.words(trim)
            reg += self.chunks(node.ne)

        # -- destination propagation: single-use producers compute straight
        # into their consumer's eventual output block (in-place VMACC
        # chains, leaky-relu's shift temp, gemm epilogues — the register-
        # pressure trick of the paper's hand-written kernels)
        uses = {n.idx: len(consumers[n.idx]) for n in nodes}
        for n in reversed(b.compute_nodes()):
            h = home.get(n.idx)
            if h is None:
                continue
            if n.op == "mac":
                acc = n.args[0]
                if isinstance(acc, _Node) and acc.idx in compute_set \
                        and uses[acc.idx] == 1 and acc.idx not in home:
                    home[acc.idx] = h       # the chain accumulates in place
            elif n.op in BINOPS:
                for a in n.args:
                    if isinstance(a, _Node) and a.idx in compute_set \
                            and uses[a.idx] == 1 and a.idx not in home:
                        home[a.idx] = h     # compute straight into the output
                        break

        # -- loads, const pools, temp space ----------------------------------
        block: dict[int, int] = {}
        for n in nodes:
            if n.op == "load":
                block[n.idx] = reg
                reg += self.chunks(n.ne)
        cpool_top = C.CARUS_N_VREGS
        cpool_base: dict[int, int] = {}
        for n in nodes:
            if n.op == "cpool":
                cpool_top -= -(-self.words(n.ne) // self.rw)
                cpool_base[n.idx] = cpool_top
        if reg > cpool_top:
            raise LoweringError(
                f"{b.name}: NM-Carus register file overflow: {reg} "
                f"registers of outputs+loads vs {cpool_top} available "
                f"below the const pools")
        temp = _RegAlloc(reg, cpool_top, b.name)

        # -- image ------------------------------------------------------------
        vrf = np.zeros((C.CARUS_N_VREGS, self.rw), np.int32)
        flat = vrf.reshape(-1)
        dt = alu.NP_DTYPES[self.sew]
        init_spans: list[tuple[int, int]] = []     # image-defined words
        for n in nodes:
            if n.op in ("load", "cpool"):
                base = block[n.idx] if n.op == "load" else cpool_base[n.idx]
                nw = self.words(n.ne)
                padded = np.zeros(nw * self.lanes, dt)
                padded[:n.ne] = n.val.astype(dt)
                flat[base * self.rw: base * self.rw + nw] = \
                    alu.pack_np(padded)
                init_spans.append((base * self.rw, nw))

        # -- emission ---------------------------------------------------------
        stream: list = []
        prov: list[int] = []                   # instr index -> tracer op idx
        remaining = dict(uses)
        cur_vl = None

        def emit(idx: int, entry) -> None:
            stream.append(entry)
            prov.append(idx)

        def setvl(idx: int, vl: int):
            nonlocal cur_vl
            if cur_vl != vl:
                emit(idx, carus_entry(VOp.VSETVL, sval1=vl))
                cur_vl = vl

        def consume(*operands):
            for x in operands:
                if isinstance(x, _Node):
                    remaining[x.idx] -= 1

        def reusable(x) -> bool:
            return isinstance(x, _Node) and remaining[x.idx] == 0 \
                and x.idx in block and x.idx not in home and x.op != "cpool"

        def release_dead(operands, chosen: int):
            """Return dead operand blocks (other than the one reused as the
            destination) to the temp free list."""
            seen = set()
            for x in operands:
                if reusable(x) and x.idx not in seen \
                        and block[x.idx] != chosen:
                    temp.free(block[x.idx], self.chunks(x.ne))
                    seen.add(x.idx)

        def scalar_emvx(idx: int, x) -> int:
            """Emit the eCPU tap read for a consts element; returns the
            wrapped scalar value for the following .vx op."""
            if isinstance(x, _ConstScalar):
                base = cpool_base[x.pool.idx]
                emit(idx, carus_entry(
                    VOp.EMVX, vs2=base + x.index // self.vlmax,
                    sval1=x.index % self.vlmax))
                return x.value
            return _wrap_scalar(x, self.sew)

        def dest_for(n: _Node, reuse: Sequence = ()) -> int:
            if n.idx in home:
                return home[n.idx]
            for cand in reuse:
                if reusable(cand):
                    return block[cand.idx]
            return temp.take(self.chunks(n.ne), repr(n))

        for n in b.compute_nodes():
            nch = self.chunks(n.ne)
            setvl(n.idx, self.vl_of(n))
            if n.op == "slide_down":
                (src,) = n.args
                src_base = block[src.idx]
                consume(src)
                d = dest_for(n, (src,))
                release_dead((src,), d)
                block[n.idx] = d
                emit(n.idx, carus_entry(
                    VOp.VSLIDEDOWN, vd=d, vs2=src_base,
                    sval1=n.amount, mode=isa.MODE_VX))
                continue
            if n.op == "mac":
                acc, x, y = n.args
                vec = y if isinstance(y, _Node) else x
                sca = x if vec is y else y
                acc_base = block[acc.idx]
                consume(acc, x, y)
                d = dest_for(n) if remaining[acc.idx] > 0 \
                    else home.get(n.idx, acc_base)
                if d != acc_base:
                    # the accumulator value is still live elsewhere, or it
                    # lives outside this mac's output block (e.g. a loaded
                    # C matrix): copy it, then accumulate into the copy
                    # (VMACC is in-place)
                    for i in range(nch):
                        emit(n.idx, carus_entry(
                            VOp.VMV,
                            sval2=isa.pack_indices(d + i, 0, acc_base + i),
                            mode=isa.MODE_VV | isa.MODE_INDIRECT))
                release_dead((acc, x, y), d)
                block[n.idx] = d
                if isinstance(sca, _Node):   # vector-vector mac
                    for i in range(nch):
                        emit(n.idx, carus_entry(
                            VOp.VMACC,
                            sval2=isa.pack_indices(d + i, block[x.idx] + i,
                                                   block[y.idx] + i),
                            mode=isa.MODE_VV | isa.MODE_INDIRECT))
                else:
                    sval = scalar_emvx(n.idx, sca)
                    for i in range(nch):
                        emit(n.idx, carus_entry(
                            VOp.VMACC, sval1=sval,
                            sval2=isa.pack_indices(d + i,
                                                   block[vec.idx] + i, 0),
                            mode=isa.MODE_VX | isa.MODE_INDIRECT))
                continue
            # binops (including the "mul" chain head, whose scalar tap may
            # sit in the first operand slot — mul is commutative)
            x, y = n.args
            if not isinstance(x, _Node):
                x, y = y, x
            spec = BINOPS[n.op]
            if isinstance(y, _Node):
                xb, yb = block[x.idx], block[y.idx]
                consume(x, y)
                d = dest_for(n, (x, y))
                release_dead((x, y), d)
                block[n.idx] = d
                for i in range(nch):
                    emit(n.idx, carus_entry(
                        spec.carus_vop,
                        sval2=isa.pack_indices(d + i, xb + i, yb + i),
                        mode=isa.MODE_VV | isa.MODE_INDIRECT))
            else:
                xb = block[x.idx]
                consume(x)
                d = dest_for(n, (x,))
                release_dead((x,), d)
                block[n.idx] = d
                if spec.carus_imm and not isinstance(y, _ConstScalar):
                    for i in range(nch):
                        emit(n.idx, carus_entry(
                            spec.carus_vop, imm=_wrap_scalar(y, self.sew),
                            sval2=isa.pack_indices(d + i, xb + i, 0),
                            mode=isa.MODE_VI | isa.MODE_INDIRECT))
                else:
                    sval = scalar_emvx(n.idx, y)
                    for i in range(nch):
                        emit(n.idx, carus_entry(
                            spec.carus_vop, sval1=sval,
                            sval2=isa.pack_indices(d + i, xb + i, 0),
                            mode=isa.MODE_VX | isa.MODE_INDIRECT))

        post = _make_post(spans, self.lanes, dt)
        used = (temp.next + (C.CARUS_N_VREGS - cpool_top)) * self.rw
        # NOTE: on NM-Carus the consts *values* also ride in the instruction
        # stream (EMVX taps embed sval1), so patching these VRF spans alone
        # does NOT retarget a resident program — serve/block.py rejects
        # carus residency for exactly this reason; the spans are recorded
        # for accounting/introspection symmetry only.
        cspans = tuple((cpool_base[n.idx] * self.rw, int(n.ne))
                       for n in nodes if n.op == "cpool")
        return LoweredKernel("carus", self.sew, stream, vrf,
                             (0, out_words), post, b.oracle(),
                             ecpu_instrs=3, used_words=used, kernel=b.name,
                             init_spans=tuple(init_spans), prov=prov,
                             cpool_spans=cspans)


class _RegAlloc:
    """Temp vector-register allocator: bump pointer + exact-size free list,
    bounded by the const-pool floor."""

    def __init__(self, start: int, limit: int, kernel: str = "kernel"):
        self.next = start
        self.limit = limit
        self.kernel = kernel
        self.free_list: dict[int, list[int]] = {}

    def take(self, n_regs: int, what: str) -> int:
        stack = self.free_list.get(n_regs)
        if stack:
            return stack.pop()
        base = self.next
        self.next += n_regs
        if self.next > self.limit:
            raise LoweringError(
                f"{self.kernel}: NM-Carus register file overflow "
                f"allocating {n_regs} registers for {what}: need "
                f"{self.next}, {self.limit} available (32 minus const "
                f"pools)")
        return base

    def free(self, base: int, n_regs: int) -> None:
        self.free_list.setdefault(n_regs, []).append(base)


# ---------------------------------------------------------------------------
# CompiledKernel + public entry points
# ---------------------------------------------------------------------------

_LOWERINGS = {"caesar": _CaesarLowering, "carus": _CarusLowering}


def _check_engine(engine: str) -> str:
    """Eager engine-name validation, shared by decoration-time kwargs and
    per-call overrides (a typo must raise a named ValueError, never a
    deep-stack KeyError)."""
    if engine != "auto" and engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}: expected 'auto' or "
                         f"one of {ENGINES}")
    return engine


def _check_backend(backend: str) -> str:
    """Eager backend-name validation (same discipline as
    :func:`_check_engine`): ``"auto"``, ``"scan"`` or ``"pallas"``."""
    from repro.nmc.engine import BACKENDS
    if backend != "auto" and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}: expected 'auto' or "
                         f"one of {BACKENDS}")
    return backend


def _check_tiles(tiles) -> int:
    try:
        n = int(tiles)
    except (TypeError, ValueError):
        raise ValueError(
            f"tiles must be an int >= 1, got {tiles!r}") from None
    if n < 1:
        raise ValueError(f"tiles must be >= 1, got {n}")
    return n


def _check_checkmode(check: str) -> str:
    """Eager check-mode validation (same discipline as
    :func:`_check_engine`): ``"error"``, ``"warn"`` or ``"off"``."""
    from repro.nmc.check import CHECK_MODES
    if check not in CHECK_MODES:
        raise ValueError(f"unknown check mode {check!r}: expected one of "
                         f"{CHECK_MODES}")
    return check


def _check_schedule(schedule):
    """Validate a ``schedule=`` argument: a mode name or an explicit
    :class:`repro.nmc.schedule.SchedulePlan` (deferred import — the
    scheduler builds on this module)."""
    from repro.nmc.schedule import SCHEDULE_MODES, SchedulePlan
    if isinstance(schedule, SchedulePlan):
        return schedule
    if schedule not in SCHEDULE_MODES:
        raise ValueError(
            f"unknown schedule {schedule!r}: expected a SchedulePlan or "
            f"one of {SCHEDULE_MODES}")
    return schedule


def _check_opt(opt: str) -> str:
    """Eager opt-level validation (same discipline as
    :func:`_check_engine`): ``"O1"`` or ``"off"``."""
    from repro.nmc.opt import OPT_LEVELS
    if opt not in OPT_LEVELS:
        raise ValueError(f"unknown opt level {opt!r}: expected one of "
                         f"{OPT_LEVELS}")
    return opt


def _apply_report(report, mode: str) -> None:
    """Enforce a :class:`repro.nmc.check.CheckReport` under the kernel's
    ``check=`` policy: ``"error"`` raises on errors, ``"warn"`` surfaces
    any finding as a Python warning."""
    if mode == "error":
        report.raise_if_errors()
    elif mode == "warn" and (report.errors or report.warnings):
        import warnings
        warnings.warn("static verification: " + report.render(),
                      stacklevel=3)


class CompiledKernel:
    """A traced kernel bound to an engine policy and element width.

    Calling it runs the whole stack synchronously (trace → select →
    lower → bucketed/resident dispatch → extract); ``call_async`` submits
    through the shared :class:`repro.nmc.runtime.DispatchQueue` and
    returns an :class:`repro.nmc.runtime.NMCFuture` whose ``result()`` is
    bit-exact equal to the synchronous output."""

    def __init__(self, fn: Callable, engine: str = "auto", sew: int = 8,
                 runtime: Optional[NmcRuntime] = None, tiles: int = 1,
                 partition: str = "auto", backend: str = "auto",
                 check: str = "error", opt: str = "O1",
                 schedule="uniform"):
        # kwargs validate eagerly: a typo'd engine string or an impossible
        # tile count must fail at decoration time with a named cause, not
        # as a deep-stack assertion at first call
        _check_engine(engine)
        _check_backend(backend)
        _check_checkmode(check)
        _check_opt(opt)
        _check_schedule(schedule)
        if sew not in alu.SEWS:
            raise ValueError(
                f"unsupported sew {sew!r}: expected one of "
                f"{tuple(sorted(alu.SEWS))}")
        tiles = _check_tiles(tiles)
        if partition not in PARTITIONS:
            raise ValueError(
                f"unknown partition strategy {partition!r}: expected one "
                f"of {PARTITIONS}")
        self.fn = fn
        self.engine = engine
        self.sew = sew
        self.tiles = tiles
        self.partition = partition
        self.backend = backend
        self.check = check
        self.opt = opt
        self.schedule = schedule
        self._runtime = runtime
        self.__name__ = getattr(fn, "__name__", "kernel")
        self.__doc__ = getattr(fn, "__doc__", None)

    def __repr__(self):
        return (f"CompiledKernel({self.__name__}, engine={self.engine!r}, "
                f"sew={self.sew}, tiles={self.tiles})")

    @property
    def runtime(self) -> NmcRuntime:
        return self._runtime if self._runtime is not None \
            else default_runtime()

    # -- pipeline stages -----------------------------------------------------
    def trace(self, *args, sew: Optional[int] = None) -> ProgramBuilder:
        builder = ProgramBuilder(sew or self.sew, name=self.__name__)
        self.fn(TileContext(builder), *args)
        if not builder.stores:
            raise LoweringError(f"kernel '{self.__name__}' stored no "
                                f"outputs — call t.store(...)")
        return builder

    def select_engine(self, *args, sew: Optional[int] = None) -> str:
        return select_engine(self.trace(*args, sew=sew))

    def _check_mode(self, check: Optional[str]) -> str:
        return self.check if check is None else _check_checkmode(check)

    def _opt_level(self, opt: Optional[str]) -> str:
        return self.opt if opt is None else _check_opt(opt)

    def _schedule_mode(self, schedule):
        return self.schedule if schedule is None else _check_schedule(schedule)

    def lower(self, *args, engine: Optional[str] = None,
              sew: Optional[int] = None,
              check: Optional[str] = None,
              opt: Optional[str] = None) -> LoweredKernel:
        builder = self.trace(*args, sew=sew)
        eng = _check_engine(engine) if engine is not None else self.engine
        if eng == "auto":
            eng = select_engine(builder)
        lk = _LOWERINGS[eng](builder).lower()
        level = self._opt_level(opt)
        if level != "off":
            # optimize before the check= gate so the verifier's report
            # describes the program the engine will actually run; every
            # rewrite was already translation-validated internally
            from repro.nmc import opt as _opt
            _opt.optimize(lk, level)
        mode = self._check_mode(check)
        if mode != "off":
            from repro.nmc import check as _chk
            _apply_report(_chk.verify_lowered(lk), mode)
        return lk

    def oracle(self, *args, sew: Optional[int] = None) -> np.ndarray:
        """Pure-numpy reference output (the traced ``alu.*_np`` values)."""
        return self.trace(*args, sew=sew).oracle()

    # -- partitioning (DESIGN.md §9) -----------------------------------------
    def plan_partition(self, *args, tiles: Optional[int] = None,
                       sew: Optional[int] = None):
        """Trace the kernel and shard its tape across the tile array via
        :func:`repro.nmc.partition.plan` (the planner layer)."""
        from repro.nmc import partition as P
        n = self.tiles if tiles is None else _check_tiles(tiles)
        return P.plan(self.trace(*args, sew=sew), n, self.partition)

    def plan_schedule(self, *args, tiles: Optional[int] = None,
                      engine: Optional[str] = None, schedule=None):
        """Trace the kernel and return the :class:`SchedulePlan` the wave
        scheduler picks for it (DESIGN.md §14) — cached, so a following
        call/``lower_wave`` with the same policy reuses the search."""
        from repro.nmc import schedule as S
        n = self.tiles if tiles is None else _check_tiles(tiles)
        eng = _check_engine(engine) if engine is not None else self.engine
        mode = self._schedule_mode(schedule)
        return S.plan_wave(self.trace(*args), n, partition=self.partition,
                           engine=eng, mode=mode)[0]

    def lower_wave(self, *args, engine: Optional[str] = None,
                   tiles: Optional[int] = None,
                   check: Optional[str] = None,
                   opt: Optional[str] = None,
                   schedule=None):
        """Lower a scheduled wave: returns ``(plan, lowered_shards)`` in
        dispatch order, with every shard program NOP-padded to its
        *engine group's* common instruction bucket — a single-engine wave
        lands in one bucketed group (one XLA compile, one batched
        dispatch) exactly as before, while a mixed Caesar+Carus wave pads
        per engine so each group batches on its own interpreter."""
        from repro.nmc import schedule as S
        n = self.tiles if tiles is None else _check_tiles(tiles)
        eng = _check_engine(engine) if engine is not None else self.engine
        mode = self._schedule_mode(schedule)
        splan, pplan, lks = S.plan_wave(
            self.trace(*args), n, partition=self.partition, engine=eng,
            mode=mode)
        level = self._opt_level(opt)
        if level != "off":
            # shards optimize *before* the common-bucket agreement: a
            # compacted wave drops into the smaller bucket as a unit
            from repro.nmc import opt as _opt
            for lk in lks:
                _opt.optimize(lk, level)
        for group in sorted({lk.engine for lk in lks}):
            members = [lk for lk in lks if lk.engine == group]
            bucket = instr_bucket(max(lk.program.n_instr
                                      for lk in members))
            for lk in members:
                lk.pad_to(bucket)
        cmode = self._check_mode(check)
        if cmode != "off":
            # partition safety + per-shard verification, over the *padded*
            # shard programs — the exact wave the scheduler will dispatch
            from repro.nmc import check as _chk
            _apply_report(_chk.verify_wave(pplan.parent, pplan, lks,
                                           kernel=self.__name__), cmode)
        return pplan, lks

    # -- execution -----------------------------------------------------------
    def __call__(self, *args, engine: Optional[str] = None,
                 tiles: Optional[int] = None,
                 backend: Optional[str] = None,
                 opt: Optional[str] = None,
                 schedule=None) -> np.ndarray:
        """Synchronous call: submit and resolve immediately.  Shares the
        async path's tiles and jit cache, so sync and async are bit-exact
        by construction and device state stays bounded (one resident
        buffer per runtime tile, re-installed per call)."""
        return self.call_async(*args, engine=engine, tiles=tiles,
                               backend=backend, opt=opt,
                               schedule=schedule).result()

    def resolve_backend(self, backend: Optional[str] = None) -> str:
        """The executor this call will use: per-call override > kernel
        default > runtime default; ``"auto"`` follows the runtime, whose
        own ``"auto"`` picks Pallas on TPU/GPU and scan on CPU."""
        from repro.nmc.engine import resolve_backend
        bk = self.backend if backend is None else _check_backend(backend)
        if bk == "auto":
            rt_bk = getattr(self.runtime, "backend", None)
            return rt_bk if rt_bk is not None else resolve_backend("auto")
        return bk

    def call_async(self, *args, engine: Optional[str] = None,
                   tiles: Optional[int] = None,
                   backend: Optional[str] = None,
                   opt: Optional[str] = None,
                   schedule=None):
        """Submit through the runtime's DispatchQueue; returns the future
        immediately (double-buffered staging, batched launch waves).

        With ``tiles=1`` the kernel runs whole on the runtime's shared
        head tile and the result is an :class:`repro.nmc.runtime.NMCFuture`.
        With ``tiles=N > 1`` the partitioning planner shards the traced
        tape across the runtime's tile set (``jit_tiles``): every shard
        submits to its own tile — the queue batches them into one launch
        wave and, since the shard programs are pre-padded to one common
        instruction bucket, one XLA compile covers the whole wave — and
        the result is a :class:`repro.nmc.runtime.GatherFuture` whose
        ``result()`` reassembles the caller's array (bit-exact vs the
        single-tile path by construction).  Per-tile FIFO order keeps any
        number of in-flight futures correct either way."""
        n = self.tiles if tiles is None else _check_tiles(tiles)
        bk = self.resolve_backend(backend)
        rt = self.runtime
        if n == 1:
            lk = self.lower(*args, engine=engine, opt=opt)
            return rt.queue.submit(rt.jit_tile, lk.program, image=lk.mem,
                                   out_slice=lk.out_slice, post=lk.post,
                                   backend=bk)
        from repro.nmc.runtime import GatherFuture
        pplan, lks = self.lower_wave(*args, engine=engine, tiles=n, opt=opt,
                                     schedule=schedule)
        futs = [rt.queue.submit(tile, lk.program, image=lk.mem,
                                out_slice=lk.out_slice, post=lk.post,
                                backend=bk)
                for tile, lk in zip(rt.jit_tiles(len(lks)), lks)]
        return GatherFuture(futs, pplan.gather)


def jit(fn: Optional[Callable] = None, *, engine: str = "auto", sew: int = 8,
        runtime: Optional[NmcRuntime] = None, tiles: int = 1,
        partition: str = "auto", backend: str = "auto",
        check: str = "error", opt: str = "O1", schedule="uniform"):
    """Compile a traced kernel function into a :class:`CompiledKernel`.

    ``engine`` is ``"auto"`` (NM-Caesar when bus-expressible, NM-Carus
    otherwise), ``"caesar"`` or ``"carus"`` — an explicit engine that
    cannot express the body raises :class:`UnsupportedOnEngine` naming the
    op.  ``sew`` is the element width (8/16/32).  ``tiles`` shards every
    call across that many tiles through the partitioning planner
    (DESIGN.md §9) — ``partition`` picks the split strategy (``"auto"``,
    ``"rows"``, ``"axis"``).  ``backend`` picks the executor
    (DESIGN.md §10): ``"scan"`` (reference interpreters), ``"pallas"``
    (fused kernels), or ``"auto"`` (Pallas on TPU/GPU, scan on CPU).
    ``check`` runs the static verifier (:mod:`repro.nmc.check`,
    DESIGN.md §11) on every lowered program: ``"error"`` (default —
    raise :class:`repro.nmc.check.VerificationError` on any error-severity
    diagnostic), ``"warn"`` (surface findings as Python warnings) or
    ``"off"``.  ``opt`` runs the analysis-driven IR optimizer
    (:mod:`repro.nmc.opt`, DESIGN.md §13) on every lowered program:
    ``"O1"`` (default — translation-validated rewrites: dead-write
    elimination, NOP/VSETVL compaction, bank-conflict-aware placement,
    copy coalescing) or ``"off"``; both are overridable per call.
    ``schedule`` picks the wave scheduler (:mod:`repro.nmc.schedule`,
    DESIGN.md §14): ``"uniform"`` (default — seed strategy and engine,
    cost-picked uniform chunking and tail placement), ``"auto"`` (the
    full autotuner: chunk skew, per-shard engine mix, dispatch order) or
    an explicit :class:`repro.nmc.schedule.SchedulePlan`; overridable
    per call.  All kwargs validate eagerly with ``ValueError``.  Usable
    as a decorator (``@nmc.jit`` / ``@nmc.jit(engine="carus", tiles=4)``)
    or a call."""
    if fn is None:
        return lambda f: CompiledKernel(f, engine=engine, sew=sew,
                                        runtime=runtime, tiles=tiles,
                                        partition=partition, backend=backend,
                                        check=check, opt=opt,
                                        schedule=schedule)
    return CompiledKernel(fn, engine=engine, sew=sew, runtime=runtime,
                          tiles=tiles, partition=partition, backend=backend,
                          check=check, opt=opt, schedule=schedule)


def kernel(fn: Optional[Callable] = None, **options):
    """Decorator sugar for :func:`jit` with default options: numpy-style
    tracing, engine auto-selection, SEW 8."""
    return jit(fn, **options)
