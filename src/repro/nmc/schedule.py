"""Cost-model-driven heterogeneous wave scheduler + plan autotuner
(DESIGN.md §14).

The partitioning planner (:mod:`repro.nmc.partition`) carves one traced
kernel into shards; *how* those shards are cut, which engine runs each
one and in what order their images stream over the shared system bus is
a scheduling decision with a measurable objective:
:func:`repro.core.timing.wave_cycles`, the N+1-resource model of the
paper's edge-node topology (one serialized 32-bit bus, N independent
tile engines).  This module searches that space:

* **Partition strategy** — ``"rows"`` vs ``"axis"`` both cost out
  through real lowerings, not the structural auto rule alone (a matmul
  that *can* row-split may still be cheaper as axis chunks: row shards
  each replicate every B-row load, axis shards slice them).
* **Per-tile chunk skew** — the bus serializes the DMA ladder, so the
  first-dispatched tile's image lands first and the last tile idles
  behind every earlier transfer.  Skewing chunk sizes (a geometric
  just-in-time ramp: first-dispatched shards get larger chunks) lets
  every tile finish together instead of the last tile starting last
  *and* finishing last.
* **Per-shard engine assignment** — within one wave, bus-expressible
  shards can run on NM-Caesar (small image, host-streamed micro-ops)
  while slide/indirect/unsigned shards run on NM-Carus; a greedy
  ladder walk proposes the mix and the exact wave model arbitrates.
* **Dispatch order** — stages stream in list order, so the ragged tail
  (and any compute-heavy shard) goes where the cost model says, not
  blindly last.

Every candidate is evaluated on **real lowered shards** (exact
:func:`repro.core.timing.stage_cost` legs), and the winning
:class:`SchedulePlan` is cached in a content-keyed blake2b-LRU registry
(the same idiom as ``opt/`` and ``verify_lowered``) keyed on the
*value-independent* tape structure — so re-calls with fresh activation
values reuse the identical plan object without re-searching.

Bit-exactness is by construction: a plan only ever reparameterizes the
partition planner (explicit chunk vectors, shard permutations) and the
per-shard lowerings; shards still replay through ``ProgramBuilder``
with the eager oracle, and the partition-safety verifier gates every
realized plan (``check="error"`` stays the frontend default).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import timing
from repro.nmc import partition as P
from repro.nmc.frontend import (ENGINES, LoweringError, ProgramBuilder,
                                UnsupportedOnEngine, _check_tiles,
                                _ConstScalar, _LOWERINGS, _Node,
                                engine_diagnosis, select_engine)

#: The valid ``schedule=`` mode names (a :class:`SchedulePlan` instance is
#: also accepted wherever a mode is).
SCHEDULE_MODES = ("auto", "uniform")

#: Fixed just-in-time skew ratios for the geometric chunk ramp, tried on
#: top of the per-kernel fitted ratio (compute/(dma+compute) of the head
#: shard).  The exact wave model arbitrates; these only seed candidates.
SKEW_RATIOS = (0.85, 0.7, 0.55)


# ---------------------------------------------------------------------------
# Plan artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """One scheduling decision for a partitioned wave, value-independent
    and reusable across calls of the same kernel structure.

    ``chunks``/``engines`` are indexed in *axis order* (the order the
    partition planner builds shards); ``order`` maps dispatch position
    ``k`` to the shard index dispatched k-th.  ``modeled_cycles`` /
    ``uniform_cycles`` / ``seed_cycles`` record the wave model's verdict
    for this plan, the best uniform single-engine plan, and the seed
    planner's fixed equal-chunk tail-last behavior respectively."""

    strategy: str                   # "single" | "rows" | "axis"
    chunks: Tuple[int, ...]         # axis: elements; rows: store counts
    engines: Tuple[str, ...]        # per shard, axis order
    order: Tuple[int, ...]          # dispatch position -> shard index
    tiles: int
    sew: int
    modeled_cycles: float
    uniform_cycles: float
    seed_cycles: float
    source: str                     # "auto" | "uniform" | "user"

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def mixed(self) -> bool:
        """True when the wave assigns more than one engine."""
        return len(set(self.engines)) > 1


def clear_plan_cache() -> None:
    """Drop every cached SchedulePlan (test isolation)."""
    _plan_cache.clear()


_PLAN_CAP = 64
_plan_cache: "OrderedDict[bytes, SchedulePlan]" = OrderedDict()


def plan_key(builder: ProgramBuilder, tiles: int, partition: str,
             engine: str, mode: str) -> bytes:
    """Content key of a scheduling problem: the blake2b digest of the
    tape's value-independent structure (op kinds, element counts, slide
    amounts, bank hints, operand wiring, store trims) plus the request
    (tiles, partition policy, engine policy, schedule mode).  Traced
    *values* are excluded on purpose — two calls of one kernel over
    different activations share the plan."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((builder.sew, int(tiles), partition, engine,
                   mode)).encode())
    for n in builder.nodes:
        args = []
        for a in n.args:
            if isinstance(a, _Node):
                args.append(("n", a.idx))
            elif isinstance(a, _ConstScalar):
                args.append(("c", a.pool.idx, a.index))
            else:                       # literal Python scalar: part of the
                args.append(("s", int(a)))   # kernel's code, not its data
        h.update(repr((n.op, n.ne, n.amount, n.bank, tuple(args))).encode())
    h.update(repr([(nd.idx, t) for nd, t in builder.stores]).encode())
    return h.digest()


def _cache_put(key: bytes, plan: SchedulePlan) -> None:
    _plan_cache[key] = plan
    while len(_plan_cache) > _PLAN_CAP:
        _plan_cache.popitem(last=False)


# ---------------------------------------------------------------------------
# Chunk-vector candidates
# ---------------------------------------------------------------------------

def _ramp(total: int, n: int, r: float) -> Tuple[int, ...]:
    """Integer geometric ramp: ``n`` positive descending values summing to
    ``total`` with shape ``~ r**i`` — the just-in-time skew (the first
    bus-served shard gets the largest share)."""
    assert 0 < r <= 1.0 and n >= 1 and total >= n, (total, n, r)
    weights = [r ** i for i in range(n)]
    scale = total / sum(weights)
    vals = [max(1, round(w * scale)) for w in weights]
    i = 0
    while sum(vals) != total:           # repair rounding drift in place
        j = i % n
        if sum(vals) > total:
            if vals[j] > 1:
                vals[j] -= 1
        else:
            vals[j] += 1
        i += 1
    vals.sort(reverse=True)
    return tuple(vals)


def _words_to_chunks(words: Sequence[int], lanes: int,
                     L: int) -> Tuple[int, ...]:
    """Word-aligned split points -> element chunk vector (tail clipped)."""
    out, lo = [], 0
    for w in words:
        hi = min(lo + int(w) * lanes, L)
        if hi > lo:
            out.append(hi - lo)
        lo = hi
    return tuple(out)


def _axis_extent(builder: ProgramBuilder) -> Optional[int]:
    """The common trimmed store length of an axis-splittable tape."""
    trims = {t for _, t in builder.stores}
    return trims.pop() if len(trims) == 1 else None


def _chunks_of(pplan: P.PartitionPlan) -> Tuple[int, ...]:
    """Recover the per-shard chunk vector from a built plan (elements for
    axis, store counts for rows)."""
    if pplan.strategy == "rows":
        return tuple(len(p) for p in pplan.pieces)
    return tuple(p[0][2] - p[0][1] for p in pplan.pieces)


def _axis_candidates(builder: ProgramBuilder, tiles: int, mode: str,
                     ratios: Sequence[float]) -> List[Tuple[int, ...]]:
    L = _axis_extent(builder)
    if L is None:
        return []
    lanes = 32 // builder.sew
    cands = [P.uniform_axis_chunks(L, tiles, lanes)]
    bal = P.balanced_axis_chunks(L, tiles, lanes)
    if bal not in cands:
        cands.append(bal)
    if mode == "auto":
        words_total = -(-L // lanes)
        n = min(tiles, words_total)
        if n >= 2:
            for r in ratios:
                c = _words_to_chunks(_ramp(words_total, n, r), lanes, L)
                if c and c not in cands:
                    cands.append(c)
    return cands


def _rows_candidates(builder: ProgramBuilder, tiles: int,
                     mode: str) -> List[Tuple[int, ...]]:
    S = len(builder.stores)
    if S < 2:
        return []
    n = min(tiles, S)
    q, rem = divmod(S, n)
    cands = [tuple(q + (1 if s < rem else 0) for s in range(n))]
    if mode == "auto" and n >= 2 and S > n:
        for r in SKEW_RATIOS:
            c = _ramp(S, n, r)
            if c not in cands:
                cands.append(c)
    return cands


# ---------------------------------------------------------------------------
# Dispatch-order search
# ---------------------------------------------------------------------------

def candidate_orders(stages: Sequence[timing.StageCost],
                     n_tiles: int) -> List[Tuple[int, ...]]:
    """Deterministic dispatch-order candidates: exhaustive for short waves,
    else identity + every single-shard relocation + the cost-sorted
    heuristics (largest-compute-first profits when a heavy shard would
    otherwise wait behind the whole DMA ladder)."""
    n = len(stages)
    ident = tuple(range(n))
    if n <= 1:
        return [ident]
    if n <= 5:
        return [ident] + [p for p in itertools.permutations(range(n))
                          if p != ident]
    cands = {ident}
    for i in range(n):
        for j in range(n):
            if i != j:
                rest = [k for k in range(n) if k != i]
                rest.insert(j, i)
                cands.add(tuple(rest))
    cands.add(tuple(sorted(range(n),
                           key=lambda k: (-stages[k].compute_cycles, k))))
    cands.add(tuple(sorted(range(n),
                           key=lambda k: (-stages[k].dma_in_cycles, k))))
    cands.add(tuple(sorted(
        range(n),
        key=lambda k: (stages[k].dma_in_cycles
                       - stages[k].compute_cycles, k))))
    return [ident] + sorted(cands - {ident})


def best_order(stages: Sequence[timing.StageCost], n_tiles: int,
               assign: str = "roundrobin") -> Tuple[Tuple[int, ...], float]:
    """The cheapest candidate dispatch order under the wave model, with a
    deterministic preference for identity on ties."""
    best_key, best = None, None
    for order in candidate_orders(stages, n_tiles):
        c = timing.wave_cycles([stages[i] for i in order], n_tiles,
                               assign=assign)
        key = (c, order != tuple(range(len(stages))), order)
        if best_key is None or key < best_key:
            best_key, best = key, (order, c)
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Candidate evaluation
# ---------------------------------------------------------------------------

#: Per shard: engine -> (LoweredKernel, StageCost); engines the shard
#: cannot lower on are simply absent.
_Options = List[Dict[str, tuple]]


def _shard_options(pplan: P.PartitionPlan,
                   allowed: Sequence[str]) -> Tuple[_Options, list]:
    """Lower every shard on every allowed engine; collect stage costs.
    Returns the per-shard option maps plus the diagnoses of failed
    (engine, shard) pairs for error reporting."""
    opts: _Options = []
    failures: list = []
    for sb in pplan.builders:
        d: Dict[str, tuple] = {}
        for eng in allowed:
            bad = engine_diagnosis(sb, eng)
            if bad is not None:
                failures.append(bad)
                continue
            try:
                lk = _LOWERINGS[eng](sb).lower()
            except LoweringError as e:
                failures.append(e)
                continue
            d[eng] = (lk, timing.stage_cost(lk))
        opts.append(d)
    return opts, failures


def _greedy_mix(opts: _Options) -> Optional[Tuple[str, ...]]:
    """Walk the DMA ladder in axis order, assigning each shard the engine
    that finishes it earliest given the bus time already committed —
    Caesar for small-image bus-expressible shards, Carus where the bus
    ALU cannot go (or its 100-cycle overhead still wins).  A heuristic
    proposal only: the exact wave model judges the result."""
    if not all(opts):
        return None
    bus = 0.0
    pick: List[str] = []
    for d in opts:
        best = None
        for eng in sorted(d):
            st = d[eng][1]
            key = (bus + st.dma_in_cycles + st.compute_cycles,
                   st.dma_in_cycles, eng)
            if best is None or key < best[0]:
                best = (key, eng, st)
        assert best is not None
        pick.append(best[1])
        bus += best[2].dma_in_cycles
    return tuple(pick)


def _assignments(opts: _Options, allowed: Sequence[str],
                 mix: bool) -> List[Tuple[str, ...]]:
    cands: List[Tuple[str, ...]] = []
    for eng in allowed:
        if all(eng in d for d in opts):
            cands.append((eng,) * len(opts))
    if mix and len(allowed) > 1:
        mixed = _greedy_mix(opts)
        if mixed is not None and mixed not in cands:
            cands.append(mixed)
    return cands


@dataclasses.dataclass
class _Eval:
    """One fully-costed candidate configuration."""

    cycles: float
    rank: tuple                     # deterministic tie-break
    strategy: str
    chunks: Tuple[int, ...]
    engines: Tuple[str, ...]        # axis order
    order: Tuple[int, ...]
    ident_cycles: float             # same config, identity dispatch order
    pplan: P.PartitionPlan          # axis order (not yet reordered)
    opts: _Options


def _fitted_ratios(builder: ProgramBuilder, tiles: int, partition: str,
                   allowed: Sequence[str]) -> Tuple[float, ...]:
    """Per-kernel just-in-time ratio fit: lower the seed plan's head shard
    per engine and read r = compute/(dma+compute) — the geometric ramp
    ratio that equalizes tile finish times when stage legs scale with
    chunk size (intercepts are left to the exact evaluator)."""
    ratios = list(SKEW_RATIOS)
    try:
        head = P.plan(builder, tiles, partition).builders[0]
    except P.PartitionError:
        return tuple(ratios)
    for eng in allowed:
        if engine_diagnosis(head, eng) is not None:
            continue
        try:
            st = timing.stage_cost(_LOWERINGS[eng](head).lower())
        except LoweringError:
            continue
        denom = st.dma_in_cycles + st.compute_cycles
        if denom > 0:
            r = round(min(0.95, max(0.3, st.compute_cycles / denom)), 3)
            if r not in ratios:
                ratios.append(r)
    return tuple(ratios)


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------

def _single_plan(builder: ProgramBuilder, engine: str,
                 mode: str) -> Tuple[SchedulePlan, P.PartitionPlan, list]:
    pplan = P.plan(builder, 1)
    eng = engine if engine != "auto" else select_engine(builder)
    lk = _LOWERINGS[eng](builder).lower()
    c = timing.wave_cycles([timing.stage_cost(lk)], 1)
    splan = SchedulePlan("single", (), (eng,), (0,), 1, builder.sew,
                         c, c, c, mode)
    return splan, pplan, [lk]


def _search(builder: ProgramBuilder, tiles: int, partition: str,
            engine: str, mode: str):
    """Evaluate the candidate space and pick the winning configuration.
    Returns ``(splan, pplan, lks)`` with the plan's shards already in
    dispatch order and lowered (unoptimized, unpadded — the frontend owns
    opt/padding/verification)."""
    if tiles == 1:
        return _single_plan(builder, engine, mode)
    seed_pplan = P.plan(builder, tiles, partition)   # seed strategy rule
    seed_strategy = seed_pplan.strategy
    seed_chunks = _chunks_of(seed_pplan)
    # the seed's single-engine choice: select on the head (largest) shard,
    # falling back to an engine every shard can lower on — a tape whose
    # shards differ in expressibility (heterogeneous store cones) must not
    # crash the uniform path
    if engine != "auto":
        uni_engines: Tuple[str, ...] = (engine,)
    else:
        head_eng = select_engine(seed_pplan.builders[0])
        uni_engines = (head_eng,) + tuple(e for e in ENGINES
                                          if e != head_eng)
    allowed = (engine,) if engine != "auto" else ENGINES
    if mode == "uniform":
        strategies = [seed_strategy]
    elif partition != "auto":
        strategies = [partition]
    else:
        strategies = [s for s in ("rows", "axis") if s == seed_strategy] + \
            [s for s in ("rows", "axis") if s != seed_strategy]
    ratios = _fitted_ratios(builder, tiles, partition, allowed) \
        if mode == "auto" else SKEW_RATIOS

    evals: List[_Eval] = []
    failures: list = []
    for strategy in strategies:
        if strategy == "rows":
            chunk_cands = _rows_candidates(builder, tiles, mode)
        else:
            chunk_cands = _axis_candidates(builder, tiles, mode, ratios)
        for chunks in chunk_cands:
            try:
                pplan = P.plan(builder, tiles, strategy, chunks=chunks)
            except P.PartitionError as e:
                failures.append(e)
                continue
            # uniform mode costs only the seed engine resolution, reaching
            # for the fallback engine lazily (the default path should not
            # pay a second lowering per shard when the seed engine covers
            # the whole wave); auto mode costs every allowed engine
            if mode == "uniform":
                opts, fails = _shard_options(pplan, uni_engines[:1])
                if not all(opts) and len(uni_engines) > 1:
                    more, fails2 = _shard_options(pplan, uni_engines[1:])
                    opts = [{**a, **b} for a, b in zip(opts, more)]
                    fails.extend(fails2)
                engines_here: Sequence[str] = uni_engines
            else:
                engines_here = allowed
                opts, fails = _shard_options(pplan, engines_here)
            failures.extend(fails)
            assigns = _assignments(opts, engines_here, mix=(mode == "auto"))
            if mode == "uniform" and assigns:
                assigns = assigns[:1]   # first feasible engine in seed order
            for assign in assigns:
                stages = [opts[i][e][1] for i, e in enumerate(assign)]
                order, cycles = best_order(stages, tiles)
                rank = (cycles,
                        len(set(assign)) > 1,          # prefer single-engine
                        strategy != seed_strategy,     # prefer seed strategy
                        chunks != seed_chunks,         # prefer seed chunks
                        order != tuple(range(len(order))),
                        assign, chunks, order)
                evals.append(_Eval(
                    cycles, rank, strategy, chunks, assign, order,
                    timing.wave_cycles(stages, tiles), pplan, opts))
    if not evals:
        for f in failures:
            if isinstance(f, (UnsupportedOnEngine, LoweringError)):
                raise f
        raise P.PartitionError(
            f"{builder.name}: no feasible schedule for tiles={tiles}, "
            f"partition={partition!r}, engine={engine!r}: "
            + "; ".join(str(f) for f in failures))

    # the seed reference: seed strategy + seed chunks + first feasible
    # seed engine, identity dispatch order — what the planner did before
    # scheduling existed (the regression baseline for satellite tests)
    seed_cycles = min(
        (e.ident_cycles for e in evals
         if e.strategy == seed_strategy and e.chunks == seed_chunks
         and len(set(e.engines)) == 1),
        default=min(e.ident_cycles for e in evals))
    # the uniform reference: best single-engine candidate within the seed
    # strategy's uniform chunkings (cost-picked tail placement included)
    uniform_evals = [e for e in evals
                     if e.strategy == seed_strategy
                     and len(set(e.engines)) == 1
                     and e.chunks in (seed_chunks,
                                      _uniform_alternatives(
                                          builder, tiles, seed_strategy))]
    uniform_cycles = min((e.cycles for e in uniform_evals),
                         default=min(e.cycles for e in evals))

    best = min(evals, key=lambda e: e.rank)
    splan = SchedulePlan(best.strategy, best.chunks, best.engines,
                         best.order, tiles, builder.sew, best.cycles,
                         uniform_cycles, seed_cycles, mode)
    pplan = best.pplan.reordered(best.order)
    lks = [best.opts[i][best.engines[i]][0] for i in best.order]
    return splan, pplan, lks


def _uniform_alternatives(builder: ProgramBuilder, tiles: int,
                          strategy: str) -> Tuple[int, ...]:
    """The non-seed uniform chunking (balanced remainder spread) — the
    only chunk vector besides the seed's that still counts as 'uniform'."""
    if strategy != "axis":
        return ()
    L = _axis_extent(builder)
    if L is None:
        return ()
    return P.balanced_axis_chunks(L, tiles, 32 // builder.sew)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def realize(builder: ProgramBuilder,
            splan: SchedulePlan) -> Tuple[P.PartitionPlan, list]:
    """Apply a SchedulePlan to a (re)traced tape: build the partition with
    the plan's chunk vector, permute shards into dispatch order and lower
    each on its assigned engine.  Raises :class:`PartitionError` /
    :class:`UnsupportedOnEngine` / :class:`LoweringError` when the plan
    does not fit the tape (user-supplied plans validate here)."""
    for e in splan.engines:
        if e not in ENGINES:
            raise ValueError(f"SchedulePlan names unknown engine {e!r}: "
                             f"expected one of {ENGINES}")
    if len(splan.order) != len(splan.engines):
        raise ValueError(
            f"SchedulePlan order/engines length mismatch: "
            f"{len(splan.order)} vs {len(splan.engines)}")
    if splan.strategy == "single":
        pplan = P.plan(builder, 1)
    else:
        pplan = P.plan(builder, splan.tiles, splan.strategy,
                       chunks=splan.chunks)
    if pplan.n_shards != splan.n_shards:
        raise P.PartitionError(
            f"{builder.name}: SchedulePlan expects {splan.n_shards} "
            f"shards, partition produced {pplan.n_shards}")
    pplan = pplan.reordered(splan.order)
    engines = [splan.engines[i] for i in splan.order]
    lks = [_LOWERINGS[e](sb).lower()
           for e, sb in zip(engines, pplan.builders)]
    return pplan, lks


def plan_wave(builder: ProgramBuilder, tiles: int, *,
              partition: str = "auto", engine: str = "auto",
              mode="uniform"):
    """The frontend's scheduling entry: returns ``(splan, pplan, lks)``
    with shards lowered in dispatch order (unoptimized, unpadded).

    ``mode`` is ``"uniform"`` (seed strategy/engine, cost-picked uniform
    chunking and tail placement), ``"auto"`` (the full autotuner search)
    or an explicit :class:`SchedulePlan`.  Searches are memoized in the
    content-keyed plan registry; a cache hit returns the identical plan
    object and only re-lowers the shards for the fresh traced values."""
    tiles = _check_tiles(tiles)
    if isinstance(mode, SchedulePlan):
        pplan, lks = realize(builder, mode)
        return mode, pplan, lks
    if mode not in SCHEDULE_MODES:
        raise ValueError(f"unknown schedule mode {mode!r}: expected a "
                         f"SchedulePlan or one of {SCHEDULE_MODES}")
    key = plan_key(builder, tiles, partition, engine, mode)
    hit = _plan_cache.get(key)
    if hit is not None:
        _plan_cache.move_to_end(key)
        pplan, lks = realize(builder, hit)
        return hit, pplan, lks
    splan, pplan, lks = _search(builder, tiles, partition, engine, mode)
    _cache_put(key, splan)
    return splan, pplan, lks


def autotune(builder: ProgramBuilder, tiles: int, *,
             partition: str = "auto",
             engine: str = "auto") -> SchedulePlan:
    """Search (strategy x chunk skew x engine assignment x dispatch
    order) for the cheapest modeled wave; cached — repeat calls with the
    same tape structure return the identical SchedulePlan object."""
    return plan_wave(builder, tiles, partition=partition, engine=engine,
                     mode="auto")[0]


def uniform_plan(builder: ProgramBuilder, tiles: int, *,
                 partition: str = "auto",
                 engine: str = "auto") -> SchedulePlan:
    """The uniform-mode reference plan (seed strategy and engine, uniform
    chunks, cost-picked remainder spread + tail placement)."""
    return plan_wave(builder, tiles, partition=partition, engine=engine,
                     mode="uniform")[0]
