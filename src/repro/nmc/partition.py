"""Tile-parallel partitioning planner (DESIGN.md §9).

The paper's headline property is *scalability*: an edge node instantiates
an array of identical NM-Caesar / NM-Carus tiles behind its SRAM macros.
The layers below this one already execute many independent programs across
tiles (vmapped pools, bucketed compiles, async waves) — this module closes
the remaining gap: carving **one** kernel across the array, so a single
``nmc.jit(fn, tiles=N)`` call occupies N tiles with shards of one logical
computation and reassembles the caller's array afterwards.

The planner operates on the *traced tape* (:class:`ProgramBuilder`), not on
host arrays, so it needs no per-kernel annotations: the tape already knows
which nodes are loads, which are scalar-tap pools, where slides read ahead
and how stores trim.  Two strategies:

* ``"rows"`` — *store-level* split: the tape's stores (matmul/gemm output
  rows) distribute across tiles in contiguous balanced blocks, and each
  shard replays exactly the backward cone of its stores.  Loads and
  ``t.consts`` pools referenced by several shards are replicated into each
  shard's tile image (the B matrix every output row reads).
* ``"axis"`` — *element-axis* split: every vector node (loads and
  computes) shares one data-parallel element axis, which splits into
  word-aligned chunks — elementwise/relu streams, conv/maxpool output
  columns.  ``slide_down`` reads ahead by its amount, so each shard's
  loads carry a *halo* of ``max`` cumulative slide depth.  By default
  chunks are ceil-packed with the ragged tail on the last shard (the
  seed behavior); callers may pass an explicit ``chunks=`` vector —
  arbitrary positive element counts, word-aligned or not — which is how
  the wave scheduler (:mod:`repro.nmc.schedule`, DESIGN.md §14) realizes
  skewed and cost-arbitrated splits.

``partition="auto"`` picks ``rows`` when the stores distribute evenly and
the tape has no slides (slides are column-structured), otherwise ``axis``,
otherwise any applicable strategy — and raises :class:`PartitionError`
naming the obstruction when the tape has no data-parallel axis at all.

Bit-exactness is by construction: shards are replayed through the same
:class:`ProgramBuilder` tracing (eager ``alu.*_np`` evaluation), so each
shard carries its own oracle, and concatenating shard oracles reproduces
the unsharded oracle exactly (property-tested in tests/test_partition.py
over random lengths × split factors).  The :meth:`PartitionPlan.gather`
closure is the inverse of the split: it reassembles per-shard outputs into
the caller's array with the same shaping rule the single-tile path uses.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import alu
from repro.nmc.frontend import (PARTITIONS, ProgramBuilder, _ConstScalar,
                                _Node, _check_tiles, _shape_parts)

#: The valid ``partition=`` strategy names — one source of truth, shared
#: with the frontend's eager kwarg validation (``nmc.jit(partition=...)``).
STRATEGIES = PARTITIONS


class PartitionError(Exception):
    """The traced tape cannot be sharded by the requested strategy (no
    data-parallel axis, too few stores, ...) — names the obstruction."""


# ---------------------------------------------------------------------------
# Plan artifact
# ---------------------------------------------------------------------------

#: One shard's slice of one original store: (store index, element range).
Piece = Tuple[int, int, int]


@dataclasses.dataclass
class PartitionPlan:
    """A sharded kernel: per-tile replayed tapes + the gather that
    reassembles their outputs into the caller's array."""

    strategy: str                      # "single" | "rows" | "axis"
    sew: int
    builders: List[ProgramBuilder]     # one replayed tape per shard
    pieces: List[List[Piece]]          # per shard, in its store order
    store_trims: List[int]             # original store trimmed lengths
    requested_tiles: int
    parent: Optional[ProgramBuilder] = None   # the unsharded tape — the
                                       # reference the partition-safety
                                       # pass (repro.nmc.check) checks
                                       # store coverage and halos against

    @property
    def n_shards(self) -> int:
        return len(self.builders)

    @property
    def signature(self) -> tuple:
        """Layout-stability fingerprint: everything about the plan that
        determines shard memory layout and gather shape — strategy, SEW,
        shard count, per-shard piece lists, store trims — and nothing that
        depends on traced *values*.  Two plans of one kernel over different
        activation values must agree on it for resident-weight patching to
        be sound (:mod:`repro.serve.block` asserts this at build time and
        falls back to a full reload on mismatch)."""
        return (self.strategy, self.sew, self.n_shards,
                tuple(tuple(p) for p in self.pieces),
                tuple(self.store_trims))

    def shard_oracles(self) -> List[np.ndarray]:
        """Each shard's traced reference output (eager numpy evaluation)."""
        return [b.oracle() for b in self.builders]

    def reordered(self, order: Sequence[int]) -> "PartitionPlan":
        """The same plan with shards permuted into dispatch order
        (``order[k]`` = which shard dispatches k-th).  Gather scatters by
        piece ranges, so any permutation reassembles bit-exactly; the
        scheduler uses this to put shards where the bus-serialized DMA
        ladder reaches them just in time."""
        perm = tuple(int(i) for i in order)
        assert sorted(perm) == list(range(self.n_shards)), \
            (perm, self.n_shards)
        return PartitionPlan(self.strategy, self.sew,
                             [self.builders[i] for i in perm],
                             [self.pieces[i] for i in perm],
                             list(self.store_trims), self.requested_tiles,
                             parent=self.parent)

    def gather(self, shard_outs: List[np.ndarray]) -> np.ndarray:
        """Reassemble per-shard outputs into the unsharded kernel's output:
        scatter each shard's pieces back into its original store's element
        range, then apply the same shaping rule as the single-tile path
        (stack equal-size stores, else concatenate)."""
        dt = alu.NP_DTYPES[self.sew]
        parts = [np.zeros(t, dt) for t in self.store_trims]
        for out, pieces in zip(shard_outs, self.pieces):
            flat = np.asarray(out).reshape(-1)
            off = 0
            for si, lo, hi in pieces:
                parts[si][lo:hi] = flat[off:off + (hi - lo)]
                off += hi - lo
        return _shape_parts(parts)

    def oracle(self) -> np.ndarray:
        """Gather of the shard oracles — must equal the unsharded oracle."""
        return self.gather(self.shard_oracles())


# ---------------------------------------------------------------------------
# Tape replay
# ---------------------------------------------------------------------------

def _map_arg(a, m: dict):
    """Translate a tape operand into the replayed tape's namespace."""
    if isinstance(a, _Node):
        return m[a.idx]
    if isinstance(a, _ConstScalar):
        return _ConstScalar(m[a.pool.idx], a.index, a.value)
    return a                            # raw Python scalar


def _replay(b: ProgramBuilder, keep: set,
            load_slice: Callable[[_Node], tuple],
            store_sel: List[Piece]) -> ProgramBuilder:
    """Re-trace a subset of the tape into a fresh builder.

    ``keep`` filters nodes; ``load_slice(node) -> (lo, end)`` slices load
    values (identity for the rows strategy); ``store_sel`` lists the shard's
    store pieces.  Replaying through the public ``ProgramBuilder`` methods
    re-runs the eager oracle evaluation on the sliced values, so the shard's
    oracle is bit-exact with the sliced original by construction, and the
    lowerings see a perfectly ordinary tape (same fusion/placement rules)."""
    nb = ProgramBuilder(b.sew, name=getattr(b, "name", "kernel"))
    m: dict[int, object] = {}       # original node idx -> replayed value
    for n in b.nodes:
        if n.idx not in keep:
            continue
        if n.op == "load":
            lo, end = load_slice(n)
            m[n.idx] = nb.load(n.val[lo:end], bank=n.bank)
        elif n.op == "cpool":
            m[n.idx] = nb.cpool(n.val)     # scalar taps replicate whole
        elif n.op == "slide_down":
            m[n.idx] = nb.slide_down(m[n.args[0].idx], n.amount)
        elif n.op == "mul":
            # mul may be a mac-chain head whose scalar tap sits in the
            # first slot; nb.mac(None, ...) reconstructs either form
            x, y = n.args
            m[n.idx] = nb.mac(None, _map_arg(x, m), _map_arg(y, m))
        elif n.op == "mac":
            acc, x, y = n.args
            m[n.idx] = nb.mac(m[acc.idx], _map_arg(x, m), _map_arg(y, m))
        else:                              # elementwise binop
            x, y = n.args
            m[n.idx] = nb.binop(n.op, m[x.idx], _map_arg(y, m))
    for si, lo, hi in store_sel:
        node, _trim = b.stores[si]
        nb.store(m[node.idx], n=hi - lo)
    return nb


# ---------------------------------------------------------------------------
# "rows" strategy: distribute stores, replay each shard's backward cone
# ---------------------------------------------------------------------------

def _cone(b: ProgramBuilder, roots: List[_Node]) -> set:
    seen: set = set()
    stack = list(roots)
    while stack:
        n = stack.pop()
        if n.idx in seen:
            continue
        seen.add(n.idx)
        for a in n.args:
            if isinstance(a, _Node):
                stack.append(a)
            elif isinstance(a, _ConstScalar):
                stack.append(a.pool)
    return seen


def _check_chunks(b: ProgramBuilder, chunks, total: int, tiles: int,
                  what: str) -> Tuple[int, ...]:
    """Validate an explicit per-shard chunk vector: positive entries that
    exactly cover ``total`` with at most ``tiles`` shards."""
    vec = tuple(int(c) for c in chunks)
    if not vec or any(c <= 0 for c in vec):
        raise PartitionError(
            f"{b.name}: explicit {what} chunk vector must be non-empty "
            f"with positive entries, got {vec}")
    if sum(vec) != total:
        raise PartitionError(
            f"{b.name}: explicit {what} chunk vector {vec} sums to "
            f"{sum(vec)}, must exactly cover {total}")
    if len(vec) > tiles:
        raise PartitionError(
            f"{b.name}: explicit {what} chunk vector has {len(vec)} "
            f"shards for {tiles} tiles")
    return vec


def _plan_rows(b: ProgramBuilder, tiles: int,
               counts: Optional[Sequence[int]] = None) -> PartitionPlan:
    S = len(b.stores)
    if S < 2:
        raise PartitionError(
            f"{b.name}: rows split needs >= 2 stores, tape has {S} — use "
            f"the element-axis strategy for single-output kernels")
    if counts is not None:
        counts = _check_chunks(b, counts, S, tiles, "rows")
    else:
        n = min(tiles, S)
        q, r = divmod(S, n)
        counts = tuple(q + (1 if s < r else 0) for s in range(n))
    builders, pieces = [], []
    off = 0
    for count in counts:
        sel = [(si, 0, b.stores[si][1]) for si in range(off, off + count)]
        keep = _cone(b, [b.stores[si][0] for si, _, _ in sel])
        builders.append(_replay(b, keep, lambda nd: (0, nd.ne), sel))
        pieces.append(sel)
        off += count
    return PartitionPlan("rows", b.sew, builders, pieces,
                         [t for _, t in b.stores], tiles, parent=b)


# ---------------------------------------------------------------------------
# "axis" strategy: word-aligned element chunks with slide halo
# ---------------------------------------------------------------------------

def slide_halo(b: ProgramBuilder) -> int:
    """Max cumulative ``slide_down`` read-ahead on any path from a load to
    a store — the halo each shard's loads must carry so slid values inside
    the chunk see their true neighbours, not the shard boundary."""
    halo = {n.idx: 0 for n in b.nodes}
    for n in reversed(b.nodes):        # tape is topologically ordered
        h = halo[n.idx]
        inc = n.amount if n.op == "slide_down" else 0
        for a in n.args:
            if isinstance(a, _Node):
                halo[a.idx] = max(halo[a.idx], h + inc)
    return max((halo[n.idx] for n in b.nodes if n.op == "load"), default=0)


#: Backwards-compatible private alias (pre-§11 name).
_slide_halo = slide_halo


def uniform_axis_chunks(L: int, tiles: int, lanes: int) -> Tuple[int, ...]:
    """The seed uniform chunking: ceil-packed word-aligned chunks, ragged
    tail last.  May occupy fewer shards than tiles when the word count
    does not divide (e.g. 9 words on 8 tiles -> [2,2,2,2,1] words)."""
    words_total = -(-L // lanes)
    words_per = -(-words_total // tiles)
    chunk = words_per * lanes
    out, lo = [], 0
    while lo < L:
        hi = min(lo + chunk, L)
        out.append(hi - lo)
        lo = hi
    return tuple(out)


def balanced_axis_chunks(L: int, tiles: int, lanes: int) -> Tuple[int, ...]:
    """Balanced word-aligned chunking: spread the word remainder across
    the first shards (divmod, largest first) so every requested tile gets
    work — the cost-model-preferred alternative the scheduler weighs
    against the ceil-packed seed chunking."""
    words_total = -(-L // lanes)
    n = min(tiles, words_total)
    q, r = divmod(words_total, n)
    out, lo = [], 0
    for s in range(n):
        hi = min(lo + (q + (1 if s < r else 0)) * lanes, L)
        out.append(hi - lo)
        lo = hi
    return tuple(c for c in out if c > 0)


def _plan_axis(b: ProgramBuilder, tiles: int,
               chunks: Optional[Sequence[int]] = None) -> PartitionPlan:
    vec = [n for n in b.nodes if n.op != "cpool"]
    nes = {n.ne for n in vec}
    if len(nes) != 1:
        raise PartitionError(
            f"{b.name}: no common data-parallel element axis: vector "
            f"nodes have lengths {sorted(nes)}")
    ne = nes.pop()
    trims = {t for _, t in b.stores}
    if len(trims) != 1:
        raise PartitionError(
            f"{b.name}: stores disagree on trimmed length "
            f"({sorted(trims)}): cannot split one element axis")
    L = trims.pop()
    lanes = 32 // b.sew
    if chunks is not None:
        chunks = _check_chunks(b, chunks, L, tiles, "axis")
    else:
        # word-aligned chunks: every shard but the last covers a whole
        # number of memory words, so shard programs differ only in the
        # ragged tail
        chunks = uniform_axis_chunks(L, tiles, lanes)
    halo = slide_halo(b)
    builders, pieces = [], []
    lo = 0
    for c in chunks:
        hi = lo + c
        end = min(hi + halo, ne)
        builders.append(_replay(
            b, {n.idx for n in b.nodes},
            lambda nd, lo=lo, end=end: (lo, end),
            [(si, lo, hi) for si in range(len(b.stores))]))
        pieces.append([(si, lo, hi) for si in range(len(b.stores))])
        lo = hi
    return PartitionPlan("axis", b.sew, builders, pieces,
                         [t for _, t in b.stores], tiles, parent=b)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def plan(builder: ProgramBuilder, tiles: int,
         partition: str = "auto",
         chunks: Optional[Sequence[int]] = None) -> PartitionPlan:
    """Shard a traced tape across ``tiles`` tiles.

    ``partition`` is ``"auto"`` (rows when the stores distribute evenly
    and the tape has no slides, else element-axis, else any applicable
    strategy), ``"rows"`` or ``"axis"``.  The plan may hold fewer shards
    than requested when the tape is too small (a 3-element vector cannot
    occupy 8 tiles); it never holds more.  ``tiles=1`` returns the
    original tape as a single trivial shard.

    ``chunks`` (optional) is an explicit per-shard chunk vector — element
    counts for ``"axis"``, store counts for ``"rows"`` — the scheduler's
    skewed split points.  It must name an explicit strategy (the vector's
    meaning depends on it) and exactly cover the axis/store set
    (:class:`PartitionError` otherwise; the partition-safety verifier
    re-checks coverage and halos on the built plan)."""
    if partition not in STRATEGIES:
        raise ValueError(f"unknown partition strategy {partition!r}: "
                         f"expected one of {STRATEGIES}")
    tiles = _check_tiles(tiles)
    if not builder.stores:
        raise PartitionError(f"{builder.name}: tape has no stores — "
                             f"nothing to shard")
    if chunks is not None and partition == "auto" and tiles > 1:
        raise ValueError(
            "an explicit chunk vector needs an explicit partition "
            "strategy ('rows' or 'axis'): the vector's meaning — store "
            "counts vs element counts — depends on it")
    if tiles == 1:
        pieces = [[(si, 0, t) for si, (_, t) in enumerate(builder.stores)]]
        return PartitionPlan("single", builder.sew, [builder], pieces,
                             [t for _, t in builder.stores], tiles,
                             parent=builder)
    if partition == "rows":
        return _plan_rows(builder, tiles, counts=chunks)
    if partition == "axis":
        return _plan_axis(builder, tiles, chunks=chunks)
    # auto: prefer structurally-identical row shards (same program on every
    # tile, trivially one bucket) when stores distribute evenly; slides are
    # column-structured (conv's shifted replicas), so their presence routes
    # to the element-axis strategy
    S = len(builder.stores)
    has_slide = any(n.op == "slide_down" for n in builder.nodes)
    if S > 1 and S >= tiles and S % tiles == 0 and not has_slide:
        try:
            return _plan_rows(builder, tiles)
        except PartitionError:
            pass
    errors = []
    for strat in (_plan_axis, _plan_rows):
        try:
            return strat(builder, tiles)
        except PartitionError as e:
            errors.append(str(e))
    raise PartitionError(f"{builder.name}: no applicable partition "
                         f"strategy: " + "; ".join(errors))
