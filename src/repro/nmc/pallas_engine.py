"""Pallas fast-path engine backend (DESIGN.md §10).

The scan engines (:mod:`repro.core.caesar` / :mod:`repro.core.carus`)
interpret one instruction per ``lax.scan`` step with a ``lax.switch`` over
the opcode — bit-exact, but the throughput ceiling of every sweep in the
repo.  This module is the third implementation of the
:class:`repro.nmc.engine.Engine` protocol: it lowers a bucketed
:class:`repro.nmc.program.Program` wave to a **single** ``pl.pallas_call``
that keeps each tile's entire memory image resident in fast kernel memory
(VMEM on TPU; the interpreter's buffer on CPU) for the whole instruction
stream — the paper's near-memory thesis applied to the simulator itself:
N instructions cost one memory round-trip, not N.

Lowering contract (the shape every kernel variant shares):

* **tile-batch dimension → Pallas grid.**  A wave of T same-bucket
  programs runs as ``grid=(T,)``; block specs slice tile ``t``'s
  instruction stream ``[1, n_instr]`` and its lane-decomposed memory image
  ``[1, state_rows, n_elems]`` out of the batch.  Tiles are independent by
  construction (the pool's vmap contract), so grid steps never
  communicate.
* **memory image → resident lanes ref.**  The int32 word image is
  unpacked once per call into *native-dtype lanes* (int8/int16/int32 —
  one dtype-specialized kernel per SEW) and packed back once at the end;
  ``input_output_aliases`` makes the state ref in-place.  Native-dtype
  arithmetic gives two's-complement wraparound at SEW for free, which is
  exactly the per-step pack/unpack truncation of the scan engines.
* **instruction stream → ``fori_loop`` over a branch-free step.**
  Instructions stay *runtime data* (the bucketed compile cache keys on
  shape, never on contents), so the kernel cannot specialize per opcode.
  Instead of a ``lax.switch``, every step computes all candidate results,
  stacks them ``[n_ops, n_elems]``, selects row ``op``, and performs one
  conditional scatter — no branches, one dynamic write per instruction.
* **SEW specialization.**  ``sew`` is a static argument of the kernel
  factory; the :class:`repro.nmc.pool.BucketedPool` cache key
  ``(engine, sew, instr-bucket, tile-bucket, backend)`` therefore maps
  one-to-one onto compiled Pallas kernels.
* **CPU fallback.**  ``interpret=True`` is selected automatically when no
  TPU/GPU is attached, so the whole backend (and its differential tests)
  runs everywhere; ``backend="auto"`` in the frontend picks Pallas only
  on accelerators, where the fused kernel is the fast path.

Semantics are bit-exact vs the scan engines and the ``alu.*_np`` numpy
oracles at SEW 8/16/32 — property-fuzzed in ``tests/test_differential.py``
and conformance-tested per opcode in ``tests/test_engines.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import alu, isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc.engine import CaesarTile, CarusTile

JNP_DTYPES = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}
JNP_UDTYPES = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32}


def default_interpret() -> bool:
    """Pallas interpret mode unless a real accelerator is attached."""
    return jax.default_backend() not in ("tpu", "gpu")


# ---------------------------------------------------------------------------
# NM-Caesar kernel: flat word memory as [mem_words, L] native-dtype lanes
# ---------------------------------------------------------------------------

def _caesar_kernel(op_ref, dest_ref, s1_ref, s2_ref, lanes_in_ref,
                   lanes_out_ref, *, sew: int):
    L = 32 // sew
    dt, udt = JNP_DTYPES[sew], JNP_UDTYPES[sew]
    n_ops = len(CaesarOp)
    lanes_out_ref[...] = lanes_in_ref[...]

    def step(i, carry):
        mac, dot = carry
        op = op_ref[0, i]
        dest = dest_ref[0, i]
        a = lanes_out_ref[0, s1_ref[0, i]]
        b = lanes_out_ref[0, s2_ref[0, i]]
        au, bu = a.astype(udt), b.astype(udt)
        # RVV shift semantics: amount mod SEW ((x mod 2^SEW) mod SEW is
        # the same because SEW is a power of two <= 2^SEW)
        sh = bu % udt(sew)
        rows = [a] * n_ops
        rows[int(CaesarOp.AND)] = a & b
        rows[int(CaesarOp.OR)] = a | b
        rows[int(CaesarOp.XOR)] = a ^ b
        rows[int(CaesarOp.ADD)] = a + b
        rows[int(CaesarOp.SUB)] = a - b
        rows[int(CaesarOp.MUL)] = a * b
        rows[int(CaesarOp.SLL)] = (au << sh).astype(dt)
        rows[int(CaesarOp.SLR)] = (au >> sh).astype(dt)
        rows[int(CaesarOp.SRA)] = a >> sh.astype(dt)
        rows[int(CaesarOp.MIN)] = jnp.minimum(a, b)
        rows[int(CaesarOp.MAX)] = jnp.maximum(a, b)
        # packed MAC accumulator (native lanes == per-step pack truncation)
        prod = a * b
        mac_new = jnp.where(op == int(CaesarOp.MAC_INIT), prod, mac + prod)
        is_mac = (op >= int(CaesarOp.MAC_INIT)) & \
            (op <= int(CaesarOp.MAC_STORE))
        mac = jnp.where(is_mac, mac_new, mac)
        # 32-bit DOT accumulator: sum of sign-extended lane products
        dsum = (a.astype(jnp.int32) * b.astype(jnp.int32)).sum()
        dot_new = jnp.where(op == int(CaesarOp.DOT_INIT), dsum, dot + dsum)
        is_dot = (op >= int(CaesarOp.DOT_INIT)) & \
            (op <= int(CaesarOp.DOT_STORE))
        dot = jnp.where(is_dot, dot_new, dot)
        rows[int(CaesarOp.MAC_STORE)] = mac
        # DOT_STORE writes the scalar as one packed word (= unpack(dot))
        rows[int(CaesarOp.DOT_STORE)] = jnp.stack(
            [(dot >> (k * sew)).astype(dt) for k in range(L)])
        val = jnp.stack(rows)[op]
        is_binop = (op <= int(CaesarOp.MUL)) | \
            ((op >= int(CaesarOp.SLL)) & (op <= int(CaesarOp.MAX))) | \
            (op == int(CaesarOp.SRA))
        writes = is_binop | (op == int(CaesarOp.MAC_STORE)) | \
            (op == int(CaesarOp.DOT_STORE))
        cur = lanes_out_ref[0, dest]
        lanes_out_ref[0, dest] = jnp.where(writes, val, cur)
        return mac, dot

    # zero carries without captured constant arrays (Pallas kernels must
    # not close over traced constants): derive the MAC zeros from a read
    mac0 = lanes_in_ref[0, 0] * 0
    jax.lax.fori_loop(0, op_ref.shape[1], step, (mac0, jnp.int32(0)))


@functools.lru_cache(maxsize=None)
def _caesar_call(sew: int, n_instr: int, n_tiles: int, mem_words: int,
                 interpret: bool):
    L = 32 // sew
    ispec = pl.BlockSpec((1, n_instr), lambda t: (t, 0))
    lspec = pl.BlockSpec((1, mem_words, L), lambda t: (t, 0, 0))
    return pl.pallas_call(
        functools.partial(_caesar_kernel, sew=sew),
        grid=(n_tiles,),
        in_specs=[ispec, ispec, ispec, ispec, lspec],
        out_specs=lspec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, mem_words, L),
                                       JNP_DTYPES[sew]),
        input_output_aliases={4: 0},
        interpret=interpret)


# ---------------------------------------------------------------------------
# NM-Carus kernel: VRF as [n_regs, n_elems] native-dtype element rows
# ---------------------------------------------------------------------------

def _carus_kernel(op_ref, vd_ref, vs1_ref, vs2_ref, sval1_ref, sval2_ref,
                  imm_ref, mode_ref, ids_ref, elems_in_ref, elems_out_ref,
                  *, sew: int, n_regs: int, vlmax: int):
    dt, udt = JNP_DTYPES[sew], JNP_UDTYPES[sew]
    n_elems = elems_in_ref.shape[2]
    n_vops = len(isa.VOP_COMPACT)
    cid = isa.COMPACT_ID
    elems_out_ref[...] = elems_in_ref[...]
    ids = ids_ref[...]                      # iota passed as input (no
                                            # captured constants in kernels)

    def step(i, vl):
        op = op_ref[0, i]
        sval1, sval2 = sval1_ref[0, i], sval2_ref[0, i]
        imm, mode = imm_ref[0, i], mode_ref[0, i]
        indirect = (mode & isa.MODE_INDIRECT) != 0
        slide1 = (mode & isa.MODE_SLIDE1) != 0
        opmode = mode & 0x3
        # indirect register addressing: indices from sval2's LSBytes
        vd = jnp.where(indirect, (sval2 >> 16) & 0xFF, vd_ref[0, i]) % n_regs
        vs2 = jnp.where(indirect, (sval2 >> 8) & 0xFF, vs2_ref[0, i]) % n_regs
        vs1 = jnp.where(indirect, sval2 & 0xFF, vs1_ref[0, i]) % n_regs
        dst = elems_out_ref[0, vd]
        s2 = elems_out_ref[0, vs2]
        s1r = elems_out_ref[0, vs1]
        scalar_b = jnp.where(opmode == isa.MODE_VI, imm, sval1)     # int32
        b = jnp.where(opmode == isa.MODE_VV, s1r, scalar_b.astype(dt))

        rows = [dst] * n_vops
        # wraparound-closed ops compute directly in the native dtype
        rows[cid[VOp.VADD]] = s2 + b
        rows[cid[VOp.VSUB]] = s2 - b
        rows[cid[VOp.VMUL]] = s2 * b
        rows[cid[VOp.VAND]] = s2 & b
        rows[cid[VOp.VOR]] = s2 | b
        rows[cid[VOp.VXOR]] = s2 ^ b
        # signed min/max compare the *untruncated* vx/vi scalar (the scan
        # engine's lanes are sign-extended int32; truncation happens at
        # pack) — widen to int32, select, then truncate the winner
        b32 = jnp.where(opmode == isa.MODE_VV, s1r.astype(jnp.int32),
                        scalar_b)
        a32 = s2.astype(jnp.int32)
        rows[cid[VOp.VMIN]] = jnp.minimum(a32, b32).astype(dt)
        rows[cid[VOp.VMAX]] = jnp.maximum(a32, b32).astype(dt)
        # unsigned min/max compare SEW-bit zero-extensions (truncation-
        # invariant) and return the original lane values
        au, bu = s2.astype(udt), b.astype(udt)
        rows[cid[VOp.VMINU]] = jnp.where(au <= bu, s2, b)
        rows[cid[VOp.VMAXU]] = jnp.where(au >= bu, s2, b)
        sh = bu % udt(sew)
        rows[cid[VOp.VSLL]] = (au << sh).astype(dt)
        rows[cid[VOp.VSRL]] = (au >> sh).astype(dt)
        rows[cid[VOp.VSRA]] = s2 >> sh.astype(dt)
        rows[cid[VOp.VMACC]] = dst + s2 * b
        rows[cid[VOp.VMV]] = b
        # slides: gather from vs2 at ids -/+ offset; MODE_SLIDE1 inserts
        # the scalar at the exposed edge element
        off = jnp.where(slide1, 1, scalar_b)
        idx_up = ids - off
        g_up = s2[jnp.clip(idx_up, 0, n_elems - 1)]
        r_up = jnp.where(idx_up >= 0, g_up, dst)
        rows[cid[VOp.VSLIDEUP]] = jnp.where(
            slide1 & (ids == 0), sval1.astype(dt), r_up)
        idx_dn = ids + off
        g_dn = s2[jnp.clip(idx_dn, 0, n_elems - 1)]
        r_dn = jnp.where(idx_dn < vl, g_dn, jnp.zeros_like(dst))
        rows[cid[VOp.VSLIDEDOWN]] = jnp.where(
            slide1 & (ids == vl - 1), sval1.astype(dt), r_dn)
        # EMVV writes one element (full-length writeback, ignores VL)
        rows[cid[VOp.EMVV]] = jnp.where(
            ids == sval2 % n_elems, sval1.astype(dt), dst)
        # EMVX (scan-output only), VSETVL and VNOP leave the VRF untouched
        val = jnp.stack(rows)[op]
        writes = op <= cid[VOp.EMVV]
        vl_eff = jnp.where(op == cid[VOp.EMVV], n_elems, vl)
        sel = jnp.where(ids < vl_eff, val, dst)     # tail-undisturbed
        elems_out_ref[0, vd] = jnp.where(writes, sel, dst)
        return jnp.where(op == cid[VOp.VSETVL],
                         jnp.minimum(sval1, vlmax), vl)

    jax.lax.fori_loop(0, op_ref.shape[1], step, jnp.int32(vlmax))


@functools.lru_cache(maxsize=None)
def _carus_call(sew: int, n_instr: int, n_tiles: int, n_regs: int,
                reg_words: int, interpret: bool):
    L = 32 // sew
    n_elems = reg_words * L
    vlmax = reg_words * (32 // sew)
    ispec = pl.BlockSpec((1, n_instr), lambda t: (t, 0))
    idspec = pl.BlockSpec((n_elems,), lambda t: (0,))
    espec = pl.BlockSpec((1, n_regs, n_elems), lambda t: (t, 0, 0))
    return pl.pallas_call(
        functools.partial(_carus_kernel, sew=sew, n_regs=n_regs,
                          vlmax=vlmax),
        grid=(n_tiles,),
        in_specs=[ispec] * 8 + [idspec, espec],
        out_specs=espec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, n_regs, n_elems),
                                       JNP_DTYPES[sew]),
        input_output_aliases={9: 0},
        interpret=interpret)


# ---------------------------------------------------------------------------
# Engine-protocol adapters
# ---------------------------------------------------------------------------

class _PallasMixin:
    """Shared plumbing: per-(sew, tiles) jit cache, scan_fn/run adapters."""

    backend = "pallas"

    def _init_backend(self, interpret):
        self.interpret = default_interpret() if interpret is None \
            else bool(interpret)
        self._fns: dict = {}

    def batched_fn(self, sew: int, n_tiles: int, donate: bool = False):
        """``(batch_state[T, ...], batch_arrays[T, n]) -> batch_state`` —
        the pool-facing executor (one fused pallas_call per wave); the
        drop-in replacement for ``jit(vmap(scan_fn(sew)))``."""
        key = (sew, n_tiles, donate)
        fn = self._fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._run_batch, sew, n_tiles),
                         donate_argnums=(0,) if donate else ())
            self._fns[key] = fn
        return fn

    def scan_fn(self, sew: int):
        """Single-tile flavor of :meth:`batched_fn` (grid of one).  The
        pool never vmaps this — ``TilePool._batched_fn`` prefers
        ``batched_fn`` — but it keeps the Engine protocol complete."""
        fn = self.batched_fn(sew, 1)

        def run_one(state, arrays):
            batch = {k: jnp.asarray(v)[None] for k, v in arrays.items()}
            return fn(jnp.asarray(state)[None], batch)[0]

        return run_one

    def run(self, state, program):
        assert program.engine == self.name, (program.engine, self.name)
        return self.scan_fn(program.sew)(state, program.lower())


class PallasCaesarEngine(_PallasMixin, CaesarTile):
    """NM-Caesar tile on the Pallas fast path: the 8192-word memory image
    resident as ``[mem_words, L]`` native-dtype lanes for the whole
    instruction stream."""

    def __init__(self, config=None, interpret: bool | None = None):
        super().__init__(config)
        self._init_backend(interpret)

    def _run_batch(self, sew, n_tiles, batch_state, arrays):
        dt = JNP_DTYPES[sew]
        call = _caesar_call(sew, int(arrays["op"].shape[-1]), n_tiles,
                            self.sim.cfg.mem_words, self.interpret)
        lanes = alu.unpack(batch_state, sew).astype(dt)
        out = call(arrays["op"], arrays["dest"], arrays["src1"],
                   arrays["src2"], lanes)
        return alu.pack(out.astype(jnp.int32), sew)


class PallasCarusEngine(_PallasMixin, CarusTile):
    """NM-Carus tile on the Pallas fast path: the VRF resident as
    ``[n_regs, n_elems]`` native-dtype element rows, VL carried through
    the ``fori_loop``."""

    def __init__(self, config=None, interpret: bool | None = None):
        super().__init__(config)
        self._init_backend(interpret)

    def _run_batch(self, sew, n_tiles, batch_state, arrays):
        dt = JNP_DTYPES[sew]
        cfg = self.sim.cfg
        L = 32 // sew
        call = _carus_call(sew, int(arrays["op"].shape[-1]), n_tiles,
                           cfg.n_regs, cfg.reg_words, self.interpret)
        elems = alu.unpack(batch_state, sew).astype(dt).reshape(
            batch_state.shape[0], cfg.n_regs, cfg.reg_words * L)
        ids = jnp.arange(cfg.reg_words * L, dtype=jnp.int32)
        out = call(arrays["op"], arrays["vd"], arrays["vs1"], arrays["vs2"],
                   arrays["sval1"], arrays["sval2"], arrays["imm"],
                   arrays["mode"], ids, elems)
        words = alu.pack(out.reshape(batch_state.shape[0], cfg.n_regs,
                                     cfg.reg_words, L).astype(jnp.int32),
                         sew)
        return words
