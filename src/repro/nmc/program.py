"""Unified NMC program IR shared by NM-Caesar and NM-Carus (DESIGN.md §5).

Historically the repo had two program formats — NM-Caesar bus-op streams
(lists of ``(op, dest, src1, src2)`` tuples) and NM-Carus xvnmc issue traces
(lists of :data:`repro.core.isa.CARUS_TRACE_DTYPE` scalars) — and every
downstream consumer (kernel builders, engines, timing, energy, benchmarks)
special-cased both.  This module replaces the split with one structured-array
:class:`Program`:

* one entry dtype (:data:`PROG_DTYPE`) that is a field superset of both
  engine trace formats (Caesar uses ``op/dest/src1/src2``; Carus maps
  ``vd/vs1/vs2 -> dest/src1/src2`` and additionally uses
  ``sval1/sval2/imm/mode``);
* loss-free converters to/from the legacy formats (round-trip tested in
  ``tests/test_nmc_ir.py``);
* :meth:`Program.lower` producing exactly the dict-of-arrays the scan-based
  engines consume, keyed by the engine's own field names; and
* :attr:`Program.shape_key` — the ``(engine, sew, n_instr)`` tuple the
  :class:`repro.nmc.pool.TilePool` uses as its jit-cache key.

The IR is deliberately *flat* (a numpy structured array, no objects) so a
batch of T same-shape programs stacks into ``[T, n_instr]`` arrays and runs
under ``jax.vmap`` unchanged.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa
from repro.core.isa import CaesarOp, VOp

ENGINES = ("caesar", "carus")

# Field superset of CAESAR_TRACE_DTYPE and CARUS_TRACE_DTYPE.  For Carus
# entries the register fields map vd->dest, vs1->src1, vs2->src2; for Caesar
# entries sval1/sval2/imm/mode are structurally zero.
PROG_DTYPE = np.dtype(
    [("op", "<i4"), ("dest", "<i4"), ("src1", "<i4"), ("src2", "<i4"),
     ("sval1", "<i4"), ("sval2", "<i4"), ("imm", "<i4"), ("mode", "<i4")])

# Carus register-field names in engine order, paired with the IR names.
_CARUS_FIELD_MAP = (("op", "op"), ("vd", "dest"), ("vs1", "src1"),
                    ("vs2", "src2"), ("sval1", "sval1"), ("sval2", "sval2"),
                    ("imm", "imm"), ("mode", "mode"))
_CAESAR_FIELD_MAP = (("op", "op"), ("dest", "dest"), ("src1", "src1"),
                     ("src2", "src2"))


def caesar_entry(op: CaesarOp, dest: int = 0, src1: int = 0,
                 src2: int = 0) -> np.void:
    """One NM-Caesar bus micro-op as an IR entry."""
    e = np.zeros((), dtype=PROG_DTYPE)
    e["op"], e["dest"], e["src1"], e["src2"] = int(op), dest, src1, src2
    return e


def carus_entry(op: VOp, vd: int = 0, vs1: int = 0, vs2: int = 0,
                sval1: int = 0, sval2: int = 0, imm: int = 0,
                mode: int = isa.MODE_VV) -> np.void:
    """One issued NM-Carus xvnmc instruction as an IR entry."""
    e = np.zeros((), dtype=PROG_DTYPE)
    e["op"] = isa.COMPACT_ID[op]
    e["dest"], e["src1"], e["src2"] = vd, vs1, vs2
    e["sval1"], e["sval2"], e["imm"], e["mode"] = (
        np.int32(sval1), np.int32(sval2), np.int32(imm), mode)
    return e


# Per-engine true-NOP opcodes: bit-exact no-op in the scan engines and zero
# cost in timing/energy — the padding filler of the bucketed scheduler.
NOP_OP_ID = {"caesar": int(CaesarOp.NOP), "carus": isa.COMPACT_ID[VOp.VNOP]}


def nop_entry(engine: str) -> np.void:
    """A padding NOP as an IR entry for the given engine."""
    e = np.zeros((), dtype=PROG_DTYPE)
    e["op"] = NOP_OP_ID[engine]
    return e


def instr_bucket(n_instr: int) -> int:
    """Power-of-two instruction-count bucket rule (DESIGN.md §5): programs
    pad up to the next power of two so heterogeneous kernels share one
    traced computation per ``(engine, sew, bucket)``."""
    n = int(n_instr)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Program:
    """An engine-agnostic NMC program: ``entries`` is a PROG_DTYPE[n] array."""

    engine: str               # "caesar" | "carus"
    sew: int                  # static element width of the whole program
    entries: np.ndarray       # PROG_DTYPE, shape [n_instr]

    def __post_init__(self):
        assert self.engine in ENGINES, self.engine
        assert self.entries.dtype == PROG_DTYPE, self.entries.dtype

    # -- construction --------------------------------------------------------
    @classmethod
    def from_entries(cls, engine: str, sew: int, entries) -> "Program":
        """From a list of PROG_DTYPE scalars (builder / eCPU output).

        Caesar entries are normalized structurally zero in the Carus-only
        fields (``sval1/sval2/imm/mode``): the bus engine never decodes
        them, so junk there would otherwise ride silently through format
        round-trips and defeat the bucket/NOP identities the scheduler
        relies on (and the :mod:`repro.nmc.check` structural pass flags
        it as an error on hand-built programs)."""
        arr = (np.array(entries, dtype=PROG_DTYPE) if len(entries)
               else np.zeros(0, dtype=PROG_DTYPE))
        if engine == "caesar" and len(arr):
            for f in ("sval1", "sval2", "imm", "mode"):
                arr[f] = 0
        return cls(engine, sew, arr)

    @classmethod
    def from_caesar_stream(cls, stream, sew: int = 32) -> "Program":
        """From the legacy list-of-tuples bus-op stream."""
        arr = np.zeros(len(stream), dtype=PROG_DTYPE)
        for i, (op, dest, s1, s2) in enumerate(stream):
            arr[i]["op"], arr[i]["dest"] = int(op), dest
            arr[i]["src1"], arr[i]["src2"] = s1, s2
        return cls("caesar", sew, arr)

    @classmethod
    def from_carus_trace(cls, trace, sew: int) -> "Program":
        """From the legacy list of CARUS_TRACE_DTYPE scalars."""
        arr = np.zeros(len(trace), dtype=PROG_DTYPE)
        for i, e in enumerate(trace):
            for src, dst in _CARUS_FIELD_MAP:
                arr[i][dst] = int(e[src])
        return cls("carus", sew, arr)

    @classmethod
    def from_legacy(cls, stream, sew: int, engine: str | None = None
                    ) -> "Program":
        """Auto-detect the legacy container format (used for EngineBuilds
        constructed by hand, e.g. in tests)."""
        if engine in ENGINES and stream and _dtype_of(stream[0]) == PROG_DTYPE:
            return cls.from_entries(engine, sew, stream)
        if not stream:
            return cls.from_entries(engine or "caesar", sew, [])
        first = stream[0]
        if isinstance(first, (tuple, list)):
            return cls.from_caesar_stream(stream, sew)
        if _dtype_of(first) == isa.CARUS_TRACE_DTYPE:
            return cls.from_carus_trace(stream, sew)
        if _dtype_of(first) == PROG_DTYPE:
            raise TypeError("PROG_DTYPE entries are engine-ambiguous: pass "
                            "engine= (or tag the EngineBuild)")
        raise TypeError(f"cannot infer program format from {type(first)}")

    # -- shape / identity ----------------------------------------------------
    @property
    def n_instr(self) -> int:
        return int(self.entries.shape[0])

    @property
    def shape_key(self) -> tuple:
        """Jit-cache / batching key: programs with equal keys lower to the
        same traced computation (one XLA compile per key)."""
        return (self.engine, self.sew, self.n_instr)

    @property
    def bucket_key(self) -> tuple:
        """Bucketed jit-cache key ``(engine, sew, instr_bucket(n_instr))``:
        programs with equal bucket keys pad (NOP-fill) to one shared traced
        computation — the compile-count unit of the bucketed scheduler."""
        return (self.engine, self.sew, instr_bucket(self.n_instr))

    @property
    def n_nops(self) -> int:
        """Number of padding NOPs in the stream (zero-cost entries)."""
        return int(np.count_nonzero(
            self.entries["op"] == NOP_OP_ID[self.engine]))

    def with_sew(self, sew: int) -> "Program":
        return self if sew == self.sew else dataclasses.replace(self, sew=sew)

    def pad_to(self, n_instr: int) -> "Program":
        """NOP-pad the instruction stream to exactly ``n_instr`` entries.

        Padding appends true NOPs, so the padded program is bit-exact with
        the original (same final state on either engine) and costs the same
        cycles/energy (NOPs are zero-cost in timing.py / energy.py)."""
        pad = n_instr - self.n_instr
        assert pad >= 0, (n_instr, self.n_instr)
        if pad == 0:
            return self
        entries = np.concatenate(
            [self.entries, np.repeat(nop_entry(self.engine)[None], pad)])
        return dataclasses.replace(self, entries=entries)

    # -- lowering ------------------------------------------------------------
    def field_map(self) -> tuple:
        return (_CAESAR_FIELD_MAP if self.engine == "caesar"
                else _CARUS_FIELD_MAP)

    def lower_np(self) -> dict[str, np.ndarray]:
        """Engine-facing dict of int32 numpy arrays (engine field names)."""
        return {eng_name: np.ascontiguousarray(self.entries[ir_name])
                for eng_name, ir_name in self.field_map()}

    def lower(self) -> dict:
        """Engine-facing dict of device arrays, ready for the lax.scan."""
        import jax.numpy as jnp
        return {k: jnp.asarray(v) for k, v in self.lower_np().items()}

    # -- decode back to the legacy formats (round-trip tested) ---------------
    def to_caesar_stream(self) -> list[tuple]:
        assert self.engine == "caesar"
        return [(CaesarOp(int(e["op"])), int(e["dest"]), int(e["src1"]),
                 int(e["src2"])) for e in self.entries]

    def to_carus_trace(self) -> list[np.ndarray]:
        assert self.engine == "carus"
        out = []
        for e in self.entries:
            t = np.zeros((), dtype=isa.CARUS_TRACE_DTYPE)
            for eng_name, ir_name in _CARUS_FIELD_MAP:
                t[eng_name] = e[ir_name]
            out.append(t)
        return out

    def vops(self) -> list[VOp]:
        """Decoded Carus opcodes (compact ids -> VOp)."""
        assert self.engine == "carus"
        return [isa.VOP_COMPACT[int(o)] for o in self.entries["op"]]


def _dtype_of(x) -> np.dtype | None:
    return getattr(x, "dtype", None)


def stack_programs(programs: list[Program]) -> dict[str, np.ndarray]:
    """Stack same-shape programs into [T, n_instr] engine-field arrays."""
    key = programs[0].shape_key
    assert all(p.shape_key == key for p in programs), \
        [p.shape_key for p in programs]
    fields = programs[0].field_map()
    return {eng_name: np.stack([p.entries[ir_name] for p in programs])
            for eng_name, ir_name in fields}
