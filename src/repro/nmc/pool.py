"""Batched multi-tile NMC executor (DESIGN.md §5).

The paper's architectures are *scalable*: an edge node instantiates arrays of
identical NM-Caesar / NM-Carus tiles across its SRAM macros, each running its
own program against its own memory.  :class:`TilePool` models exactly that:
T independent tiles execute T same-shape programs in one ``jax.vmap`` over
the existing ``lax.scan`` engines.

Compilation discipline: programs are grouped by
:attr:`repro.nmc.program.Program.shape_key` ``(engine, sew, n_instr)`` and
each group dispatches through one jit-compiled batched executor — one XLA
compile per program *shape* within a :meth:`TilePool.run` call, not one per
kernel instance.  Re-dispatching a shape later at a *different* tile count
retraces (the batch dimension is part of the traced shapes), which is why
the cache key carries ``n_tiles`` and ``compiles`` counts actual trace-cache
misses: benchmarks/tests can assert the one-compile-per-shape property
exactly where it is claimed — over a single grouped sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nmc.engine import get_engine
from repro.nmc.program import Program, stack_programs


class TilePool:
    """Dispatch batches of same-shape NMC programs over virtual tiles.

    The pool is stateless between dispatches (tiles own no persistent
    memory); callers hand in one initial state per program and get the final
    state back, in input order.  Heterogeneous batches are grouped by shape
    key internally, so a full kernel sweep can be thrown at :meth:`run` in
    one call and same-shape instances (e.g. xor/add/mul/relu at one SEW)
    share a single compile and a single batched device dispatch.
    """

    def __init__(self):
        self._cache: dict[tuple, object] = {}
        self.compiles = 0          # distinct (shape_key, n_tiles) traces
        self.dispatches = 0        # batched device executions
        self.programs_run = 0      # total tile-programs executed

    # -- compile cache -------------------------------------------------------
    def _batched_fn(self, shape_key: tuple, n_tiles: int):
        key = (*shape_key, n_tiles)
        fn = self._cache.get(key)
        if fn is None:
            engine_name, sew, _ = shape_key
            fn = jax.jit(jax.vmap(get_engine(engine_name).scan_fn(sew)))
            self._cache[key] = fn
            self.compiles += 1
        return fn

    @property
    def shape_keys_compiled(self) -> set[tuple]:
        return {k[:3] for k in self._cache}

    # -- execution -----------------------------------------------------------
    def run(self, programs: list[Program], states: list) -> list[np.ndarray]:
        """Run ``programs[i]`` against ``states[i]``; return final states."""
        assert len(programs) == len(states)
        by_key: dict[tuple, list[int]] = {}
        for i, p in enumerate(programs):
            by_key.setdefault(p.shape_key, []).append(i)
        out: list = [None] * len(programs)
        for key, idxs in by_key.items():
            fn = self._batched_fn(key, len(idxs))
            engine = get_engine(key[0])
            batch_state = jnp.stack(
                [engine.init_state(states[i]) for i in idxs])
            batch_arrays = {k: jnp.asarray(v) for k, v in stack_programs(
                [programs[i] for i in idxs]).items()}
            final = np.asarray(fn(batch_state, batch_arrays))
            self.dispatches += 1
            self.programs_run += len(idxs)
            for t, i in enumerate(idxs):
                out[i] = final[t]
        return out

    def run_builds(self, builds: list) -> list[np.ndarray]:
        """Run a list of :class:`repro.core.programs.EngineBuild` instances
        (each tagged with engine/sew by its kernel builder) and return each
        build's output *elements*, with its host-side ``post`` stage applied
        — bit-identical to the single-instance ``run_build`` path."""
        programs = [eb.program for eb in builds]
        finals = self.run(programs, [eb.mem for eb in builds])
        outs = []
        for eb, prog, final in zip(builds, programs, finals):
            elems = get_engine(prog.engine).extract(final, eb.out_slice,
                                                    prog.sew)
            outs.append(eb.post(elems) if eb.post else elems)
        return outs
