"""Batched multi-tile NMC executors (DESIGN.md §5).

The paper's architectures are *scalable*: an edge node instantiates arrays of
identical NM-Caesar / NM-Carus tiles across its SRAM macros, each running its
own program against its own memory.  This module models that at three levels:

* :class:`TilePool` — T independent tiles execute T same-shape programs in
  one batched executor (``jax.vmap`` over the ``lax.scan`` engines, or one
  fused Pallas grid when ``backend="pallas"``), jit-cached per exact
  ``(engine, sew, n_instr, n_tiles, backend)``.
* :class:`BucketedPool` — the shape-bucketed scheduler: instruction streams
  NOP-pad to power-of-two buckets (:func:`repro.nmc.program.instr_bucket`)
  and partial tile batches pad to power-of-two tile counts
  (:func:`tile_bucket`, extra lanes replicated and masked off on readback),
  so a heterogeneous kernel sweep compiles once per **(engine, sew,
  instr-bucket, tile-bucket)** instead of once per exact shape/count pair.
* :class:`ResidentPool` — persistently-resident tile memories: per-tile
  state stays on device across dispatches (the paper's memory-mode /
  compute-mode duality), with explicit load/store accounting so benchmarks
  can assert that steady-state dispatch moves only instruction bytes.

Compilation discipline: ``compiles`` counts actual trace-cache misses, and
``pad_waste`` / ``bytes_moved`` quantify the cost of the bucketing trade —
benchmarks and tests assert on all three exactly where the property is
claimed (one compile per bucket over a grouped sweep).
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.nmc.engine import get_engine, resolve_backend
from repro.nmc.program import (PROG_DTYPE, Program, instr_bucket,
                               stack_programs)

WORD_BYTES = 4


def tile_bucket(n_tiles: int) -> int:
    """Power-of-two tile-count bucket rule: a partial batch pads up to the
    next power of two (replicated lanes, outputs discarded) so it reuses the
    padded-batch trace instead of re-tracing per tile count.  Same rule as
    :func:`repro.nmc.program.instr_bucket`, delegated so the two bucketing
    dimensions can never drift apart."""
    return instr_bucket(n_tiles)


class TilePool:
    """Dispatch batches of same-shape NMC programs over virtual tiles.

    The pool is stateless between dispatches (tiles own no persistent
    memory); callers hand in one initial state per program and get the final
    state back, in input order.  Heterogeneous batches are grouped by shape
    key internally, so a full kernel sweep can be thrown at :meth:`run` in
    one call and same-shape instances (e.g. xor/add/mul/relu at one SEW)
    share a single compile and a single batched device dispatch.

    Grouping/padding policy lives in three overridable hooks
    (:meth:`_group_key`, :meth:`_pad_programs`, :meth:`_pad_tiles`);
    the base class groups by *exact* ``Program.shape_key`` and never pads —
    :class:`BucketedPool` overrides all three.
    """

    def __init__(self, donate: bool = False, backend: str = "scan"):
        self._cache: dict[tuple, object] = {}
        self._donate = donate
        self.backend = resolve_backend(backend)
        self.compiles = 0          # distinct traces (cache misses)
        self.dispatches = 0        # batched device executions
        self.programs_run = 0      # total (real) tile-programs executed

    # -- compile cache -------------------------------------------------------
    def _batched_fn(self, shape_key: tuple, n_tiles: int,
                    backend: str | None = None):
        backend = self.backend if backend is None \
            else resolve_backend(backend)
        key = (*shape_key, n_tiles, backend)
        fn = self._cache.get(key)
        if fn is None:
            engine_name, sew, _ = shape_key
            engine = get_engine(engine_name, backend)
            make = getattr(engine, "batched_fn", None)
            if make is not None:
                # fused-kernel backends build the whole tile batch in one
                # call (Pallas grid) instead of vmapping the scan step
                fn = make(sew, n_tiles, donate=self._donate)
            else:
                fn = jax.jit(jax.vmap(engine.scan_fn(sew)),
                             donate_argnums=(0,) if self._donate else ())
            self._cache[key] = fn
            self.compiles += 1
        return fn

    @property
    def shape_keys_compiled(self) -> set[tuple]:
        return {k[:3] for k in self._cache}

    # -- grouping / padding hooks (overridden by BucketedPool) ---------------
    def _group_key(self, p: Program) -> tuple:
        return p.shape_key

    def _pad_programs(self, programs: list[Program]) -> list[Program]:
        return programs

    def _pad_tiles(self, n_tiles: int) -> int:
        return n_tiles

    def _account(self, programs: list[Program], n_tiles: int,
                 batch_state, final) -> None:
        """Counter hook: called once per batched dispatch with the *real*
        (unreplicated) padded programs and the padded tile count."""

    # -- execution -----------------------------------------------------------
    def run(self, programs: list[Program], states: list) -> list[np.ndarray]:
        """Run ``programs[i]`` against ``states[i]``; return final states."""
        assert len(programs) == len(states)
        by_key: dict[tuple, list[int]] = {}
        for i, p in enumerate(programs):
            by_key.setdefault(self._group_key(p), []).append(i)
        out: list = [None] * len(programs)
        for idxs in by_key.values():
            group = self._pad_programs([programs[i] for i in idxs])
            n_tiles = self._pad_tiles(len(idxs))
            engine = get_engine(group[0].engine)
            fn = self._batched_fn(group[0].shape_key, n_tiles)
            tile_states = [engine.init_state(states[i]) for i in idxs]
            # padding lanes replicate tile 0; their outputs are masked off
            # below (only real lanes are written back, in input order)
            tile_states += [tile_states[0]] * (n_tiles - len(idxs))
            batch_state = jnp.stack(tile_states)
            padded = group + [group[0]] * (n_tiles - len(idxs))
            batch_arrays = {k: jnp.asarray(v)
                            for k, v in stack_programs(padded).items()}
            final = np.asarray(fn(batch_state, batch_arrays))
            self.dispatches += 1
            self.programs_run += len(idxs)
            self._account(group, n_tiles, batch_state, final)
            for t, i in enumerate(idxs):
                out[i] = final[t]
        return out

    def run_builds(self, builds: list) -> list[np.ndarray]:
        """Run a list of :class:`repro.core.programs.EngineBuild` instances
        (each tagged with engine/sew by its kernel builder) and return each
        build's output *elements*, with its host-side ``post`` stage applied
        — bit-identical to the single-instance ``run_build`` path."""
        programs = [eb.program for eb in builds]
        finals = self.run(programs, [eb.mem for eb in builds])
        outs = []
        for eb, prog, final in zip(builds, programs, finals):
            elems = get_engine(prog.engine).extract(final, eb.out_slice,
                                                    prog.sew)
            outs.append(eb.post(elems) if eb.post else elems)
        return outs


class BucketedPool(TilePool):
    """Shape-bucketed :class:`TilePool` (the scheduler of DESIGN.md §5).

    Programs group by :attr:`repro.nmc.program.Program.bucket_key`
    ``(engine, sew, instr_bucket(n_instr))`` and NOP-pad to the bucket;
    partial batches pad to power-of-two tile counts.  A heterogeneous sweep
    therefore compiles at most once per (engine, sew, instr-bucket,
    tile-bucket) — O(#buckets), not O(#distinct shapes x tile counts).

    Extra counters (asserted by benchmarks/tests):

    * ``pad_waste``   — instruction slots spent on padding: NOP tails of
      real programs plus the whole streams of replicated padding lanes.
    * ``bytes_moved`` — host<->device traffic of the stateless dispatch
      path: initial-state upload + instruction-stream upload + final-state
      download (the von-Neumann tax :class:`ResidentPool` removes).
    """

    def __init__(self, donate: bool = False, backend: str = "scan"):
        super().__init__(donate=donate, backend=backend)
        self.pad_waste = 0
        self.useful_instrs = 0
        self.bytes_moved = 0

    def _group_key(self, p: Program) -> tuple:
        return p.bucket_key

    def _pad_programs(self, programs: list[Program]) -> list[Program]:
        from repro.nmc.check import assert_wave
        bucket = instr_bucket(max(p.n_instr for p in programs))
        padded = [p.pad_to(bucket) for p in programs]
        # wave-level floor of the static checking contract (DESIGN.md §11):
        # one shape key across the padded wave, every program submittable
        assert_wave(padded)
        return padded

    def _pad_tiles(self, n_tiles: int) -> int:
        return tile_bucket(n_tiles)

    def _account(self, programs, n_tiles, batch_state, final) -> None:
        bucket = programs[0].n_instr
        real = sum(p.n_instr - p.n_nops for p in programs)
        self.pad_waste += bucket * n_tiles - real
        self.useful_instrs += real
        self.bytes_moved += (n_tiles * bucket * PROG_DTYPE.itemsize
                             + batch_state.size * WORD_BYTES
                             + final.size * WORD_BYTES)


class ResidentPool:
    """Persistently-resident tile array over a :class:`BucketedPool`.

    Models the paper's memory-mode / compute-mode duality: a tile's SRAM
    macro is *loaded* once (memory-mode write), then arbitrarily many
    programs execute against the resident state (compute mode) with only
    instruction streams crossing the host/device boundary, and results are
    *stored* back explicitly (memory-mode read).  Between dispatches the
    per-tile state lives on device; the batched executor donates the stacked
    state buffer (``donate_argnums``) so XLA reuses the tile-memory
    allocation in place.

    Accounting: ``bytes_moved`` counts only explicit host<->device traffic —
    ``load`` (full image), ``dispatch`` (instruction bytes), ``store``
    (result words) — so benchmarks can assert that steady-state dispatch
    cost is O(program), not O(tile memory).
    """

    def __init__(self, pool: BucketedPool | None = None,
                 backend: str = "scan"):
        self.pool = pool if pool is not None \
            else BucketedPool(donate=True, backend=backend)
        self._engine: dict[object, str] = {}   # tile id -> engine name
        self._state: dict[object, object] = {}  # tile id -> resident state
        self._ids = itertools.count()
        self.loads = 0
        self.stores = 0
        self.dispatches = 0
        self.dispatch_calls = 0      # dispatch() invocations (launch waves)
        self.programs_run = 0
        self.bytes_moved = 0
        self.patches = 0             # partial memory-mode writes (word spans)
        self.patch_bytes = 0         # bytes moved by patches (also counted
                                     # in bytes_moved)

    @property
    def compiles(self) -> int:
        return self.pool.compiles

    @property
    def tiles(self) -> list:
        return list(self._state)

    def state(self, tile) -> jax.Array:
        """The tile's resident device buffer (memory-mode view)."""
        return self._state[tile]

    # -- memory mode ---------------------------------------------------------
    def load(self, tile, engine: str, image) -> None:
        """Memory-mode write: host image -> resident tile memory."""
        self.install(tile, engine, get_engine(engine).init_state(image))

    def install(self, tile, engine: str, state) -> None:
        """Adopt an already-staged device buffer as the tile's resident
        state (the buffer swap of the double-buffered dispatch runtime:
        :mod:`repro.nmc.runtime` stages images asynchronously and installs
        them at launch time).  Accounted exactly like ``load``."""
        self._engine[tile] = engine
        self._state[tile] = state
        self.loads += 1
        self.bytes_moved += int(state.size) * WORD_BYTES

    def patch(self, tile, updates: list[tuple[int, np.ndarray]]) -> None:
        """Memory-mode *partial* write: apply ``(word_start, words)`` spans
        onto the tile's resident state without re-uploading the full image.

        This is the steady-state serving path (DESIGN.md §12): weights stay
        resident across calls, only the per-call activation words cross the
        bus.  Accounted under dedicated counters (``patches`` /
        ``patch_bytes``, also rolled into ``bytes_moved``) and *not* under
        ``loads`` — so residency proofs can assert weights DMA'd onto the
        tile once while activations streamed per call."""
        assert tile in self._state, \
            f"patch of tile {tile!r} with no resident state — load first"
        state = self._state[tile]
        flat = state.reshape(-1)
        nw = 0
        for lo, words in updates:
            w = jnp.asarray(np.asarray(words, np.int32).reshape(-1))
            assert int(lo) >= 0 and int(lo) + w.size <= flat.size, \
                (tile, lo, int(w.size), int(flat.size))
            flat = flat.at[int(lo):int(lo) + w.size].set(w)
            nw += int(w.size)
        self._state[tile] = flat.reshape(state.shape)
        self.patches += 1
        self.patch_bytes += nw * WORD_BYTES
        self.bytes_moved += nw * WORD_BYTES

    def store(self, tile, out_slice: tuple[int, int], sew: int) -> np.ndarray:
        """Memory-mode read: resident output words -> host elements."""
        engine = get_engine(self._engine[tile])
        elems = engine.extract(self._state[tile], out_slice, sew)
        self.stores += 1
        # word-granular accounting: ``out_slice`` is (word_start, n_words),
        # and the 32-bit system bus moves whole words — a sub-word element
        # tail at SEW 8/16 (common for gathered shard outputs) still costs
        # its full last word.  Locked by tests/test_runtime.py.
        self.bytes_moved += int(out_slice[1]) * WORD_BYTES
        return elems

    # -- compute mode --------------------------------------------------------
    def dispatch(self, assignments: list[tuple],
                 backend: str | None = None) -> None:
        """Execute ``(tile, program)`` pairs against the resident states.

        Grouped by bucket key and batched through the shared jit cache like
        :class:`BucketedPool`; final states replace the resident buffers
        without ever leaving the device.  Only the instruction streams are
        uploaded (counted in ``bytes_moved``).  ``backend`` overrides the
        wrapped pool's default executor ("scan"/"pallas") for this wave.

        One dispatch is one parallel step across the tile array, so a tile
        may appear at most once per call — chained programs on one tile are
        sequential ``dispatch`` calls (each sees the previous final state).
        A mixed-engine wave (DESIGN.md §14) rides one call: its Caesar and
        Carus shards fall into separate bucket-key groups below (the
        bucket key carries the engine), each batched on its own
        interpreter, but they remain one parallel step — ``dispatch_calls``
        counts the steps, ``dispatches`` the per-group executions."""
        self.dispatch_calls += 1
        seen = set()
        by_key: dict[tuple, list[tuple]] = {}
        for tile, prog in assignments:
            assert tile not in seen, \
                f"tile {tile!r} assigned twice in one dispatch — chain " \
                f"programs via sequential dispatch() calls"
            seen.add(tile)
            assert self._engine[tile] == prog.engine, \
                (tile, self._engine[tile], prog.engine)
            by_key.setdefault(prog.bucket_key, []).append((tile, prog))
        from repro.nmc.check import assert_wave
        for key, group in by_key.items():
            tiles = [t for t, _ in group]
            bucket = key[2]
            progs = [p.pad_to(bucket) for _, p in group]
            # wave-level floor of the static checking contract (§11)
            assert_wave(progs)
            tb = tile_bucket(len(tiles))
            states = [self._state[t] for t in tiles]
            states += [states[0]] * (tb - len(tiles))
            progs += [progs[0]] * (tb - len(tiles))
            batch_state = jnp.stack(states)
            batch_arrays = {k: jnp.asarray(v)
                            for k, v in stack_programs(progs).items()}
            fn = self.pool._batched_fn(progs[0].shape_key, tb,
                                       backend=backend)
            final = fn(batch_state, batch_arrays)    # stays on device
            for t, tile in enumerate(tiles):
                self._state[tile] = final[t]
            self.dispatches += 1
            self.programs_run += len(tiles)
            self.bytes_moved += tb * bucket * PROG_DTYPE.itemsize
            # ragged-tail visibility: resident waves report padding waste
            # into the wrapped pool's counters exactly like stateless runs
            # (NOP tails of real programs + whole replicated padding lanes)
            real = sum(p.n_instr - p.n_nops for _, p in group)
            self.pool.pad_waste += bucket * tb - real
            self.pool.useful_instrs += real

    # -- convenience ---------------------------------------------------------
    def run_builds(self, builds: list, queue=None) -> list[np.ndarray]:
        """EngineBuild list -> output elements via load/dispatch/store —
        bit-identical to ``TilePool.run_builds`` (and the single-program
        path), but leaving every tile memory resident afterwards.

        With ``queue`` (a :class:`repro.nmc.runtime.DispatchQueue` wrapping
        *this* pool) the builds go through the async double-buffered path
        instead: all images stage up front, waves launch batched, and
        results materialize at future resolution — bit-exact either way."""
        if queue is not None:
            assert queue.pool is self, "queue must wrap this ResidentPool"
            return queue.run_builds(builds)
        tiles = []
        for eb in builds:
            tile = ("build", next(self._ids))
            self.load(tile, eb.program.engine, eb.mem)
            tiles.append(tile)
        self.dispatch([(t, eb.program) for t, eb in zip(tiles, builds)])
        outs = []
        for t, eb in zip(tiles, builds):
            elems = self.store(t, eb.out_slice, eb.program.sew)
            outs.append(eb.post(elems) if eb.post else elems)
        return outs
