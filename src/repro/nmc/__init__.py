"""Unified NMC program IR + batched multi-tile execution (DESIGN.md §5).

* :mod:`repro.nmc.program` — the engine-agnostic structured-array Program IR
  covering NM-Caesar bus-op streams and NM-Carus xvnmc issue traces, plus
  the padding NOP and the power-of-two instruction-bucket rule.
* :mod:`repro.nmc.engine` — the Engine protocol (lower / run / extract /
  cost) and the two tile adapters over the functional simulators.
* :mod:`repro.nmc.pool` — the vmapped executors: exact-shape :class:`TilePool`,
  the shape-bucketed :class:`BucketedPool` (one jit compile per
  ``(engine, sew, instr-bucket, tile-bucket)``) and the persistently-resident
  :class:`ResidentPool` (tile memories stay on device across dispatches).
* :mod:`repro.nmc.runtime` — the async double-buffered
  :class:`DispatchQueue`: futures over queued (tile, program, image,
  out_slice) work items, shadow-buffer staging while the previous program
  runs, and pluggable in-order/overlapped scheduling (DESIGN.md §5.2).
"""

from repro.nmc.program import (PROG_DTYPE, Program, caesar_entry, carus_entry,
                               instr_bucket, nop_entry, stack_programs)
from repro.nmc.engine import CaesarTile, CarusTile, Engine, get_engine
from repro.nmc.pool import BucketedPool, ResidentPool, TilePool, tile_bucket
from repro.nmc.runtime import DeviceFuture, DispatchQueue, NMCFuture

__all__ = [
    "PROG_DTYPE", "Program", "caesar_entry", "carus_entry", "nop_entry",
    "instr_bucket", "stack_programs",
    "CaesarTile", "CarusTile", "Engine", "get_engine",
    "TilePool", "BucketedPool", "ResidentPool", "tile_bucket",
    "DispatchQueue", "NMCFuture", "DeviceFuture",
]
