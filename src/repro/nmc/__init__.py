"""The NMC stack behind one import: ``from repro import nmc`` (DESIGN.md §5/§7).

Authoring — write numpy-style Python, get the whole stack::

    from repro import nmc

    @nmc.kernel                       # trace + engine auto-selection, SEW 8
    def fused(t, x, y):
        t.store((t.load(x) * 3 + t.load(y)).max(0))

    out = fused(xs, ys)               # sync: lower, schedule, run, extract
    fut = fused.call_async(xs, ys)    # async future — bit-exact vs sync

    wide = nmc.jit(fused.fn, tiles=4) # shard one kernel across 4 tiles
    assert (wide(xs, ys) == out).all()  # bit-exact vs single-tile

Layers (each usable directly for expert control):

* :mod:`repro.nmc.frontend` — the traced frontend: :func:`kernel` /
  :func:`jit` compile a Python function over :class:`NmcValue` tracers
  into a :class:`CompiledKernel`; engine auto-selection with
  :class:`UnsupportedOnEngine` diagnostics.
* :mod:`repro.nmc.registry` — the op registry and the shared
  :class:`NmcRuntime` (one bucketed jit cache for sync + async dispatch).
* :mod:`repro.nmc.program` — the engine-agnostic structured-array
  :class:`Program` IR covering NM-Caesar bus-op streams and NM-Carus
  xvnmc issue traces, plus the padding NOP and bucket rules.
* :mod:`repro.nmc.engine` — the :class:`Engine` protocol (lower / run /
  extract / cost), the two scan-backend tile adapters over the functional
  simulators, and the backend registry (``get_engine(name, backend)``).
* :mod:`repro.nmc.pallas_engine` — the fused-kernel fast path
  (DESIGN.md §10): ``backend="pallas"`` lowers whole bucketed waves to one
  ``pl.pallas_call`` (interpret-mode on CPU), bit-exact vs scan.
* :mod:`repro.nmc.pool` — the vmapped executors: exact-shape
  :class:`TilePool`, shape-bucketed :class:`BucketedPool` (one XLA
  compile per ``(engine, sew, instr-bucket, tile-bucket)``) and the
  persistently-resident :class:`ResidentPool`.
* :mod:`repro.nmc.runtime` — the async double-buffered
  :class:`DispatchQueue`: futures, shadow-buffer staging, batched launch
  waves (DESIGN.md §5.2).
* :mod:`repro.nmc.partition` — the tile-parallel partitioning planner
  (DESIGN.md §9): shards one traced kernel across the tile array
  (``nmc.jit(fn, tiles=N)``), reassembled by :class:`GatherFuture` —
  bit-exact vs the single-tile path by construction.
* :mod:`repro.nmc.schedule` — the cost-model-driven wave scheduler and
  plan autotuner (DESIGN.md §14): searches partition strategy × chunk
  skew × per-shard engine assignment × dispatch order against
  :func:`repro.core.timing.wave_cycles`
  (``nmc.jit(fn, tiles=N, schedule="auto")``), caching winning
  :class:`SchedulePlan` objects in a content-keyed blake2b-LRU registry.
"""

from repro.nmc.program import (PROG_DTYPE, Program, caesar_entry, carus_entry,
                               instr_bucket, nop_entry, stack_programs)
from repro.nmc.engine import (BACKENDS, CaesarTile, CarusTile, Engine,
                              get_engine, implementations, resolve_backend)
from repro.nmc.pool import BucketedPool, ResidentPool, TilePool, tile_bucket
from repro.nmc.runtime import (DeviceFuture, DispatchQueue, GatherFuture,
                               NMCFuture)
from repro.nmc.registry import (NmcRuntime, default_runtime,
                                set_default_runtime)
from repro.nmc.frontend import (CompiledKernel, LoweredKernel, LoweringError,
                                NmcValue, ProgramBuilder, TileContext,
                                UnsupportedOnEngine, jit, kernel, mac,
                                select_engine)
from repro.nmc.partition import (PartitionError, PartitionPlan, slide_halo,
                                 plan as plan_partition)
from repro.nmc.schedule import (SCHEDULE_MODES, SchedulePlan, autotune,
                                clear_plan_cache, plan_wave, uniform_plan)
from repro.nmc.check import (CHECK_MODES, CheckReport, Diagnostic,
                             VerificationError, assert_submittable,
                             assert_wave, verify_chained_waves,
                             verify_lowered, verify_plan, verify_program,
                             verify_resident, verify_wave)
from repro.nmc.opt import (OPT_LEVELS, OptError, OptReport, RewriteRecord,
                           optimize)

__all__ = [
    # the one-call frontend (DESIGN.md §7)
    "jit", "kernel", "mac", "CompiledKernel", "LoweredKernel", "NmcValue",
    "ProgramBuilder", "TileContext", "UnsupportedOnEngine", "LoweringError",
    "select_engine",
    # static verification (DESIGN.md §11)
    "CHECK_MODES", "CheckReport", "Diagnostic", "VerificationError",
    "verify_program", "verify_lowered", "verify_plan", "verify_wave",
    "verify_resident", "verify_chained_waves",
    "assert_wave", "assert_submittable", "slide_halo",
    # analysis-driven IR optimizer (DESIGN.md §13)
    "OPT_LEVELS", "OptError", "OptReport", "RewriteRecord", "optimize",
    # tile-parallel partitioning planner (DESIGN.md §9)
    "plan_partition", "PartitionPlan", "PartitionError",
    # wave scheduler + plan autotuner (DESIGN.md §14)
    "SCHEDULE_MODES", "SchedulePlan", "autotune", "uniform_plan",
    "plan_wave", "clear_plan_cache",
    # shared execution runtime
    "NmcRuntime", "default_runtime", "set_default_runtime",
    # unified program IR
    "PROG_DTYPE", "Program", "caesar_entry", "carus_entry", "nop_entry",
    "instr_bucket", "stack_programs",
    # engines / backends
    "CaesarTile", "CarusTile", "Engine", "get_engine", "BACKENDS",
    "implementations", "resolve_backend",
    # pools / scheduler
    "TilePool", "BucketedPool", "ResidentPool", "tile_bucket",
    # async dispatch runtime
    "DispatchQueue", "NMCFuture", "DeviceFuture", "GatherFuture",
]
