"""Unified NMC program IR + batched multi-tile execution (DESIGN.md §5).

* :mod:`repro.nmc.program` — the engine-agnostic structured-array Program IR
  covering NM-Caesar bus-op streams and NM-Carus xvnmc issue traces.
* :mod:`repro.nmc.engine` — the Engine protocol (lower / run / extract /
  cost) and the two tile adapters over the functional simulators.
* :mod:`repro.nmc.pool` — the vmapped TilePool executor with one jit compile
  per ``(engine, sew, n_instr)`` program shape.
"""

from repro.nmc.program import (PROG_DTYPE, Program, caesar_entry, carus_entry,
                               stack_programs)
from repro.nmc.engine import CaesarTile, CarusTile, Engine, get_engine
from repro.nmc.pool import TilePool

__all__ = [
    "PROG_DTYPE", "Program", "caesar_entry", "carus_entry", "stack_programs",
    "CaesarTile", "CarusTile", "Engine", "get_engine",
    "TilePool",
]
