"""Op registry + shared execution runtime for the traced NMC frontend.

Two registries back :mod:`repro.nmc.frontend` (DESIGN.md §7):

* **Op registry** — one :class:`OpSpec` per tracer-level operation, naming
  the pure-numpy lane semantics (``repro.core.alu.lane_binop_np``), the
  NM-Caesar bus micro-op and the NM-Carus ``xvnmc`` funct6 it lowers to.
  ``caesar_op is None`` marks an op that is *not bus-expressible*
  (e.g. unsigned min/max): the frontend's engine auto-selection consults
  exactly this table, and an explicit ``engine="caesar"`` request raises
  :class:`repro.nmc.frontend.UnsupportedOnEngine` naming the op.
* **Runtime registry** — the process-wide :class:`NmcRuntime` every
  :class:`repro.nmc.frontend.CompiledKernel` dispatches through by default:
  one shared :class:`repro.nmc.pool.BucketedPool` jit cache (one XLA
  compile per ``(engine, sew, instr-bucket, tile-bucket)``) under a
  :class:`repro.nmc.pool.ResidentPool` and its
  :class:`repro.nmc.runtime.DispatchQueue`.  Every kernel call — sync or
  async — submits to the queue on the shared ``jit_tile`` (a synchronous
  call simply resolves its future immediately), so both call styles share
  one code path, one jit cache, and are bit-exact equal by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.isa import CaesarOp, VOp


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One tracer-level elementwise op and its per-engine lowering."""

    name: str                          # alu lane-op name (= tracer op name)
    caesar_op: Optional[CaesarOp]      # bus micro-op; None = not expressible
    carus_vop: Optional[VOp]           # xvnmc funct6
    carus_imm: bool = False            # int scalar lowers to MODE_VI (imm5)

    @property
    def on_caesar(self) -> bool:
        return self.caesar_op is not None


#: Elementwise binary ops the tracer records (vector-vector or
#: vector-scalar).  ``mac`` / ``slide_down`` are structural ops handled by
#: the lowerings directly (accumulator chains / data movement, not lane
#: arithmetic), so they live outside this table.
BINOPS: dict[str, OpSpec] = {s.name: s for s in (
    OpSpec("add", CaesarOp.ADD, VOp.VADD),
    OpSpec("sub", CaesarOp.SUB, VOp.VSUB),
    OpSpec("mul", CaesarOp.MUL, VOp.VMUL),
    OpSpec("and", CaesarOp.AND, VOp.VAND),
    OpSpec("or", CaesarOp.OR, VOp.VOR),
    OpSpec("xor", CaesarOp.XOR, VOp.VXOR),
    OpSpec("min", CaesarOp.MIN, VOp.VMIN),
    OpSpec("max", CaesarOp.MAX, VOp.VMAX),
    # unsigned compares exist only in the xvnmc vector ISA (Table III);
    # NM-Caesar's bus ALU has signed MIN/MAX only (Section III-A2)
    OpSpec("minu", None, VOp.VMINU),
    OpSpec("maxu", None, VOp.VMAXU),
    OpSpec("sll", CaesarOp.SLL, VOp.VSLL, carus_imm=True),
    OpSpec("srl", CaesarOp.SLR, VOp.VSRL, carus_imm=True),
    OpSpec("sra", CaesarOp.SRA, VOp.VSRA, carus_imm=True),
)}


def engine_op_ids(engine: str) -> frozenset:
    """Every opcode id valid in ``engine``'s instruction stream — the
    single source of truth the static verifier (:mod:`repro.nmc.check`)
    and the dispatch-time asserts validate the ``op`` field against."""
    if engine == "caesar":
        return frozenset(int(o) for o in CaesarOp)
    if engine == "carus":
        from repro.core.isa import VOP_COMPACT
        return frozenset(range(len(VOP_COMPACT)))
    raise ValueError(f"unknown engine {engine!r}")


class NmcRuntime:
    """Shared execution stack for compiled kernels (DESIGN.md §7).

    Holds the three scheduler layers as one object so every kernel compiled
    by :func:`repro.nmc.frontend.jit` reuses one jit cache:

    * ``bucketed`` — the shape-bucketed compile cache (donated state),
    * ``resident`` — the device-resident tile array under the queue,
    * ``queue``    — the double-buffered dispatch queue all kernel calls
      submit to (sync calls resolve their future immediately; async ones
      return it) — bit-exact either way (tests/test_frontend.py).

    Kernel calls dispatch on the runtime's tile set (:meth:`jit_tiles`):
    unpartitioned calls on the head tile, partitioned waves (``tiles=N``)
    one shard per tile.
    """

    def __init__(self, mode: str = "overlapped", backend: str = "auto"):
        from repro.nmc.engine import resolve_backend
        from repro.nmc.pool import BucketedPool, ResidentPool
        from repro.nmc.runtime import DispatchQueue

        self.backend = resolve_backend(backend)
        self.bucketed = BucketedPool(donate=True, backend=self.backend)
        self.resident = ResidentPool(pool=self.bucketed)
        self.queue = DispatchQueue(pool=self.resident, mode=mode)

    @classmethod
    def for_queue(cls, queue) -> "NmcRuntime":
        """Wrap an existing :class:`repro.nmc.runtime.DispatchQueue` (and
        the pools under it) as a runtime, so compiled kernels can join a
        caller-owned dispatch discipline instead of the process default —
        e.g. a :class:`repro.serve.engine.ServeEngine` given a private
        queue routes its tile-array projections through the same queue it
        uses for prefill/decode work."""
        rt = cls.__new__(cls)
        rt.bucketed = queue.pool.pool
        rt.resident = queue.pool
        rt.queue = queue
        rt.backend = rt.bucketed.backend
        return rt

    def jit_tiles(self, n: int) -> tuple:
        """The runtime's shared tile *set*: partitioned kernel waves
        dispatch shard ``k`` on tile ``("jit", k)``.  A fixed, reused id
        space keeps the resident device state bounded (one buffer per
        array position, re-installed per call) instead of leaking a tile
        memory per kernel invocation; per-tile FIFO order makes
        arbitrarily many in-flight futures safe — each captures its own
        wave's final state."""
        return tuple(("jit", k) for k in range(int(n)))

    #: The head of the tile set: where unpartitioned (``tiles=1``) kernel
    #: calls dispatch.
    @property
    def jit_tile(self):
        return self.jit_tiles(1)[0]


_DEFAULT: Optional[NmcRuntime] = None


def default_runtime() -> NmcRuntime:
    """The process-wide runtime ``CompiledKernel`` dispatches through."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = NmcRuntime()
    return _DEFAULT


def set_default_runtime(rt: Optional[NmcRuntime]) -> Optional[NmcRuntime]:
    """Swap the process-wide runtime (``None`` resets to a fresh one on
    next use); returns the previous runtime so callers can restore it."""
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, rt
    return old
