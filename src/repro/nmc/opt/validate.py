"""Per-rewrite translation validation (DESIGN.md §13).

Every applied rewrite must survive two independent gates before the
optimized program replaces the original:

1. **re-verification** — the full static pass pipeline
   (:func:`repro.nmc.check.verify_program`) over the rewritten program
   with its updated metadata must report zero errors, and
2. **oracle differential** — the numpy reference interpreters
   (:mod:`repro.nmc.opt.interp`) must produce bit-identical output-window
   words for the rewritten program as for the original.

A failure raises :class:`OptError` naming the rule — an optimizer bug
fails loudly at lowering time; it can never silently miscompile.
"""

from __future__ import annotations

import numpy as np

from repro.nmc.program import Program

from repro.nmc.opt import interp
from repro.nmc.opt.rules import Work


class OptError(Exception):
    """A rewrite failed translation validation (optimizer bug)."""


def reference_output(engine: str, image: np.ndarray, entries: np.ndarray,
                     sew: int, out_slice) -> np.ndarray:
    lo, nw = int(out_slice[0]), int(out_slice[1])
    return interp.run(engine, image, entries, sew)[lo:lo + nw]


def validate(w: Work, ref_out: np.ndarray, kernel: str, rule: str) -> None:
    """Gate one applied rewrite; raises :class:`OptError` on any failure."""
    from repro.nmc import check
    prog = Program.from_entries(w.engine, w.sew, w.entries)
    rep = check.verify_program(
        prog, kernel=f"{kernel}+{rule}", out_slice=tuple(w.out_slice),
        init_spans=tuple(w.init_spans), used_words=w.used_words,
        prov=None if w.prov is None else list(w.prov))
    if rep.errors:
        raise OptError(
            f"rule '{rule}' broke static verification of {kernel}:\n"
            + rep.render())
    got = reference_output(w.engine, w.mem, w.entries, w.sew, w.out_slice)
    if not np.array_equal(got, ref_out):
        bad = int(np.count_nonzero(got != ref_out))
        raise OptError(
            f"rule '{rule}' miscompiled {kernel}: {bad}/{len(ref_out)} "
            f"output words differ from the pre-rewrite oracle")
