"""Independent numpy interpreters — the optimizer's differential oracle.

These reimplement the engine semantics (``repro.core.caesar`` /
``repro.core.carus``) directly over numpy words, sharing only the lane
arithmetic in :mod:`repro.core.alu`.  They are deliberately *not* the JAX
scan engines: the translation-validation gate (:mod:`repro.nmc.opt.
validate`) compares a rewritten program against the pre-rewrite program
under this third implementation, so an optimizer bug and an engine bug
cannot mask each other.

Both entry points take the flat int32 image and the PROG_DTYPE entries
and return the final flat image; observable output is the ``out_slice``
window of that image (EMVX scan-outputs never leave the trace — the
frontend embeds tap values at lowering time — and the MAC/DOT
accumulators are not architecturally visible after the stream ends).
"""

from __future__ import annotations

import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.caesar import _BINOP_OF
from repro.core.isa import CaesarOp, VOp

_CAESAR_BINOP = {int(op): name for op, name in _BINOP_OF.items()}
_K = isa.COMPACT_ID
_K_ARITH = {_K[v]: name for v, name in isa.ARITH_OPS.items()}


def run_caesar(mem_words: np.ndarray, entries: np.ndarray,
               sew: int) -> np.ndarray:
    """Execute a caesar stream over a flat word image; returns the final
    image (mirrors ``CaesarEngine.run_stream`` row by row)."""
    mem = np.array(mem_words, np.int32).reshape(-1).copy()
    mac = np.zeros(1, np.int32)
    dot = 0
    nop, csrw = int(CaesarOp.NOP), int(CaesarOp.CSRW)
    for r in entries:
        op = int(r["op"])
        if op == nop or op == csrw:
            continue
        a = mem[int(r["src1"])][None]
        b = mem[int(r["src2"])][None]
        name = _CAESAR_BINOP.get(op)
        if name is not None:
            mem[int(r["dest"])] = alu.word_binop_np(name, a, b, sew)[0]
        elif op == int(CaesarOp.MAC_INIT):
            mac = alu.word_macc_np(np.zeros(1, np.int32), a, b, sew)
        elif op == int(CaesarOp.MAC):
            mac = alu.word_macc_np(mac, a, b, sew)
        elif op == int(CaesarOp.MAC_STORE):
            mac = alu.word_macc_np(mac, a, b, sew)
            mem[int(r["dest"])] = mac[0]
        elif op == int(CaesarOp.DOT_INIT):
            dot = alu.word_dot_np(0, a, b, sew)
        elif op == int(CaesarOp.DOT):
            dot = alu.word_dot_np(dot, a, b, sew)
        elif op == int(CaesarOp.DOT_STORE):
            dot = alu.word_dot_np(dot, a, b, sew)
            mem[int(r["dest"])] = dot
        else:
            raise ValueError(f"caesar oracle: unknown opcode {op}")
    return mem


def run_carus(vrf_words: np.ndarray, entries: np.ndarray,
              sew: int) -> np.ndarray:
    """Execute a carus trace over a flat VRF image; returns the final
    image (mirrors ``CarusVPU.run_trace``: indirect operand resolution,
    VL-masked tail-undisturbed writeback, dynamic VL)."""
    n_regs, rw = C.CARUS_N_VREGS, C.CARUS_REG_WORDS
    L = 32 // sew
    n_elems = rw * L
    vlmax = n_elems
    vrf = np.array(vrf_words, np.int32).reshape(n_regs, rw).copy()
    vl = vlmax
    elem_ids = np.arange(n_elems)

    def elems(reg):
        return alu.unpack_lanes_np(vrf[reg], sew).reshape(-1)

    for r in entries:
        op = int(r["op"])
        if op == _K[VOp.VNOP]:
            continue
        mode = int(r["mode"])
        sval1, sval2 = int(r["sval1"]), int(r["sval2"])
        if op == _K[VOp.VSETVL]:
            vl = min(sval1, vlmax)
            continue
        if op == _K[VOp.EMVX]:
            continue            # scan-output only: VRF and VL untouched
        indirect = mode & isa.MODE_INDIRECT
        opmode = mode & 0x3
        vd = ((sval2 >> 16) & 0xFF if indirect else int(r["dest"])) % n_regs
        vs2 = ((sval2 >> 8) & 0xFF if indirect else int(r["src2"])) % n_regs
        vs1 = ((sval2 & 0xFF) if indirect else int(r["src1"])) % n_regs
        dst_e = elems(vd)
        s2_e = elems(vs2)
        scalar_b = int(r["imm"]) if opmode == isa.MODE_VI else sval1
        s1_e = elems(vs1) if opmode == isa.MODE_VV \
            else np.full(n_elems, scalar_b, np.int64)
        wb_vl = vl
        name = _K_ARITH.get(op)
        if name is not None:
            r_e = alu.lane_binop_np(name, s2_e, s1_e, sew)
        elif op == _K[VOp.VMACC]:
            r_e = dst_e + s2_e * s1_e
        elif op == _K[VOp.VMV]:
            r_e = s1_e
        elif op in (_K[VOp.VSLIDEUP], _K[VOp.VSLIDEDOWN]):
            slide1 = mode & isa.MODE_SLIDE1
            off = 1 if slide1 else scalar_b
            if op == _K[VOp.VSLIDEUP]:
                idx = elem_ids - off
                r_e = np.where(idx >= 0,
                               s2_e[np.clip(idx, 0, n_elems - 1)], dst_e)
                if slide1:
                    r_e = np.where(elem_ids == 0, sval1, r_e)
            else:
                idx = elem_ids + off
                r_e = np.where(idx < vl,
                               s2_e[np.clip(idx, 0, n_elems - 1)], 0)
                if slide1:
                    r_e = np.where(elem_ids == vl - 1, sval1, r_e)
        elif op == _K[VOp.EMVV]:
            r_e = np.where(elem_ids == sval2 % n_elems, sval1, dst_e)
            wb_vl = n_elems     # element write: full-length writeback
        else:
            raise ValueError(f"carus oracle: unknown opcode {op}")
        sel = np.where(elem_ids < wb_vl, r_e, dst_e)
        vrf[vd] = alu.pack_lanes_np(sel.reshape(rw, L), sew)
    return vrf.reshape(-1)


def run(engine: str, image: np.ndarray, entries: np.ndarray,
        sew: int) -> np.ndarray:
    return (run_caesar if engine == "caesar" else run_carus)(
        image, entries, sew)
