"""Analysis-driven IR optimizer with per-rewrite translation validation
(DESIGN.md §13).

The check package (:mod:`repro.nmc.check`) computes exact dataflow facts
about every lowered program — dead writes, def/use event streams,
accumulator chains, bank-conflict recounts.  This package turns those
same analyses into rewrites over the unified IR:

* **dead-write elimination + store-cone trimming** — stores (and whole
  MAC/DOT accumulation cones) that no later instruction or output word
  observes are removed, to fixpoint;
* **NOP/padding compaction + stream canonicalization** — neutral NOPs
  and redundant VSETVLs are stripped, so kernels drop into smaller
  instruction buckets (fewer scan steps, fewer XLA compile shapes);
* **bank-conflict-aware placement (Caesar)** — read-only image spans
  migrate across the bank boundary when that reduces same-bank operand
  fetches (each costs +1 cycle on the single-port banks);
* **copy propagation / register coalescing (Carus)** — VMV block copies
  of image-defined registers are deleted by loading the image directly
  at the destination registers.

Every applied rewrite is **translation-validated**
(:mod:`repro.nmc.opt.validate`): the full static pass pipeline re-runs
over the rewritten program and a numpy oracle differential must
reproduce the output window bit-exactly — :class:`OptError` otherwise.
The structured :class:`OptReport` (rule, instructions removed/moved,
modeled cycles before/after) is attached to the lowering as
``lk.opt_report``.

Wired end to end as ``nmc.jit(fn, opt="O1" | "off")`` (default ``O1``)
with per-call override on ``lower`` / ``lower_wave`` — partitioned
shards optimize *before* the common-bucket agreement, so a compacted
wave lands in a smaller bucket as a unit.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

from repro.core import timing
from repro.nmc.program import PROG_DTYPE, Program

from repro.nmc.opt import interp, rules
from repro.nmc.opt.rules import Work
from repro.nmc.opt.validate import OptError, reference_output, validate

#: Optimization levels accepted by ``nmc.jit(fn, opt=...)``.
OPT_LEVELS = ("O1", "off")

__all__ = ["OPT_LEVELS", "OptError", "OptReport", "RewriteRecord",
           "optimize", "clear_memo", "interp", "rules"]


@dataclasses.dataclass(frozen=True)
class RewriteRecord:
    """One applied, translation-validated rewrite."""

    rule: str
    removed: int                    # instructions deleted
    moved: int                      # operand references relocated
    n_before: int                   # instruction count entering the rule
    n_after: int
    cycles_before: float            # modeled engine cycles entering
    cycles_after: float


@dataclasses.dataclass(frozen=True)
class OptReport:
    """Structured result of one :func:`optimize` run."""

    kernel: str
    engine: str
    sew: int
    level: str
    rewrites: Tuple[RewriteRecord, ...]
    n_instr_before: int
    n_instr_after: int
    cycles_before: float
    cycles_after: float
    validated: int                  # translation-validation gates passed

    @property
    def removed(self) -> int:
        return sum(r.removed for r in self.rewrites)

    @property
    def moved(self) -> int:
        return sum(r.moved for r in self.rewrites)

    def render(self) -> str:
        head = (f"{self.kernel} [{self.engine}/sew{self.sew}] {self.level}: "
                f"{self.n_instr_before} -> {self.n_instr_after} instrs, "
                f"{self.cycles_before:.0f} -> {self.cycles_after:.0f} "
                f"cycles ({self.validated} rewrites validated)")
        lines = [head] + [
            f"  {r.rule}: -{r.removed} instrs, {r.moved} refs moved, "
            f"{r.cycles_before:.0f} -> {r.cycles_after:.0f} cycles"
            for r in self.rewrites]
        return "\n".join(lines)


def _check_level(level: str) -> str:
    if level not in OPT_LEVELS:
        raise ValueError(f"unknown opt level {level!r}: expected one of "
                         f"{OPT_LEVELS}")
    return level


# optimize() is a pure function of (entries, image, lowering metadata) —
# all rules are value-independent, and the validation gate is as well
# deterministic — so repeated lowerings of the same kernel reuse the
# optimized artifact from a content-keyed LRU (same discipline as the
# verify_lowered memo).
_MEMO_CAP = 64
_opt_memo: "OrderedDict[bytes, tuple]" = OrderedDict()


def clear_memo() -> None:
    """Drop the optimization memo (benchmarks, tests)."""
    _opt_memo.clear()


def _memo_key(lk, entries: np.ndarray, level: str) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(entries))
    h.update(np.ascontiguousarray(np.asarray(lk.mem).reshape(-1)))
    meta = (lk.engine, lk.sew, level, tuple(map(int, lk.out_slice)),
            tuple((int(s), int(n)) for s, n in lk.init_spans),
            tuple((int(s), int(n)) for s, n in lk.cpool_spans),
            int(lk.used_words))
    h.update(repr(meta).encode())
    return h.digest()


def _memo_put(key: bytes, value) -> None:
    _opt_memo[key] = value
    while len(_opt_memo) > _MEMO_CAP:
        _opt_memo.popitem(last=False)


def _install(lk, w: Work, report: OptReport) -> None:
    lk.stream = list(w.entries)
    mem = np.asarray(lk.mem).copy()
    mem.reshape(-1)[:] = w.mem
    lk.mem = mem
    lk.init_spans = tuple(w.init_spans)
    lk.used_words = int(w.used_words)
    if lk.prov is not None and w.prov is not None:
        lk.prov = [int(p) for p in w.prov]
    lk._prog = None                 # padded/cached Program is stale
    lk.opt_report = report


def optimize(lk, level: str = "O1") -> Optional[OptReport]:
    """Optimize a :class:`repro.nmc.frontend.LoweredKernel` in place.

    Runs the engine's rule pipeline (:data:`repro.nmc.opt.rules.
    PIPELINE`), translation-validating each applied rewrite; returns the
    :class:`OptReport` (also attached as ``lk.opt_report``) or ``None``
    when nothing fired.  Raises :class:`OptError` on a rewrite that fails
    validation."""
    _check_level(level)
    if level == "off" or not len(lk.stream):
        return None
    from repro.nmc import check
    if check.verify_lowered(lk).errors:
        return None                 # broken input: leave it to check=
    entries = np.array(lk.stream, dtype=PROG_DTYPE)
    key = _memo_key(lk, entries, level)
    hit = _opt_memo.get(key)
    if hit is not None:
        _opt_memo.move_to_end(key)
        if hit[0] is None:
            return None             # known no-op for this artifact
        w, report = hit
        _install(lk, Work(w.engine, w.sew, w.entries.copy(), w.mem.copy(),
                          w.out_slice, list(w.init_spans), w.cpool_spans,
                          w.used_words,
                          None if w.prov is None else w.prov.copy()),
                 report)
        return report
    kernel = lk.kernel or f"<{lk.engine} kernel>"
    w = Work(engine=lk.engine, sew=lk.sew, entries=entries,
             mem=np.asarray(lk.mem).reshape(-1).copy(),
             out_slice=tuple(map(int, lk.out_slice)),
             init_spans=[(int(s), int(n)) for s, n in lk.init_spans],
             cpool_spans=tuple((int(s), int(n)) for s, n in lk.cpool_spans),
             used_words=int(lk.used_words),
             prov=None if lk.prov is None else np.asarray(lk.prov))
    ref_out = None                  # oracle runs lazily: only if a rule fires
    orig = (w.entries.copy(), w.mem.copy())
    records: List[RewriteRecord] = []
    n0 = len(w.entries)
    cycles = None

    def modeled_cycles() -> float:
        return float(timing.program_cycles(
            Program.from_entries(w.engine, w.sew, w.entries)).cycles)

    for rule_name, rule_fn in rules.PIPELINE[w.engine]:
        n_before = len(w.entries)
        stats = rule_fn(w)
        if not stats:
            continue
        if ref_out is None:
            ref_out = reference_output(w.engine, orig[1], orig[0], w.sew,
                                       w.out_slice)
            cycles = float(timing.program_cycles(
                Program.from_entries(w.engine, w.sew, orig[0])).cycles)
        validate(w, ref_out, kernel, rule_name)
        after = modeled_cycles()
        records.append(RewriteRecord(
            rule=rule_name, removed=int(stats.get("removed", 0)),
            moved=int(stats.get("moved", 0)), n_before=n_before,
            n_after=len(w.entries), cycles_before=cycles,
            cycles_after=after))
        cycles = after
    if not records:
        _memo_put(key, (None, None))
        return None
    report = OptReport(
        kernel=kernel, engine=w.engine, sew=w.sew, level=level,
        rewrites=tuple(records), n_instr_before=n0,
        n_instr_after=len(w.entries),
        cycles_before=records[0].cycles_before,
        cycles_after=records[-1].cycles_after, validated=len(records))
    _install(lk, w, report)
    _memo_put(key, (Work(w.engine, w.sew, w.entries.copy(), w.mem.copy(),
                         w.out_slice, list(w.init_spans), w.cpool_spans,
                         w.used_words,
                         None if w.prov is None else w.prov.copy()),
                    report))
    return report
