"""Rewrite rules over the unified IR, driven by the check package's
analyses (DESIGN.md §13).

Every rule takes a mutable :class:`Work` (entries + image + lowering
metadata) and either returns rewrite stats after mutating it, or ``None``
when it has nothing to do.  Rules only *propose* cheaper programs — the
driver (:func:`repro.nmc.opt.optimize`) translation-validates each
applied rewrite before it is allowed to survive.

All rules are value-independent: they look at the instruction stream, the
span metadata and structurally-zero image words, never at live operand
values — so an optimized layout is stable across calls (the residency
contract of ``serve/block.py`` depends on this).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp
from repro.nmc.program import NOP_OP_ID

from repro.nmc.check.structural import (_C_CODE, _CAESAR_BANK_WORDS,
                                        _CAESAR_MEM_WORDS, _CARUS_N_REGS,
                                        _CARUS_REG_WORDS, _LUT_N, _K_ARITH,
                                        _K_MACC, _K_MV, _K_SETVL, _K_SLIDES,
                                        _carus_regs, _carus_uses, _columns,
                                        _member)

_K_VMV = _K_MV
_MODE_COPY = isa.MODE_VV | isa.MODE_INDIRECT


@dataclasses.dataclass
class Work:
    """Mutable working copy of a lowering under optimization."""

    engine: str
    sew: int
    entries: np.ndarray                 # PROG_DTYPE rows
    mem: np.ndarray                     # flat int32 image (mutated in place)
    out_slice: Tuple[int, int]
    init_spans: List[Tuple[int, int]]
    cpool_spans: Tuple[Tuple[int, int], ...]
    used_words: int
    prov: Optional[np.ndarray]

    def drop_rows(self, keep: np.ndarray) -> None:
        self.entries = self.entries[keep]
        if self.prov is not None:
            self.prov = self.prov[keep]


def _code_of(op: np.ndarray) -> np.ndarray:
    code = _C_CODE[np.clip(op, 0, _LUT_N - 1)].copy()
    code[(op < 0) | (op >= _LUT_N)] = 0
    return code


# ---------------------------------------------------------------------------
# R1 — dead-write elimination + store-cone trimming
# ---------------------------------------------------------------------------

_CHAINS = ((int(CaesarOp.MAC_INIT), int(CaesarOp.MAC),
            int(CaesarOp.MAC_STORE)),
           (int(CaesarOp.DOT_INIT), int(CaesarOp.DOT),
            int(CaesarOp.DOT_STORE)))


def _dead_write_rows_caesar(m: np.ndarray, code: np.ndarray,
                            out: Tuple[int, int]) -> np.ndarray:
    """Per-row dead-write flag via the dataflow pass's event sort: a write
    is dead when the next same-location event is another write, or when it
    is the last event and the location falls outside the output window."""
    n = len(m)
    ridx = np.flatnonzero(code & 1)
    widx = np.flatnonzero(code & 2)
    dead_row = np.zeros(n, bool)
    if not len(widx):
        return dead_row
    r_loc = m[ridx, 2:4].T.reshape(-1).astype(np.int64)
    r_row = np.concatenate([ridx, ridx])
    w_loc = m[widx, 1].astype(np.int64)
    shift = (2 * max(n, 1) + 1).bit_length()
    key = np.empty(2 * len(ridx) + len(widx), np.int64)
    key[:2 * len(ridx)] = (r_loc << shift) + 2 * r_row
    key[2 * len(ridx):] = (w_loc << shift) + 2 * widx + 1
    key.sort()
    loc = key >> shift
    row = (key & ((1 << shift) - 1)) >> 1
    kind = key & 1
    nxt_same = np.zeros(len(key), bool)
    nxt_same[:-1] = loc[1:] == loc[:-1]
    nxt_write = np.zeros(len(key), bool)
    nxt_write[:-1] = kind[1:] == 1
    lo, hi = out
    dead_ev = (kind == 1) & np.where(
        nxt_same, nxt_write, (loc < lo) | (loc >= hi))
    dead_row[row[dead_ev]] = True
    return dead_row


def dead_write_elim(w: Work) -> Optional[dict]:
    """Remove stores no later instruction (or the output window) observes,
    and whole MAC/DOT chain segments whose every store is dead — the
    store-cone trim.  Runs to fixpoint: a removed store frees its source
    reads, which may expose further dead cones."""
    if w.engine != "caesar":
        return _dead_write_elim_carus(w)
    lo = int(w.out_slice[0])
    out = (lo, lo + int(w.out_slice[1]))
    removed = 0
    while True:
        e = w.entries
        m = _columns(e)
        op = m[:, 0]
        code = _code_of(op)
        dead_row = _dead_write_rows_caesar(m, code, out)
        # pure binop stores drop individually ...
        removable = dead_row & ((code & 2) != 0) & ((code & 8) == 0)
        # ... chain segments (INIT .. next INIT) only as a unit, when every
        # store of the segment is dead — partial removal would change the
        # accumulator for the surviving stores
        for init_id, body_id, store_id in _CHAINS:
            member = (op == init_id) | (op == body_id) | (op == store_id)
            rows = np.flatnonzero(member)
            if not len(rows):
                continue
            starts = np.flatnonzero(op[rows] == init_id)
            for si, s0 in enumerate(starts):
                s1 = starts[si + 1] if si + 1 < len(starts) else len(rows)
                seg = rows[s0:s1]
                stores = seg[op[seg] == store_id]
                if not len(stores) or dead_row[stores].all():
                    removable[seg] = True
        if not removable.any():
            break
        removed += int(removable.sum())
        w.drop_rows(~removable)
    return {"removed": removed} if removed else None


def _dead_write_elim_carus(w: Work) -> Optional[dict]:
    """Carus dead-final elimination at register granularity: an op whose
    written register is never touched again and lies outside the output
    registers is unobservable.  (WAW kills are left alone: tail-undisturbed
    writeback makes every write also a partial read of its destination.)"""
    removed = 0
    out_regs = -(-(int(w.out_slice[0]) + int(w.out_slice[1]))
                 // _CARUS_REG_WORDS)
    while True:
        e = w.entries
        (vd, vs2, vs1), (_, reads_vd, uses_vs2, uses_vs1, writes_vd) = \
            _carus_regs(e), _carus_uses(e)
        vd = vd % _CARUS_N_REGS
        vs2 = vs2 % _CARUS_N_REGS
        vs1 = vs1 % _CARUS_N_REGS
        n = len(e)
        last_event = np.full(_CARUS_N_REGS, -1)
        for regs, used in ((vs2, uses_vs2), (vs1, uses_vs1),
                           (vd, reads_vd | writes_vd)):
            rr = np.flatnonzero(used)
            if len(rr):
                np.maximum.at(last_event, regs[rr], rr)
        cand = writes_vd & ~reads_vd & (vd >= out_regs)
        removable = cand & (last_event[vd] == np.arange(n))
        if not removable.any():
            break
        removed += int(removable.sum())
        w.drop_rows(~removable)
    return {"removed": removed} if removed else None


# ---------------------------------------------------------------------------
# R2 — NOP / padding compaction
# ---------------------------------------------------------------------------

def nop_compact(w: Work) -> Optional[dict]:
    """Strip neutral NOP rows: zero modeled cycles either way, but fewer
    entries drop the kernel into a smaller instruction bucket (fewer
    scan/fori_loop steps and possibly one less XLA compile shape)."""
    m = _columns(w.entries)
    neutral = (m[:, 0] == NOP_OP_ID[w.engine]) & ~m[:, 1:].any(axis=1)
    if not neutral.any():
        return None
    w.drop_rows(~neutral)
    return {"removed": int(neutral.sum())}


# ---------------------------------------------------------------------------
# R3 — VSETVL canonicalization (carus)
# ---------------------------------------------------------------------------

def vsetvl_dedup(w: Work) -> Optional[dict]:
    """Remove VSETVLs that re-request the live VL (the engine clamps to
    ``min(sval1, vlmax)``; initial VL is VLMAX) or whose VL no following
    VL-sensitive op observes before the next VSETVL rewrites it."""
    if w.engine != "carus":
        return None
    e = w.entries
    op = e["op"]
    setvls = np.flatnonzero(op == _K_SETVL)
    if not len(setvls):
        return None
    vlmax = _CARUS_REG_WORDS * (32 // w.sew)
    sensitive = (_member(op, _K_ARITH) | (op == _K_MACC) | (op == _K_MV)
                 | _member(op, _K_SLIDES))
    remove = np.zeros(len(e), bool)
    cur = vlmax
    for j, i in enumerate(setvls):
        eff = min(int(e["sval1"][i]), vlmax)
        nxt = setvls[j + 1] if j + 1 < len(setvls) else len(e)
        if eff == cur or not sensitive[i + 1:nxt].any():
            remove[i] = True            # cur unchanged: VL is unobserved
        else:
            cur = eff
    if not remove.any():
        return None
    w.drop_rows(~remove)
    return {"removed": int(remove.sum())}


# ---------------------------------------------------------------------------
# R4 — bank-conflict-aware span placement (caesar)
# ---------------------------------------------------------------------------

def _first_fit(free: np.ndarray, n: int) -> Optional[int]:
    run = 0
    for i, f in enumerate(free):
        run = run + 1 if f else 0
        if run == n:
            return i - n + 1
    return None


def rebank(w: Work) -> Optional[dict]:
    """Move read-only image spans across the bank boundary when doing so
    reduces the same-bank operand-fetch count (every same-bank op pays
    +1 cycle on the single-port banks, Section III-A2).  Only spans that
    are never written, never patched (cpool) and outside the output
    window move; every instruction reference is remapped in place."""
    if w.engine != "caesar":
        return None
    e = w.entries
    m = _columns(e)
    op = m[:, 0]
    code = _code_of(op)
    real = np.flatnonzero(code & 1)     # operand-fetching rows
    if not len(real):
        return None
    wdest = m[np.flatnonzero(code & 2), 1]
    lo, hi = int(w.out_slice[0]), int(w.out_slice[0]) + int(w.out_slice[1])
    occupied = np.zeros(_CAESAR_MEM_WORDS, bool)
    for s, n in w.init_spans:
        occupied[int(s):int(s) + int(n)] = True
    occupied[lo:hi] = True
    occupied[m[:, 1]] = True            # every referenced word stays fixed
    occupied[m[:, 2]] = True
    occupied[m[:, 3]] = True
    cpools = {(int(s), int(n)) for s, n in w.cpool_spans}
    bw = _CAESAR_BANK_WORDS
    moved_refs = 0
    moved_spans = 0
    for si, (s, n) in enumerate(list(w.init_spans)):
        s, n = int(s), int(n)
        if (s, n) in cpools or n == 0:
            continue
        if s // bw != (s + n - 1) // bw:
            continue                    # bank-straddling span: leave it
        if s < hi and lo < s + n:
            continue                    # overlaps the output window
        if len(wdest) and np.any((wdest >= s) & (wdest < s + n)):
            continue                    # written: not a read-only span
        src1 = e["src1"][real].astype(np.int64)
        src2 = e["src2"][real].astype(np.int64)
        in1 = (src1 >= s) & (src1 < s + n)
        in2 = (src2 >= s) & (src2 < s + n)
        touched = in1 ^ in2             # both-in-span rows never change
        if not touched.any():
            continue
        cur_bank = s // bw
        other = np.where(in1[touched], src2[touched], src1[touched]) // bw
        before = int(np.count_nonzero(other == cur_bank))
        after = int(np.count_nonzero(other == 1 - cur_bank))
        if after >= before:
            continue                    # no same-bank cycles to win
        tb = 1 - cur_bank
        fit = _first_fit(~occupied[tb * bw:(tb + 1) * bw], n)
        if fit is None:
            continue
        new_s = tb * bw + fit
        delta = new_s - s
        for field, mask in (("src1", in1), ("src2", in2)):
            col = e[field][real]
            col[mask] += delta
            e[field][real] = col
        w.mem[new_s:new_s + n] = w.mem[s:s + n]
        w.mem[s:s + n] = 0
        occupied[s:s + n] = False
        occupied[new_s:new_s + n] = True
        w.init_spans[si] = (new_s, n)
        moved_refs += int(touched.sum())
        moved_spans += 1
    if not moved_spans:
        return None
    # allocator high-water from the post-move occupancy (drives the DMA-in
    # leg of the bus model)
    b0 = np.flatnonzero(occupied[:bw])
    b1 = np.flatnonzero(occupied[bw:])
    w.used_words = (int(b0[-1]) + 1 if len(b0) else 0) \
        + (int(b1[-1]) + 1 if len(b1) else 0)
    return {"moved": moved_refs, "spans": moved_spans}


# ---------------------------------------------------------------------------
# R5 — copy propagation / register coalescing (carus)
# ---------------------------------------------------------------------------

def copy_coalesce(w: Work) -> Optional[dict]:
    """Delete VMV block copies by loading the source image directly at the
    destination registers.  Fires on the lowering's accumulator-copy
    pattern (a loaded accumulator VMV'd into the output block before
    VMACC): when the copied registers are defined by exactly one image
    span, read by nothing but the copies, and the destination block is
    untouched before them, the copy is pure data movement."""
    if w.engine != "carus":
        return None
    removed = 0
    rw = _CARUS_REG_WORDS
    L = 32 // w.sew
    vlmax = rw * L
    while True:
        group = _find_coalescable(w, rw, L, vlmax)
        if group is None:
            break
        rows, d, s, k, span_idx = group
        ws, wn = w.init_spans[span_idx]
        off = ws - s * rw
        new_ws = d * rw + off
        w.mem[new_ws:new_ws + wn] = w.mem[ws:ws + wn]
        w.mem[ws:ws + wn] = 0
        w.init_spans[span_idx] = (new_ws, wn)
        keep = np.ones(len(w.entries), bool)
        keep[rows] = False
        w.drop_rows(keep)
        removed += len(rows)
    return {"removed": removed} if removed else None


def _find_coalescable(w: Work, rw: int, L: int, vlmax: int):
    from repro.core import alu
    e = w.entries
    n = len(e)
    (vd, vs2, vs1), (_, reads_vd, uses_vs2, uses_vs1, writes_vd) = \
        _carus_regs(e), _carus_uses(e)
    vd, vs2, vs1 = (vd % _CARUS_N_REGS, vs2 % _CARUS_N_REGS,
                    vs1 % _CARUS_N_REGS)
    is_copy = (e["op"] == _K_VMV) & (e["mode"] == _MODE_COPY) & (vs2 == 0)
    copies = np.flatnonzero(is_copy)
    if not len(copies):
        return None
    # live VL at each row (initial VL is VLMAX, VSETVL clamps)
    vl_at = np.full(n, vlmax)
    cur = vlmax
    svl = e["sval1"]
    ops = e["op"]
    for i in range(n):
        vl_at[i] = cur
        if ops[i] == _K_SETVL:
            cur = min(int(svl[i]), vlmax)
    # maximal consecutive runs: rows r..r+k-1 copying s+i -> d+i
    g0 = 0
    groups = []
    for j in range(1, len(copies) + 1):
        if j < len(copies) and copies[j] == copies[j - 1] + 1 \
                and vd[copies[j]] == vd[copies[g0]] + (j - g0) \
                and vs1[copies[j]] == vs1[copies[g0]] + (j - g0):
            continue
        groups.append((copies[g0:j], int(vd[copies[g0]]),
                       int(vs1[copies[g0]])))
        g0 = j
    for rows, d, s in groups:
        k = len(rows)
        in_group = np.zeros(n, bool)
        in_group[rows] = True
        src_hit = np.zeros(n, bool)
        dst_hit = np.zeros(n, bool)
        for regs, used in ((vs2, uses_vs2), (vs1, uses_vs1),
                           (vd, reads_vd | writes_vd)):
            src_hit |= used & (regs >= s) & (regs < s + k)
            dst_hit |= used & (regs >= d) & (regs < d + k)
        if (src_hit & ~in_group).any():
            continue                    # source block read/written elsewhere
        if dst_hit[:rows[0]].any():
            continue                    # destination live before the copy
        spans = [(i, int(ws), int(wn))
                 for i, (ws, wn) in enumerate(w.init_spans)
                 if ws < (s + k) * rw and s * rw < ws + wn]
        if len(spans) != 1:
            continue
        span_idx, ws, wn = spans[0]
        if ws < s * rw or ws + wn > (s + k) * rw:
            continue                    # span leaks outside the block
        if any(ws2 < (d + k) * rw and d * rw < ws2 + wn2
               for ws2, wn2 in w.init_spans):
            continue                    # destination block is image-defined
        if w.mem[d * rw:(d + k) * rw].any():
            continue                    # non-zero destination image words
        # tail safety: elements at/after the copy's VL must be zero in the
        # source image, since the coalesced load skips the tail-undisturbed
        # (zero-preserving) writeback the VMV performed
        vl = int(vl_at[rows[0]])
        ok = True
        for i in range(k):
            lanes = alu.unpack_lanes_np(
                w.mem[(s + i) * rw:(s + i + 1) * rw], w.sew).reshape(-1)
            if lanes[vl:].any():
                ok = False
                break
        if ok:
            return rows, d, s, k, span_idx
    return None


#: Rule pipeline per engine, in application order (each entry:
#: (stable rule name, callable)).
PIPELINE = {
    "caesar": (("dead-write-elim", dead_write_elim),
               ("nop-compact", nop_compact),
               ("rebank", rebank)),
    "carus": (("dead-write-elim", dead_write_elim),
              ("copy-coalesce", copy_coalesce),
              ("vsetvl-dedup", vsetvl_dedup),
              ("nop-compact", nop_compact)),
}
