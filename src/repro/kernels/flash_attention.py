"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Near-memory pattern at the attention level: K/V tiles stream HBM->VMEM once;
the softmax statistics (running max / sum) and the output accumulator stay
resident in VMEM scratch across the whole KV reduction — logits (Sq x Skv)
are never materialized.  Required by the 32k/500k context shapes.

Supports GQA (q-head -> kv-head via index_map), causal masking, sliding
windows, and a q-position offset for chunked prefill.  Block shapes default
to (128, head_dim) q-tiles x (512, head_dim) kv-tiles; VMEM per step ~
bq*d + 2*bk*d + bq*bk floats << VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, scale: float, causal: bool,
            window: int | None, q_offset: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)

    qpos = (pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0) + q_offset)
    kpos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]               # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(kv_i == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, bq: int = 128, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k: (B, Hkv, Skv, D); v: (B, Hkv, Skv, Dv).
    Dv may differ from D (MLA)."""
    b, hq, sq, d = q.shape
    dv = v.shape[-1]
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq, bk = min(bq, sq), min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    grid = (b * hq, sq // bq, skv // bk)
    scale = float(1.0 / np.sqrt(d))

    qs = q.reshape(b * hq, sq, d)
    ks = k.reshape(b * hkv, skv, d)
    vs = v.reshape(b * hkv, skv, dv)

    def kv_map(h, i, j):
        return (h // group, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=skv // bk, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(b, hq, sq, dv)
