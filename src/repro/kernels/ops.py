"""Dispatching wrappers: Pallas on TPU, same-math XLA fallback elsewhere.

Models call these entry points.  On TPU hardware the Pallas kernels run; on
CPU (tests, this container) and in the multi-pod dry-run the mathematically
identical XLA path is used — deliberately, because (a) ``pallas_call`` has no
CPU lowering for compile-only, and (b) the roofline analysis reads FLOP/byte
attribution from XLA's cost model, which custom calls would hide.  Kernel
correctness is established separately in ``tests/test_kernels.py`` via
``interpret=True`` against :mod:`repro.kernels.ref`.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import nmc_matmul as _mm
from repro.kernels import ref

_BACKEND_IS_TPU = None


def backend_is_tpu() -> bool:
    global _BACKEND_IS_TPU
    if _BACKEND_IS_TPU is None:
        _BACKEND_IS_TPU = jax.default_backend() == "tpu"
    return _BACKEND_IS_TPU


def nmc_matmul(x_q, w_q, scale, bias=None, *, act: str = "none",
               out_dtype=jnp.bfloat16):
    """W8A8 matmul with fused epilogue (2-D operands)."""
    if backend_is_tpu():
        return _mm.nmc_matmul(x_q, w_q, scale, bias, act=act,
                              out_dtype=out_dtype)
    return ref.nmc_matmul(x_q, w_q, scale, bias, act=act, out_dtype=out_dtype)


def attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """Memory-safe attention: flash kernel on TPU, chunked lax fallback."""
    if backend_is_tpu():
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      kv_chunk: int = 1024):
    """Online-softmax attention as a lax.scan over KV chunks — the same math
    as the Pallas kernel, expressed in XLA ops.  Never materializes Sq x Skv;
    peak temp is Sq x kv_chunk per head.  Supports dv != dq (MLA)."""
    b, hq, sq, d = q.shape
    dv = v.shape[-1]
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kv_chunk = min(kv_chunk, skv)
    if skv % kv_chunk:
        pad = kv_chunk - skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group * sq, d)
    kc = k.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nk, kv_chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = (jnp.arange(sq) + q_offset)
    qpos = jnp.tile(qpos, (group,))                       # (group*sq,)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, j = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb.astype(jnp.float32))
        kpos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = kpos[None, :] < skv
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p,
                                       vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group * sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group * sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group * sq, dv), jnp.float32)
    with jax.named_scope("flashattn_fallback"):
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(b, hq, sq, dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """Single-token decode attention against a (possibly padded) KV cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); cache_len: (B,) valid lengths
    (the new token is at index cache_len - 1)."""
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qf = q.astype(jnp.float32).reshape(b, hkv, group, d) * scale
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32))
    kpos = jnp.arange(s)[None, :]
    mask = kpos < cache_len[:, None]
    if window is not None:
        mask &= kpos > (cache_len[:, None] - 1 - window)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, v_cache.shape[-1]).astype(q.dtype)
