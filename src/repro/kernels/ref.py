"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel correctness: each Pallas
kernel is swept over shapes/dtypes in ``tests/test_kernels.py`` and asserted
allclose (bit-exact for the integer kernels) against these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# nmc_matmul: W8A8 integer matmul with int32 accumulation + fused epilogue
# ---------------------------------------------------------------------------


def nmc_matmul(x_q: jax.Array, w_q: jax.Array, scale: jax.Array,
               bias: jax.Array | None = None, act: str = "none",
               out_dtype=jnp.float32) -> jax.Array:
    """y = act((x_q @ w_q) * scale + bias).

    x_q: (M, K) int8, w_q: (K, N) int8, scale: (N,) f32 (= s_x * s_w),
    bias: (N,) f32 or None.  Accumulation in int32 — the NM-Carus vmacc
    semantics (never accumulate at operand width)."""
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * scale[None, :]
    if bias is not None:
        y = y + bias[None, :]
    y = apply_act(y, act)
    return y.astype(out_dtype)


def apply_act(y: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0)
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(act)


def quantize_rowwise(w: jax.Array, axis: int = 0) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 quantization of a weight matrix."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    wq = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return wq, s.reshape(-1)


def quantize_dynamic(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor dynamic symmetric int8 quantization of activations."""
    amax = jnp.max(jnp.abs(x))
    s = jnp.maximum(amax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return xq, s


# ---------------------------------------------------------------------------
# vrf_alu: the NM-Carus VPU as a fused element-wise program interpreter
# ---------------------------------------------------------------------------

# op ids (shared with the Pallas kernel)
VRF_OPS = ("add", "sub", "mul", "min", "max", "and", "or", "xor",
           "sll", "srl", "sra", "mv")
VRF_OP_ID = {n: i for i, n in enumerate(VRF_OPS)}
VRF_MODE_VV, VRF_MODE_VX = 0, 1


def _vrf_binop(opid, a, b, dtype):
    bits = dtype.itemsize * 8
    sh = (b.astype(jnp.uint32) % bits).astype(dtype)
    u = a.astype(jnp.dtype(f"uint{bits}"))
    return jnp.stack([
        a + b, a - b, a * b, jnp.minimum(a, b), jnp.maximum(a, b),
        a & b, a | b, a ^ b,
        (u << sh.astype(u.dtype)).astype(dtype),
        (u >> sh.astype(u.dtype)).astype(dtype),
        a >> sh,
        jnp.broadcast_to(b, a.shape),
    ])[opid]


def vrf_alu(vrf: jax.Array, prog: dict) -> jax.Array:
    """Execute `prog` over a (n_regs, vl) integer VRF; wraparound semantics.

    prog fields (int32 arrays, equal length): op, vd, vs1, vs2, scalar, mode.
    mode 0 = vv (operand b from vrf[vs1]); 1 = vx (operand b = scalar)."""
    dtype = vrf.dtype

    def step(vrf, ins):
        a = vrf[ins["vs2"]]
        b = jnp.where(ins["mode"] == VRF_MODE_VV, vrf[ins["vs1"]],
                      jnp.asarray(ins["scalar"], dtype))
        r = _vrf_binop(ins["op"], a, b.astype(dtype), dtype)
        return vrf.at[ins["vd"]].set(r.astype(dtype)), None

    vrf, _ = jax.lax.scan(step, vrf, prog)
    return vrf


# ---------------------------------------------------------------------------
# flash attention (blocked online-softmax reference: plain softmax here)
# ---------------------------------------------------------------------------


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              q_offset: int = 0) -> jax.Array:
    """Reference attention.  q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D).
    GQA by head repetition.  `window` = sliding-window size (None = full).
    `q_offset` positions q tokens at kv index q_offset + i (for decode)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
