"""Pallas TPU kernels (Layer B): nmc_matmul (W8A8 + fused epilogue), vrf_alu
(fused vector-program engine), flash_attention — each with a pure-jnp oracle
in ref.py and a dispatching wrapper in ops.py."""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nmc_matmul import nmc_matmul
from repro.kernels.vrf_alu import make_prog, vrf_alu

__all__ = ["ops", "ref", "flash_attention", "nmc_matmul", "vrf_alu",
           "make_prog"]
