"""Pallas TPU kernel: W8A8 integer matmul with fused near-memory epilogue.

This is the NM-Carus ``vmacc`` loop mapped onto the MXU (DESIGN.md Layer B):

* int8 x int8 -> int32 accumulation (the paper's rule: MACs accumulate at
  32-bit regardless of operand width — Section III-A2 / III-B2);
* the accumulator lives in VMEM scratch across the whole K reduction — the
  "compute at the register file" pattern: partial sums never round-trip HBM;
* the dequant + bias + activation epilogue is fused: the result leaves VMEM
  exactly once, already in its final form (the NMC "results are directly
  accessible, eliminating additional data movement" contract).

Block shapes are MXU/VREG aligned: multiples of (32, 128) for int8 operands,
(8, 128) for the f32 output.  VMEM footprint per grid step:
  bm*bk + bk*bn (int8)  +  bm*bn*4 (int32 acc)  +  bm*bn*out_bytes
e.g. the default 256/256/512 tiles use 256*512*2 + 256*256*4 + ... ~ 0.6 MiB,
far under the ~128 MiB VMEM budget, allowing the pipeline to double-buffer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref


def _kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
            nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * scale_ref[...][None, :]
        y = y + bias_ref[...][None, :]
        o_ref[...] = ref.apply_act(y, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "out_dtype", "bm", "bn",
                                             "bk", "interpret"))
def nmc_matmul(x_q: jax.Array, w_q: jax.Array, scale: jax.Array,
               bias: jax.Array | None = None, *, act: str = "none",
               out_dtype=jnp.float32, bm: int = 256, bn: int = 256,
               bk: int = 512, interpret: bool = False) -> jax.Array:
    """y[M,N] = act((x_q[M,K] @ w_q[K,N]) * scale[N] + bias[N])."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})"
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, scale.astype(jnp.float32), bias.astype(jnp.float32))
