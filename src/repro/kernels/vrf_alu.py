"""Pallas TPU kernel: the NM-Carus VPU as a fused vector-program engine.

The paper's headline software property is that a *program* of vector
instructions runs against data that never leaves the compute memory.  The TPU
transcription: the vector register file (VRF) is a (n_regs, VL) integer array;
one ``pallas_call`` loads a VL-tile of every register into VMEM, executes the
*entire instruction program* there (N ops = one HBM round-trip instead of N),
and writes the file back in place (``input_output_aliases`` — the
memory-mode/compute-mode duality: the buffer is storage and operand at once).

Instructions are runtime data (int32 arrays), so — exactly like the paper's
indirect register addressing — the same compiled kernel executes arbitrary
programs over arbitrary register operands without retracing or unrolling.
Register indices are dynamic row indices into the VMEM-resident file.

Grid: VL is split into lane-blocks; every lane-block is independent (the
paper's per-lane bank alignment, Fig. 6: element i of every register lives in
the same bank).  Element-wise semantics are two's-complement wraparound at
the element width, identical to :mod:`repro.core.alu`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

N_FIELDS = 6  # op, vd, vs1, vs2, scalar, mode


def _kernel(prog_ref, vrf_ref, o_ref, *, n_instr: int):
    dtype = vrf_ref.dtype

    def body(t, file):
        op = prog_ref[t, 0]
        vd = prog_ref[t, 1]
        vs1 = prog_ref[t, 2]
        vs2 = prog_ref[t, 3]
        scalar = prog_ref[t, 4]
        mode = prog_ref[t, 5]
        a = jax.lax.dynamic_index_in_dim(file, vs2, 0, keepdims=False)
        bv = jax.lax.dynamic_index_in_dim(file, vs1, 0, keepdims=False)
        b = jnp.where(mode == ref.VRF_MODE_VV, bv,
                      jnp.broadcast_to(scalar.astype(dtype), bv.shape))
        r = ref._vrf_binop(op, a, b.astype(dtype), dtype).astype(dtype)
        return jax.lax.dynamic_update_index_in_dim(file, r, vd, 0)

    file = jax.lax.fori_loop(0, n_instr, body, vrf_ref[...])
    o_ref[...] = file


@functools.partial(jax.jit, static_argnames=("block_vl", "interpret"))
def vrf_alu(vrf: jax.Array, prog: jax.Array, *, block_vl: int = 512,
            interpret: bool = False) -> jax.Array:
    """Execute `prog` (int32 (n_instr, 6)) over `vrf` (n_regs, VL) in place.

    Returns the updated register file; the input buffer is donated/aliased."""
    n_regs, vl = vrf.shape
    block_vl = min(block_vl, vl)
    assert vl % block_vl == 0, (vl, block_vl)
    n_instr = prog.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, n_instr=n_instr),
        grid=(vl // block_vl,),
        in_specs=[
            pl.BlockSpec((n_instr, N_FIELDS), lambda i: (0, 0)),
            pl.BlockSpec((n_regs, block_vl), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_regs, block_vl), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(vrf.shape, vrf.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(prog, vrf)


def make_prog(entries: list[tuple]) -> jax.Array:
    """entries: (op_name, vd, vs1, vs2, scalar, mode) -> (n,6) int32 array."""
    import numpy as np
    rows = [(ref.VRF_OP_ID[op], vd, vs1, vs2, scalar, mode)
            for (op, vd, vs1, vs2, scalar, mode) in entries]
    return jnp.asarray(np.asarray(rows, dtype=np.int32))
