"""Serving engine: prefill + decode with donated KV caches and continuous
batching.

The near-memory contract at the serving level: caches are donated buffers
updated in place (memory-mode/compute-mode duality), and with
``nmc_mode='w8a8'`` every projection runs the quantized int8 path
(params converted once via ``quantize_params``).

``ServeEngine`` implements slot-based continuous batching: a fixed decode
batch of S slots; finished sequences free their slot, queued requests are
prefilled into it (prefill at batch 1 here; production would chunk).

All device work — prefill admission and decode steps — is dispatched as
queued work through an :class:`repro.nmc.DispatchQueue` from the curated
``repro.nmc`` public surface (with ``nmc_mode='w8a8'`` those are exactly
the int8 NMC projections): the queue launches the computations
asynchronously and the engine blocks only at future resolution, so a
batch of admissions issues all its prefills before the first host-side
cache merge (DESIGN.md §5.2).  By default the engine joins the shared
:func:`repro.nmc.default_runtime` queue, so serving traffic and
``nmc.jit`` kernel calls drain through one dispatch discipline.

W8A8 projections offloaded to the simulated tile array
(:meth:`ServeEngine.nmc_project`) shard across ``nmc_tiles`` tiles via
the partitioning planner (DESIGN.md §9) — the same planner, queue and
bucketed jit cache every ``nmc.jit(tiles=N)`` kernel uses.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nmc
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.nmc import DispatchQueue


def quantize_params(params: dict, cfg: ModelConfig) -> dict:
    """Convert trained params to the NMC int8 serving form (DESIGN.md B)."""
    return L.quantize_tree(params)


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, caches, cache_len):
        return lm.decode_step(params, tokens, caches, cache_len, cfg)
    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: Optional[list] = None


class ServeEngine:
    """Slot-based continuous batching on a single host (tests/examples)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 nmc_queue: Optional[DispatchQueue] = None,
                 nmc_tiles: int = 1):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.nmc_queue = nmc_queue if nmc_queue is not None \
            else nmc.default_runtime().queue
        # W8A8 projections offloaded to the NMC tile array shard across
        # this many tiles via the partitioning planner (DESIGN.md §9);
        # they dispatch through THIS engine's queue (for_queue wraps a
        # caller-owned queue as a kernel runtime), so serving traffic and
        # projection waves share one dispatch discipline and jit cache
        self.nmc_tiles = int(nmc_tiles)
        if self.nmc_tiles < 1:
            raise ValueError(f"nmc_tiles must be >= 1, got {nmc_tiles!r}")
        self._nmc_rt = nmc.NmcRuntime.for_queue(self.nmc_queue)
        self._nmc_proj: dict = {}       # (m, k) -> CompiledKernel
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
        self.prefill = jax.jit(make_prefill_step(cfg, max_len))
        self.caches = lm.init_caches(params, cfg, n_slots, max_len,
                                     dtype=cfg.dtype)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)
        self.slot_remaining = np.zeros(n_slots, np.int32)
        self.slot_last_tok = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []

    # -- NMC tile-array offload ----------------------------------------------
    def nmc_project(self, x8, w8) -> np.ndarray:
        """One W8A8 projection ``y = x8 @ w8`` executed on the NMC tile
        array, sharded across ``nmc_tiles`` tiles by the partitioning
        planner (DESIGN.md §9): activation entries are scalar taps, weight
        rows are resident vectors, output rows distribute across the array
        and the gather reassembles ``(m, n)`` — bit-exact int8 wrap-at-8
        semantics (two's complement), matching the quantized kernels the
        Table V matmul models.

        This is the serving-level hook onto the paper's hardware path: the
        jitted bf16/int8 JAX decode loop stands in for the host CPU, and
        projections routed here run on the simulated tile array through
        the same planner and bucketed jit cache as ``nmc.jit`` kernels —
        submitted to *this engine's* dispatch queue (``nmc_queue``), so
        prefill/decode work and projection waves drain through one
        discipline.  Demo-scale by design — one projection per call,
        shapes bounded by a tile's SRAM macro."""
        x8 = np.asarray(x8, np.int8)
        w8 = np.asarray(w8, np.int8)
        m, k = x8.shape
        assert w8.shape[0] == k, (x8.shape, w8.shape)
        kern = self._nmc_proj.get((m, k))
        if kern is None:
            def proj(t, X, W):
                a = t.consts(X)
                rows = [t.load(W[r]) for r in range(k)]
                for i in range(m):
                    acc = None
                    for kk in range(k):
                        acc = nmc.mac(acc, a[i, kk], rows[kk])
                    t.store(acc)
            kern = nmc.jit(proj, sew=8, tiles=self.nmc_tiles,
                           runtime=self._nmc_rt)
            self._nmc_proj[(m, k)] = kern
        return np.asarray(kern(x8, w8)).reshape(m, w8.shape[1])

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        # two-phase admission: launch a prefill for every (free slot, queued
        # request) pair as queued device work first — the dispatch queue's
        # async launches overlap on the device — then resolve the futures
        # and merge caches host-side.  Bit-identical to admitting one slot
        # at a time (prefills are independent); only the overlap differs.
        launches = []
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                fut = self.nmc_queue.submit_call(
                    self.prefill, self.params,
                    {"tokens": jnp.asarray(req.prompt[None])})
                launches.append((s, req, fut))
        for s, req, fut in launches:
            # .value, not .result(): the arrays are their own futures — the
            # argmax below forces logits while the cache merge stays queued
            logits, caches1 = fut.value
            # copy the single-sequence cache into slot s
            self.caches = jax.tree.map(
                lambda full, one: _insert_slot(full, one, s),
                self.caches, caches1)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.slot_req[s] = req
            self.slot_len[s] = len(req.prompt) + 1
            self.slot_remaining[s] = req.max_new - 1
            self.slot_last_tok[s] = tok

    # -- decode loop ----------------------------------------------------------
    def step(self):
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return False
        toks = jnp.asarray(self.slot_last_tok[:, None])
        clen = jnp.asarray(self.slot_len)
        # decode is queued NMC work too: launched async; only the sampled
        # tokens are forced below, the cache update stays in flight under
        # the host-side slot bookkeeping
        fut = self.nmc_queue.submit_call(self.decode, self.params, toks,
                                         self.caches, clen)
        logits, self.caches = fut.value
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self.slot_last_tok[s] = int(nxt[s])
            self.slot_len[s] += 1
            self.slot_remaining[s] -= 1
            if self.slot_remaining[s] <= 0 or self.slot_len[s] >= self.max_len:
                self.done.append(req)
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done


def _insert_slot(full, one, s: int):
    """Write a batch-1 cache entry into slot s of the batched cache.  Works
    for any leaf with the batch dim in position 1 (layer-stacked) or 0."""
    if one.ndim >= 2 and one.shape[0] != 1 and one.shape[1] == 1:
        return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype),
                                                   s, axis=1)
    return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype),
                                               s, axis=0)
