"""Serving engine: prefill + decode with donated KV caches and continuous
batching.

The near-memory contract at the serving level: caches are donated buffers
updated in place (memory-mode/compute-mode duality), and with
``nmc_mode='w8a8'`` every projection runs the quantized int8 path
(params converted once via ``quantize_params``).

``ServeEngine`` implements slot-based continuous batching: a fixed decode
batch of S slots; finished sequences free their slot, queued requests are
prefilled into it (prefill at batch 1 here; production would chunk).

All device work — prefill admission and decode steps — is dispatched as
queued work through an :class:`repro.nmc.DispatchQueue` from the curated
``repro.nmc`` public surface (with ``nmc_mode='w8a8'`` those are exactly
the int8 NMC projections): the queue launches the computations
asynchronously and the engine blocks only at future resolution, so a
batch of admissions issues all its prefills before the first host-side
cache merge (DESIGN.md §5.2).  By default the engine joins the shared
:func:`repro.nmc.default_runtime` queue, so serving traffic and
``nmc.jit`` kernel calls drain through one dispatch discipline.

W8A8 projections offloaded to the simulated tile array
(:meth:`ServeEngine.nmc_project`) shard across ``nmc_tiles`` tiles via
the partitioning planner (DESIGN.md §9) — the same planner, queue and
bucketed jit cache every ``nmc.jit(tiles=N)`` kernel uses.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nmc
from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.nmc import DispatchQueue


def quantize_params(params: dict, cfg: ModelConfig) -> dict:
    """Convert trained params to the NMC int8 serving form (DESIGN.md B)."""
    return L.quantize_tree(params)


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, caches, cache_len):
        return lm.decode_step(params, tokens, caches, cache_len, cfg)
    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: Optional[list] = None


class ServeEngine:
    """Slot-based continuous batching on a single host (tests/examples)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 nmc_queue: Optional[DispatchQueue] = None,
                 nmc_tiles: int = 1,
                 max_prefills: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        # admission control: at most this many prefills launch per step
        # (None = one per free slot), so at serving scale prefill waves
        # interleave with decode waves instead of stalling every active
        # slot behind a burst of arrivals
        if max_prefills is not None and max_prefills < 1:
            raise ValueError(
                f"max_prefills must be >= 1 or None, got {max_prefills!r}")
        self.max_prefills = max_prefills
        self.nmc_queue = nmc_queue if nmc_queue is not None \
            else nmc.default_runtime().queue
        # W8A8 projections offloaded to the NMC tile array shard across
        # this many tiles via the partitioning planner (DESIGN.md §9);
        # they dispatch through THIS engine's queue (for_queue wraps a
        # caller-owned queue as a kernel runtime), so serving traffic and
        # projection waves share one dispatch discipline and jit cache
        self.nmc_tiles = int(nmc_tiles)
        if self.nmc_tiles < 1:
            raise ValueError(f"nmc_tiles must be >= 1, got {nmc_tiles!r}")
        self._nmc_rt = nmc.NmcRuntime.for_queue(self.nmc_queue)
        self._nmc_proj: dict = {}       # (m, k, n, sew) -> CompiledKernel
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
        self.prefill = jax.jit(make_prefill_step(cfg, max_len))
        self.caches = lm.init_caches(params, cfg, n_slots, max_len,
                                     dtype=cfg.dtype)
        # explicit per-leaf batch axes from the family that built the
        # cache — slot writes must not sniff axes from leaf shapes (a
        # size-1 layer axis is indistinguishable from a size-1 batch axis)
        self._cache_axes = lm.cache_batch_axes(cfg, self.caches)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)
        self.slot_remaining = np.zeros(n_slots, np.int32)
        self.slot_last_tok = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []

    # -- NMC tile-array offload ----------------------------------------------
    def nmc_project(self, x8, w8, sew: int = 8) -> np.ndarray:
        """One W8A8 projection ``y = x8 @ w8`` executed on the NMC tile
        array, sharded across ``nmc_tiles`` tiles by the partitioning
        planner (DESIGN.md §9): activation entries are scalar taps, weight
        rows are resident vectors, and the ``"axis"`` strategy gives each
        tile a contiguous column slice of every weight row (the same
        layout the resident-block path keeps on-array), the gather
        reassembling ``(m, n)``.  At the default ``sew=8``
        the result carries bit-exact int8 wrap-at-8 semantics (two's
        complement), matching the quantized kernels the Table V matmul
        models; ``sew=32`` widens the int8 operands into 32-bit lanes for
        exact int32 accumulation (the resident-block serving contract).

        This is the serving-level hook onto the paper's hardware path: the
        jitted bf16/int8 JAX decode loop stands in for the host CPU, and
        projections routed here run on the simulated tile array through
        the same planner and bucketed jit cache as ``nmc.jit`` kernels —
        submitted to *this engine's* dispatch queue (``nmc_queue``), so
        prefill/decode work and projection waves drain through one
        discipline.  Demo-scale by design — one projection per call,
        shapes bounded by a tile's SRAM macro."""
        x8 = np.asarray(x8, np.int8)
        w8 = np.asarray(w8, np.int8)
        m, k = x8.shape
        assert w8.shape[0] == k, (x8.shape, w8.shape)
        n = int(w8.shape[1])
        # keyed on the full shape (m, k, n) plus sew: two weights with the
        # same (m, k) but different output widths n must not share a cache
        # entry, and sew=32 callers (exact int32 accumulation for the
        # resident-block comparison path) must not collide with sew=8
        kern = self._nmc_proj.get((m, k, n, sew))
        if kern is None:
            def proj(t, X, W):
                a = t.consts(X)
                rows = [t.load(W[r]) for r in range(k)]
                for i in range(m):
                    acc = None
                    for kk in range(k):
                        acc = nmc.mac(acc, a[i, kk], rows[kk])
                    t.store(acc)
            # "axis" column-shards the weight loads (each tile holds its
            # slice of W, cpool replicated) — the layout wide projections
            # need to fit a tile's bank, and the one ResidentProjection
            # keeps on-array
            kern = nmc.jit(proj, sew=sew, tiles=self.nmc_tiles,
                           partition="axis", runtime=self._nmc_rt)
            self._nmc_proj[(m, k, n, sew)] = kern
        if sew == 8:
            return np.asarray(kern(x8, w8)).reshape(m, n)
        # widen int8 operands into sew-bit lanes: accumulation is exact
        # (k * 127^2 < 2^31 for any tile-resident k), true W8A8 GEMM
        return np.asarray(kern(x8.astype(np.int32),
                               w8.astype(np.int32))).reshape(m, n)

    def resident_block(self, layer: int = 0, rows: Optional[int] = None,
                       tiles: Optional[int] = None):
        """Build a :class:`repro.serve.block.ResidentBlock` over one decoder
        layer's weights: the whole W8A8 block (q/k/v/o projections + MLP)
        runs as chained partitioned waves on the tile array with the
        quantized weights resident — loaded once, reused every token; only
        activation words cross the bus per call (DESIGN.md §12).

        ``rows`` is the per-call token-row count (defaults to this engine's
        slot count); ``tiles`` the per-projection shard width (defaults to
        ``nmc_tiles``).  Dispatches through this engine's queue, so block
        waves and serving traffic share one discipline."""
        from repro.serve.block import ResidentBlock
        if self.cfg.family not in ("dense", "vlm"):
            raise ValueError(
                f"resident_block supports stacked dense decoder layers, "
                f"not family {self.cfg.family!r}")
        lp = jax.tree.map(lambda a: np.asarray(a[layer]),
                          self.params["layers"])
        return ResidentBlock(self.cfg, lp, queue=self.nmc_queue,
                             rows=rows if rows is not None else self.n_slots,
                             tiles=tiles if tiles is not None
                             else self.nmc_tiles)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        # two-phase admission: launch a prefill for every (free slot, queued
        # request) pair as queued device work first — the dispatch queue's
        # async launches overlap on the device — then resolve the futures
        # and merge caches host-side.  Bit-identical to admitting one slot
        # at a time (prefills are independent); only the overlap differs.
        launches = []
        for s in range(self.n_slots):
            if self.max_prefills is not None \
                    and len(launches) >= self.max_prefills:
                break
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                fut = self.nmc_queue.submit_call(
                    self.prefill, self.params,
                    {"tokens": jnp.asarray(req.prompt[None])})
                launches.append((s, req, fut))
        for s, req, fut in launches:
            # .value, not .result(): the arrays are their own futures — the
            # argmax below forces logits while the cache merge stays queued
            logits, caches1 = fut.value
            # copy the single-sequence cache into slot s, on the batch axis
            # the cache family declares for each leaf (never sniffed from
            # leaf shapes)
            self.caches = jax.tree.map(
                lambda full, one, ax: _insert_slot(full, one, s, ax),
                self.caches, caches1, self._cache_axes)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.slot_req[s] = req
            self.slot_len[s] = len(req.prompt) + 1
            self.slot_remaining[s] = req.max_new - 1
            self.slot_last_tok[s] = tok
            # prefill itself produced one token; a request exhausted by it
            # (max_new=1, or the prompt already fills max_len) retires here
            # instead of riding a decode step that would emit an extra token
            if self.slot_remaining[s] <= 0 or self.slot_len[s] >= self.max_len:
                self.done.append(req)
                self.slot_req[s] = None

    # -- decode loop ----------------------------------------------------------
    def step(self):
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return False
        toks = jnp.asarray(self.slot_last_tok[:, None])
        clen = jnp.asarray(self.slot_len)
        # decode is queued NMC work too: launched async; only the sampled
        # tokens are forced below, the cache update stays in flight under
        # the host-side slot bookkeeping
        fut = self.nmc_queue.submit_call(self.decode, self.params, toks,
                                         self.caches, clen)
        logits, self.caches = fut.value
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self.slot_last_tok[s] = int(nxt[s])
            self.slot_len[s] += 1
            self.slot_remaining[s] -= 1
            if self.slot_remaining[s] <= 0 or self.slot_len[s] >= self.max_len:
                self.done.append(req)
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done


def _insert_slot(full, one, s: int, axis: int):
    """Write a batch-1 cache entry into slot s of the batched cache along
    the explicit ``axis`` declared by :func:`repro.models.lm.cache_batch_axes`
    (shape sniffing misreads single-layer stacks, whose layer dim of 1 is
    indistinguishable from a batch dim of 1)."""
    return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype),
                                               s, axis=axis)
