"""Resident W8A8 transformer-block serving on the tile array (DESIGN.md §12).

The serving layer's steady state is the paper's memory-mode / compute-mode
duality at block granularity: a decoder layer's quantized weights are DMA'd
onto the tile array **once** (memory-mode write), and every decoded token
then runs the whole block — attention q/k/v/o projections plus the MLP
up/gate/down GEMMs — as a chain of partitioned waves against the resident
weights, with only the per-call activation words and instruction streams
crossing the 32-bit system bus.

Three cooperating pieces:

* :class:`ResidentProjection` — one ``y = X @ W`` GEMM kept resident on a
  dedicated set of tiles.  Built once per weight: the kernel is traced,
  column-sharded across the array (``partition="axis"`` — each tile owns a
  contiguous column slice of ``W``, so the *weights* are partitioned, not
  replicated), and lowered to a fixed wave of tile images.  Per call, only
  the activation scalar-tap pool changes: the builder proves the memory
  layout is value-independent (two traces over different activations must
  agree on :attr:`repro.nmc.partition.PartitionPlan.signature`, program
  entries and every non-``t.consts`` image word) and then serves every
  call by *patching* exactly the cpool words
  (:meth:`repro.nmc.pool.ResidentPool.patch` via the queue's ``patch=``
  submission) — weights never cross the bus again.  If the proof fails the
  projection degrades to a correct full-reload path (never wrong, just not
  resident).
* :class:`ResidentBlock` — the whole decoder block.  Host stages (RMSNorm,
  dynamic per-row activation quantization, GQA attention softmax, SiLU
  gating, dequantization epilogues) run in float on the host — the paper's
  eCPU/host split: NMC tiles own the integer GEMMs, the host owns the
  cheap nonlinearities.  Every GEMM routes through a pluggable ``mm``
  backend, so the resident path, the per-projection
  :meth:`repro.serve.engine.ServeEngine.nmc_project` path and the pure-JAX
  ``jnp.matmul`` reference share every non-GEMM instruction — bit-exact
  equality of the three paths reduces to bit-exact int32 GEMMs, which SEW
  32 guarantees (``k * 127^2 < 2^31``: int8 operands in 32-bit lanes
  accumulate exactly).
* :func:`ResidentBlock.step_cycles` — the modeled cost of one token step:
  the four dependent waves (q/k/v | o | up/gate | down) through
  :func:`repro.core.timing.chained_wave_cycles`, with steady-state stages
  charged only their patched activation words on the input DMA leg.

Engine restriction: NM-Caesar only.  Caesar materializes every ``t.consts``
element as one splat word in tile memory, so patching the cpool span
retargets the resident program.  NM-Carus embeds scalar-tap *values* in the
instruction stream (``EMVX``/``sval1``), so patching the VRF alone cannot
change what a resident Carus program computes — the builder rejects it.

Positional rotation (RoPE) is deliberately outside this block: it acts on
q/k *after* projection and is host-side float work like the softmax, so the
resident GEMM contract is unchanged by it.  Callers that need positions
apply the rotation between :meth:`ResidentBlock.step`'s projections — the
block models the paper's tile-array workload, not a full LM stack.
"""

from __future__ import annotations

import itertools
from typing import Callable, Optional

import numpy as np

from repro import nmc
from repro.core import timing
from repro.nmc import check as nmc_check
from repro.nmc.pool import WORD_BYTES
from repro.nmc.runtime import DispatchQueue, GatherFuture

#: Unique id per ResidentProjection: its tiles live in a private namespace
#: ``("resident", uid, shard)`` that can never collide with the runtime's
#: ``("jit", k)`` tiles or the pools' ``("build", n)`` / ``("lane", k)``
#: ids — residency depends on nobody ever re-installing these tiles.
_IDS = itertools.count()


# ---------------------------------------------------------------------------
# Host-side numerics (shared verbatim by all three mm backends)
# ---------------------------------------------------------------------------

def splat_words(vals: np.ndarray, sew: int) -> np.ndarray:
    """Vectorized :func:`repro.nmc.frontend.splat_word`: replicate each
    SEW-bit value across its 32-bit word (identity at SEW 32).  These are
    the words a ``t.consts`` element occupies in an NM-Caesar image — the
    patch payload of the resident serving path."""
    v = np.asarray(vals).astype(np.int64) & ((1 << sew) - 1)
    w = np.zeros(v.shape, np.int64)
    for k in range(32 // sew):
        w = w | (v << (sew * k))
    return (w & 0xFFFFFFFF).astype(np.uint32).view(np.int32)


def quantize_rows(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Dynamic symmetric per-row int8 activation quantization (the W8A8
    "A8" half): each row scales by ``max|x| / 127``."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1)
    s = np.maximum(amax, 1e-8) / 127.0
    q = np.clip(np.rint(x / s[:, None]), -127, 127).astype(np.int8)
    return q, s


def quantize_cols(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-column int8 weight quantization (the "W8"
    half — the same rule as :func:`repro.models.layers.linear_quantize`,
    in numpy)."""
    w = np.asarray(w, np.float32)
    amax = np.max(np.abs(w), axis=0)
    s = np.maximum(amax, 1e-8) / 127.0
    q = np.clip(np.rint(w / s[None, :]), -127, 127).astype(np.int8)
    return q, s


def _quantize_linear(p: dict) -> tuple[np.ndarray, np.ndarray,
                                       Optional[np.ndarray]]:
    """A linear param leaf -> (int8 weight, per-column scale, bias|None).
    Accepts both trained (``{"w", "b"?}``) and already-quantized serving
    (``{"w_q", "scale", "b"?}``) forms."""
    if "w_q" in p:
        w8 = np.asarray(p["w_q"], np.int8)
        s = np.asarray(p["scale"], np.float32)
    else:
        w8, s = quantize_cols(np.asarray(p["w"], np.float32))
    b = np.asarray(p["b"], np.float32) if "b" in p else None
    return w8, s, b


def _rmsnorm(x: np.ndarray, g: np.ndarray, eps: float) -> np.ndarray:
    xf = np.asarray(x, np.float32)
    r = 1.0 / np.sqrt(np.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return xf * r * g[None, :]


def _silu(x: np.ndarray) -> np.ndarray:
    return x / (1.0 + np.exp(-x))


# ---------------------------------------------------------------------------
# One GEMM resident on a set of tiles
# ---------------------------------------------------------------------------

class ResidentProjection:
    """One W8A8 projection ``y = X @ W`` with ``W`` resident on the array.

    ``W`` (``(k, n)`` int8) is column-sharded across ``tiles`` NM-Caesar
    tiles at SEW 32 (exact int32 accumulation) by the ``"axis"`` partition
    strategy: each shard's image holds its column slice of every weight
    row (bank 1) plus the replicated activation scalar-tap pool (bank 0).
    The build proves the image layout is independent of activation
    *values*; per call only the cpool words are patched onto the resident
    state and the wave re-dispatches — ``ResidentPool.loads`` counts the
    one-time weight DMA, ``patches``/``patch_bytes`` the per-call
    activation traffic.
    """

    def __init__(self, name: str, w8: np.ndarray, queue: DispatchQueue,
                 rows: int, tiles: int, engine: str = "caesar"):
        if engine != "caesar":
            raise nmc.LoweringError(
                f"resident projection '{name}' requires NM-Caesar: NM-Carus "
                f"embeds scalar-tap values in the instruction stream "
                f"(EMVX/sval1), so patching resident VRF words cannot "
                f"retarget the program")
        self.name = name
        self.queue = queue
        self.w8 = np.ascontiguousarray(np.asarray(w8, np.int8))
        self.k, self.n = (int(d) for d in self.w8.shape)
        self.m = int(rows)
        self.sew = 32
        m, k = self.m, self.k

        def proj(t, X, W):
            a = t.consts(X)
            cols = [t.load(W[r]) for r in range(k)]
            for i in range(m):
                acc = None
                for r in range(k):
                    acc = nmc.mac(acc, a[i, r], cols[r])
                t.store(acc)

        proj.__name__ = f"resident_{name}"
        self.kern = nmc.jit(proj, engine="caesar", sew=self.sew,
                            tiles=int(tiles), partition="axis")
        self._w32 = self.w8.astype(np.int32)
        # value-independence proof: lower the wave over two activation
        # fillings (a deterministic non-zero probe and all-zeros) — the
        # plan signature, every program entry and every image word outside
        # the cpool spans must agree, or patching is unsound
        probe = ((np.arange(m * k, dtype=np.int64) * 37 + 11) % 251 - 125)
        probe = probe.astype(np.int32).reshape(m, k)
        plan_p, lks_p = self.kern.lower_wave(probe, self._w32)
        plan_z, lks_z = self.kern.lower_wave(np.zeros((m, k), np.int32),
                                             self._w32)
        self.static = _layout_static(plan_p, lks_p, plan_z, lks_z)
        self.plan, self.lks = plan_p, lks_p
        # residency hazard pass (repro.nmc.check.residency): statically
        # prove per shard that patch spans never alias the resident
        # weight spans and no program write mutates image-defined words —
        # the contract every later patch-only submit depends on
        self.hazard_reports = tuple(
            nmc_check.verify_resident(lk, kernel=f"{proj.__name__}[{j}]")
            for j, lk in enumerate(lks_p))
        for rep in self.hazard_reports:
            rep.raise_if_errors()
        uid = next(_IDS)
        self.tiles = tuple(("resident", uid, j) for j in range(len(lks_p)))
        self._installed = False

    @property
    def n_shards(self) -> int:
        return len(self.lks)

    # -- execution -----------------------------------------------------------
    def submit(self, x8) -> GatherFuture:
        """Queue the projection over one activation batch ``(m, k)``;
        returns the gather future immediately (so q/k/v can land in one
        launch wave).  First call ships the weight images; every later
        call patches only the cpool words."""
        x = np.asarray(x8)
        assert x.shape == (self.m, self.k), (x.shape, (self.m, self.k))
        x32 = np.ascontiguousarray(x.astype(np.int32))
        if not self.static:
            # correct-but-cold fallback: value-dependent layout means the
            # whole image reloads per call (residency proof failed)
            plan, lks = self.kern.lower_wave(x32, self._w32)
            futs = [self.queue.submit(t, lk.program, image=lk.mem,
                                      out_slice=lk.out_slice, post=lk.post)
                    for t, lk in zip(self.tiles, lks)]
            return GatherFuture(futs, plan.gather)
        words = splat_words(x32.reshape(-1), self.sew)
        futs = []
        for tile, lk in zip(self.tiles, self.lks):
            patch = []
            for lo, ne in lk.cpool_spans:
                assert ne == words.size, (self.name, ne, words.size)
                patch.append((lo, words))
            futs.append(self.queue.submit(
                tile, lk.program,
                image=None if self._installed else lk.mem,
                out_slice=lk.out_slice, post=lk.post, patch=patch))
        self._installed = True
        return GatherFuture(futs, self.plan.gather)

    def __call__(self, x8) -> np.ndarray:
        return np.asarray(self.submit(x8).result()).reshape(self.m, self.n)

    # -- cost model ----------------------------------------------------------
    def stage_costs(self, steady: bool = True) -> list[timing.StageCost]:
        """One :class:`repro.core.timing.StageCost` per shard.  Cold stages
        charge the full image DMA on the input leg (``used_words``, the
        :func:`repro.core.timing.stage_cost` convention); steady stages
        charge only the patched cpool words — the resident contract that
        per-call memory-mode traffic is O(activations), not O(image).
        Instruction-stream bytes are charged by neither (same as
        ``stage_cost``), so steady-vs-cold compares memory-mode DMA only;
        :meth:`patch_bytes_per_call` exposes the raw byte count for
        benchmark-side accounting."""
        out = []
        for j, lk in enumerate(self.lks):
            cold = timing.stage_cost(lk, name=f"{self.name}[{j}]")
            if not steady or not self.static:
                out.append(cold)
                continue
            patch_words = sum(ne for _, ne in lk.cpool_spans)
            out.append(timing.StageCost(
                cold.name,
                dma_in_cycles=timing.dma_cycles(patch_words * WORD_BYTES),
                compute_cycles=cold.compute_cycles,
                dma_out_cycles=cold.dma_out_cycles))
        return out

    @property
    def patch_bytes_per_call(self) -> int:
        """Bytes patched onto the array per steady-state call: every
        shard's replicated cpool words.  Matches what one resident call
        adds to ``ResidentPool.patch_bytes`` exactly (asserted in
        tests/test_block.py); instruction-stream bytes are separate
        (``ResidentPool.dispatch`` accounting)."""
        return sum(ne for lk in self.lks
                   for _, ne in lk.cpool_spans) * WORD_BYTES


def _layout_static(plan_a, lks_a, plan_b, lks_b) -> bool:
    """True iff two lowerings of one kernel over different activation
    values agree on everything but the ``t.consts`` image words."""
    if plan_a.signature != plan_b.signature or len(lks_a) != len(lks_b):
        return False
    for a, b in zip(lks_a, lks_b):
        if a.cpool_spans != b.cpool_spans or a.out_slice != b.out_slice:
            return False
        if not np.array_equal(a.program.entries, b.program.entries):
            return False
        fa = np.asarray(a.mem).reshape(-1)
        fb = np.asarray(b.mem).reshape(-1)
        if fa.size != fb.size:
            return False
        keep = np.ones(fa.size, bool)
        for lo, ne in a.cpool_spans:     # Caesar: one splat word / element
            keep[lo:lo + ne] = False
        if not np.array_equal(fa[keep], fb[keep]):
            return False
    return True


# ---------------------------------------------------------------------------
# The whole decoder block, weights resident
# ---------------------------------------------------------------------------

class ResidentBlock:
    """One W8A8 decoder block (GQA attention + MLP) served off the tile
    array with all seven projection weights resident.

    ``step(x, state)`` advances ``m`` independent decode rows by one token:
    four dependent GEMM waves (q/k/v — o — up/gate — down) chained through
    the dispatch queue, all host stages in float32 numpy.  The ``mm``
    hook swaps the GEMM backend — ``None`` (resident tiles, the real
    path), :meth:`project_mm` (per-projection ``ServeEngine.nmc_project``
    at SEW 32) or :meth:`jax_mm` (pure ``jnp.matmul`` int32 reference) —
    while every other instruction is shared, so the three paths are
    bit-exact equal (tests/test_block.py).
    """

    def __init__(self, cfg, layer_params: dict, queue: Optional[DispatchQueue]
                 = None, rows: int = 4, tiles: int = 1):
        self.cfg = cfg
        self.m = int(rows)
        self.queue = queue if queue is not None \
            else nmc.default_runtime().queue
        self.d = int(cfg.d_model)
        self.heads = int(cfg.n_heads)
        self.kv_heads = int(cfg.n_kv_heads)
        self.hd = int(cfg.head_dim) or self.d // self.heads
        attn, mlp = layer_params["attn"], layer_params["mlp"]
        self.gated = "wg" in mlp
        self.g1 = np.asarray(layer_params["ln1"]["g"], np.float32)
        self.g2 = np.asarray(layer_params["ln2"]["g"], np.float32)
        specs = [("wq", attn["wq"]), ("wk", attn["wk"]), ("wv", attn["wv"]),
                 ("wo", attn["wo"]), ("wi", mlp["wi"])]
        if self.gated:
            specs.append(("wg", mlp["wg"]))
        specs.append(("wo2", mlp["wo"]))
        self.w8: dict = {}
        self.w_scale: dict = {}
        self.bias: dict = {}
        self._proj: dict = {}
        for name, p in specs:
            w8, s, b = _quantize_linear(p)
            self.w8[name], self.w_scale[name], self.bias[name] = w8, s, b
            self._proj[name] = ResidentProjection(
                name, w8, self.queue, rows=self.m, tiles=tiles)
        # the four dependent waves of one step must be tile-disjoint, or
        # wave k+1's DMA-in races wave k's DMA-out on a shared tile; the
        # private ("resident", uid, shard) namespace makes this hold by
        # construction — the hazard pass proves it stays that way
        self.wave_report = nmc_check.verify_chained_waves(
            self._step_wave_tiles(), kernel="resident_block")
        self.wave_report.raise_if_errors()

    def _step_wave_tiles(self) -> list:
        """Tile IDs of the four dependent GEMM waves of one step
        (mirrors :meth:`step_waves`): [q/k/v], [o], [up(/gate)], [down]."""
        qkv = [t for n in ("wq", "wk", "wv") for t in self._proj[n].tiles]
        up = list(self._proj["wi"].tiles)
        if self.gated:
            up += self._proj["wg"].tiles
        return [qkv, list(self._proj["wo"].tiles), up,
                list(self._proj["wo2"].tiles)]

    # -- introspection -------------------------------------------------------
    @property
    def pool(self):
        """The ResidentPool under the queue — where the residency counters
        (``loads`` / ``patches`` / ``patch_bytes``) live."""
        return self.queue.pool

    @property
    def n_shards(self) -> int:
        """Total tile count the block occupies (sum over projections)."""
        return sum(p.n_shards for p in self._proj.values())

    @property
    def static(self) -> bool:
        """True iff every projection passed the value-independence proof
        (all weights genuinely resident; no full-reload fallbacks)."""
        return all(p.static for p in self._proj.values())

    @property
    def patch_bytes_per_call(self) -> int:
        """Activation bytes patched onto the array per block step (sum
        over all seven projections' shards)."""
        return sum(p.patch_bytes_per_call for p in self._proj.values())

    # -- mm backends ---------------------------------------------------------
    def jax_mm(self, name: str, x8: np.ndarray) -> np.ndarray:
        """Pure-JAX int32 GEMM reference: ``jnp.matmul`` over widened int8
        operands — exactly what SEW-32 MAC chains accumulate."""
        import jax.numpy as jnp
        return np.asarray(jnp.matmul(jnp.asarray(x8, jnp.int32),
                                     jnp.asarray(self.w8[name], jnp.int32)))

    def project_mm(self, engine) -> Callable:
        """mm backend routing each GEMM through
        :meth:`repro.serve.engine.ServeEngine.nmc_project` at SEW 32 (the
        per-projection tile-array comparison path)."""
        return lambda name, x8: engine.nmc_project(x8, self.w8[name], sew=32)

    # -- block step ----------------------------------------------------------
    def init_state(self, max_len: int = 64) -> dict:
        """Fresh attention state: per-row k/v history (float32, post-
        dequantization values) plus the current length."""
        shape = (self.m, int(max_len), self.kv_heads, self.hd)
        return {"k": np.zeros(shape, np.float32),
                "v": np.zeros(shape, np.float32), "len": 0}

    def _project(self, names: list, x: np.ndarray, mm) -> dict:
        """Quantize once, run the named GEMMs (one launch wave on the
        resident path: all submits precede the first resolve), dequantize
        with the shared epilogue."""
        x8, sx = quantize_rows(x)
        if mm is None:
            futs = [(n, self._proj[n].submit(x8)) for n in names]
            raw = {n: np.asarray(f.result()) for n, f in futs}
        else:
            raw = {n: np.asarray(mm(n, x8)) for n in names}
        out = {}
        for n in names:
            y = raw[n].reshape(self.m, -1).astype(np.float32) \
                * (sx[:, None] * self.w_scale[n][None, :])
            if self.bias[n] is not None:
                y = y + self.bias[n][None, :]
            out[n] = y
        return out

    def _attention(self, q, k_hist, v_hist) -> np.ndarray:
        """Host-side GQA attention (float32 softmax; kv heads repeat up to
        query heads).  q: (m, H, hd); histories: (m, T, KVH, hd)."""
        rep = self.heads // self.kv_heads
        kf = np.repeat(k_hist, rep, axis=2)
        vf = np.repeat(v_hist, rep, axis=2)
        s = np.einsum("mhd,mthd->mht", q, kf) / np.sqrt(float(self.hd))
        s = s - s.max(axis=-1, keepdims=True)
        p = np.exp(s)
        p = p / p.sum(axis=-1, keepdims=True)
        return np.einsum("mht,mthd->mhd", p, vf)

    def step(self, x, state: dict, mm=None) -> tuple[np.ndarray, dict]:
        """One decode step for ``m`` rows: ``(m, d) -> (m, d)``, updating
        ``state`` in place (callers comparing backends pass independent
        states)."""
        x = np.asarray(x, np.float32)
        assert x.shape == (self.m, self.d), (x.shape, (self.m, self.d))
        h = _rmsnorm(x, self.g1, self.cfg.norm_eps)
        qkv = self._project(["wq", "wk", "wv"], h, mm)
        q = qkv["wq"].reshape(self.m, self.heads, self.hd)
        knew = qkv["wk"].reshape(self.m, self.kv_heads, self.hd)
        vnew = qkv["wv"].reshape(self.m, self.kv_heads, self.hd)
        t = int(state["len"])
        assert t < state["k"].shape[1], "attention state full — raise max_len"
        state["k"][:, t] = knew
        state["v"][:, t] = vnew
        state["len"] = t + 1
        att = self._attention(q, state["k"][:, :t + 1], state["v"][:, :t + 1])
        x = x + self._project(
            ["wo"], att.reshape(self.m, self.heads * self.hd), mm)["wo"]
        h = _rmsnorm(x, self.g2, self.cfg.norm_eps)
        if self.gated:
            up = self._project(["wi", "wg"], h, mm)
            mid = up["wi"] * _silu(up["wg"])
        else:
            mid = _silu(self._project(["wi"], h, mm)["wi"])
        x = x + self._project(["wo2"], mid, mm)["wo2"]
        return x, state

    # -- cost model ----------------------------------------------------------
    def step_waves(self, steady: bool = True) -> list:
        """The four dependent GEMM waves of one step as StageCost lists:
        [q/k/v], [o], [up(/gate)], [down]."""
        qkv = [s for n in ("wq", "wk", "wv")
               for s in self._proj[n].stage_costs(steady)]
        up = list(self._proj["wi"].stage_costs(steady))
        if self.gated:
            up += self._proj["wg"].stage_costs(steady)
        return [qkv, self._proj["wo"].stage_costs(steady), up,
                self._proj["wo2"].stage_costs(steady)]

    def step_cycles(self, steady: bool = True) -> float:
        """Modeled cycles of one block step: the dependent wave chain
        through :func:`repro.core.timing.chained_wave_cycles` on an array
        wide enough that every shard owns its tile (which is how the
        resident tiles are actually laid out)."""
        waves = self.step_waves(steady)
        return timing.chained_wave_cycles(waves,
                                          max(len(w) for w in waves))
