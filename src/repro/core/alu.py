"""Bit-exact packed-SIMD integer ALU semantics shared by NM-Caesar and NM-Carus.

Both NMC macros operate on 32-bit memory words interpreted as packed vectors of
4x8-bit, 2x16-bit or 1x32-bit two's-complement integers (the paper's "standard
data types", Section III).  This module is the single source of arithmetic
truth: the Caesar engine, the Carus VPU, the Pallas `vrf_alu` kernel and the
pure-jnp oracles all reduce to these lane operations.

All functions are jit-compatible and vectorized over arrays of words.  `sew`
(selected element width, bits) is a static Python int — JAX traces one program
per element width, exactly like the hardware statically configuring its CSR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SEWS = (8, 16, 32)

# canonical SEW -> numpy dtype map (shared by builders, engines, tests)
NP_DTYPES = {8: np.int8, 16: np.int16, 32: np.int32}


def lanes_per_word(sew: int) -> int:
    assert sew in SEWS, f"unsupported SEW {sew}"
    return 32 // sew


# ---------------------------------------------------------------------------
# Pack / unpack between int32 words and sign-extended int32 lanes
# ---------------------------------------------------------------------------

def unpack(words: jax.Array, sew: int) -> jax.Array:
    """words int32[...] -> sign-extended lanes int32[..., L], little-endian."""
    words = words.astype(jnp.int32)
    nl = lanes_per_word(sew)
    if nl == 1:
        return words[..., None]
    u = _bitcast_u32(words)
    shifts = jnp.arange(nl, dtype=jnp.uint32) * sew
    mask = jnp.uint32((1 << sew) - 1)
    raw = (u[..., None] >> shifts) & mask                     # u32 lanes
    sign = jnp.uint32(1 << (sew - 1))
    # sign extension: (raw ^ sign) - sign in modular u32, then bitcast
    ext = (raw ^ sign) - sign
    return _bitcast_i32(ext)


def pack(lanes: jax.Array, sew: int) -> jax.Array:
    """lanes int32[..., L] -> int32 words[...]; lanes truncated to SEW bits."""
    nl = lanes_per_word(sew)
    if nl == 1:
        return lanes[..., 0].astype(jnp.int32)
    mask = jnp.uint32((1 << sew) - 1)
    u = _bitcast_u32(lanes.astype(jnp.int32)) & mask
    shifts = jnp.arange(nl, dtype=jnp.uint32) * sew
    word = jax.lax.reduce(u << shifts, jnp.uint32(0), jax.lax.bitwise_or,
                          (lanes.ndim - 1,))
    return _bitcast_i32(word)


def _bitcast_u32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.uint32)


def _bitcast_i32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.uint32), jnp.int32)


# numpy-side helpers for building memory images in tests/benchmarks ---------

def pack_np(arr: np.ndarray) -> np.ndarray:
    """Pack a little-endian int8/int16/int32 numpy array into int32 words."""
    b = np.ascontiguousarray(arr).tobytes()
    assert len(b) % 4 == 0, "array byte size must be a multiple of 4"
    return np.frombuffer(b, dtype="<i4").copy()


def unpack_np(words: np.ndarray, dtype) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(words, dtype="<i4").tobytes(),
                         dtype=np.dtype(dtype).newbyteorder("<")).copy()


# ---------------------------------------------------------------------------
# Lane ops (two's complement, wraparound at SEW — RVV / NM-Caesar semantics)
# ---------------------------------------------------------------------------

def _shift_amount(b_lanes: jax.Array, sew: int) -> jax.Array:
    # RVV: shift amount is taken modulo SEW.
    return _bitcast_u32(b_lanes) % jnp.uint32(sew)


def lane_binop(op: str, a: jax.Array, b: jax.Array, sew: int) -> jax.Array:
    """Apply `op` on sign-extended int32 lanes; result is NOT yet truncated
    (pack() truncates).  Multiplies wrap modulo 2^32 which is exact for the
    low SEW bits of the product — matching hardware truncating multiplies."""
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    if op == "minu":
        au, bu = _zext(a, sew), _zext(b, sew)
        return jnp.where(au <= bu, a, b)
    if op == "maxu":
        au, bu = _zext(a, sew), _zext(b, sew)
        return jnp.where(au >= bu, a, b)
    if op == "sll":
        sh = _shift_amount(b, sew)
        return _bitcast_i32(_bitcast_u32(a) << sh)
    if op == "srl":
        sh = _shift_amount(b, sew)
        mask = jnp.uint32((1 << sew) - 1) if sew < 32 else jnp.uint32(0xFFFFFFFF)
        return _bitcast_i32((_bitcast_u32(a) & mask) >> sh)
    if op == "sra":
        sh = _shift_amount(b, sew).astype(jnp.int32)
        return a >> sh   # lanes are sign-extended => arithmetic shift correct
    raise ValueError(f"unknown lane op {op!r}")


def _zext(lanes: jax.Array, sew: int) -> jax.Array:
    mask = jnp.uint32((1 << sew) - 1) if sew < 32 else jnp.uint32(0xFFFFFFFF)
    return _bitcast_u32(lanes) & mask


BINOPS = ("add", "sub", "mul", "and", "or", "xor", "min", "max", "minu",
          "maxu", "sll", "srl", "sra")


# ---------------------------------------------------------------------------
# Word-level operations used by the engines
# ---------------------------------------------------------------------------

def word_binop(op: str, a_words: jax.Array, b_words: jax.Array, sew: int) -> jax.Array:
    """Element-wise packed-SIMD op on arrays of int32 words."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    return pack(lane_binop(op, a, b, sew), sew)


def word_macc(acc_words: jax.Array, a_words: jax.Array, b_words: jax.Array,
              sew: int) -> jax.Array:
    """Per-lane multiply-accumulate: acc[i] += a[i]*b[i] (wraps at SEW).
    NM-Caesar MAC / NM-Carus vmacc semantics."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    acc = unpack(acc_words, sew)
    return pack(acc + a * b, sew)


def word_dot(acc32: jax.Array, a_words: jax.Array, b_words: jax.Array,
             sew: int) -> jax.Array:
    """Word-wise dot product accumulated into a 32-bit scalar accumulator:
    acc32 += sum_l a_l * b_l  (NM-Caesar DOT; wraps modulo 2^32)."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    prod = (a * b).sum(axis=-1).astype(jnp.int32)
    if prod.ndim:
        prod = prod.sum(dtype=jnp.int32)
    return (acc32 + prod).astype(jnp.int32)
