"""Bit-exact packed-SIMD integer ALU semantics shared by NM-Caesar and NM-Carus.

Both NMC macros operate on 32-bit memory words interpreted as packed vectors of
4x8-bit, 2x16-bit or 1x32-bit two's-complement integers (the paper's "standard
data types", Section III).  This module is the single source of arithmetic
truth: the Caesar engine, the Carus VPU, the Pallas `vrf_alu` kernel and the
pure-jnp oracles all reduce to these lane operations.

All functions are jit-compatible and vectorized over arrays of words.  `sew`
(selected element width, bits) is a static Python int — JAX traces one program
per element width, exactly like the hardware statically configuring its CSR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SEWS = (8, 16, 32)

# canonical SEW -> numpy dtype map (shared by builders, engines, tests)
NP_DTYPES = {8: np.int8, 16: np.int16, 32: np.int32}


def lanes_per_word(sew: int) -> int:
    assert sew in SEWS, f"unsupported SEW {sew}"
    return 32 // sew


# ---------------------------------------------------------------------------
# Pack / unpack between int32 words and sign-extended int32 lanes
# ---------------------------------------------------------------------------

def unpack(words: jax.Array, sew: int) -> jax.Array:
    """words int32[...] -> sign-extended lanes int32[..., L], little-endian."""
    words = words.astype(jnp.int32)
    nl = lanes_per_word(sew)
    if nl == 1:
        return words[..., None]
    u = _bitcast_u32(words)
    shifts = jnp.arange(nl, dtype=jnp.uint32) * sew
    mask = jnp.uint32((1 << sew) - 1)
    raw = (u[..., None] >> shifts) & mask                     # u32 lanes
    sign = jnp.uint32(1 << (sew - 1))
    # sign extension: (raw ^ sign) - sign in modular u32, then bitcast
    ext = (raw ^ sign) - sign
    return _bitcast_i32(ext)


def pack(lanes: jax.Array, sew: int) -> jax.Array:
    """lanes int32[..., L] -> int32 words[...]; lanes truncated to SEW bits."""
    nl = lanes_per_word(sew)
    if nl == 1:
        return lanes[..., 0].astype(jnp.int32)
    mask = jnp.uint32((1 << sew) - 1)
    u = _bitcast_u32(lanes.astype(jnp.int32)) & mask
    shifts = jnp.arange(nl, dtype=jnp.uint32) * sew
    word = jax.lax.reduce(u << shifts, jnp.uint32(0), jax.lax.bitwise_or,
                          (lanes.ndim - 1,))
    return _bitcast_i32(word)


def _bitcast_u32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.uint32)


def _bitcast_i32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.uint32), jnp.int32)


# numpy-side helpers for building memory images in tests/benchmarks ---------

def pack_np(arr: np.ndarray) -> np.ndarray:
    """Pack a little-endian int8/int16/int32 numpy array into int32 words."""
    b = np.ascontiguousarray(arr).tobytes()
    assert len(b) % 4 == 0, "array byte size must be a multiple of 4"
    return np.frombuffer(b, dtype="<i4").copy()


def unpack_np(words: np.ndarray, dtype) -> np.ndarray:
    return np.frombuffer(np.ascontiguousarray(words, dtype="<i4").tobytes(),
                         dtype=np.dtype(dtype).newbyteorder("<")).copy()


# ---------------------------------------------------------------------------
# Lane ops (two's complement, wraparound at SEW — RVV / NM-Caesar semantics)
# ---------------------------------------------------------------------------

def _shift_amount(b_lanes: jax.Array, sew: int) -> jax.Array:
    # RVV: shift amount is taken modulo SEW.
    return _bitcast_u32(b_lanes) % jnp.uint32(sew)


def lane_binop(op: str, a: jax.Array, b: jax.Array, sew: int) -> jax.Array:
    """Apply `op` on sign-extended int32 lanes; result is NOT yet truncated
    (pack() truncates).  Multiplies wrap modulo 2^32 which is exact for the
    low SEW bits of the product — matching hardware truncating multiplies."""
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    if op == "minu":
        au, bu = _zext(a, sew), _zext(b, sew)
        return jnp.where(au <= bu, a, b)
    if op == "maxu":
        au, bu = _zext(a, sew), _zext(b, sew)
        return jnp.where(au >= bu, a, b)
    if op == "sll":
        sh = _shift_amount(b, sew)
        return _bitcast_i32(_bitcast_u32(a) << sh)
    if op == "srl":
        sh = _shift_amount(b, sew)
        mask = jnp.uint32((1 << sew) - 1) if sew < 32 else jnp.uint32(0xFFFFFFFF)
        return _bitcast_i32((_bitcast_u32(a) & mask) >> sh)
    if op == "sra":
        sh = _shift_amount(b, sew).astype(jnp.int32)
        return a >> sh   # lanes are sign-extended => arithmetic shift correct
    raise ValueError(f"unknown lane op {op!r}")


def _zext(lanes: jax.Array, sew: int) -> jax.Array:
    mask = jnp.uint32((1 << sew) - 1) if sew < 32 else jnp.uint32(0xFFFFFFFF)
    return _bitcast_u32(lanes) & mask


BINOPS = ("add", "sub", "mul", "and", "or", "xor", "min", "max", "minu",
          "maxu", "sll", "srl", "sra")


# ---------------------------------------------------------------------------
# Pure-numpy mirrors (the differential-test oracle, tests/test_differential.py)
#
# These reimplement the lane/word semantics above with numpy-only integer
# arithmetic — no JAX, no tracing — so randomized programs executed by the
# scanned engines can be checked bit-exactly against an implementation with
# an entirely independent evaluation path.  Inputs/outputs follow the JAX
# versions: lanes are *sign-extended int32 values* carried in int64 (so a
# splat vx scalar is the raw 32-bit value, exactly like `lane_binop`), and
# truncation to SEW happens at `pack_lanes_np`, exactly like `pack`.
# ---------------------------------------------------------------------------

_U32 = (1 << 32) - 1


def _to_i32_np(x: np.ndarray) -> np.ndarray:
    """Wrap int64 values into signed 32-bit range (bitcast semantics)."""
    x = np.asarray(x, np.int64) & _U32
    return np.where(x >= (1 << 31), x - (1 << 32), x)


def trunc_lanes_np(x, sew: int) -> np.ndarray:
    """Truncate int64 lane values to SEW bits, sign-extended (= pack+unpack)."""
    mask = (1 << sew) - 1
    x = np.asarray(x, np.int64) & mask
    sign = 1 << (sew - 1)
    return (x ^ sign) - sign


def unpack_lanes_np(words: np.ndarray, sew: int) -> np.ndarray:
    """int32 words[...] -> sign-extended lanes int64[..., L] (mirror of
    :func:`unpack`, little-endian lane order)."""
    words = np.asarray(words, np.int64) & _U32
    nl = lanes_per_word(sew)
    shifts = np.arange(nl, dtype=np.int64) * sew
    return trunc_lanes_np(words[..., None] >> shifts, sew)


def pack_lanes_np(lanes: np.ndarray, sew: int) -> np.ndarray:
    """lanes int64[..., L] -> int32 words (mirror of :func:`pack`:
    truncates each lane to SEW bits)."""
    nl = lanes_per_word(sew)
    mask = (1 << sew) - 1
    u = np.asarray(lanes, np.int64) & mask
    shifts = np.arange(nl, dtype=np.int64) * sew
    return _to_i32_np((u << shifts).sum(axis=-1) & _U32)


def lane_binop_np(op: str, a, b, sew: int) -> np.ndarray:
    """numpy mirror of :func:`lane_binop` — untruncated int64 results over
    sign-extended int32 lane values (truncation happens at pack, like JAX)."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    mask = (1 << sew) - 1
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "min":
        return np.minimum(a, b)
    if op == "max":
        return np.maximum(a, b)
    if op == "minu":
        au, bu = a & mask, b & mask
        return np.where(au <= bu, a, b)
    if op == "maxu":
        au, bu = a & mask, b & mask
        return np.where(au >= bu, a, b)
    sh = (b & _U32) % sew                      # RVV: shift amount mod SEW
    if op == "sll":
        return _to_i32_np(((a & _U32) << sh) & _U32)
    if op == "srl":
        return (a & mask) >> sh
    if op == "sra":
        return a >> sh                          # sign-extended => arithmetic
    raise ValueError(f"unknown lane op {op!r}")


def word_binop_np(op: str, a_words, b_words, sew: int) -> np.ndarray:
    """numpy mirror of :func:`word_binop`."""
    return pack_lanes_np(
        lane_binop_np(op, unpack_lanes_np(a_words, sew),
                      unpack_lanes_np(b_words, sew), sew), sew)


def word_macc_np(acc_words, a_words, b_words, sew: int) -> np.ndarray:
    """numpy mirror of :func:`word_macc`."""
    acc = unpack_lanes_np(acc_words, sew)
    a = unpack_lanes_np(a_words, sew)
    b = unpack_lanes_np(b_words, sew)
    return pack_lanes_np(acc + a * b, sew)


def word_dot_np(acc32: int, a_words, b_words, sew: int) -> int:
    """numpy mirror of :func:`word_dot` (wraps modulo 2^32)."""
    a = unpack_lanes_np(a_words, sew)
    b = unpack_lanes_np(b_words, sew)
    return int(_to_i32_np(int(acc32) + int((a * b).sum())))


# ---------------------------------------------------------------------------
# Word-level operations used by the engines
# ---------------------------------------------------------------------------

def word_binop(op: str, a_words: jax.Array, b_words: jax.Array, sew: int) -> jax.Array:
    """Element-wise packed-SIMD op on arrays of int32 words."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    return pack(lane_binop(op, a, b, sew), sew)


def word_macc(acc_words: jax.Array, a_words: jax.Array, b_words: jax.Array,
              sew: int) -> jax.Array:
    """Per-lane multiply-accumulate: acc[i] += a[i]*b[i] (wraps at SEW).
    NM-Caesar MAC / NM-Carus vmacc semantics."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    acc = unpack(acc_words, sew)
    return pack(acc + a * b, sew)


def word_dot(acc32: jax.Array, a_words: jax.Array, b_words: jax.Array,
             sew: int) -> jax.Array:
    """Word-wise dot product accumulated into a 32-bit scalar accumulator:
    acc32 += sum_l a_l * b_l  (NM-Caesar DOT; wraps modulo 2^32)."""
    a = unpack(a_words, sew)
    b = unpack(b_words, sew)
    prod = (a * b).sum(axis=-1).astype(jnp.int32)
    if prod.ndim:
        prod = prod.sum(dtype=jnp.int32)
    return (acc32 + prod).astype(jnp.int32)
