"""NM-Caesar functional model: 2-bank memory + multi-cycle packed-SIMD ALU.

NM-Caesar is micro-controlled by the host: each instruction arrives as a bus
write (see :func:`repro.core.isa.caesar_encode`).  The engine here executes a
pre-assembled instruction *stream* — exactly what the system DMA engine would
replay from main memory — inside one ``jax.lax.scan``.

State: a flat 8192-word memory (2 x 16 KiB single-port banks; bank = high
address bit), a packed MAC accumulator word, and a 32-bit DOT accumulator.
SEW is static per stream (the CSRW configuration instruction is modeled as a
stream boundary, matching how the paper's kernels configure the width once).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp


@dataclasses.dataclass(frozen=True)
class CaesarConfig:
    mem_words: int = C.CAESAR_MEM_BYTES // C.WORD_BYTES  # 8192
    n_banks: int = C.CAESAR_N_BANKS

    @property
    def bank_words(self) -> int:
        return self.mem_words // self.n_banks

    def bank_of(self, word_addr):
        return word_addr // self.bank_words


_BINOP_OF = {
    CaesarOp.AND: "and", CaesarOp.OR: "or", CaesarOp.XOR: "xor",
    CaesarOp.ADD: "add", CaesarOp.SUB: "sub", CaesarOp.MUL: "mul",
    CaesarOp.SLL: "sll", CaesarOp.SLR: "srl", CaesarOp.SRA: "sra",
    CaesarOp.MIN: "min", CaesarOp.MAX: "max",
}


def stream_to_arrays(entries: list[tuple[CaesarOp, int, int, int]]) -> dict:
    arr = np.array([(int(op), d, s1, s2) for op, d, s1, s2 in entries],
                   dtype=isa.CAESAR_TRACE_DTYPE)
    return {n: jnp.asarray(arr[n]) for n in arr.dtype.names}


class CaesarEngine:
    def __init__(self, config: CaesarConfig | None = None):
        self.cfg = config or CaesarConfig()

    def run_program(self, mem: jax.Array, program):
        """Execute a unified-IR :class:`repro.nmc.program.Program`."""
        assert program.engine == "caesar", program.engine
        return self.run_stream(mem, program.lower(), program.sew)

    @functools.partial(jax.jit, static_argnames=("self", "sew"))
    def run_stream(self, mem: jax.Array, stream: dict, sew: int):
        """Execute an instruction stream.  Returns (mem, mac_acc, dot_acc)."""

        def step(carry, ins):
            mem, mac_acc, dot_acc = carry
            op, dest, src1, src2 = ins["op"], ins["dest"], ins["src1"], ins["src2"]
            a = mem[src1]
            b = mem[src2]

            def binop_branch(name):
                def f(_):
                    r = alu.word_binop(name, a[None], b[None], sew)[0]
                    return mem.at[dest].set(r), mac_acc, dot_acc
                return f

            def mac_init(_):
                z = jnp.int32(0)
                acc = alu.word_macc(z[None], a[None], b[None], sew)[0]
                return mem, acc, dot_acc

            def mac(_):
                acc = alu.word_macc(mac_acc[None], a[None], b[None], sew)[0]
                return mem, acc, dot_acc

            def mac_store(_):
                acc = alu.word_macc(mac_acc[None], a[None], b[None], sew)[0]
                return mem.at[dest].set(acc), acc, dot_acc

            def dot_init(_):
                acc = alu.word_dot(jnp.int32(0), a, b, sew)
                return mem, mac_acc, acc

            def dot(_):
                acc = alu.word_dot(dot_acc, a, b, sew)
                return mem, mac_acc, acc

            def dot_store(_):
                acc = alu.word_dot(dot_acc, a, b, sew)
                return mem.at[dest].set(acc), mac_acc, acc

            def nop(_):
                return mem, mac_acc, dot_acc

            branches = []
            for o in CaesarOp:
                if o in _BINOP_OF:
                    branches.append(binop_branch(_BINOP_OF[o]))
                elif o == CaesarOp.MAC_INIT:
                    branches.append(mac_init)
                elif o == CaesarOp.MAC:
                    branches.append(mac)
                elif o == CaesarOp.MAC_STORE:
                    branches.append(mac_store)
                elif o == CaesarOp.DOT_INIT:
                    branches.append(dot_init)
                elif o == CaesarOp.DOT:
                    branches.append(dot)
                elif o == CaesarOp.DOT_STORE:
                    branches.append(dot_store)
                else:  # CSRW (handled at stream boundaries) and NOP (true
                    branches.append(nop)  # no-op: bucket padding, bit-exact)
            return jax.lax.switch(op, branches, None), jnp.int32(0)

        mem = jnp.asarray(mem, jnp.int32)
        carry0 = (mem, jnp.int32(0), jnp.int32(0))
        (mem, mac_acc, dot_acc), _ = jax.lax.scan(step, carry0, stream)
        return mem, mac_acc, dot_acc
