"""Cycle-accurate timing models for NM-Caesar, NM-Carus and the CPU baseline.

The models are *mechanistic*: cycle counts are derived from the actual
instruction streams/traces produced by :mod:`repro.core.programs` using the
microarchitectural rules of the paper (Sections III-A2 and III-B2), with the
constants documented in :mod:`repro.core.constants`.  They are validated
against every relative claim in Table V / Table VIII / Fig. 12 in
``benchmarks/table_v.py`` (results in EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import constants as C
from repro.core import isa
from repro.core.caesar import CaesarConfig
from repro.core.carus import _COMPACT, CarusConfig
from repro.core.isa import CaesarOp, VOp
from repro.core.programs import EngineBuild, KernelBuild


@dataclasses.dataclass(frozen=True)
class TimingReport:
    cycles: float            # NMC-engine cycles (incl. kernel overhead)
    host_cycles: float       # host-CPU / eCPU-serial cycles (e.g. h-pooling)
    n_instrs: int
    detail: dict

    @property
    def total_cycles(self) -> float:
        return self.cycles + self.host_cycles

    def seconds(self, f_hz: float = C.F_CLK_BENCH_HZ) -> float:
        return self.total_cycles / f_hz


# ---------------------------------------------------------------------------
# NM-Caesar
# ---------------------------------------------------------------------------

def caesar_cycles(eb: EngineBuild, cfg: CaesarConfig | None = None) -> TimingReport:
    cfg = cfg or CaesarConfig()
    cycles = C.CAESAR_OFFLOAD_CYCLES
    same_bank = 0
    for (op, dest, s1, s2) in eb.stream:
        if cfg.bank_of(s1) == cfg.bank_of(s2):
            cycles += C.CAESAR_SAME_BANK_CYCLES
            same_bank += 1
        else:
            cycles += C.CAESAR_CYCLES_PER_OP
    return TimingReport(cycles, eb.host_cycles, len(eb.stream),
                        {"same_bank_ops": same_bank})


# ---------------------------------------------------------------------------
# NM-Carus
# ---------------------------------------------------------------------------

def _port_accesses(vop: VOp, mode: int) -> int:
    """VRF bank-port words touched per result word (single-port banks)."""
    opmode = mode & 0x3
    if vop == VOp.VMACC:
        return 4 if opmode == isa.MODE_VV else 3   # reads vd + srcs, writes vd
    if vop == VOp.VMV:
        return 1 if opmode != isa.MODE_VV else 2   # splat: write-only
    if vop in (VOp.VSLIDEUP, VOp.VSLIDEDOWN):
        return 2
    if opmode == isa.MODE_VV:
        return 3
    return 2                                        # vx / vi


def carus_cycles(eb: EngineBuild, sew: int,
                 cfg: CarusConfig | None = None) -> TimingReport:
    cfg = cfg or CarusConfig()
    vl = cfg.vlmax(sew)
    cycles = float(C.CARUS_KERNEL_OVERHEAD_CYCLES)
    busy = 0.0
    for e in eb.stream:
        vop = _COMPACT[int(e["op"])]
        mode = int(e["mode"])
        if vop == VOp.VSETVL:
            vl = min(int(e["sval1"]), cfg.vlmax(sew))
            cycles += 1
            continue
        if vop in (VOp.EMVV, VOp.EMVX):
            cycles += C.CARUS_ISSUE_CYCLES   # overlapped with in-flight vector
            continue
        tclass = isa.VOP_TIMING_CLASS[vop]
        alu_w = C.CARUS_ALU_WORD_CYCLES[tclass][sew]
        port_w = _port_accesses(vop, mode)
        words_per_lane = math.ceil(math.ceil(vl * sew / 32) / cfg.n_lanes)
        instr_cycles = max(alu_w, port_w) * words_per_lane
        cycles += max(instr_cycles, C.CARUS_ISSUE_CYCLES)
        busy += instr_cycles
    return TimingReport(cycles, eb.host_cycles, len(eb.stream),
                        {"vector_busy": busy})


def carus_vrf_accesses(eb: EngineBuild, sew: int,
                       cfg: CarusConfig | None = None) -> int:
    """Total VRF word accesses of a trace (drives the energy model)."""
    cfg = cfg or CarusConfig()
    vl = cfg.vlmax(sew)
    acc = 0
    for e in eb.stream:
        vop = _COMPACT[int(e["op"])]
        if vop == VOp.VSETVL:
            vl = min(int(e["sval1"]), cfg.vlmax(sew))
            continue
        if vop in (VOp.EMVV, VOp.EMVX):
            acc += 1
            continue
        words = math.ceil(vl * sew / 32)
        acc += _port_accesses(vop, int(e["mode"])) * words
    return acc


# ---------------------------------------------------------------------------
# CPU baseline (RV32IMC, Table V measurements)
# ---------------------------------------------------------------------------

def cpu_cycles(kernel: str, sew: int, n_outputs: int) -> TimingReport:
    cyc = C.CPU_CYCLES_PER_OUTPUT[kernel][sew] * n_outputs
    return TimingReport(0.0, cyc, 0, {"model": "table_v"})


def kernel_timing(kb: KernelBuild) -> dict[str, TimingReport]:
    """Timing for all three execution targets of a KernelBuild."""
    name = kb.name
    out = {
        "cpu": cpu_cycles(name, kb.sew, kb.n_outputs),
        "caesar": caesar_cycles(kb.caesar),
        "carus": carus_cycles(kb.carus, kb.sew),
    }
    return out
