"""Cycle-accurate timing models for NM-Caesar, NM-Carus and the CPU baseline.

The models are *mechanistic*: cycle counts are derived from the actual
instruction streams/traces produced by :mod:`repro.core.programs` using the
microarchitectural rules of the paper (Sections III-A2 and III-B2), with the
constants documented in :mod:`repro.core.constants`.  They are validated
against every relative claim in Table V / Table VIII / Fig. 12 in
``benchmarks/table_v.py`` (results in EXPERIMENTS.md §Paper-validation).

Since the unified-IR refactor (DESIGN.md §5) both engines are costed through
one entry point, :func:`program_cycles`, which walks a
:class:`repro.nmc.program.Program`'s structured-array entries; the legacy
``caesar_cycles`` / ``carus_cycles`` signatures survive as thin wrappers over
it (they accept both IR-emitting builds and hand-rolled legacy streams).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import constants as C
from repro.core import isa
from repro.core.caesar import CaesarConfig
from repro.core.carus import CarusConfig
from repro.core.isa import VOp
from repro.nmc.program import Program


@dataclasses.dataclass(frozen=True)
class TimingReport:
    cycles: float            # NMC-engine cycles (incl. kernel overhead)
    host_cycles: float       # host-CPU / eCPU-serial cycles (e.g. h-pooling)
    n_instrs: int
    detail: dict

    @property
    def total_cycles(self) -> float:
        return self.cycles + self.host_cycles

    def seconds(self, f_hz: float = C.F_CLK_BENCH_HZ) -> float:
        return self.total_cycles / f_hz


# ---------------------------------------------------------------------------
# Unified IR costing — one code path for both engines
# ---------------------------------------------------------------------------

def program_cycles(prog: Program, host_cycles: float = 0.0,
                   cfg=None) -> TimingReport:
    """Cost a unified-IR program with the engine's microarchitectural rules."""
    if prog.engine == "caesar":
        return _caesar_program_cycles(prog, host_cycles,
                                      cfg or CaesarConfig())
    return _carus_program_cycles(prog, host_cycles, cfg or CarusConfig())


def _caesar_program_cycles(prog: Program, host_cycles: float,
                           cfg: CaesarConfig) -> TimingReport:
    # Section III-A2: one op per 2 cycles sustained when the operands sit in
    # opposite banks; +1 serialized-fetch cycle when they collide.  Padding
    # NOPs (bucketed scheduler) are zero-cost: the DMA stream simply ends.
    e = prog.entries
    e = e[e["op"] != int(isa.CaesarOp.NOP)]
    same = int(np.count_nonzero(e["src1"] // cfg.bank_words
                                == e["src2"] // cfg.bank_words))
    cycles = (C.CAESAR_OFFLOAD_CYCLES + same * C.CAESAR_SAME_BANK_CYCLES
              + (len(e) - same) * C.CAESAR_CYCLES_PER_OP)
    return TimingReport(float(cycles), host_cycles, len(e),
                        {"same_bank_ops": same})


def _port_accesses(vop: VOp, mode: int) -> int:
    """VRF bank-port words touched per result word (single-port banks)."""
    opmode = mode & 0x3
    if vop == VOp.VMACC:
        return 4 if opmode == isa.MODE_VV else 3   # reads vd + srcs, writes vd
    if vop == VOp.VMV:
        return 1 if opmode != isa.MODE_VV else 2   # splat: write-only
    if vop in (VOp.VSLIDEUP, VOp.VSLIDEDOWN):
        return 2
    if opmode == isa.MODE_VV:
        return 3
    return 2                                        # vx / vi

def _carus_walk(prog: Program, cfg: CarusConfig):
    """Yield (vop, mode, vl) per entry, tracking the dynamic VL carry."""
    vl = cfg.vlmax(prog.sew)
    for op, sval1, mode in zip(prog.entries["op"], prog.entries["sval1"],
                               prog.entries["mode"]):
        vop = isa.VOP_COMPACT[int(op)]
        if vop == VOp.VSETVL:
            vl = min(int(sval1), cfg.vlmax(prog.sew))
        yield vop, int(mode), vl


def _carus_program_cycles(prog: Program, host_cycles: float,
                          cfg: CarusConfig) -> TimingReport:
    sew = prog.sew
    cycles = float(C.CARUS_KERNEL_OVERHEAD_CYCLES)
    busy = 0.0
    for vop, mode, vl in _carus_walk(prog, cfg):
        if vop == VOp.VNOP:
            continue                     # padding: never issued, zero cost
        if vop == VOp.VSETVL:
            cycles += 1
            continue
        if vop in (VOp.EMVV, VOp.EMVX):
            cycles += C.CARUS_ISSUE_CYCLES   # overlapped with in-flight vector
            continue
        tclass = isa.VOP_TIMING_CLASS[vop]
        alu_w = C.CARUS_ALU_WORD_CYCLES[tclass][sew]
        port_w = _port_accesses(vop, mode)
        words_per_lane = math.ceil(math.ceil(vl * sew / 32) / cfg.n_lanes)
        instr_cycles = max(alu_w, port_w) * words_per_lane
        cycles += max(instr_cycles, C.CARUS_ISSUE_CYCLES)
        busy += instr_cycles
    return TimingReport(cycles, host_cycles, prog.n_instr - prog.n_nops,
                        {"vector_busy": busy})


def program_vrf_accesses(prog: Program, cfg: CarusConfig | None = None) -> int:
    """Total VRF word accesses of a Carus program (drives the energy model)."""
    assert prog.engine == "carus", prog.engine
    cfg = cfg or CarusConfig()
    acc = 0
    for vop, mode, vl in _carus_walk(prog, cfg):
        if vop in (VOp.VSETVL, VOp.VNOP):
            continue
        if vop in (VOp.EMVV, VOp.EMVX):
            acc += 1
            continue
        acc += _port_accesses(vop, mode) * math.ceil(vl * prog.sew / 32)
    return acc


def _program_of(eb, engine: str, sew: int) -> Program:
    """IR program of an EngineBuild; accepts hand-built streams too.

    Untagged builds (``eb.engine`` unset) can hold any entry format —
    legacy tuples, legacy CARUS_TRACE_DTYPE scalars, or raw PROG_DTYPE
    entries — so the caller's engine knowledge is passed through rather
    than relying on the build's own (auto-detecting) ``program`` property.
    """
    if getattr(eb, "engine", ""):
        prog = eb.program
    else:
        prog = Program.from_legacy(getattr(eb, "stream", eb), sew, engine)
    assert prog.engine == engine, (prog.engine, engine)
    return prog.with_sew(sew)


# ---------------------------------------------------------------------------
# Legacy per-engine entry points (thin wrappers over the IR path)
# ---------------------------------------------------------------------------

def caesar_cycles(eb, cfg: CaesarConfig | None = None) -> TimingReport:
    prog = _program_of(eb, "caesar", getattr(eb, "sew", 0) or 32)
    return program_cycles(prog, eb.host_cycles, cfg)


def carus_cycles(eb, sew: int, cfg: CarusConfig | None = None) -> TimingReport:
    return program_cycles(_program_of(eb, "carus", sew), eb.host_cycles, cfg)


def carus_vrf_accesses(eb, sew: int, cfg: CarusConfig | None = None) -> int:
    return program_vrf_accesses(_program_of(eb, "carus", sew), cfg)


# ---------------------------------------------------------------------------
# Dispatch-pipeline cost model: serial vs overlapped (double-buffered) DMA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageCost:
    """One dispatch stage of the host-orchestration pipeline: DMA the image
    in (memory-mode write), run the program (compute mode), DMA the result
    out (memory-mode read).  Cycle legs are modeled independently so the
    scheduler modes below can serialize or overlap them."""

    name: str
    dma_in_cycles: float
    compute_cycles: float
    dma_out_cycles: float

    @property
    def serial_cycles(self) -> float:
        return self.dma_in_cycles + self.compute_cycles + self.dma_out_cycles


def dma_cycles(n_bytes: int) -> float:
    """Streaming host<->tile transfer cost on the 32-bit system bus."""
    return float(n_bytes) / C.DMA_BYTES_PER_CYCLE


def stage_cost(eb, name: str = "") -> StageCost:
    """StageCost of one (engine-tagged) EngineBuild: image load, modeled
    program cycles (incl. host-side work), result-slice store.

    Builds that record their allocator high-water (``used_words`` on
    :class:`repro.nmc.frontend.LoweredKernel`) are charged only for the
    words the tile image actually occupies — partitioned shards DMA their
    slice of the data, not the whole 32 KiB macro.  Legacy builds without
    the attribute keep the full-image cost."""
    prog = eb.program
    rep = program_cycles(prog, eb.host_cycles)
    words = int(getattr(eb, "used_words", 0)) \
        or int(np.asarray(eb.mem).size)
    return StageCost(
        name or f"{prog.engine}/{prog.sew}",
        dma_in_cycles=dma_cycles(words * C.WORD_BYTES),
        compute_cycles=rep.total_cycles,
        dma_out_cycles=dma_cycles(int(eb.out_slice[1]) * C.WORD_BYTES))


def dispatch_cycles(stages: list[StageCost], mode: str = "serial") -> float:
    """Total cycles to run a sequence of dispatch stages.

    ``"serial"`` is the synchronous baseline: every leg fully serializes,
    so the total is ``sum(dma_in + compute + dma_out)`` — what a blocking
    ``load -> dispatch -> store`` loop costs.

    ``"overlapped"`` models the double-buffered runtime
    (:class:`repro.nmc.runtime.DispatchQueue`): one DMA engine and one
    compute engine run concurrently, the DMA engine streams stage ``i+1``'s
    image into the shadow buffer while stage ``i`` computes, and stores
    drain between loads.  In steady state each stage therefore costs
    ``max(dma, compute)`` instead of their sum; only the first load and the
    last compute/store are exposed.  The makespan is computed by walking
    the two resource timelines with the DMA queue ordered
    ``load_0, load_1, store_0, load_2, store_1, ...`` (load-ahead depth 2 =
    double buffering); it is always <= the serial total, and strictly less
    whenever two adjacent stages have work to overlap.
    """
    assert mode in ("serial", "overlapped"), mode
    if not stages:
        return 0.0
    if mode == "serial":
        return sum(s.serial_cycles for s in stages)
    dma_free = 0.0                  # DMA engine timeline
    comp_free = 0.0                 # compute engine timeline
    comp_end: list[float] = []
    for i, s in enumerate(stages):
        # load stage i into the shadow buffer (DMA serializes on the bus)
        load_end = dma_free + s.dma_in_cycles
        dma_free = load_end
        # compute stage i once its image is in and the engine is free
        comp_free = max(load_end, comp_free) + s.compute_cycles
        comp_end.append(comp_free)
        # store stage i-1 (its compute is done; next load already issued)
        if i >= 1:
            dma_free = max(dma_free, comp_end[i - 1]) \
                + stages[i - 1].dma_out_cycles
    dma_free = max(dma_free, comp_end[-1]) + stages[-1].dma_out_cycles
    return max(dma_free, comp_free)


def sweep_dispatch_cycles(builds: list, mode: str = "serial") -> float:
    """dispatch_cycles over a list of engine-tagged EngineBuilds."""
    return dispatch_cycles([stage_cost(eb) for eb in builds], mode)


# ---------------------------------------------------------------------------
# Multi-tile system cost model: one shared bus, N overlapped tiles
# ---------------------------------------------------------------------------

#: Stage -> tile placement policies of the multi-tile wave models:
#: ``"roundrobin"`` pins stage ``i`` to tile ``i % n_tiles`` (the dispatch
#: order the runtime uses), ``"greedy"`` places each stage on the
#: least-loaded tile at its DMA-arrival time (a free tile never idles
#: behind a busy one just because of its index).
ASSIGN_MODES = ("roundrobin", "greedy")


def _place_stage(i: int, tile_free: list, assign: str) -> int:
    """Tile index for stage ``i`` under the given placement policy."""
    if assign == "roundrobin":
        return i % len(tile_free)
    # greedy: earliest-free tile; ties resolve to the lowest index, so the
    # policy is deterministic and degenerates to roundrobin on fresh tiles
    return min(range(len(tile_free)), key=lambda t: (tile_free[t], t))


def chained_wave_cycles(waves: list[list[StageCost]], n_tiles: int,
                        assign: str = "roundrobin") -> float:
    """Makespan of a *chain* of dependent partitioned waves on one
    ``n_tiles`` array — the resident-block serving shape (DESIGN.md §12):
    wave ``w+1`` consumes wave ``w``'s outputs, so its input DMA cannot
    start until the previous wave's result slices have drained over the
    shared system bus (the tile-to-tile activation hop), while the bus and
    per-tile compute timelines carry over between waves instead of
    resetting.

    The model is the same N+1-resource system as :func:`wave_cycles`
    (one serialized 32-bit bus, N independent tile engines); chaining just
    keeps the timelines hot across waves.  Consequences the tests lock:

    * one wave degenerates to ``wave_cycles(stages, n_tiles)`` exactly;
    * the chain is never cheaper than its longest wave, and never costs
      more than running the waves back-to-back with cold timelines
      (``sum(wave_cycles(w, n) for w in waves)``).

    ``assign`` picks the stage->tile placement (:data:`ASSIGN_MODES`):
    ``"roundrobin"`` pins stage ``i`` to tile ``i % n_tiles``;
    ``"greedy"`` places each stage on the least-loaded tile at its
    DMA-arrival time — never worse than roundrobin when stages outnumber
    tiles, identical when they don't (each stage gets a fresh tile).
    """
    n_tiles = int(n_tiles)
    assert n_tiles >= 1, n_tiles
    assert assign in ASSIGN_MODES, assign
    bus = 0.0                          # shared system-bus timeline
    tile_free = [0.0] * n_tiles        # per-tile compute timelines
    for stages in waves:
        comp_end: list[float] = []
        for i, s in enumerate(stages):     # images/patches stream in
            t = _place_stage(i, tile_free, assign)
            bus += s.dma_in_cycles
            tile_free[t] = max(bus, tile_free[t]) + s.compute_cycles
            comp_end.append(tile_free[t])
        for i, s in enumerate(stages):     # outputs drain: the activation
            bus = max(bus, comp_end[i]) + s.dma_out_cycles   # hop the next
    return max(bus, max(tile_free))        # wave's input DMA waits behind


def wave_cycles(stages, n_tiles: int,
                mode: str = "overlapped",
                assign: str = "roundrobin") -> float:
    """Makespan of one partitioned wave on an ``n_tiles`` tile array.

    The paper's edge-node topology hangs every tile's SRAM macro off one
    32-bit system bus (``constants.SYS_BUS_BYTES_PER_CYCLE``), so the
    model has N + 1 resources: the shared bus serializes **every** DMA leg
    (stage images stream in submission order, result slices drain after
    their compute), while each tile's compute engine runs independently —
    stage ``i`` executes on tile ``i % n_tiles`` as soon as its image has
    landed and the tile is free.

    ``"serial"`` is the single-tile synchronous reference (every leg
    serializes: ``sum(dma_in + compute + dma_out)``), so
    ``wave_cycles(stages, 1, "serial") / wave_cycles(shards, N)`` is the
    modeled wave speedup of a partitioned kernel.  The overlapped makespan
    reproduces the paper's system-level scaling shape: speedup grows with
    N while per-tile compute dominates and saturates once the serialized
    bus stream binds (adding tiles then only adds queued DMA).

    ``"chained"`` accepts a list of *waves* (each a list of StageCosts)
    and delegates to :func:`chained_wave_cycles` — the cost of dependent
    back-to-back waves whose activations hop tile-to-tile over the bus.

    ``assign`` picks the stage->tile placement (:data:`ASSIGN_MODES`):
    ``"roundrobin"`` (default) models the runtime's dispatch order,
    ``"greedy"`` the least-loaded placement a work-stealing host would
    use — the two differ only when stages outnumber tiles.
    """
    assert mode in ("serial", "overlapped", "chained"), mode
    if mode == "chained":
        return chained_wave_cycles(stages, n_tiles, assign=assign)
    n_tiles = int(n_tiles)
    assert n_tiles >= 1, n_tiles
    assert assign in ASSIGN_MODES, assign
    if not stages:
        return 0.0
    if mode == "serial":
        return sum(s.serial_cycles for s in stages)
    bus = 0.0                          # shared system-bus timeline
    tile_free = [0.0] * n_tiles        # per-tile compute timelines
    comp_end: list[float] = []
    for i, s in enumerate(stages):     # images stream in, bus-serialized
        t = _place_stage(i, tile_free, assign)
        bus += s.dma_in_cycles
        tile_free[t] = max(bus, tile_free[t]) + s.compute_cycles
        comp_end.append(tile_free[t])
    for i, s in enumerate(stages):     # result slices drain, bus-serialized
        bus = max(bus, comp_end[i]) + s.dma_out_cycles
    return max(bus, max(tile_free))


def wave_speedup(single: StageCost, shards: list[StageCost],
                 n_tiles: int) -> float:
    """Modeled speedup of a partitioned wave over its unsharded single-tile
    dispatch (both through the same two-resource bus/compute model)."""
    return wave_cycles([single], 1) / wave_cycles(shards, n_tiles)


# ---------------------------------------------------------------------------
# CPU baseline (RV32IMC, Table V measurements)
# ---------------------------------------------------------------------------

def cpu_cycles(kernel: str, sew: int, n_outputs: int) -> TimingReport:
    cyc = C.CPU_CYCLES_PER_OUTPUT[kernel][sew] * n_outputs
    return TimingReport(0.0, cyc, 0, {"model": "table_v"})


def kernel_timing(kb) -> dict[str, TimingReport]:
    """Timing for all three execution targets of a KernelBuild."""
    return {
        "cpu": cpu_cycles(kb.name, kb.sew, kb.n_outputs),
        "caesar": caesar_cycles(kb.caesar),
        "carus": carus_cycles(kb.carus, kb.sew),
    }
