"""The paper's core: NMC functional simulators, ISA, timing & energy models.

Layer A of DESIGN.md — the faithful reproduction of NM-Caesar / NM-Carus.
Engine programs are represented in the unified IR of :mod:`repro.nmc`
(DESIGN.md §5); the builders in :mod:`repro.core.programs` emit it and the
timing/energy models cost it through one code path.
"""

from repro.core import alu, constants, isa
from repro.core.caesar import CaesarConfig, CaesarEngine, stream_to_arrays
from repro.core.carus import CarusConfig, CarusVPU, trace_entry, trace_to_arrays
from repro.core.ecpu import ECpu, assemble

__all__ = [
    "alu", "constants", "isa",
    "CaesarConfig", "CaesarEngine", "stream_to_arrays",
    "CarusConfig", "CarusVPU", "trace_entry", "trace_to_arrays",
    "ECpu", "assemble",
]
