"""NM-Carus embedded controller: an RV32E interpreter + a tiny assembler.

The paper's eCPU is an OpenHW CV32E40X configured as RV32EC (16 GPRs, no
hardware mul/div) that offloads ``xvnmc`` instructions to the VPU over the
CORE-V X interface.  This module provides:

* :class:`ECpu` — an instruction-accurate RV32E interpreter executing real
  32-bit RISC-V words from an eMEM image.  ``xvnmc`` (Custom-2) instructions
  are decoded and dispatched to a :class:`repro.core.carus.CarusVPU`
  *eagerly*, while also being appended to a unified-IR issue trace
  (:mod:`repro.nmc.program` entries), so the exact same kernel can later be
  replayed through the scanned VPU executor — or batched across tiles by
  :class:`repro.nmc.pool.TilePool` — and costed by :mod:`repro.core.timing`
  via :meth:`ECpu.program`.
* :func:`assemble` — a minimal assembler for the supported subset (enough to
  write the paper's kernel-driver loops, e.g. the indirect-addressing loop of
  Section III-B1).

This is a correctness/programmability model, not a performance model: timing
is derived from the issue trace by :mod:`repro.core.timing`.
"""

from __future__ import annotations

import numpy as np

from repro.core import carus as carus_mod
from repro.core import isa
from repro.core.isa import F3, VOp

N_GPRS = 16  # RV32E


def _ir():
    # Deferred: repro.nmc.program imports repro.core, which imports this
    # module — a top-level import here would close that cycle.
    from repro.nmc import program as nmc_program
    return nmc_program


def _sx(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v & (1 << (bits - 1)) else v


def _u32(v: int) -> int:
    return v & 0xFFFFFFFF


def _i32(v: int) -> int:
    return _sx(v, 32)


class ECpu:
    """RV32E + xvnmc interpreter over a byte-addressable eMEM."""

    def __init__(self, vpu: carus_mod.CarusVPU, vrf, emem_bytes: int = 4096,
                 sew: int = 32):
        self.vpu = vpu
        self.vrf = vrf          # jax array (n_regs, reg_words)
        self.emem = np.zeros(emem_bytes, dtype=np.uint8)
        self.x = [0] * N_GPRS
        self.pc = 0
        self.sew = sew
        self.vl = vpu.cfg.vlmax(sew)
        self.issue_trace: list[np.ndarray] = []   # unified-IR entries
        self.scalar_retired = 0
        self.vector_retired = 0

    def program(self):
        """The issue trace as a unified-IR Program (replayable / costable)."""
        return _ir().Program.from_entries("carus", self.sew, self.issue_trace)

    # -- memory helpers -----------------------------------------------------
    def load_program(self, words: list[int], base: int = 0) -> None:
        for i, w in enumerate(words):
            self.emem[base + 4 * i: base + 4 * i + 4] = \
                np.frombuffer(int(w).to_bytes(4, "little"), dtype=np.uint8)
        self.pc = base

    def _lw(self, addr: int) -> int:
        return _i32(int.from_bytes(self.emem[addr:addr + 4].tobytes(), "little"))

    def _sw(self, addr: int, val: int) -> None:
        self.emem[addr:addr + 4] = np.frombuffer(
            _u32(val).to_bytes(4, "little"), dtype=np.uint8)

    def _set(self, rd: int, val: int) -> None:
        if rd != 0:
            self.x[rd] = _i32(val)

    # -- execution ----------------------------------------------------------
    def run(self, max_steps: int = 200_000) -> None:
        for _ in range(max_steps):
            word = _u32(self._lw(self.pc))
            if word == 0x0000006F:   # `j .` — halt convention
                return
            self.step(word)
        raise RuntimeError("eCPU did not halt within max_steps")

    def step(self, word: int) -> None:
        op = word & 0x7F
        rd = (word >> 7) & 0x1F
        f3 = (word >> 12) & 0x7
        rs1 = (word >> 15) & 0x1F
        rs2 = (word >> 20) & 0x1F
        f7 = (word >> 25) & 0x7F
        next_pc = self.pc + 4
        X = self.x

        if op == 0x37:      # LUI
            self._set(rd, word & 0xFFFFF000)
        elif op == 0x17:    # AUIPC
            self._set(rd, self.pc + _sx(word & 0xFFFFF000, 32))
        elif op == 0x6F:    # JAL
            imm = (((word >> 31) & 1) << 20) | (((word >> 12) & 0xFF) << 12) \
                | (((word >> 20) & 1) << 11) | (((word >> 21) & 0x3FF) << 1)
            self._set(rd, next_pc)
            next_pc = self.pc + _sx(imm, 21)
        elif op == 0x67:    # JALR
            t = (X[rs1] + _sx(word >> 20, 12)) & ~1
            self._set(rd, next_pc)
            next_pc = _u32(t)
        elif op == 0x63:    # branches
            imm = (((word >> 31) & 1) << 12) | (((word >> 7) & 1) << 11) \
                | (((word >> 25) & 0x3F) << 5) | (((word >> 8) & 0xF) << 1)
            off = _sx(imm, 13)
            a, b = X[rs1], X[rs2]
            ua, ub = _u32(a), _u32(b)
            taken = {0: a == b, 1: a != b, 4: a < b, 5: a >= b,
                     6: ua < ub, 7: ua >= ub}[f3]
            if taken:
                next_pc = self.pc + off
        elif op == 0x03:    # loads
            addr = _u32(X[rs1] + _sx(word >> 20, 12))
            w = self._lw(addr & ~3)
            sh = (addr & 3) * 8
            if f3 == 0:   self._set(rd, _sx(w >> sh, 8))       # LB
            elif f3 == 1: self._set(rd, _sx(w >> sh, 16))      # LH
            elif f3 == 2: self._set(rd, w)                     # LW
            elif f3 == 4: self._set(rd, (w >> sh) & 0xFF)      # LBU
            elif f3 == 5: self._set(rd, (w >> sh) & 0xFFFF)    # LHU
        elif op == 0x23:    # stores
            imm = ((word >> 25) << 5) | rd
            addr = _u32(X[rs1] + _sx(imm, 12))
            if f3 == 2:
                self._sw(addr, X[rs2])
            else:
                n = 1 if f3 == 0 else 2
                self.emem[addr:addr + n] = np.frombuffer(
                    _u32(X[rs2]).to_bytes(4, "little")[:n], dtype=np.uint8)
        elif op == 0x13:    # op-imm
            imm = _sx(word >> 20, 12)
            sh = (word >> 20) & 0x1F
            r = {0: X[rs1] + imm,
                 2: int(X[rs1] < imm),
                 3: int(_u32(X[rs1]) < _u32(imm)),
                 4: X[rs1] ^ imm, 6: X[rs1] | imm, 7: X[rs1] & imm,
                 1: X[rs1] << sh,
                 5: (_u32(X[rs1]) >> sh) if f7 == 0 else (X[rs1] >> sh)}[f3]
            self._set(rd, r)
        elif op == 0x33:    # op
            a, b = X[rs1], X[rs2]
            sh = b & 31
            if f3 == 0:
                r = a - b if f7 == 0x20 else a + b
            elif f3 == 1: r = a << sh
            elif f3 == 2: r = int(a < b)
            elif f3 == 3: r = int(_u32(a) < _u32(b))
            elif f3 == 4: r = a ^ b
            elif f3 == 5: r = (_u32(a) >> sh) if f7 == 0 else (a >> sh)
            elif f3 == 6: r = a | b
            else:         r = a & b
            self._set(rd, r)
        elif op == isa.XVNMC_OPCODE:
            self._exec_xvnmc(word)
            self.vector_retired += 1
            self.pc = next_pc
            return
        else:
            raise ValueError(f"unsupported opcode {op:#x} at pc={self.pc:#x}")
        self.scalar_retired += 1
        self.pc = next_pc

    # -- xvnmc offload --------------------------------------------------------
    def _exec_xvnmc(self, word: int) -> None:
        d = isa.xvnmc_decode(word)
        f6 = d.funct6

        if d.funct3 == F3.OPCFG:     # vsetvl: vl = min(x[rs1], VLMAX(sew))
            sew = 8 << d.vs2_f
            self.sew = sew
            avl = self.x[d.vs1_f]
            self.vl = min(avl, self.vpu.cfg.vlmax(sew))
            self._set(d.vd_f, self.vl)
            self.issue_trace.append(_ir().carus_entry(
                VOp.VSETVL, sval1=avl))
            self._replay_last()
            return

        if f6 == VOp.EMVX:
            e = _ir().carus_entry(VOp.EMVX, vs2=d.vs2_f,
                                  sval1=self.x[d.vs1_f])
            self.issue_trace.append(e)
            out = self._replay_last()
            self._set(d.vd_f, int(out))
            return
        if f6 == VOp.EMVV:
            e = _ir().carus_entry(VOp.EMVV, vd=d.vd_f,
                                  sval1=self.x[d.vs1_f],
                                  sval2=self.x[d.vs2_f])
            self.issue_trace.append(e)
            self._replay_last()
            return

        mode = {F3.OPIVV: isa.MODE_VV, F3.OPIVX: isa.MODE_VX,
                F3.OPIVI: isa.MODE_VI, F3.OPMVX: isa.MODE_VX}[F3(d.funct3)]
        if d.indirect:
            mode |= isa.MODE_INDIRECT
        slide1 = d.funct3 == F3.OPMVX and f6 in (VOp.VSLIDEUP, VOp.VSLIDEDOWN)
        if slide1:
            mode |= isa.MODE_SLIDE1
        sval1 = self.x[d.vs1_f] if mode & 0x3 != isa.MODE_VI else 0
        imm = _sx(d.vs1_f, 5) if mode & 0x3 == isa.MODE_VI else 0
        # In indirect mode the vs2 field names the GPR carrying the indices.
        sval2 = self.x[d.vs2_f] if d.indirect else 0
        e = _ir().carus_entry(VOp(f6), vd=d.vd_f, vs1=d.vs1_f,
                              vs2=d.vs2_f, sval1=sval1, sval2=sval2,
                              imm=imm, mode=mode)
        self.issue_trace.append(e)
        self._replay_last()

    def _replay_last(self):
        prog = _ir().Program.from_entries("carus", self.sew,
                                          [self.issue_trace[-1]])
        self.vrf, vl, outs = self.vpu.run_program(self.vrf, prog,
                                                  vl0=self.vl)
        self.vl = int(vl)
        return outs[0]


# ---------------------------------------------------------------------------
# Minimal assembler (subset used by the demo kernels and tests)
# ---------------------------------------------------------------------------

_REGS = {f"x{i}": i for i in range(32)}
_REGS.update({"zero": 0, "ra": 1, "sp": 2, "gp": 3, "tp": 4, "t0": 5,
              "t1": 6, "t2": 7, "s0": 8, "s1": 9, "a0": 10, "a1": 11,
              "a2": 12, "a3": 13, "a4": 14, "a5": 15})
_VREGS = {f"v{i}": i for i in range(32)}


def _enc_i(op, rd, f3, rs1, imm):
    return _u32((imm & 0xFFF) << 20 | rs1 << 15 | f3 << 12 | rd << 7 | op)


def _enc_r(f7, rs2, rs1, f3, rd, op):
    return _u32(f7 << 25 | rs2 << 20 | rs1 << 15 | f3 << 12 | rd << 7 | op)


def _enc_b(f3, rs1, rs2, off):
    imm = off & 0x1FFF
    return _u32((((imm >> 12) & 1) << 31) | (((imm >> 5) & 0x3F) << 25)
                | (rs2 << 20) | (rs1 << 15) | (f3 << 12)
                | (((imm >> 1) & 0xF) << 8) | (((imm >> 11) & 1) << 7) | 0x63)


def assemble(src: str) -> list[int]:
    """Two-pass assembler for the supported RV32E + xvnmc subset."""
    lines = []
    for raw in src.splitlines():
        line = raw.split("#")[0].strip().replace(",", " ")
        if line:
            lines.append(line)
    def _li_words(line: str) -> int:
        toks = line.split()
        if toks[0] != "li":
            return 1
        imm = int(toks[2], 0)
        return 1 if -2048 <= imm < 2048 else 2   # addi vs lui+addi

    # pass 1: labels
    labels, pc = {}, 0
    for line in lines:
        if line.endswith(":"):
            labels[line[:-1]] = pc
        else:
            pc += 4 * _li_words(line)
    # pass 2
    words, pc = [], 0
    for line in lines:
        if line.endswith(":"):
            continue
        toks = line.split()
        m, args = toks[0], toks[1:]

        def R(i):
            return _REGS[args[i]]

        def V(i):
            return _VREGS[args[i]]

        def IMM(i):
            a = args[i]
            return labels[a] - pc if a in labels else int(a, 0)

        if m == "li":
            imm = IMM(1)
            if -2048 <= imm < 2048:
                words.append(_enc_i(0x13, R(0), 0, 0, imm))          # addi rd,x0
            else:
                upper = (imm + 0x800) >> 12
                words.append(_u32((upper << 12) | (R(0) << 7) | 0x37))  # lui
                words.append(_enc_i(0x13, R(0), 0, R(0), imm - (upper << 12)))
                pc += 4
        elif m == "mv":
            words.append(_enc_i(0x13, R(0), 0, R(1), 0))
        elif m == "addi":
            words.append(_enc_i(0x13, R(0), 0, R(1), IMM(2)))
        elif m == "slli":
            words.append(_enc_i(0x13, R(0), 1, R(1), IMM(2) & 31))
        elif m == "add":
            words.append(_enc_r(0, R(2), R(1), 0, R(0), 0x33))
        elif m == "sub":
            words.append(_enc_r(0x20, R(2), R(1), 0, R(0), 0x33))
        elif m == "lw":
            off, base = args[1].split("(")
            words.append(_enc_i(0x03, R(0), 2, _REGS[base[:-1]], int(off, 0)))
        elif m == "sw":
            off, base = args[1].split("(")
            imm = int(off, 0)
            rs1, rs2 = _REGS[base[:-1]], R(0)
            words.append(_u32(((imm >> 5) << 25) | (rs2 << 20) | (rs1 << 15)
                              | (2 << 12) | ((imm & 0x1F) << 7) | 0x23))
        elif m in ("beq", "bne", "blt", "bge"):
            f3 = {"beq": 0, "bne": 1, "blt": 4, "bge": 5}[m]
            words.append(_enc_b(f3, R(0), R(1), IMM(2)))
        elif m == "j":
            off = IMM(0) & 0x1FFFFF
            words.append(_u32((((off >> 20) & 1) << 31)
                              | (((off >> 1) & 0x3FF) << 21)
                              | (((off >> 11) & 1) << 20)
                              | (((off >> 12) & 0xFF) << 12) | 0x6F))
        elif m == "halt":
            words.append(0x0000006F)                                  # j .
        elif m == "vsetvli":  # vsetvli rd, rs1, e{sew}
            sew = int(args[2][1:])
            words.append(isa.vsetvli_encode(R(0), R(1), sew))
        elif m.startswith("xvnmc."):
            words.append(_asm_xvnmc(m[6:], args))
        else:
            raise ValueError(f"unknown mnemonic {m!r}")
        pc += 4
    return words


_VOP_BY_NAME = {
    "vadd": VOp.VADD, "vsub": VOp.VSUB, "vmul": VOp.VMUL, "vmacc": VOp.VMACC,
    "vand": VOp.VAND, "vor": VOp.VOR, "vxor": VOp.VXOR, "vmin": VOp.VMIN,
    "vminu": VOp.VMINU, "vmax": VOp.VMAX, "vmaxu": VOp.VMAXU,
    "vsll": VOp.VSLL, "vsrl": VOp.VSRL, "vsra": VOp.VSRA, "vmv": VOp.VMV,
    "vslideup": VOp.VSLIDEUP, "vslidedown": VOp.VSLIDEDOWN,
}


def _asm_xvnmc(name: str, args: list[str]) -> int:
    if name == "emvv":      # emvv vd, x_idx, x_val  -> vd[x[idx]] = x[val]
        return isa.xvnmc_encode(isa.VInstr(VOp.EMVV, False,
                                           _REGS[args[1]], _REGS[args[2]],
                                           F3.OPMVX, _VREGS[args[0]]))
    if name == "emvx":      # emvx rd, vs2, x_idx
        return isa.xvnmc_encode(isa.VInstr(VOp.EMVX, False,
                                           _VREGS[args[1]], _REGS[args[2]],
                                           F3.OPMVX, _REGS[args[0]]))
    base, _, var = name.partition(".")
    indirect = base.endswith("r")
    if indirect:
        base = base[:-1]
    vop = _VOP_BY_NAME[base]
    if indirect:
        # xvnmc.vaddr.vv xN  (indices in GPR xN; fields vd/vs1 unused)
        f3 = {"vv": F3.OPIVV, "vx": F3.OPIVX, "vi": F3.OPIVI}[var]
        gpr = _REGS[args[0]]
        vs1 = _REGS[args[1]] if var == "vx" else (
            int(args[1], 0) & 0x1F if var == "vi" else 0)
        return isa.xvnmc_encode(isa.VInstr(vop, True, gpr, vs1, f3, 0))
    if var == "vv":
        return isa.xvnmc_encode(isa.VInstr(vop, False, _VREGS[args[1]],
                                           _VREGS[args[2]], F3.OPIVV,
                                           _VREGS[args[0]]))
    if var == "vx":
        return isa.xvnmc_encode(isa.VInstr(vop, False, _VREGS[args[1]],
                                           _REGS[args[2]], F3.OPIVX,
                                           _VREGS[args[0]]))
    if var == "vi":
        return isa.xvnmc_encode(isa.VInstr(vop, False, _VREGS[args[1]],
                                           int(args[2], 0) & 0x1F, F3.OPIVI,
                                           _VREGS[args[0]]))
    raise ValueError(f"bad xvnmc variant {name}")
