"""NM-Carus functional model: banked VRF + single-issue xvnmc VPU.

The VPU executes *traces* — arrays of issued instructions (scalar GPR operands
already resolved, see :data:`repro.core.isa.CARUS_TRACE_DTYPE`) — inside a
single ``jax.lax.scan``: one dispatch from the host, then the whole kernel runs
"autonomously" against the VRF.  This mirrors the hardware split: the eCPU
(see :mod:`repro.core.ecpu`) produces the issue stream; the VPU consumes it.

Indirect register addressing (the paper's code-size mechanism) is resolved
*inside* the engine from the scalar value's three LSBytes, i.e. register
indices are runtime data — the same scanned instruction template is reused
for arbitrary operand locations, exactly like the hardware.

Functional semantics are element-exact (two's complement, wrap at SEW) via
:mod:`repro.core.alu`.  SEW is static per trace (the paper's kernels configure
the element width once via ``vsetvl``); VL is dynamic carry state.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.isa import VOp


@dataclasses.dataclass(frozen=True)
class CarusConfig:
    n_regs: int = C.CARUS_N_VREGS
    reg_words: int = C.CARUS_REG_WORDS
    n_lanes: int = C.CARUS_N_LANES

    @property
    def mem_words(self) -> int:
        return self.n_regs * self.reg_words

    def vlmax(self, sew: int) -> int:
        return self.reg_words * (32 // sew)


# Compact opcode ids used by the scanned executor (dense for lax.switch).
# Canonical table lives in repro.core.isa; kept as aliases for back-compat.
_COMPACT = list(isa.VOP_COMPACT)
COMPACT_ID = isa.COMPACT_ID
_ARITH_BY_ID = {COMPACT_ID[k]: v for k, v in isa.ARITH_OPS.items()}


def trace_entry(op: VOp, vd=0, vs1=0, vs2=0, sval1=0, sval2=0, imm=0,
                mode=isa.MODE_VV) -> np.ndarray:
    e = np.zeros((), dtype=isa.CARUS_TRACE_DTYPE)
    e["op"] = COMPACT_ID[op]
    e["vd"], e["vs1"], e["vs2"] = vd, vs1, vs2
    e["sval1"], e["sval2"], e["imm"], e["mode"] = (
        np.int32(sval1), np.int32(sval2), np.int32(imm), mode)
    return e


class CarusVPU:
    """Scan-based xvnmc trace executor over a (n_regs, reg_words) int32 VRF."""

    def __init__(self, config: CarusConfig | None = None):
        self.cfg = config or CarusConfig()

    # -- host memory-mode view ------------------------------------------------
    def vrf_from_words(self, words) -> jax.Array:
        """Host address space -> register view (registers are bank-aligned,
        Fig. 6; host word w lives in register w // reg_words)."""
        return jnp.asarray(words, jnp.int32).reshape(
            self.cfg.n_regs, self.cfg.reg_words)

    def words_from_vrf(self, vrf: jax.Array) -> jax.Array:
        return vrf.reshape(-1)

    def run_program(self, vrf: jax.Array, program, vl0=None):
        """Execute a unified-IR :class:`repro.nmc.program.Program`."""
        assert program.engine == "carus", program.engine
        return self.run_trace(vrf, program.lower(), program.sew, vl0=vl0)

    # -- execution -------------------------------------------------------------
    @functools.partial(jax.jit, static_argnames=("self", "sew"))
    def run_trace(self, vrf: jax.Array, trace: dict, sew: int, vl0=None):
        """Execute a trace.  `trace` is a dict of equal-length int32 arrays
        with the CARUS_TRACE_DTYPE fields.  Returns (vrf, vl, emvx_outs)."""
        cfg = self.cfg
        vlmax = cfg.vlmax(sew)
        vl0 = jnp.int32(vlmax if vl0 is None else vl0)
        L = 32 // sew
        n_elems = cfg.reg_words * L
        elem_ids = jnp.arange(n_elems, dtype=jnp.int32)

        def read_reg(vrf, idx):
            return jax.lax.dynamic_index_in_dim(vrf, idx, axis=0,
                                                keepdims=False)

        def elems(reg_words):
            return alu.unpack(reg_words, sew).reshape(-1)

        def write_back(vrf, vd, old_words, new_elems, vl):
            """VL-masked (tail-undisturbed) writeback of element vector."""
            old_elems = elems(old_words)
            sel = jnp.where(elem_ids < vl, new_elems, old_elems)
            packed = alu.pack(sel.reshape(cfg.reg_words, L), sew)
            return jax.lax.dynamic_update_index_in_dim(vrf, packed, vd, axis=0)

        def step(carry, tr):
            vrf, vl = carry
            op, vd_f, vs1_f, vs2_f = tr["op"], tr["vd"], tr["vs1"], tr["vs2"]
            sval1, sval2, imm, mode = (tr["sval1"], tr["sval2"], tr["imm"],
                                       tr["mode"])
            indirect = (mode & isa.MODE_INDIRECT) != 0
            slide1 = (mode & isa.MODE_SLIDE1) != 0
            opmode = mode & 0x3
            # Indirect register addressing: indices from sval2's LSBytes.
            vd = jnp.where(indirect, (sval2 >> 16) & 0xFF, vd_f) % cfg.n_regs
            vs2 = jnp.where(indirect, (sval2 >> 8) & 0xFF, vs2_f) % cfg.n_regs
            vs1 = jnp.where(indirect, sval2 & 0xFF, vs1_f) % cfg.n_regs

            dst_w = read_reg(vrf, vd)
            s2_w = read_reg(vrf, vs2)
            s1_w = read_reg(vrf, vs1)
            dst_e, s2_e, s1_reg_e = elems(dst_w), elems(s2_w), elems(s1_w)
            scalar_b = jnp.where(opmode == isa.MODE_VI, imm, sval1)
            # operand-1 elements: vs1 register (vv) or splat scalar/imm
            s1_e = jnp.where(opmode == isa.MODE_VV, s1_reg_e, scalar_b)

            def arith(lane_op):
                def f(_):
                    r = alu.lane_binop(lane_op, s2_e, s1_e, sew)
                    return write_back(vrf, vd, dst_w, r, vl), jnp.int32(0)
                return f

            def macc(_):
                r = dst_e + s2_e * s1_e
                return write_back(vrf, vd, dst_w, r, vl), jnp.int32(0)

            def vmv(_):
                return write_back(vrf, vd, dst_w, s1_e, vl), jnp.int32(0)

            def slide(up):
                def f(_):
                    off = jnp.where(slide1, 1, scalar_b)
                    if up:
                        idx = elem_ids - off
                        gathered = s2_e[jnp.clip(idx, 0, n_elems - 1)]
                        r = jnp.where(idx >= 0, gathered, dst_e)
                        r = jnp.where(slide1 & (elem_ids == 0), sval1, r)
                    else:
                        idx = elem_ids + off
                        gathered = s2_e[jnp.clip(idx, 0, n_elems - 1)]
                        r = jnp.where(idx < vl, gathered, 0)
                        r = jnp.where(slide1 & (elem_ids == vl - 1), sval1, r)
                    return write_back(vrf, vd, dst_w, r, vl), jnp.int32(0)
                return f

            def emvv(_):
                idx = sval2 % n_elems
                r = jnp.where(elem_ids == idx, sval1, dst_e)
                new = write_back(vrf, vd, dst_w, r, jnp.int32(n_elems))
                return new, jnp.int32(0)

            def emvx(_):
                idx = sval1 % n_elems
                return vrf, s2_e[idx]

            def vsetvl(_):
                return vrf, jnp.minimum(sval1, vlmax)

            def vnop(_):
                # true no-op (bucket padding): VRF untouched, VL untouched
                return vrf, jnp.int32(0)

            branches = []
            for cid in range(len(_COMPACT)):
                if cid in _ARITH_BY_ID:
                    branches.append(arith(_ARITH_BY_ID[cid]))
                elif _COMPACT[cid] == VOp.VMACC:
                    branches.append(macc)
                elif _COMPACT[cid] == VOp.VMV:
                    branches.append(vmv)
                elif _COMPACT[cid] == VOp.VSLIDEUP:
                    branches.append(slide(True))
                elif _COMPACT[cid] == VOp.VSLIDEDOWN:
                    branches.append(slide(False))
                elif _COMPACT[cid] == VOp.EMVV:
                    branches.append(emvv)
                elif _COMPACT[cid] == VOp.EMVX:
                    branches.append(emvx)
                elif _COMPACT[cid] == VOp.VSETVL:
                    branches.append(vsetvl)
                elif _COMPACT[cid] == VOp.VNOP:
                    branches.append(vnop)
            new_vrf, out = jax.lax.switch(op, branches, None)
            new_vl = jnp.where(op == COMPACT_ID[VOp.VSETVL],
                               jnp.minimum(sval1, vlmax), vl)
            return (new_vrf, new_vl), out

        (vrf, vl), emvx_outs = jax.lax.scan(step, (vrf, vl0), trace)
        return vrf, vl, emvx_outs


def trace_to_arrays(entries: list[np.ndarray]) -> dict:
    """Stack trace entries into the dict-of-arrays form run_trace expects."""
    arr = np.array([tuple(int(e[f]) for f in isa.CARUS_TRACE_DTYPE.names)
                    for e in entries], dtype=isa.CARUS_TRACE_DTYPE)
    return {name: jnp.asarray(arr[name]) for name in arr.dtype.names}
