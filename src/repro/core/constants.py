"""Hardware constants for the NMC reproduction and the TPU roofline target.

Every number in the `paper` section is lifted directly from the paper
(Caon, Choné et al., "Scalable and RISC-V Programmable Near-Memory Computing
Architectures for Edge Nodes", IEEE TETC) with its provenance recorded, so the
timing/energy models in :mod:`repro.core.timing` / :mod:`repro.core.energy`
are auditable against the publication.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper: physical implementation (Table IV, Section IV)
# ---------------------------------------------------------------------------

TECH_NODE_NM = 65                 # low-power 65 nm CMOS
F_CLK_MAX_HZ = 330e6              # post-layout max clock (Table IV)
F_CLK_BENCH_HZ = 250e6            # frequency used for all benchmarks (Table V)

SRAM_REF_AREA_UM2 = 200e3         # 32 KiB reference SRAM (Table IV)
CAESAR_AREA_UM2 = 256e3           # +28 % (Table IV)
CARUS_AREA_UM2 = 419e3            # +110 % (Table IV)

# Memory geometry (Sections III-A2, III-B2, IV)
CAESAR_MEM_BYTES = 32 * 1024      # 2 x 16 KiB single-port banks
CAESAR_N_BANKS = 2
CARUS_MEM_BYTES = 32 * 1024       # 4 x 8 KiB single-port banks (= VRF)
CARUS_N_LANES = 4                 # one ALU lane per VRF bank
CARUS_N_VREGS = 32                # architectural vector registers (RVV-like)
CARUS_EMEM_BYTES = 512            # eCPU code/data memory (Section IV-B)
WORD_BYTES = 4

# Host-side DMA between main memory and the tiles' SRAM macros: the macros
# hang off a 32-bit system bus and accept one word per bus cycle in memory
# mode (Section III — the tile "behaves as a standard SRAM" when not
# computing), so streaming transfers sustain 4 B/cycle.  This drives the
# DMA legs of the dispatch-pipeline cost model (timing.dispatch_cycles).
DMA_BYTES_PER_CYCLE = 4

# Multi-tile system: the whole tile array hangs off ONE such bus (Fig. 1's
# edge-node topology — N SRAM macros, one interconnect).  Concurrent tiles'
# memory-mode DMA transfers therefore *serialize* on the bus while each
# tile's compute-mode execution proceeds independently — the saturation
# mechanism of the system-level scaling model (timing.wave_cycles): wave
# speedup grows with the tile count until the serialized DMA stream, not
# per-tile compute, binds the makespan.
SYS_BUS_BYTES_PER_CYCLE = DMA_BYTES_PER_CYCLE

# Derived VRF geometry: 32 KiB / 32 regs = 1 KiB per register (VLEN = 8192 b)
CARUS_REG_BYTES = CARUS_MEM_BYTES // CARUS_N_VREGS
CARUS_REG_WORDS = CARUS_REG_BYTES // WORD_BYTES          # 256 words
CARUS_VLMAX = {8: 1024, 16: 512, 32: 256}                # elements per register

# ---------------------------------------------------------------------------
# Paper: microarchitectural timing rules (Sections III-A2, III-B2, V-B)
# ---------------------------------------------------------------------------

# NM-Caesar: multi-cycle SIMD ALU. The partitioned adder and the 4x17-bit
# multiplier array both produce one 32-bit word of results every 2 cycles
# (Section III-A2), independent of element width.
CAESAR_CYCLES_PER_OP = 2          # sustained, operands in different banks
CAESAR_SAME_BANK_CYCLES = 3       # +1 cycle serialized fetch (Section III-A2)
CAESAR_OFFLOAD_CYCLES = 5         # "negligible overhead of five cycles" (V-B1)

# NM-Carus: per-lane *word* timing.  Each lane owns one single-port VRF bank,
# so an instruction's per-word cost is the max of its ALU latency and its
# bank-port occupancy ("the throughput of the arithmetic unit is never lower
# than the slower unit between the ALU and the VRF", Section III-B2):
#
#   cycles/word = max(ALU_WORD_CYCLES[class][sew], port_accesses(op))
#
# ALU word latencies follow Section III-B2: the partitioned adder retires one
# 32-bit word every 2 cycles at any SEW; the 16-bit multiplier produces four
# 8-bit / two 16-bit / one 32-bit results in 4 / 2 / 3 cycles; vmacc adds the
# shared-adder accumulate (fit: Table V/VIII cycle counts — note the paper's
# text quotes 0.33 MAC/cycle at 32-bit while Table VIII implies 0.25; we use
# the table-consistent value, flagged in EXPERIMENTS.md); the serial 8-bit
# barrel shifter and the move/slide unit stream one byte per cycle (4/word).
# Port occupancy counts register-file words touched per result word:
# vv = 3 (2 reads + 1 write), vx/vi = 2, vmacc.vx = 3, vmacc.vv = 4, splat = 1,
# slide = 2.  This model reproduces every Table V Carus cell within ~5 %
# (exactly, for add/mul/relu/leaky/xor — see EXPERIMENTS.md §Paper-validation).
CARUS_ALU_WORD_CYCLES = {
    "add":   {8: 2, 16: 2, 32: 2},
    "logic": {8: 2, 16: 2, 32: 2},
    "mul":   {8: 4, 16: 2, 32: 3},
    "macc":  {8: 4, 16: 3, 32: 4},
    "shift": {8: 4, 16: 4, 32: 4},
    "move":  {8: 4, 16: 4, 32: 4},
}
CARUS_ISSUE_CYCLES = 1            # issue slot when overlapped with eCPU
CARUS_KERNEL_OVERHEAD_CYCLES = 100  # eCPU bootstrap + driver loop (fitted on
                                    # Table V element-wise kernels)
CARUS_ECPU_CPI = 1.3              # CV32E40X-class in-order CPI for scalar code

# RV32IMC CPU baseline: cycles per output element, per kernel and bitwidth
# (Table V, "Cycles/output" rows — these are the paper's own measurements and
# serve as the baseline of every relative claim we reproduce).
CPU_CYCLES_PER_OUTPUT = {
    "xor":        {8: 2.5,   16: 5.0,   32: 10.0},
    "add":        {8: 4.0,   16: 11.0,  32: 10.0},
    "mul":        {8: 11.0,  16: 11.0,  32: 10.0},
    "matmul":     {8: 112.0, 16: 112.0, 32: 89.1},
    "gemm":       {8: 73.1,  16: 81.2,  32: 66.3},
    "conv2d":     {8: 135.0, 16: 133.0, 32: 115.1},
    "relu":       {8: 13.0,  16: 12.0,  32: 10.0},
    "leaky_relu": {8: 12.0,  16: 11.5,  32: 9.5},
    "maxpool":    {8: 64.6,  16: 65.6,  32: 50.3},
}

# CPU baseline energy per output element in pJ (Table V).
CPU_ENERGY_PER_OUTPUT_PJ = {
    "xor":        {8: 61.0,   16: 124.0,  32: 281.0},
    "add":        {8: 99.0,   16: 269.0,  32: 278.0},
    "mul":        {8: 267.0,  16: 285.0,  32: 279.0},
    "matmul":     {8: 2880.0, 16: 3000.0, 32: 2540.0},
    "gemm":       {8: 1910.0, 16: 2260.0, 32: 1950.0},
    "conv2d":     {8: 3300.0, 16: 3400.0, 32: 3100.0},
    "relu":       {8: 344.0,  16: 338.0,  32: 300.0},
    "leaky_relu": {8: 300.0,  16: 295.0,  32: 258.0},
    "maxpool":    {8: 1440.0, 16: 1500.0, 32: 1200.0},
}

# Macro-level energy per 8/16/32-bit MAC in pJ (Table VIII, 65 nm columns).
MACRO_PJ_PER_MAC = {
    "caesar": {8: 16.3, 16: 32.0, 32: 61.8},
    "carus":  {8: 6.8,  16: 12.0, 32: 31.2},
}

# System-level average power model (mW @ 250 MHz, 65 nm typical), calibrated
# on Table V (energy/output = power x cycles/output across all kernels):
#   * CPU-only system: Table V implies 22-27 pJ/cycle, nearly flat across
#     kernels -> constant 6.25 mW ("memory accesses consume approximately as
#     much power as the CPU itself", Fig. 13).
#   * NM-Caesar system: 7.1-7.7 mW, flat — the DMA streams one micro-op per
#     2 cycles from system memory regardless of kernel ("half of [memory
#     power] is used to fetch the kernel micro-instructions", Fig. 13).
#   * NM-Carus system: P = P_FIX + e_VRF x (VRF word-accesses per cycle).
#     Fitting Table V gives P_FIX ~= 6.4 mW and e_VRF ~= 5.4 pJ per 32-bit
#     word access — squarely in the expected range for an 8 KiB 65 nm LP SRAM
#     read, a strong consistency check of the model.
P_CPU_SYS_MW = 6.25
P_CAESAR_SYS_MW = 7.4
P_CARUS_FIX_MW = 6.4
E_CARUS_VRF_ACCESS_PJ = 5.4
# Component split of the fixed terms (Fig. 13 power-breakdown shape):
P_CARUS_FIX_SPLIT_MW = {"host_idle+bus": 1.5, "ecpu": 0.45,
                        "vpu+ctrl": 2.6, "vrf_static": 1.85}
P_CARUS_ECPU_PHASE_MW = 4.9   # eCPU-serial phases (e.g. horizontal pooling)

# Peak figures (Table VII) used as model cross-checks.
CAESAR_PEAK_GOPS = 1.32           # 2 ops x 2 MAC/2cyc x 330 MHz (8-bit DOT)
CARUS_PEAK_GOPS = 2.64            # 2 ops x 4 lanes x 1 MAC/cyc x 330 MHz
CARUS_PEAK_GOPS_W = 306.7         # 8-bit matmul, post-layout
CAESAR_PEAK_GOPS_W = 200.3        # (421.9 without controller power)
VECIM_PEAK_GOPS_W = 289.1         # ISSCC'24 comparison point

# Anomaly-detection end-to-end application (Table VI).
TABLE_VI = {
    # config: (cycle_factor, energy_factor, area_factor) vs 1-core CV32E40P
    "cv32e40p_1c": (1.0, 1.0, 1.0),
    "cv32e40p_2c": (2.0, 1.37, 1.43),
    "cv32e40p_4c": (4.0, 1.67, 2.29),
    "caesar_e20":  (1.29, 1.20, 0.90),
    "carus_e20":   (3.55, 2.36, 1.36),
}
TABLE_VI_BASE_CYCLES = 561e3
TABLE_VI_BASE_ENERGY_UJ = 13.5
TABLE_VI_BASE_AREA_UM2 = 350e3

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (per chip) — the adaptation target.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12     # FLOP/s
    peak_int8_ops: float = 394e12       # OP/s (2x bf16 via int8 MXU)
    hbm_bw: float = 819e9               # B/s
    ici_link_bw: float = 50e9           # B/s per link (roofline: per-chip)
    hbm_bytes: float = 16e9             # 16 GiB HBM per chip
    vmem_bytes: float = 128 * 2**20     # ~128 MiB VMEM
    mxu_dim: int = 128


TPU_V5E = TpuSpec()
