"""Energy models for the HEEPerator-style system (CPU / NM-Caesar / NM-Carus).

Calibration strategy (documented in DESIGN.md §3.3): we cannot run the
paper's post-layout PrimePower flow, so the component powers in
:mod:`repro.core.constants` are *fitted once* on Table V (system level) and
then validated against the paper's independent claims: Table VIII pJ/MAC,
Fig. 12 energy saturation (66 pJ/output @8-bit matmul), Fig. 13 power
breakdown shape, and the Table VII peak GOPS/W figures.

Model:
  * CPU system:     E = P_CPU_SYS x t                  (flat ~6.25 mW)
  * NM-Caesar sys:  E = P_CAESAR_SYS x t               (flat ~7.4 mW; the
                    1-op/2-cycle DMA instruction stream keeps the system
                    memory active at a constant rate)
  * NM-Carus sys:   E = P_CARUS_FIX x t + e_VRF x (VRF word accesses)
  * host/eCPU-serial phases (horizontal pooling): P_CPU_SYS / P_ECPU_PHASE.

Both engines are costed through :func:`program_energy` on the unified
program IR (DESIGN.md §5); the per-engine ``caesar_energy`` / ``carus_energy``
helpers are wrappers that pull the IR out of a KernelBuild.

Padding NOPs (the bucketed scheduler's instruction-stream filler,
``repro.nmc.pool``) are zero-energy by construction: they contribute no
cycles in :mod:`repro.core.timing` and no VRF accesses, so a NOP-padded
program costs exactly what the unpadded program costs (property-tested in
``tests/test_nmc_ir.py``).
"""

from __future__ import annotations

import dataclasses

from repro.core import constants as C
from repro.core import timing as T
from repro.nmc.program import Program


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    energy_pj: float
    avg_power_mw: float
    detail: dict

    def per_output_pj(self, n_outputs: int) -> float:
        return self.energy_pj / n_outputs


def _mw_cycles_to_pj(p_mw: float, cycles: float,
                     f_hz: float = C.F_CLK_BENCH_HZ) -> float:
    return p_mw * 1e-3 * (cycles / f_hz) * 1e12


def cpu_energy(kernel: str, sew: int, n_outputs: int) -> EnergyReport:
    """CPU baseline straight from Table V measurements."""
    e = C.CPU_ENERGY_PER_OUTPUT_PJ[kernel][sew] * n_outputs
    cyc = C.CPU_CYCLES_PER_OUTPUT[kernel][sew] * n_outputs
    p = e / (cyc / C.F_CLK_BENCH_HZ) * 1e-9 if cyc else 0.0
    return EnergyReport(e, p, {"model": "table_v"})


# ---------------------------------------------------------------------------
# Unified IR costing
# ---------------------------------------------------------------------------

def program_energy(prog: Program, host_cycles: float = 0.0) -> EnergyReport:
    """System-level energy of one NMC program (either engine)."""
    tr = T.program_cycles(prog, host_cycles)
    if prog.engine == "caesar":
        e_nmc = _mw_cycles_to_pj(C.P_CAESAR_SYS_MW, tr.cycles)
        e_host = _mw_cycles_to_pj(C.P_CPU_SYS_MW, tr.host_cycles)
        e = e_nmc + e_host
        detail = {"nmc_pj": e_nmc, "host_pj": e_host}
    else:
        acc = T.program_vrf_accesses(prog)
        e_fix = _mw_cycles_to_pj(C.P_CARUS_FIX_MW, tr.cycles)
        e_vrf = acc * C.E_CARUS_VRF_ACCESS_PJ
        e_host = _mw_cycles_to_pj(C.P_CARUS_ECPU_PHASE_MW, tr.host_cycles)
        e = e_fix + e_vrf + e_host
        detail = {"fix_pj": e_fix, "vrf_pj": e_vrf, "host_pj": e_host,
                  "vrf_accesses": acc}
    p = e / (tr.total_cycles / C.F_CLK_BENCH_HZ) * 1e-9
    return EnergyReport(e, p, detail)


def _prog(kb, engine: str) -> tuple[Program, float]:
    eb = getattr(kb, engine)
    return eb.program.with_sew(kb.sew), eb.host_cycles


def caesar_energy(kb) -> EnergyReport:
    return program_energy(*_prog(kb, "caesar"))


def carus_energy(kb) -> EnergyReport:
    return program_energy(*_prog(kb, "carus"))


def carus_macro_energy_pj(kb) -> float:
    """Macro-only energy (Table VIII / peak-GOPS/W comparisons): excludes the
    host-idle + bus share of the fixed power."""
    prog, host_cycles = _prog(kb, "carus")
    tr = T.program_cycles(prog, host_cycles)
    acc = T.program_vrf_accesses(prog)
    p_macro = C.P_CARUS_FIX_MW - C.P_CARUS_FIX_SPLIT_MW["host_idle+bus"]
    return _mw_cycles_to_pj(p_macro, tr.cycles) + acc * C.E_CARUS_VRF_ACCESS_PJ


def caesar_macro_energy_pj(kb) -> float:
    """NM-Caesar energy for macro-level comparisons (Table VIII): system
    minus the idle host CPU — the instruction stream fetch IS part of
    operating the macro (it has no controller of its own)."""
    prog, host_cycles = _prog(kb, "caesar")
    tr = T.program_cycles(prog, host_cycles)
    return _mw_cycles_to_pj(C.P_CAESAR_SYS_MW - 0.35, tr.cycles)


def kernel_energy(kb) -> dict[str, EnergyReport]:
    return {
        "cpu": cpu_energy(kb.name, kb.sew, kb.n_outputs),
        "caesar": caesar_energy(kb),
        "carus": carus_energy(kb),
    }


def power_breakdown_mw(engine: str, access_rate_per_cycle: float = 0.0) -> dict:
    """Average power split (Fig. 13 reproduction)."""
    if engine == "cpu":
        return {"host_cpu": 2.9, "system_mem": 2.9, "bus_other": 0.45}
    if engine == "caesar":
        # half the memory power fetches the micro-instruction stream (Fig. 13)
        return {"host_cpu": 0.35, "instr_fetch": 1.65, "system_mem": 1.65,
                "bus_other": 0.45, "nmc_logic": 1.25, "nmc_mem": 2.05}
    if engine == "carus":
        vrf_dyn = access_rate_per_cycle * C.E_CARUS_VRF_ACCESS_PJ * \
            C.F_CLK_BENCH_HZ * 1e-9
        s = C.P_CARUS_FIX_SPLIT_MW
        return {"host_cpu+bus": s["host_idle+bus"], "ecpu": s["ecpu"],
                "vpu+ctrl": s["vpu+ctrl"],
                "vrf": s["vrf_static"] + vrf_dyn}
    raise KeyError(engine)
