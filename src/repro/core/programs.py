"""Kernel library: the paper's benchmark suite for NM-Caesar and NM-Carus.

Since the traced-frontend redesign (DESIGN.md §7) every builder is an
ordinary numpy-style kernel function compiled through
:mod:`repro.nmc.frontend`: the function is traced once per engine, the
tracer's eager ``alu.*_np`` evaluation *is* the quantized oracle, and the
per-engine lowerings emit the same instruction structure the hand-written
builders used to:

* NM-Caesar operands land in opposite banks (loads in bank 1; constants,
  outputs and temporaries in bank 0) so sustained throughput is one op per
  2 cycles (Section III-A2); conv2d's shifted-replica trick falls out of
  ``slide_down`` on loaded values (the packed-SIMD alignment trick; cf. the
  C-SRAM comparison's data-replication remark, Table VII).
* NM-Carus chunks operands across vector registers and iterates with the
  *indirect register addressing* template of Section III-B1; ``t.consts``
  taps (matmul A entries, conv filter weights) are read through EMVX
  exactly like the eCPU does.
* Max-pooling's horizontal reduction runs on the host CPU / eCPU (Section
  V-B1: "the lack of subword reduction operations ... requires horizontal
  pooling to be implemented in software") and is accounted as host cycles.

Each builder returns a :class:`KernelBuild` holding, per engine, the
lowered instruction stream + initial memory image + output location and
the numpy oracle.  :class:`EngineBuild` / :class:`KernelBuild` are kept as
thin shims over the frontend's :class:`repro.nmc.frontend.LoweredKernel`
so the pool/runtime/timing/energy layers and hand-constructed test builds
keep one artifact type.

Kernel default shapes follow Table V footnotes (a-g).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import alu
from repro.nmc import frontend
from repro.nmc.frontend import mac as _mac
from repro.nmc.program import Program, carus_entry

# Legacy alias: hand-built test traces still use this entry helper.
trace_entry = carus_entry

DTYPES = alu.NP_DTYPES


@dataclasses.dataclass
class EngineBuild:
    stream: list                      # unified-IR entries (nmc.program)
    mem: np.ndarray                   # initial memory / VRF image (int32 words)
    out_slice: tuple[int, int]        # (word_start, n_words) in flat memory view
    host_cycles: float = 0.0          # work left on the host CPU / eCPU
    ecpu_instrs: int = 0              # scalar instructions per vector instr (ovl.)
    oracle: np.ndarray | None = None  # expected final outputs for this engine
    post: Callable | None = None      # host-side finishing stage (e.g. h-pool)
    n_outputs: int = 0                # outputs produced by this engine's build
    engine: str = ""                  # "caesar" | "carus" (set by the builder)
    sew: int = 0                      # element width (set by the builder)
    _prog: Program | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def program(self) -> Program:
        """The build's unified-IR Program.  Legacy hand-built streams
        (tuples / CARUS_TRACE_DTYPE scalars) are converted on the fly."""
        if self._prog is None:
            self._prog = Program.from_legacy(self.stream, self.sew or 32,
                                             self.engine or None)
        return self._prog


@dataclasses.dataclass
class KernelBuild:
    name: str
    sew: int
    n_outputs: int
    oracle: np.ndarray                # expected output elements
    caesar: EngineBuild | None
    carus: EngineBuild | None


def _kernel_build(name: str, sew: int, caesar_pack, carus_pack) -> KernelBuild:
    """Tag the per-engine builds with engine/sew/oracle and assemble."""
    (cz, orc_c), (kz, orc_k, n_out) = caesar_pack, carus_pack
    for eb, orc, engine in ((cz, orc_c, "caesar"), (kz, orc_k, "carus")):
        eb.oracle, eb.n_outputs = orc, orc.size
        eb.engine, eb.sew = engine, sew
    return KernelBuild(name, sew, n_out, orc_k, cz, kz)


def _traced_build(kfn, args, engine: str, sew: int, host_cycles: float = 0.0,
                  post_wrap: Callable | None = None) -> tuple:
    """Trace + lower a frontend kernel for one engine; shim the result into
    an :class:`EngineBuild` (optionally composing a host-side finishing
    stage after the frontend's extraction ``post``)."""
    # opt="off": registry streams reproduce the paper's hand-written
    # kernels verbatim (Table V instruction counts) — the optimizer is
    # benchmarked against them, not baked into them
    lk = frontend.jit(kfn, engine=engine, sew=sew, opt="off").lower(*args)
    post = lk.post if post_wrap is None \
        else (lambda e, _p=lk.post, _w=post_wrap: _w(_p(e)))
    eb = EngineBuild(list(lk.stream), lk.mem, lk.out_slice,
                     host_cycles=host_cycles, ecpu_instrs=lk.ecpu_instrs,
                     post=post)
    # keep the full lowering (init spans, per-instruction provenance) so
    # the static verifier sweep (python -m repro.nmc.check) can run the
    # dataflow passes over registry builds, not just the bare program
    eb.lowered = lk
    return eb, np.asarray(lk.oracle)


def _rng(seed):
    return np.random.default_rng(seed)


def _rand(rng, shape, sew):
    info = np.iinfo(DTYPES[sew])
    return rng.integers(info.min, info.max + 1, shape, dtype=DTYPES[sew])


# ---------------------------------------------------------------------------
# Element-wise kernels: XOR / ADD / MUL / ReLU / Leaky-ReLU
# ---------------------------------------------------------------------------

_EW_OPS: dict[str, Callable] = {
    "xor": lambda a, b: a ^ b,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
}


def build_elementwise(op_name: str, sew: int, caesar_bytes: int = 8 * 1024,
                      carus_bytes: int = 10 * 1024, seed: int = 0) -> KernelBuild:
    fn = _EW_OPS[op_name]
    rng = _rng(seed)

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        a, b = _rand(rng, n, sew), _rand(rng, n, sew)

        def kfn(t, x, y):
            # operands in opposite banks: one op per 2 cycles sustained
            t.store(fn(t.load(x, bank=0), t.load(y)))

        eb, oracle = _traced_build(kfn, (a, b), engine, sew)
        return eb, oracle, n

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    # oracles differ per engine (different sizes); stored per-engine
    return _kernel_build(op_name, sew, (cz, orc_c), (kz, orc_k, n_out))


def build_relu(sew: int, caesar_bytes: int = 8 * 1024,
               carus_bytes: int = 16 * 1024, seed: int = 1,
               leaky_shift: int = 0) -> KernelBuild:
    """ReLU (leaky_shift=0) or Leaky-ReLU with slope 2^-shift.

    Trick used on both engines: leaky_relu(x) = max(x, x >> shift) for
    arithmetic right shift — for shift=0 this degenerates to plain max(x, x)
    so plain ReLU uses max(x, 0) instead (1 op/word)."""
    rng = _rng(seed)
    name = "relu" if leaky_shift == 0 else "leaky_relu"

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        x = _rand(rng, n, sew)

        def kfn(t, xa):
            xv = t.load(xa)
            t.store(xv.max(0) if leaky_shift == 0
                    else xv.max(xv >> leaky_shift))

        eb, oracle = _traced_build(kfn, (x,), engine, sew)
        return eb, oracle, n

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    return _kernel_build(name, sew, (cz, orc_c), (kz, orc_k, n_out))


def build_axpy(sew: int, caesar_bytes: int = 2 * 1024,
               carus_bytes: int = 8 * 1024, seed: int = 5) -> KernelBuild:
    """Fused multiply-add over full vectors: out = c0 + w * x.

    Written naively — no bank placement hints, accumulator loaded as a
    plain operand — so its lowering carries exactly the slack the IR
    optimizer (repro.nmc.opt, DESIGN.md §13) is built to reclaim: on
    NM-Carus the multi-use accumulator forces a VMV register copy that
    copy-coalescing deletes; on NM-Caesar all three operands land in one
    bank and bank-aware placement rehomes one span."""
    rng = _rng(seed)

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        c0, w, x = (_rand(rng, n, sew) for _ in range(3))

        def kfn(t, c0a, wa, xa):
            t.store(_mac(t.load(c0a), t.load(wa), t.load(xa)))

        eb, oracle = _traced_build(kfn, (c0, w, x), engine, sew)
        return eb, oracle, n

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    return _kernel_build("axpy", sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# Quantized ReLU + unsigned clamp: the registry's heterogeneous kernel
# ---------------------------------------------------------------------------

def qrelu_case(sew: int, rows: int = 8, row_bytes: int = 128,
               seed: int = 11) -> tuple:
    """The qrelu kernel function and its inputs: ``rows`` independent
    activation rows, all but the last requantized through the affine ReLU
    ``max(3x + 1, 0)`` (bus-expressible), the last clamped with the
    *unsigned* ``minu`` cap — an op NM-Caesar's bus ALU does not have
    (``OpSpec("minu", None, ...)``), so that one row's shard is
    Carus-only while the rest lower on either engine.  This is the
    deliberately heterogeneous tape the wave scheduler (DESIGN.md §14)
    splits into a mixed Caesar+Carus wave.  Returns ``(kfn, args)``."""
    rng = _rng(seed)
    n = row_bytes // (sew // 8)
    X = _rand(rng, (rows, n), sew)
    cap = (1 << (sew - 2)) - 1       # positive at every SEW; actually clamps

    def kfn(t, X):
        vals = [t.load(X[r]) for r in range(rows)]
        for r in range(rows - 1):
            t.store((vals[r] * 3 + 1).max(0))
        t.store(vals[rows - 1].minu(cap))

    return kfn, (X,)


def build_qrelu(sew: int, rows: int = 8, row_bytes: int = 128,
                seed: int = 11) -> KernelBuild:
    """Single-tile registry build of :func:`qrelu_case`.  The whole tape
    is Carus-only (the ``minu`` row), so ``caesar`` is ``None`` — Table V
    sweeps exclude it (no paper CPU baseline); it exists for the
    heterogeneous scheduling path, where the *rows-split* wave runs its
    bus-expressible shards on Caesar."""
    kfn, args = qrelu_case(sew, rows=rows, row_bytes=row_bytes, seed=seed)
    eb, oracle = _traced_build(kfn, args, "carus", sew)
    eb.oracle, eb.n_outputs = oracle, oracle.size
    eb.engine, eb.sew = "carus", sew
    return KernelBuild("qrelu", sew, oracle.size, oracle, None, eb)


# ---------------------------------------------------------------------------
# Matmul / GEMM:  A[8,8] x B[8,P]  (Table V footnotes b, c)
# ---------------------------------------------------------------------------

CAESAR_MATMUL_P = {32: 128, 16: 256, 8: 512}
CARUS_MATMUL_P = {32: 256, 16: 512, 8: 1024}


def build_matmul(sew: int, p: int | None = None, seed: int = 2,
                 gemm: bool = False, alpha: int = 3, beta: int = 2,
                 shift: int = 4) -> KernelBuild:
    """C = A@B (matmul) or C = (alpha*(A@B) >> s) + (beta*C0 >> s) (gemm,
    fixed-point scaling by powers-of-two-normalized integer constants)."""
    rng = _rng(seed)
    m, k = 8, 8

    def make(P, engine):
        A = _rand(rng, (m, k), sew)
        B = _rand(rng, (k, P), sew)
        C0 = _rand(rng, (m, P), sew) if gemm else np.zeros((m, P), DTYPES[sew])

        def kfn(t, A, B, C0):
            # A entries are scalar taps (EMVX reads / splat words); B rows
            # are resident vectors — the first tap is a mul, the rest
            # accumulate (MAC_INIT/MAC/MAC_STORE on Caesar, in-place
            # VMUL/VMACC.vx on Carus)
            a = t.consts(A)
            rows = [t.load(B[r]) for r in range(k)]
            c0 = [t.load(C0[r]) for r in range(m)] if gemm else None
            for i in range(m):
                acc = None
                for kk in range(k):
                    acc = _mac(acc, a[i, kk], rows[kk])
                if gemm:
                    acc = ((acc * alpha) >> shift) + ((c0[i] * beta) >> shift)
                t.store(acc)

        eb, oracle = _traced_build(kfn, (A, B, C0), engine, sew)
        return eb, oracle, m * P

    cz, orc_c, _ = make(p or CAESAR_MATMUL_P[sew], "caesar")
    kz, orc_k, n_out = make(p or CARUS_MATMUL_P[sew], "carus")
    return _kernel_build("gemm" if gemm else "matmul", sew,
                         (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# 2D convolution:  A[8,n] (*) F[f,f], 'valid' (Table V footnote d)
# ---------------------------------------------------------------------------

CAESAR_CONV = {32: (64, 3), 16: (64, 4), 8: (128, 4)}   # (n, f)
CARUS_CONV = {32: (256, 3), 16: (512, 3), 8: (1024, 3)}


def build_conv2d(sew: int, n: int | None = None, f: int | None = None,
                 seed: int = 3) -> KernelBuild:
    rng = _rng(seed)
    rows = 8

    def make(nn, ff, engine):
        A = _rand(rng, (rows, nn), sew)
        F = _rand(rng, (ff, ff), sew)
        out_r, out_c = rows - ff + 1, nn - ff + 1

        def kfn(t, A, F):
            # filter taps as scalar consts; column offsets via slide_down —
            # VSLIDEDOWN on Carus, host-prepared byte-shifted replicas on
            # Caesar (slides of loaded values lower to data replication)
            fw = t.consts(F)
            av = [t.load(A[r]) for r in range(rows)]
            sh = {(dj, r): av[r].slide_down(dj)
                  for dj in range(1, ff) for r in range(rows)}
            for i in range(out_r):
                acc = None
                for di in range(ff):
                    for dj in range(ff):
                        src = av[i + di] if dj == 0 else sh[(dj, i + di)]
                        acc = _mac(acc, fw[di, dj], src)
                t.store(acc, n=out_c)     # 'valid' width

        eb, oracle = _traced_build(kfn, (A, F), engine, sew)
        return eb, oracle, out_r * out_c

    nn_c, ff_c = (n, f) if n else CAESAR_CONV[sew]
    nn_k, ff_k = (n, f) if n else CARUS_CONV[sew]
    cz, orc_c, _ = make(nn_c, ff_c, "caesar")
    kz, orc_k, n_out = make(nn_k, ff_k, "carus")
    return _kernel_build("conv2d", sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# Max pooling 2x2 stride 2 (vertical on NMC, horizontal on host — Sec. V-B1)
# ---------------------------------------------------------------------------

def build_maxpool(sew: int, caesar_bytes: int = 8 * 1024,
                  carus_bytes: int = 16 * 1024, seed: int = 4,
                  width: int = 128) -> KernelBuild:
    rng = _rng(seed)

    def pool_oracle(X):
        v = np.maximum(X[0::2], X[1::2])
        return np.maximum(v[:, 0::2], v[:, 1::2]).astype(DTYPES[sew])

    # host-side horizontal-pool cycle cost per output (fitted to Table V;
    # see EXPERIMENTS.md §Paper-validation for the residuals).  Sub-word
    # widths need lane extraction/repacking on the host (~16 cycles/output);
    # 32-bit is a plain load/load/max/store (~4 cycles/output).
    horiz_cpu = {8: 15.6, 16: 17.2, 32: 4.2}[sew]
    horiz_ecpu = {8: 10.0, 16: 11.3, 32: 13.2}[sew]

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        rows_n = n // width
        X = _rand(rng, (rows_n, width), sew)
        oracle = pool_oracle(X)
        n_out = (rows_n // 2) * (width // 2)
        even = np.ascontiguousarray(X[0::2]).reshape(-1)
        odd = np.ascontiguousarray(X[1::2]).reshape(-1)

        def kfn(t, e, o):
            # vertical stage on the NMC engine (even rows bank 0, odd rows
            # bank 1 on Caesar: no same-bank conflicts)
            t.store(t.load(e, bank=0).max(t.load(o)))

        def horiz(v):
            v = np.asarray(v).reshape(rows_n // 2, width)
            return np.maximum(v[:, 0::2], v[:, 1::2]).astype(DTYPES[sew])

        hc = n_out * (horiz_cpu if engine == "caesar" else horiz_ecpu)
        eb, _vert = _traced_build(kfn, (even, odd), engine, sew,
                                  host_cycles=hc, post_wrap=horiz)
        return eb, oracle, n_out

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    # engine oracles: the full pooled output (vertical stage on the NMC
    # engine + horizontal host stage applied by the composed post)
    return _kernel_build("maxpool", sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------

def build(name: str, sew: int, **kw) -> KernelBuild:
    if name in _EW_OPS:
        return build_elementwise(name, sew, **kw)
    if name == "relu":
        return build_relu(sew, **kw)
    if name == "leaky_relu":
        return build_relu(sew, leaky_shift=kw.pop("leaky_shift", 2), **kw)
    if name == "matmul":
        return build_matmul(sew, **kw)
    if name == "gemm":
        return build_matmul(sew, gemm=True, **kw)
    if name == "conv2d":
        return build_conv2d(sew, **kw)
    if name == "maxpool":
        return build_maxpool(sew, **kw)
    if name == "axpy":
        return build_axpy(sew, **kw)
    if name == "qrelu":
        return build_qrelu(sew, **kw)
    raise KeyError(name)


# the paper's Table V kernel set — these have published CPU baselines
# (constants.CPU_CYCLES_PER_OUTPUT) and throughput/energy reference rows
TABLE_V_KERNELS = ("xor", "add", "mul", "matmul", "gemm", "conv2d", "relu",
                   "leaky_relu", "maxpool")
# the full registry: Table V plus kernels added for the optimizer (axpy is
# deliberately naive — it exhibits the slack opt="O1" reclaims — and has no
# paper CPU baseline, so Table V sweeps exclude it)
ALL_KERNELS = TABLE_V_KERNELS + ("axpy",)
# kernels whose tape is deliberately heterogeneous (some store cones
# bus-expressible, some Carus-only) — built for the mixed-engine wave
# scheduler (DESIGN.md §14); excluded from ALL_KERNELS sweeps because
# they carry no per-engine build pair (qrelu's ``caesar`` is None)
HETERO_KERNELS = ("qrelu",)


# ---------------------------------------------------------------------------
# Execution helpers (used by tests and benchmarks) — all engine dispatch goes
# through the unified IR (repro.nmc); the engines only ever see Programs.
# ---------------------------------------------------------------------------

def run_build(eb: EngineBuild, sew: int | None = None) -> np.ndarray:
    """Execute one EngineBuild on its functional engine; return outputs
    (elements, with the host-side ``post`` stage applied).  ``sew`` overrides
    the build's own tag (needed for hand-constructed untagged builds)."""
    from repro.nmc.engine import get_engine

    prog = eb.program if sew is None else eb.program.with_sew(sew)
    engine = get_engine(prog.engine)
    final = engine.run(engine.init_state(eb.mem), prog)
    elems = engine.extract(final, eb.out_slice, prog.sew)
    return eb.post(elems) if eb.post else elems


def run_caesar(kb: KernelBuild) -> np.ndarray:
    """Execute the Caesar build on the functional engine; return outputs."""
    return run_build(kb.caesar, kb.sew)


def run_carus(kb: KernelBuild) -> np.ndarray:
    """Execute the Carus build on the scanned VPU; return outputs."""
    return run_build(kb.carus, kb.sew)


def _matches_oracle(got: np.ndarray, eb: EngineBuild) -> bool:
    exp = np.asarray(eb.oracle).reshape(-1)
    return bool((got.reshape(-1)[:exp.size] == exp).all())


def verify(kb: KernelBuild) -> dict[str, bool]:
    """Run both engines and compare against their oracles (bit-exact)."""
    return {engine: _matches_oracle(run_build(getattr(kb, engine)),
                                    getattr(kb, engine))
            for engine in ("caesar", "carus")}


def verify_sweep(kbs: list[KernelBuild], pool=None) -> dict:
    """Batched functional verification of a whole kernel sweep.

    Dispatches every (kernel, sew, engine) instance through one
    :class:`repro.nmc.pool.BucketedPool` (or any pool the caller hands in),
    so programs sharing an ``(engine, sew, instr-bucket)`` — e.g. the whole
    elementwise family at one SEW, or ragged matmul P-sweeps — share a
    single XLA compile and run as one vmapped multi-tile batch.  Returns
    ``{(name, sew): {engine: ok}}`` — bit-exact against the same oracles as
    the single-instance :func:`verify`.
    """
    from repro.nmc.pool import BucketedPool

    pool = pool if pool is not None else BucketedPool()
    builds, keys = [], []
    for kb in kbs:
        for engine in ("caesar", "carus"):
            eb = getattr(kb, engine)
            if eb is not None:
                builds.append(eb)
                keys.append((kb.name, kb.sew, engine))
    outs = pool.run_builds(builds)
    results: dict = {}
    for (name, sew, engine), eb, got in zip(keys, builds, outs):
        # AND-combine: a sweep may hold several instances of one (name, sew)
        # — e.g. fig12's matmul P-sweep — and every one must be bit-exact.
        slot = results.setdefault((name, sew), {})
        slot[engine] = slot.get(engine, True) and _matches_oracle(got, eb)
    return results
