"""Kernel library: the paper's benchmark suite for NM-Caesar and NM-Carus.

Each builder returns a :class:`KernelBuild` holding, for one kernel instance
(shape x element width):

* a NM-Caesar instruction stream + initial memory image + output location,
* a NM-Carus xvnmc issue trace + initial VRF image + output registers,
* a pure-numpy quantized oracle (two's complement, wrap at SEW), and
* bookkeeping used by the timing/energy models (#outputs, host-side work).

Data placement mirrors the paper's setups:
* Caesar operands are placed in opposite banks so sustained throughput is one
  op per 2 cycles (Section III-A2); conv2d uses host-prepared byte-shifted
  input replicas (the standard packed-SIMD alignment trick; cf. the C-SRAM
  comparison's data-replication remark, Table VII).
* Carus chunks operands across vector registers and iterates with the
  *indirect register addressing* template of Section III-B1.
* Max-pooling's horizontal reduction runs on the host CPU / eCPU (Section
  V-B1: "the lack of subword reduction operations ... requires horizontal
  pooling to be implemented in software") and is accounted as host cycles.

Kernel default shapes follow Table V footnotes (a-g).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import alu
from repro.core import constants as C
from repro.core import isa
from repro.core.isa import CaesarOp, VOp
from repro.nmc.program import Program, caesar_entry, carus_entry

# Builders emit unified-IR entries (DESIGN.md §5); `trace_entry` is kept as a
# local alias so the Carus instruction templates below read like the paper.
trace_entry = carus_entry

DTYPES = alu.NP_DTYPES


@dataclasses.dataclass
class EngineBuild:
    stream: list                      # unified-IR entries (nmc.program)
    mem: np.ndarray                   # initial memory / VRF image (int32 words)
    out_slice: tuple[int, int]        # (word_start, n_words) in flat memory view
    host_cycles: float = 0.0          # work left on the host CPU / eCPU
    ecpu_instrs: int = 0              # scalar instructions per vector instr (ovl.)
    oracle: np.ndarray | None = None  # expected final outputs for this engine
    post: Callable | None = None      # host-side finishing stage (e.g. h-pool)
    n_outputs: int = 0                # outputs produced by this engine's build
    engine: str = ""                  # "caesar" | "carus" (set by the builder)
    sew: int = 0                      # element width (set by the builder)
    _prog: Program | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def program(self) -> Program:
        """The build's unified-IR Program.  Legacy hand-built streams
        (tuples / CARUS_TRACE_DTYPE scalars) are converted on the fly."""
        if self._prog is None:
            self._prog = Program.from_legacy(self.stream, self.sew or 32,
                                             self.engine or None)
        return self._prog


@dataclasses.dataclass
class KernelBuild:
    name: str
    sew: int
    n_outputs: int
    oracle: np.ndarray                # expected output elements
    caesar: EngineBuild | None
    carus: EngineBuild | None


def _kernel_build(name: str, sew: int, caesar_pack, carus_pack) -> KernelBuild:
    """Tag the per-engine builds with engine/sew/oracle and assemble."""
    (cz, orc_c), (kz, orc_k, n_out) = caesar_pack, carus_pack
    for eb, orc, engine in ((cz, orc_c, "caesar"), (kz, orc_k, "carus")):
        eb.oracle, eb.n_outputs = orc, orc.size
        eb.engine, eb.sew = engine, sew
    return KernelBuild(name, sew, n_out, orc_k, cz, kz)


def _wrap(x: np.ndarray, sew: int) -> np.ndarray:
    return x.astype(np.int64).astype(DTYPES[sew])


def _splat_word(val: int, sew: int) -> int:
    """Replicate a SEW-bit value across a 32-bit word (host-side helper)."""
    v = int(np.int64(val) & ((1 << sew) - 1))
    w = 0
    for k in range(32 // sew):
        w |= v << (sew * k)
    w &= 0xFFFFFFFF
    return w - (1 << 32) if w >= (1 << 31) else w


def _rng(seed):
    return np.random.default_rng(seed)


def _rand(rng, shape, sew):
    info = np.iinfo(DTYPES[sew])
    return rng.integers(info.min, info.max + 1, shape, dtype=DTYPES[sew])


# ---------------------------------------------------------------------------
# Element-wise kernels: XOR / ADD / MUL / ReLU / Leaky-ReLU
# ---------------------------------------------------------------------------

_EW_OPS: dict[str, tuple[CaesarOp, VOp, Callable]] = {
    "xor": (CaesarOp.XOR, VOp.VXOR, lambda a, b: a ^ b),
    "add": (CaesarOp.ADD, VOp.VADD, lambda a, b: a + b),
    "mul": (CaesarOp.MUL, VOp.VMUL, lambda a, b: a * b),
}


def build_elementwise(op_name: str, sew: int, caesar_bytes: int = 8 * 1024,
                      carus_bytes: int = 10 * 1024, seed: int = 0) -> KernelBuild:
    cop, vop, fn = _EW_OPS[op_name]
    rng = _rng(seed)

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        a, b = _rand(rng, n, sew), _rand(rng, n, sew)
        oracle = _wrap(fn(a.astype(np.int64), b.astype(np.int64)), sew)
        nw = nbytes // 4
        if engine == "caesar":
            mem = np.zeros(C.CAESAR_MEM_BYTES // 4, np.int32)
            s1, s2, d = 0, 4096, nw          # src1 bank0, src2 bank1, dst bank0
            mem[s1:s1 + nw] = alu.pack_np(a)
            mem[s2:s2 + nw] = alu.pack_np(b)
            stream = [caesar_entry(cop, d + i, s1 + i, s2 + i)
                      for i in range(nw)]
            return EngineBuild(stream, mem, (d, nw)), oracle, n
        # carus: chunk across registers, indirect template
        rw = C.CARUS_REG_WORDS
        n_chunks = -(-nw // rw)
        vrf = np.zeros((C.CARUS_N_VREGS, rw), np.int32)
        flat = vrf.reshape(-1)
        flat[0:nw] = alu.pack_np(a)
        flat[10 * rw:10 * rw + nw] = alu.pack_np(b)
        vlmax = rw * (32 // sew)
        ents = [trace_entry(VOp.VSETVL, sval1=vlmax)]
        for i in range(n_chunks):
            ents.append(trace_entry(
                vop, sval2=isa.pack_indices(20 + i, 10 + i, i),
                mode=isa.MODE_VV | isa.MODE_INDIRECT))
        return EngineBuild(ents, vrf, (20 * rw, nw), ecpu_instrs=3), oracle, n

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    # oracles differ per engine (different sizes); stored per-engine
    return _kernel_build(op_name, sew, (cz, orc_c), (kz, orc_k, n_out))


def build_relu(sew: int, caesar_bytes: int = 8 * 1024,
               carus_bytes: int = 16 * 1024, seed: int = 1,
               leaky_shift: int = 0) -> KernelBuild:
    """ReLU (leaky_shift=0) or Leaky-ReLU with slope 2^-shift.

    Trick used on both engines: leaky_relu(x) = max(x, x >> shift) for
    arithmetic right shift — for shift=0 this degenerates to plain max(x, x)
    so plain ReLU uses max(x, 0) instead (1 op/word)."""
    rng = _rng(seed)
    name = "relu" if leaky_shift == 0 else "leaky_relu"

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        x = _rand(rng, n, sew)
        if leaky_shift == 0:
            oracle = np.maximum(x, 0)
        else:
            oracle = np.maximum(x, (x >> leaky_shift)).astype(DTYPES[sew])
        nw = nbytes // 4
        if engine == "caesar":
            mem = np.zeros(C.CAESAR_MEM_BYTES // 4, np.int32)
            s, d, zero_addr, t = 4096, nw, 0, 16     # src bank1; consts+scratch
            mem[s:s + nw] = alu.pack_np(x)           # bank0: zero@0, shift@1,
            assert d + nw <= 4096                    # scratch@16..31, dst@nw..
            stream = []
            for i in range(nw):
                if leaky_shift == 0:
                    stream.append(caesar_entry(CaesarOp.MAX, d + i, s + i,
                                               zero_addr))
                else:
                    mem[1] = _splat_word(leaky_shift, sew)
                    stream.append(caesar_entry(CaesarOp.SRA, t + i % 16,
                                               s + i, 1))
                    stream.append(caesar_entry(
                        CaesarOp.MAX, d + i, s + i,
                        (t + i % 16) | 0))  # no same-bank penalty: t bank0, s bank1
            return EngineBuild(stream, mem, (d, nw)), oracle, n
        rw = C.CARUS_REG_WORDS
        n_chunks = -(-nw // rw)
        vrf = np.zeros((C.CARUS_N_VREGS, rw), np.int32)
        vrf.reshape(-1)[:nw] = alu.pack_np(x)
        vlmax = rw * (32 // sew)
        ents = [trace_entry(VOp.VSETVL, sval1=vlmax)]
        for i in range(n_chunks):
            if leaky_shift == 0:
                ents.append(trace_entry(
                    VOp.VMAX, sval1=0,
                    sval2=isa.pack_indices(16 + i, i, 0),
                    mode=isa.MODE_VX | isa.MODE_INDIRECT))
            else:
                ents.append(trace_entry(
                    VOp.VSRA, imm=leaky_shift,
                    sval2=isa.pack_indices(16 + i, i, 0),
                    mode=isa.MODE_VI | isa.MODE_INDIRECT))
                ents.append(trace_entry(
                    VOp.VMAX,
                    sval2=isa.pack_indices(16 + i, i, 16 + i),
                    mode=isa.MODE_VV | isa.MODE_INDIRECT))
        return EngineBuild(ents, vrf, (16 * rw, nw), ecpu_instrs=3), oracle, n

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    return _kernel_build(name, sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# Matmul / GEMM:  A[8,8] x B[8,P]  (Table V footnotes b, c)
# ---------------------------------------------------------------------------

CAESAR_MATMUL_P = {32: 128, 16: 256, 8: 512}
CARUS_MATMUL_P = {32: 256, 16: 512, 8: 1024}


def build_matmul(sew: int, p: int | None = None, seed: int = 2,
                 gemm: bool = False, alpha: int = 3, beta: int = 2,
                 shift: int = 4) -> KernelBuild:
    """C = A@B (matmul) or C = (alpha*(A@B) >> s) + (beta*C0 >> s) (gemm,
    fixed-point scaling by powers-of-two-normalized integer constants)."""
    rng = _rng(seed)
    m, k = 8, 8
    lanes = 32 // sew

    def oracle_fn(A, B, C0):
        P_ = _wrap(A.astype(np.int64) @ B.astype(np.int64), sew)
        if not gemm:
            return P_
        t1 = _wrap(_wrap(P_.astype(np.int64) * alpha, sew) >> shift, sew)
        t2 = _wrap(_wrap(C0.astype(np.int64) * beta, sew) >> shift, sew)
        return _wrap(t1.astype(np.int64) + t2.astype(np.int64), sew)

    def make_caesar(P):
        A = _rand(rng, (m, k), sew)
        B = _rand(rng, (k, P), sew)
        C0 = _rand(rng, (m, P), sew) if gemm else np.zeros((m, P), DTYPES[sew])
        oracle = oracle_fn(A, B, C0)
        mem = np.zeros(C.CAESAR_MEM_BYTES // 4, np.int32)
        row_w = P // lanes
        # bank0: splatted A (m*k words), constants, C; bank1: B (+ C0 for gemm)
        a_base, const_base, c_base, b_base = 0, 64, 128, 4096
        for i in range(m):
            for kk in range(k):
                mem[a_base + i * k + kk] = _splat_word(A[i, kk], sew)
        mem[const_base] = _splat_word(alpha, sew)
        mem[const_base + 1] = _splat_word(beta, sew)
        mem[const_base + 2] = _splat_word(shift, sew)
        for r in range(k):
            mem[b_base + r * row_w: b_base + (r + 1) * row_w] = \
                alu.pack_np(B[r])
        c0_base = b_base + k * row_w
        if gemm:
            for r in range(m):
                mem[c0_base + r * row_w: c0_base + (r + 1) * row_w] = \
                    alu.pack_np(C0[r])
        stream = []
        t = 2048  # scratch, bank0
        for i in range(m):
            for jw in range(row_w):
                dest = c_base + i * row_w + jw
                stream.append(caesar_entry(CaesarOp.MAC_INIT, 0,
                                           a_base + i * k, b_base + jw))
                for kk in range(1, k - 1):
                    stream.append(caesar_entry(
                        CaesarOp.MAC, 0, a_base + i * k + kk,
                        b_base + kk * row_w + jw))
                stream.append(caesar_entry(
                    CaesarOp.MAC_STORE, dest if not gemm else t,
                    a_base + i * k + (k - 1), b_base + (k - 1) * row_w + jw))
                if gemm:
                    stream.append(caesar_entry(CaesarOp.MUL, t + 1, t,
                                               const_base))
                    stream.append(caesar_entry(CaesarOp.SRA, t + 2, t + 1,
                                               const_base + 2))
                    stream.append(caesar_entry(CaesarOp.MUL, t + 3,
                                               c0_base + i * row_w + jw,
                                               const_base + 1))
                    stream.append(caesar_entry(CaesarOp.SRA, t + 4, t + 3,
                                               const_base + 2))
                    stream.append(caesar_entry(CaesarOp.ADD, dest, t + 2,
                                               t + 4))
        post = lambda e: e.reshape(m, row_w * lanes)[:, :P]
        return EngineBuild(stream, mem, (c_base, m * row_w), post=post), \
            oracle, m * P

    def make_carus(P):
        A = _rand(rng, (m, k), sew)
        B = _rand(rng, (k, P), sew)
        C0 = _rand(rng, (m, P), sew) if gemm else np.zeros((m, P), DTYPES[sew])
        oracle = oracle_fn(A, B, C0)
        rw = C.CARUS_REG_WORDS
        row_regs = -(-P // (rw * lanes))   # registers per row (1 at paper sizes)
        assert row_regs == 1, "paper shapes fit one register per row"
        vrf = np.zeros((C.CARUS_N_VREGS, rw), np.int32)
        for r in range(k):
            vrf[r, :P // lanes] = alu.pack_np(B[r])
        c_regs = 8
        if gemm:
            for r in range(m):
                vrf[16 + r, :P // lanes] = alu.pack_np(C0[r])
        vrf[31, :m * k // lanes] = alu.pack_np(A.reshape(-1))
        ents = [trace_entry(VOp.VSETVL, sval1=P)]
        for i in range(m):
            for kk in range(k):
                # eCPU reads A[i,k] from v31 (emvx), then issues vmul/vmacc.vx
                # (first tap uses vmul — no separate accumulator init needed)
                ents.append(trace_entry(VOp.EMVX, vs2=31, sval1=i * k + kk))
                op = VOp.VMUL if kk == 0 else VOp.VMACC
                ents.append(trace_entry(op, vd=c_regs + i, vs2=kk,
                                        sval1=int(A[i, kk]),
                                        mode=isa.MODE_VX))
            if gemm:
                ents.append(trace_entry(VOp.VMUL, vd=c_regs + i,
                                        vs2=c_regs + i, sval1=alpha,
                                        mode=isa.MODE_VX))
                ents.append(trace_entry(VOp.VSRA, vd=c_regs + i,
                                        vs2=c_regs + i, imm=shift,
                                        mode=isa.MODE_VI))
                ents.append(trace_entry(VOp.VMUL, vd=16 + i, vs2=16 + i,
                                        sval1=beta, mode=isa.MODE_VX))
                ents.append(trace_entry(VOp.VSRA, vd=16 + i, vs2=16 + i,
                                        imm=shift, mode=isa.MODE_VI))
                ents.append(trace_entry(VOp.VADD, vd=c_regs + i,
                                        vs2=c_regs + i, vs1=16 + i,
                                        mode=isa.MODE_VV))
        out_words = m * rw
        post = lambda e: e.reshape(m, rw * lanes)[:, :P]
        return EngineBuild(ents, vrf, (c_regs * rw, out_words),
                           ecpu_instrs=3, post=post), oracle, m * P

    cz, orc_c, _ = make_caesar(p or CAESAR_MATMUL_P[sew])
    kz, orc_k, n_out = make_carus(p or CARUS_MATMUL_P[sew])
    return _kernel_build("gemm" if gemm else "matmul", sew,
                         (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# 2D convolution:  A[8,n] (*) F[f,f], 'valid' (Table V footnote d)
# ---------------------------------------------------------------------------

CAESAR_CONV = {32: (64, 3), 16: (64, 4), 8: (128, 4)}   # (n, f)
CARUS_CONV = {32: (256, 3), 16: (512, 3), 8: (1024, 3)}


def build_conv2d(sew: int, n: int | None = None, f: int | None = None,
                 seed: int = 3) -> KernelBuild:
    rng = _rng(seed)
    rows = 8
    lanes = 32 // sew

    def conv_oracle(A, F):
        out_r, out_c = rows - F.shape[0] + 1, A.shape[1] - F.shape[1] + 1
        out = np.zeros((out_r, out_c), np.int64)
        for di in range(F.shape[0]):
            for dj in range(F.shape[1]):
                out += (A[di:di + out_r, dj:dj + out_c].astype(np.int64)
                        * int(F[di, dj]))
        return _wrap(out, sew)

    def make_caesar(nn, ff):
        A = _rand(rng, (rows, nn), sew)
        F = _rand(rng, (ff, ff), sew)
        oracle = conv_oracle(A, F)
        out_c = nn - ff + 1
        out_w = -(-out_c // lanes)
        row_w = nn // lanes
        mem = np.zeros(C.CAESAR_MEM_BYTES // 4, np.int32)
        # bank1: byte-shifted replicas of A (lane-alignment trick)
        rep_base = 4096
        rep = {}
        for dj in range(ff):
            base = rep_base + dj * rows * row_w
            rep[dj] = base
            shifted = np.zeros((rows, row_w * lanes), DTYPES[sew])
            shifted[:, :nn - dj] = A[:, dj:]
            for r in range(rows):
                mem[base + r * row_w: base + (r + 1) * row_w] = \
                    alu.pack_np(shifted[r, :row_w * lanes])
        # bank0: splatted filter taps + output
        f_base, c_base = 0, 64
        for di in range(ff):
            for dj in range(ff):
                mem[f_base + di * ff + dj] = _splat_word(F[di, dj], sew)
        stream = []
        out_r = rows - ff + 1
        for i in range(out_r):
            for jw in range(out_w):
                first = True
                for di in range(ff):
                    for dj in range(ff):
                        src1 = f_base + di * ff + dj
                        src2 = rep[dj] + (i + di) * row_w + jw
                        last = (di == ff - 1 and dj == ff - 1)
                        opc = (CaesarOp.MAC_INIT if first else
                               (CaesarOp.MAC_STORE if last else CaesarOp.MAC))
                        stream.append(caesar_entry(
                            opc, c_base + i * out_w + jw if last else 0,
                            src1, src2))
                        first = False
        post = lambda e: e.reshape(out_r, out_w * lanes)[:, :out_c]
        return (EngineBuild(stream, mem, (c_base, out_r * out_w), post=post),
                oracle, out_r * out_c, out_w, out_c)

    def make_carus(nn, ff):
        A = _rand(rng, (rows, nn), sew)
        F = _rand(rng, (ff, ff), sew)
        oracle = conv_oracle(A, F)
        rw = C.CARUS_REG_WORDS
        vrf = np.zeros((C.CARUS_N_VREGS, rw), np.int32)
        for r in range(rows):
            vrf[r, :nn // lanes] = alu.pack_np(A[r])
        out_r = rows - ff + 1
        ents = [trace_entry(VOp.VSETVL, sval1=nn)]
        # slid copies: v[8 + (dj-1)*rows + r] = slidedown(v[r], dj)
        for dj in range(1, ff):
            for r in range(rows):
                ents.append(trace_entry(VOp.VSLIDEDOWN,
                                        vd=8 + (dj - 1) * rows + r, vs2=r,
                                        sval1=dj, mode=isa.MODE_VX))
        c0 = 8 + (ff - 1) * rows
        fflat = F.reshape(-1)
        fw = alu.pack_np(np.pad(fflat, (0, (-len(fflat)) % lanes)))
        vrf[31, :len(fw)] = fw
        for i in range(out_r):
            for di in range(ff):
                for dj in range(ff):
                    src = (i + di) if dj == 0 else 8 + (dj - 1) * rows + (i + di)
                    ents.append(trace_entry(VOp.EMVX, vs2=31,
                                            sval1=di * ff + dj))
                    op = VOp.VMUL if (di == 0 and dj == 0) else VOp.VMACC
                    ents.append(trace_entry(op, vd=c0 + i, vs2=src,
                                            sval1=int(F[di, dj]),
                                            mode=isa.MODE_VX))
        out_c = nn - ff + 1
        post = lambda e: e.reshape(out_r, rw * lanes)[:, :out_c]
        return (EngineBuild(ents, vrf, (c0 * rw, out_r * rw), ecpu_instrs=3,
                            post=post),
                oracle, out_r * out_c, c0)

    nn_c, ff_c = (n, f) if n else CAESAR_CONV[sew]
    nn_k, ff_k = (n, f) if n else CARUS_CONV[sew]
    cz, orc_c, _, _, _ = make_caesar(nn_c, ff_c)
    kz, orc_k, n_out, _ = make_carus(nn_k, ff_k)
    return _kernel_build("conv2d", sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------
# Max pooling 2x2 stride 2 (vertical on NMC, horizontal on host — Sec. V-B1)
# ---------------------------------------------------------------------------

def build_maxpool(sew: int, caesar_bytes: int = 8 * 1024,
                  carus_bytes: int = 16 * 1024, seed: int = 4,
                  width: int = 128) -> KernelBuild:
    rng = _rng(seed)
    lanes = 32 // sew

    def pool_oracle(X):
        r, c = X.shape
        v = np.maximum(X[0::2], X[1::2])
        return np.maximum(v[:, 0::2], v[:, 1::2]).astype(DTYPES[sew])

    # host-side horizontal-pool cycle cost per output (fitted to Table V;
    # see EXPERIMENTS.md §Paper-validation for the residuals).  Sub-word
    # widths need lane extraction/repacking on the host (~16 cycles/output);
    # 32-bit is a plain load/load/max/store (~4 cycles/output).
    horiz_cpu = {8: 15.6, 16: 17.2, 32: 4.2}[sew]
    horiz_ecpu = {8: 10.0, 16: 11.3, 32: 13.2}[sew]

    def make(nbytes, engine):
        n = nbytes // (sew // 8)
        rows_n = n // width
        X = _rand(rng, (rows_n, width), sew)
        oracle = pool_oracle(X)

        def post(vert_elems: np.ndarray) -> np.ndarray:
            v = vert_elems.reshape(rows_n // 2, width)
            return np.maximum(v[:, 0::2], v[:, 1::2]).astype(DTYPES[sew])

        row_w = width // lanes
        n_out = (rows_n // 2) * (width // 2)
        if engine == "caesar":
            mem = np.zeros(C.CAESAR_MEM_BYTES // 4, np.int32)
            # even rows bank0, odd rows bank1 => no same-bank conflicts
            e_base, o_base, d_base = 0, 4096, 2048
            for r in range(rows_n // 2):
                mem[e_base + r * row_w:(e_base + (r + 1) * row_w)] = \
                    alu.pack_np(X[2 * r])
                mem[o_base + r * row_w:(o_base + (r + 1) * row_w)] = \
                    alu.pack_np(X[2 * r + 1])
            stream = [caesar_entry(CaesarOp.MAX, d_base + i, e_base + i,
                                   o_base + i)
                      for i in range((rows_n // 2) * row_w)]
            return (EngineBuild(stream, mem, (d_base, (rows_n // 2) * row_w),
                                host_cycles=n_out * horiz_cpu, post=post),
                    oracle, n_out)
        rw = C.CARUS_REG_WORDS
        rows_per_reg = rw * lanes // width
        n_regs_half = -(-(rows_n // 2) // rows_per_reg)
        vrf = np.zeros((C.CARUS_N_VREGS, rw), np.int32)
        even = X[0::2].reshape(-1)
        odd = X[1::2].reshape(-1)
        vrf.reshape(-1)[:len(even) // lanes] = alu.pack_np(even)
        vrf.reshape(-1)[10 * rw:10 * rw + len(odd) // lanes] = alu.pack_np(odd)
        vlmax = rw * lanes
        ents = [trace_entry(VOp.VSETVL, sval1=vlmax)]
        for i in range(n_regs_half):
            ents.append(trace_entry(
                VOp.VMAX, sval2=isa.pack_indices(20 + i, 10 + i, i),
                mode=isa.MODE_VV | isa.MODE_INDIRECT))
        return (EngineBuild(ents, vrf, (20 * rw, len(even) // lanes),
                            host_cycles=n_out * horiz_ecpu,
                            ecpu_instrs=3, post=post), oracle, n_out)

    cz, orc_c, _ = make(caesar_bytes, "caesar")
    kz, orc_k, n_out = make(carus_bytes, "carus")
    # engine oracles: vertical-stage outputs live in NMC memory; full pooled
    # oracle (orc_*) includes host horizontal stage.
    return _kernel_build("maxpool", sew, (cz, orc_c), (kz, orc_k, n_out))


# ---------------------------------------------------------------------------

def build(name: str, sew: int, **kw) -> KernelBuild:
    if name in _EW_OPS:
        return build_elementwise(name, sew, **kw)
    if name == "relu":
        return build_relu(sew, **kw)
    if name == "leaky_relu":
        return build_relu(sew, leaky_shift=kw.pop("leaky_shift", 2), **kw)
    if name == "matmul":
        return build_matmul(sew, **kw)
    if name == "gemm":
        return build_matmul(sew, gemm=True, **kw)
    if name == "conv2d":
        return build_conv2d(sew, **kw)
    if name == "maxpool":
        return build_maxpool(sew, **kw)
    raise KeyError(name)


ALL_KERNELS = ("xor", "add", "mul", "matmul", "gemm", "conv2d", "relu",
               "leaky_relu", "maxpool")


# ---------------------------------------------------------------------------
# Execution helpers (used by tests and benchmarks) — all engine dispatch goes
# through the unified IR (repro.nmc); the engines only ever see Programs.
# ---------------------------------------------------------------------------

def run_build(eb: EngineBuild, sew: int | None = None) -> np.ndarray:
    """Execute one EngineBuild on its functional engine; return outputs
    (elements, with the host-side ``post`` stage applied).  ``sew`` overrides
    the build's own tag (needed for hand-constructed untagged builds)."""
    from repro.nmc.engine import get_engine

    prog = eb.program if sew is None else eb.program.with_sew(sew)
    engine = get_engine(prog.engine)
    final = engine.run(engine.init_state(eb.mem), prog)
    elems = engine.extract(final, eb.out_slice, prog.sew)
    return eb.post(elems) if eb.post else elems


def run_caesar(kb: KernelBuild) -> np.ndarray:
    """Execute the Caesar build on the functional engine; return outputs."""
    return run_build(kb.caesar, kb.sew)


def run_carus(kb: KernelBuild) -> np.ndarray:
    """Execute the Carus build on the scanned VPU; return outputs."""
    return run_build(kb.carus, kb.sew)


def _matches_oracle(got: np.ndarray, eb: EngineBuild) -> bool:
    exp = np.asarray(eb.oracle).reshape(-1)
    return bool((got.reshape(-1)[:exp.size] == exp).all())


def verify(kb: KernelBuild) -> dict[str, bool]:
    """Run both engines and compare against their oracles (bit-exact)."""
    return {engine: _matches_oracle(run_build(getattr(kb, engine)),
                                    getattr(kb, engine))
            for engine in ("caesar", "carus")}


def verify_sweep(kbs: list[KernelBuild], pool=None) -> dict:
    """Batched functional verification of a whole kernel sweep.

    Dispatches every (kernel, sew, engine) instance through one
    :class:`repro.nmc.pool.BucketedPool` (or any pool the caller hands in),
    so programs sharing an ``(engine, sew, instr-bucket)`` — e.g. the whole
    elementwise family at one SEW, or ragged matmul P-sweeps — share a
    single XLA compile and run as one vmapped multi-tile batch.  Returns
    ``{(name, sew): {engine: ok}}`` — bit-exact against the same oracles as
    the single-instance :func:`verify`.
    """
    from repro.nmc.pool import BucketedPool

    pool = pool if pool is not None else BucketedPool()
    builds, keys = [], []
    for kb in kbs:
        for engine in ("caesar", "carus"):
            eb = getattr(kb, engine)
            if eb is not None:
                builds.append(eb)
                keys.append((kb.name, kb.sew, engine))
    outs = pool.run_builds(builds)
    results: dict = {}
    for (name, sew, engine), eb, got in zip(keys, builds, outs):
        # AND-combine: a sweep may hold several instances of one (name, sew)
        # — e.g. fig12's matmul P-sweep — and every one must be bit-exact.
        slot = results.setdefault((name, sew), {})
        slot[engine] = slot.get(engine, True) and _matches_oracle(got, eb)
    return results
