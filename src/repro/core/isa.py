"""Instruction encodings for NM-Caesar (bus micro-ops) and NM-Carus (xvnmc).

NM-Caesar (paper Section III-A1): in *computing* mode each bus write is one
instruction.  The write-data word packs ``opcode[31:26] | src2[25:13] |
src1[12:0]`` (word offsets relative to the macro base); the *address* bus
carries the destination offset, exactly as a normal store would.

NM-Carus (Section III-B1, Tables II/III): the ``xvnmc`` custom RISC-V vector
extension lives in the Custom-2 major opcode ``0x5b``.  We implement genuine
32-bit encodings (RVV-style bit layout) so the eCPU interpreter executes real
instruction words from its eMEM:

    31      26 25   24  20 19   15 14  12 11  7 6    0
    [ funct6 ][ind][ vs2 ][ vs1  ][funct3][ vd ][opcode]

``funct3`` selects the operand variant (OPIVV/OPIVX/OPIVI/OPMVX); bit 25 — the
RVV mask bit, unused by xvnmc — is repurposed as the **indirect register
addressing** flag ``[r]``: when set, the register indices are taken from the
three least-significant bytes of scalar GPR ``x[vs2_field]`` at *runtime*
(``[7:0]=vs1, [15:8]=vs2, [23:16]=vd``), which is the paper's key code-size
mechanism (one encoded instruction iterates over arbitrary registers).
"""

from __future__ import annotations

import enum
from typing import NamedTuple

import numpy as np

# ---------------------------------------------------------------------------
# NM-Caesar
# ---------------------------------------------------------------------------

class CaesarOp(enum.IntEnum):
    AND = 0; OR = 1; XOR = 2
    ADD = 3; SUB = 4; MUL = 5
    MAC_INIT = 6; MAC = 7; MAC_STORE = 8
    DOT_INIT = 9; DOT = 10; DOT_STORE = 11
    SLL = 12; SLR = 13
    MIN = 14; MAX = 15
    CSRW = 16
    SRA = 17   # arithmetic right shift — inherited from the CV32E40P ALU the
               # design is based on (Sec. III-A2); needed by the power-of-two
               # negative slope of Leaky-ReLU (Table V footnote f).
    NOP = 18   # true no-op: no state change, zero cycles, zero energy.  Used
               # by the bucketed scheduler (repro.nmc.pool) to pad instruction
               # streams to power-of-two lengths so heterogeneous kernels
               # share one traced computation per bucket.


# Ops that use the 32-bit scalar DOT accumulator vs the packed MAC accumulator
CAESAR_DOT_OPS = {CaesarOp.DOT_INIT, CaesarOp.DOT, CaesarOp.DOT_STORE}
CAESAR_MAC_OPS = {CaesarOp.MAC_INIT, CaesarOp.MAC, CaesarOp.MAC_STORE}
CAESAR_STORE_OPS = {CaesarOp.AND, CaesarOp.OR, CaesarOp.XOR, CaesarOp.ADD,
                    CaesarOp.SUB, CaesarOp.MUL, CaesarOp.MAC_STORE,
                    CaesarOp.DOT_STORE, CaesarOp.SLL, CaesarOp.SLR,
                    CaesarOp.SRA, CaesarOp.MIN, CaesarOp.MAX}

CAESAR_ADDR_BITS = 13
CAESAR_ADDR_MASK = (1 << CAESAR_ADDR_BITS) - 1


def caesar_encode(op: CaesarOp, dest: int, src1: int, src2: int) -> tuple[int, int]:
    """-> (write_data_word, write_address) as issued on the bus."""
    assert 0 <= src1 <= CAESAR_ADDR_MASK and 0 <= src2 <= CAESAR_ADDR_MASK
    data = (int(op) << 26) | (src2 << CAESAR_ADDR_BITS) | src1
    return data & 0xFFFFFFFF, dest


def caesar_decode(data: int, addr: int) -> tuple[CaesarOp, int, int, int]:
    op = CaesarOp((data >> 26) & 0x3F)
    src2 = (data >> CAESAR_ADDR_BITS) & CAESAR_ADDR_MASK
    src1 = data & CAESAR_ADDR_MASK
    return op, addr, src1, src2


# Trace representation consumed by the scan-based engine.
CAESAR_TRACE_DTYPE = np.dtype(
    [("op", "<i4"), ("dest", "<i4"), ("src1", "<i4"), ("src2", "<i4")])


# ---------------------------------------------------------------------------
# NM-Carus: xvnmc
# ---------------------------------------------------------------------------

XVNMC_OPCODE = 0x5B  # RISC-V Custom-2

class F3(enum.IntEnum):
    OPIVV = 0b000
    OPIVI = 0b011
    OPIVX = 0b100
    OPMVX = 0b110
    OPCFG = 0b111     # vsetvli-style configuration


class VOp(enum.IntEnum):
    """funct6 assignments for xvnmc (custom space; RVV-inspired)."""
    VADD = 0b000000
    VSUB = 0b000010
    VMINU = 0b000100
    VMIN = 0b000101
    VMAXU = 0b000110
    VMAX = 0b000111
    VAND = 0b001001
    VOR = 0b001010
    VXOR = 0b001011
    VSLIDEUP = 0b001110    # also slide1up under OPMVX
    VSLIDEDOWN = 0b001111  # also slide1down under OPMVX
    VMV = 0b010111
    VMUL = 0b100100
    VMACC = 0b101101
    VSLL = 0b100101
    VSRL = 0b101000
    VSRA = 0b101001
    EMVV = 0b110000        # v[d][x[vs2_f]] = x[rs1]        (OPMVX)
    EMVX = 0b110001        # x[rd] = v[vs2][x[rs1]]         (OPMVX)
    VSETVL = 0b111111      # configuration (OPCFG)
    VNOP = 0b111110        # true no-op (VRF/VL untouched, zero cycles) —
                           # instruction-stream padding for the bucketed
                           # scheduler (repro.nmc.pool)


ARITH_OPS = {VOp.VADD: "add", VOp.VSUB: "sub", VOp.VMUL: "mul",
             VOp.VAND: "and", VOp.VOR: "or", VOp.VXOR: "xor",
             VOp.VMIN: "min", VOp.VMINU: "minu", VOp.VMAX: "max",
             VOp.VMAXU: "maxu", VOp.VSLL: "sll", VOp.VSRL: "srl",
             VOp.VSRA: "sra"}

# Compact opcode ids shared by the scanned Carus executor (dense for
# lax.switch) and the unified program IR (repro.nmc.program).
VOP_COMPACT = (VOp.VADD, VOp.VSUB, VOp.VMUL, VOp.VMACC, VOp.VAND, VOp.VOR,
               VOp.VXOR, VOp.VMIN, VOp.VMINU, VOp.VMAX, VOp.VMAXU, VOp.VSLL,
               VOp.VSRL, VOp.VSRA, VOp.VMV, VOp.VSLIDEUP, VOp.VSLIDEDOWN,
               VOp.EMVV, VOp.EMVX, VOp.VSETVL, VOp.VNOP)
COMPACT_ID = {op: i for i, op in enumerate(VOP_COMPACT)}

# Timing classes (see constants.CARUS_CPE)
VOP_TIMING_CLASS = {
    VOp.VADD: "add", VOp.VSUB: "add", VOp.VMIN: "add", VOp.VMINU: "add",
    VOp.VMAX: "add", VOp.VMAXU: "add", VOp.VAND: "logic", VOp.VOR: "logic",
    VOp.VXOR: "logic", VOp.VMUL: "mul", VOp.VMACC: "macc", VOp.VSLL: "shift",
    VOp.VSRL: "shift", VOp.VSRA: "shift", VOp.VMV: "move",
    VOp.VSLIDEUP: "move", VOp.VSLIDEDOWN: "move",
}


class VInstr(NamedTuple):
    """Decoded xvnmc instruction (fields straight from the encoding)."""
    funct6: int
    indirect: bool
    vs2_f: int      # vs2 / scalar GPR holding indirect indices / idx GPR
    vs1_f: int      # vs1 / rs1 / simm5
    funct3: int
    vd_f: int       # vd / rd
    one: bool = False  # slide1up/slide1down variant


def xvnmc_encode(i: VInstr) -> int:
    imm5 = i.vs1_f & 0x1F
    word = ((int(i.funct6) & 0x3F) << 26) | ((1 if i.indirect else 0) << 25) \
        | ((i.vs2_f & 0x1F) << 20) | (imm5 << 15) | ((int(i.funct3) & 0x7) << 12) \
        | ((i.vd_f & 0x1F) << 7) | XVNMC_OPCODE
    return word & 0xFFFFFFFF


def xvnmc_decode(word: int) -> VInstr:
    assert (word & 0x7F) == XVNMC_OPCODE, hex(word)
    return VInstr(
        funct6=(word >> 26) & 0x3F,
        indirect=bool((word >> 25) & 1),
        vs2_f=(word >> 20) & 0x1F,
        vs1_f=(word >> 15) & 0x1F,
        funct3=(word >> 12) & 0x7,
        vd_f=(word >> 7) & 0x1F,
    )


def vsetvli_encode(rd: int, rs1: int, sew: int) -> int:
    """vsetvl-style: vl = min(x[rs1], VLMAX(sew)); x[rd] = vl."""
    vsew = {8: 0, 16: 1, 32: 2}[sew]
    return (((VOp.VSETVL & 0x3F) << 26) | (vsew << 20) | ((rs1 & 0x1F) << 15)
            | (F3.OPCFG << 12) | ((rd & 0x1F) << 7) | XVNMC_OPCODE)


# ---------------------------------------------------------------------------
# Trace representation for the scan-based Carus VPU executor.
#
# A trace entry is an *issued* instruction: scalar operands already read from
# the eCPU GPRs (`sval1` = x[rs1], `sval2` = x[rs2-like field]).  Indirect
# register addressing is still resolved inside the engine from `sval2`'s bytes
# — faithfully modeling the hardware mechanism (and exercised as such).
#
# mode: 0=vv, 1=vx, 2=vi  |  bit2 (4): indirect  |  bit3 (8): slide1 variant
# ---------------------------------------------------------------------------

CARUS_TRACE_DTYPE = np.dtype(
    [("op", "<i4"), ("vd", "<i4"), ("vs1", "<i4"), ("vs2", "<i4"),
     ("sval1", "<i4"), ("sval2", "<i4"), ("imm", "<i4"), ("mode", "<i4")])

MODE_VV, MODE_VX, MODE_VI = 0, 1, 2
MODE_INDIRECT = 4
MODE_SLIDE1 = 8


def pack_indices(vd: int, vs2: int, vs1: int) -> int:
    """Pack register indices into a GPR value for indirect addressing
    (paper: 'the three least-significant bytes of a scalar GPR')."""
    return ((vd & 0xFF) << 16) | ((vs2 & 0xFF) << 8) | (vs1 & 0xFF)
