"""Sharding rules: parameter / optimizer / activation PartitionSpecs.

Layout (DESIGN.md Layer C):
  * `model` axis — tensor parallelism: attention heads, FFN hidden, MoE
    experts, Mamba2 heads/d_inner, vocab (head + embedding).
  * `data` (x `pod`) axes — batch parallelism; optimizer moments are
    additionally sharded over data on their largest divisible dim (ZeRO-2:
    moments are only touched elementwise at the update, so extra sharding is
    free at forward time and cuts optimizer HBM by the DP degree).
  * KV caches shard sequence over `model` (KV head counts often don't divide
    the axis); long-context batch=1 shapes shard sequence over data too.

Everything is *name-based*: the rule walks the param pytree and matches the
last two path components, so new modules compose without touching this file
as long as they follow the naming convention (wq/wk/wv/wi/wg/up = column
sharded, wo/down/out_proj = row sharded, norms replicated, ...).
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"

# parents whose "w" shards the OUTPUT (last) dim over `model`
# NB: img_proj is deliberately NOT here — its output feeds the residual
# stream, and a model-sharded feature axis there forces an all-gather of x
# in front of every projection of every layer (§Perf pixtral iteration 1:
# residual-stream layout poisoning, 8.2e11 B/dev of all-gathers).
_COL = {"wq", "wk", "wv", "wi", "wg", "up", "up_gate", "in_z", "in_x",
        "w_uk", "w_uv", "head", "w_z", "w_i", "w_f", "w_o"}
# parents whose "w" shards the INPUT (second-to-last) dim over `model`
_ROW = {"wo", "down", "out_proj"}
# replicated parents (small projections / routers / norms)
_REPL = {"router", "in_b", "in_c", "in_dt", "conv_bc", "w_dkv", "w_krope",
         "norm", "ln1", "ln2", "lnx", "norm_ckv", "final_norm", "enc_norm",
         "ffn_norm", "out_norm", "pos_dec"}
# head-indexed vectors sharded over `model` on their last dim
_HEADVEC = {"A_log", "D", "dt_bias"}


def _spec_for(path: tuple, leaf) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    nd = leaf.ndim

    def last_dim(axis_name):
        s = [None] * nd
        s[-1] = axis_name
        return P(*s)

    def dim(i, axis_name):
        s = [None] * nd
        s[i] = axis_name
        return P(*s)

    if last == "table" and parent == "embed":
        return dim(-2, MODEL)                       # vocab-sharded embedding
    if last == "table":                             # pos embeddings
        return P(*([None] * nd))
    if parent == "moe" and last in ("wi", "wg", "wo",
                                    "wi_q", "wg_q", "wo_q") and nd >= 3:
        return dim(-3, MODEL)                       # expert-sharded
    if parent == "moe" and last in ("wi_s", "wg_s", "wo_s"):
        return dim(-2, MODEL)                       # per-(expert,out) scales
    if any(n in _REPL for n in names[-2:]):
        return P(*([None] * nd))
    if last in _HEADVEC:
        return last_dim(MODEL)
    if last.startswith("r_"):                       # sLSTM recurrent (h,p,p)
        return dim(-3, MODEL)
    if parent == "conv_x":
        return last_dim(MODEL) if nd >= 1 else P()
    if parent in _COL:
        # quantized (NMC) form: w_q shards like w; the per-output-channel
        # scale vector shards with the output dim
        return last_dim(MODEL) if last in ("w", "b", "w_q", "scale") \
            else P(*([None] * nd))
    if parent in _ROW:
        return dim(-2, MODEL) if last in ("w", "w_q") else P(*([None] * nd))
    return P(*([None] * nd))


def param_specs(params) -> dict:
    """PartitionSpec pytree matching `params`."""
    return jax.tree_util.tree_map_with_path(_spec_for, params)


def fix_divisibility(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (e.g. whisper's 51865
    vocab is not divisible by 16 -> replicate instead of crash)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(part if dim % size == 0 else None)
    return P(*out)


def param_shardings(params, mesh: Mesh):
    specs = param_specs(params)
    return jax.tree.map(
        lambda s, p: NamedSharding(mesh, fix_divisibility(s, p.shape, mesh)),
        specs, params)


def _extend_with_data(spec: P, shape: tuple, mesh: Mesh) -> P:
    """ZeRO-2: add pod/data sharding on the largest divisible free dim."""
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dax:
        return spec
    dsize = 1
    for a in dax:
        dsize *= mesh.shape[a]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if parts[i] is None and shape[i] % dsize == 0:
            parts[i] = dax if len(dax) > 1 else dax[0]
            return P(*parts)
    return spec


def opt_state_shardings(opt_state: dict, params, mesh: Mesh):
    """Moments: param sharding + extra data-axis sharding (ZeRO-2)."""
    pspecs = param_specs(params)

    def mom(spec, p):
        spec = fix_divisibility(spec, p.shape, mesh)
        return NamedSharding(mesh, _extend_with_data(spec, p.shape, mesh))

    mspec = jax.tree.map(mom, pspecs, params)
    return {"m": mspec, "v": jax.tree.map(lambda x: x, mspec),
            "step": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def _data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shardings(batch: dict, mesh: Mesh):
    """tokens/frames/images: shard batch dim over pod+data if divisible."""
    dax = _data_axes(mesh)
    dsize = 1
    for a in dax:
        dsize *= mesh.shape[a]

    def spec(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if x.shape[0] % dsize == 0 and dsize > 1:
            return NamedSharding(mesh, P(dax, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * x.ndim)))

    return jax.tree.map(spec, batch)


def cache_shardings(caches, mesh: Mesh, batch: int, seq_axis_hints=None):
    """KV caches / recurrent states.  Rule: shard the batch dim over data if
    divisible; shard the longest remaining dim (the sequence for KV caches,
    heads for SSM states) over `model` if divisible; for batch=1 long-context
    shapes the sequence also takes the data axes."""
    dax = _data_axes(mesh)
    dsize = 1
    for a in dax:
        dsize *= mesh.shape[a]
    msize = mesh.shape[MODEL] if MODEL in mesh.axis_names else 1

    def spec(x):
        parts = [None] * x.ndim
        # find batch dim (== batch)
        bdim = None
        for i, s in enumerate(x.shape):
            if s == batch:
                bdim = i
                break
        batch_sharded = False
        if bdim is not None and batch % dsize == 0 and dsize > 1:
            parts[bdim] = dax if len(dax) > 1 else dax[0]
            batch_sharded = True
        # longest free dim -> model (sequence of KV caches, heads of states)
        free = [i for i in range(x.ndim) if parts[i] is None
                and i != bdim]
        free.sort(key=lambda i: -x.shape[i])
        for i in free:
            if msize > 1 and x.shape[i] % msize == 0:
                if not batch_sharded and dsize > 1 and \
                        x.shape[i] % (msize * dsize) == 0:
                    parts[i] = (*dax, MODEL)
                else:
                    parts[i] = MODEL
                break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(spec, caches)
