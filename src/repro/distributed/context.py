"""Process-wide mesh context: which mesh / axis names the model runs under.

Set by the trainer / server / dry-run launcher; consulted by model code for
sharding constraints and by the MoE layer for its shard_map.  When no mesh is
active (unit tests, single-host experiments) everything degrades to plain
single-device execution.

Also home of the version-compat :func:`shard_map` wrapper (DESIGN.md §6):
newer JAX exposes ``jax.shard_map(..., check_vma=...)``, JAX 0.4.x only has
``jax.experimental.shard_map.shard_map(..., check_rep=...)`` — every
shard_map in the repo goes through this one function.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None

DATA_AXES = ("pod", "data")      # batch-parallel axes (present subset used)
MODEL_AXIS = "model"


def shard_map(fn, *, mesh: Mesh, in_specs, out_specs):
    """Version-compat ``shard_map`` (replication checking disabled).

    Newer JAX: ``jax.shard_map`` with ``check_vma``; JAX 0.4.x:
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    old = _MESH
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(old)


def data_axes() -> tuple:
    if _MESH is None:
        return ()
    return tuple(a for a in DATA_AXES if a in _MESH.axis_names)


def has_model_axis() -> bool:
    return _MESH is not None and MODEL_AXIS in _MESH.axis_names \
        and _MESH.shape[MODEL_AXIS] > 1


def batch_spec(ndim: int) -> Optional[NamedSharding]:
    """(batch, ...) arrays: shard batch over pod+data."""
    if _MESH is None:
        return None
    ax = data_axes()
    spec = P(ax if ax else None, *([None] * (ndim - 1)))
    return NamedSharding(_MESH, spec)


def hidden_spec(ndim: int, axis: int = -1,
                shape: Optional[tuple] = None) -> Optional[NamedSharding]:
    """Activations with a model-sharded feature axis: (batch, ..., features).
    Axes that don't divide their dim are dropped (uneven vocab etc.)."""
    if _MESH is None or not has_model_axis():
        return None
    axis = axis % ndim
    parts = [None] * ndim
    ax = data_axes()
    if ax:
        parts[0] = ax
    parts[axis] = MODEL_AXIS
    if shape is not None:
        for i, part in enumerate(parts):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            size = 1
            for a in axes:
                size *= _MESH.shape[a]
            if shape[i] % size:
                parts[i] = None
    return NamedSharding(_MESH, P(*parts))


def replicated_spec() -> Optional[NamedSharding]:
    if _MESH is None:
        return None
    return NamedSharding(_MESH, P())
