"""Gradient compression for cross-pod data parallelism.

At 512+ chips the pod-level gradient all-reduce crosses the (slower)
inter-pod links; int8 compression with error feedback cuts those bytes 4x
at negligible quality cost (the error-feedback buffer makes the compression
unbiased over time).  This is one of the paper-independent "distributed
optimization tricks" the framework ships (DESIGN.md Layer C).

Usage inside a train step (grads are per-microbatch, already meaned over
the local data axis):

    cgrads, new_err = compress_tree(grads, err_state)
    # all-reduce / psum happens on the int8 payload via GSPMD
    grads = decompress_tree(cgrads)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array):
    """int8 stochastic-free symmetric quantization with error feedback."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    return (q, scale), new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_state):
    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    qs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        (q, s), ne = compress(g, e)
        qs.append((q, s))
        errs.append(ne)
    return jax.tree.unflatten(td, qs), jax.tree.unflatten(td, errs)


def decompress_tree(cgrads):
    return jax.tree.map(lambda qs: decompress(*qs), cgrads,
                        is_leaf=lambda x: isinstance(x, tuple))
