"""Pipeline parallelism: GPipe-style microbatched stages over a mesh axis.

For pod-scale deployments the `pod` axis can run pipeline stages instead of
pure data parallelism: each pod holds a contiguous block of layers and
microbatches stream through `collective_permute` (the jax-native analogue of
the paper's bus-level streaming: activations move, weights stay put — the
near-memory principle applied across pods).

Implementation: `shard_map` over the chosen axis; stage i's parameters are
the i-th slice of layer-stacked params; a rotating buffer carries activations
to stage i+1 via `ppermute`.  Schedule is GPipe (fill/steady/drain =
n_micro + n_stages - 1 ticks); bubble fraction (S-1)/(M+S-1).

This is the building block exercised in tests/test_pipeline.py (equivalence
with sequential execution on a 4-stage fake-device mesh); wiring it as a
`--pipeline` launch option simply re-points the `pod` axis here instead of
the gradient all-reduce.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import context


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str, n_microbatches: int):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a GPipe pipeline.

    stage_fn(params_i, h) -> h          (one stage's computation)
    stage_params: pytree with leading axis = n_stages (sharded over `axis`)
    x: (batch, ...) global input; split into n_microbatches on axis 0.
    Returns y with x's shape.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches
    xm = x.reshape(n_microbatches, mb, *x.shape[1:])

    def shard_fn(params_local, xm_local):
        # params_local: this stage's params (leading stage axis stripped to 1)
        params_i = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        ticks = n_microbatches + n_stages - 1

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if still filling)
            inject = jnp.where(t < n_microbatches, t, 0)
            h_in = jnp.where(stage == 0, xm_local[inject], buf)
            h_out = stage_fn(params_i, h_in)
            # last stage emits microbatch (t - (n_stages-1))
            emit = t - (n_stages - 1)
            outs = jax.lax.cond(
                (stage == n_stages - 1) & (emit >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(emit, 0), 0),
                lambda o: o, outs)
            # rotate activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(h_out, axis, perm)
            return buf, outs

        buf0 = jnp.zeros_like(xm_local[0])
        outs0 = jnp.zeros_like(xm_local)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf0, outs0))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    out = context.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
    )(stage_params, xm)
    return out.reshape(b, *x.shape[1:])


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead — exposed for schedule planning/telemetry."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
