"""repro: NM-Caesar / NM-Carus near-memory computing, rebuilt as a TPU-native
JAX training/serving framework.  See DESIGN.md for the layer map."""

__version__ = "1.0.0"
