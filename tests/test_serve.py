"""Serving engine: continuous batching, donated caches, NMC quantized mode."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import layers as L
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, quantize_params


def _greedy_reference(cfg, params, prompt, n_new):
    """Step-by-step single-sequence greedy decode as ground truth."""
    lg, caches = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cfg, max_len=128)
    toks = [int(jnp.argmax(lg[0]))]
    clen = jnp.asarray([len(prompt) + 1], jnp.int32)
    for _ in range(n_new - 1):
        lg, caches = lm.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches, clen, cfg)
        toks.append(int(jnp.argmax(lg[0])))
        clen = clen + 1
    return toks


def test_continuous_batching_matches_single_stream():
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 13, 7, 11)]   # more requests than slots
    eng = ServeEngine(cfg, params, n_slots=2, max_len=128)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=6))
    done = eng.run()
    assert len(done) == len(prompts)
    for req in done:
        ref = _greedy_reference(cfg, params, req.prompt, 6)
        assert req.out == ref, (req.rid, req.out, ref)


def test_nmc_quantized_serving_runs():
    """The paper's technique end-to-end in serving: int8 NMC params."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    # all 2-D linears converted
    leaves = jax.tree_util.tree_flatten_with_path(qparams)[0]
    assert any("w_q" in str(p) for p, _ in leaves)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = ServeEngine(cfg, qparams, n_slots=1, max_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 4


def test_cache_donation_shapes_stable():
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_len=32)
    shapes_before = jax.tree.map(lambda x: x.shape, eng.caches)
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=3))
    eng.run()
    shapes_after = jax.tree.map(lambda x: x.shape, eng.caches)
    assert shapes_before == shapes_after

def test_int8_kv_cache_close_to_bf16():
    """Beyond-paper NMC extension: int8 KV cache (per-token/head scales)
    must track the bf16 cache's logits closely under teacher forcing."""
    import jax.numpy as jnp
    from repro.configs import base as cb
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    cfg8 = cfg.scaled(kv_cache_dtype="int8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)))
    forced = rng.integers(0, cfg.vocab_size, (5, 2)).astype(np.int32)
    logit_traces = {}
    for name, c in (("bf16", cfg), ("int8", cfg8)):
        lg, caches = lm.prefill(params, {"tokens": toks}, c, max_len=32)
        clen = jnp.full((2,), 13, jnp.int32)
        trace = [lg]
        for t in range(5):                      # same forced continuation
            tok = jnp.asarray(forced[t][:, None])
            lg, caches = lm.decode_step(params, tok, caches, clen, c)
            clen = clen + 1
            trace.append(lg)
        logit_traces[name] = jnp.stack(trace)
        if name == "int8":
            assert caches["layers"]["k"].dtype == jnp.int8
    scale = float(jnp.std(logit_traces["bf16"]))
    err = float(jnp.max(jnp.abs(logit_traces["bf16"]
                                - logit_traces["int8"])))
    assert err < 0.15 * scale, (err, scale)


def test_moe_expert_quantization():
    """NMC w8 on MoE expert banks: router stays fp (routing margins are
    below int8 noise), experts quantize per-(expert, out-channel)."""
    import jax.numpy as jnp
    from repro.configs import base as cb
    cfg = cb.get("moonshot-v1-16b-a3b", smoke=True).scaled(dtype=jnp.float32)
    p = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
    base, _ = lm.forward(p, batch, cfg)
    qp = quantize_params(p, cfg)
    flat = jax.tree_util.tree_flatten_with_path(qp)[0]
    assert any("wi_q" in str(path) for path, _ in flat)
    assert any("router" in str(path) and "'w'" in str(path)
               for path, _ in flat)          # router NOT quantized
    qcfg = cfg.scaled(nmc_mode="w8")
    qlog, _ = lm.forward(qp, batch, qcfg)
    agree = float((jnp.argmax(base, -1) == jnp.argmax(qlog, -1)).mean())
    assert agree > 0.85, agree
    # decode path runs with quantized experts
    lg, caches = lm.prefill(qp, batch, qcfg, max_len=32)
    lg2, _ = lm.decode_step(qp, jnp.argmax(lg, -1)[:, None].astype(jnp.int32),
                            caches, jnp.full((2,), 17, jnp.int32), qcfg)
    assert np.isfinite(np.asarray(lg2)).all()
