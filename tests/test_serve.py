"""Serving engine: continuous batching, donated caches, NMC quantized mode."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import lm
from repro.serve.engine import Request, ServeEngine, quantize_params


def _greedy_reference(cfg, params, prompt, n_new):
    """Step-by-step single-sequence greedy decode as ground truth."""
    lg, caches = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cfg, max_len=128)
    toks = [int(jnp.argmax(lg[0]))]
    clen = jnp.asarray([len(prompt) + 1], jnp.int32)
    for _ in range(n_new - 1):
        lg, caches = lm.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches, clen, cfg)
        toks.append(int(jnp.argmax(lg[0])))
        clen = clen + 1
    return toks


def test_continuous_batching_matches_single_stream():
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 13, 7, 11)]   # more requests than slots
    eng = ServeEngine(cfg, params, n_slots=2, max_len=128)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=6))
    done = eng.run()
    assert len(done) == len(prompts)
    for req in done:
        ref = _greedy_reference(cfg, params, req.prompt, 6)
        assert req.out == ref, (req.rid, req.out, ref)


def test_nmc_quantized_serving_runs():
    """The paper's technique end-to-end in serving: int8 NMC params."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    # all 2-D linears converted
    leaves = jax.tree_util.tree_flatten_with_path(qparams)[0]
    assert any("w_q" in str(p) for p, _ in leaves)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = ServeEngine(cfg, qparams, n_slots=1, max_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 4


def test_w8a8_projection_shards_across_tile_array():
    """ServeEngine.nmc_project runs a W8A8 projection on the simulated
    NMC tile array, sharded across nmc_tiles by the partitioning planner
    (DESIGN.md §9) — bit-exact int8 wrap semantics, identical across tile
    counts, riding the shared nmc runtime's jit cache."""
    from repro import nmc
    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    rng = np.random.default_rng(3)
    x8 = rng.integers(-128, 128, (4, 4), dtype=np.int8)
    w8 = rng.integers(-128, 128, (4, 24), dtype=np.int8)
    oracle = (x8.astype(np.int64) @ w8.astype(np.int64)).astype(np.int8)
    eng1 = ServeEngine(cfg, qparams, n_slots=1, max_len=32)
    eng4 = ServeEngine(cfg, qparams, n_slots=1, max_len=32, nmc_tiles=4)
    assert eng1.nmc_tiles == 1 and eng4.nmc_tiles == 4
    y1 = eng1.nmc_project(x8, w8)
    y4 = eng4.nmc_project(x8, w8)
    assert y1.shape == y4.shape == (4, 24)
    assert (y1 == oracle).all() and (y4 == oracle).all()
    # the projection kernels dispatch through the shared default runtime
    # (one jit cache for serving offloads and nmc.jit kernel calls)
    assert nmc.default_runtime().queue.submitted > 0
    # an engine given a PRIVATE queue routes projection waves through it,
    # not the global default (regression: nmc_project used to ignore
    # nmc_queue entirely)
    own = nmc.DispatchQueue(pool=nmc.ResidentPool(
        pool=nmc.default_runtime().bucketed))   # share the jit cache only
    engq = ServeEngine(cfg, qparams, n_slots=1, max_len=32,
                       nmc_queue=own, nmc_tiles=2)
    yq = engq.nmc_project(x8, w8)
    assert (yq == oracle).all()
    assert own.submitted == 2                   # the 2-shard wave
    assert len(own.pool.tiles) == 2             # resident on the own pool


def test_cache_donation_shapes_stable():
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=2, max_len=32)
    shapes_before = jax.tree.map(lambda x: x.shape, eng.caches)
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=3))
    eng.run()
    shapes_after = jax.tree.map(lambda x: x.shape, eng.caches)
    assert shapes_before == shapes_after

def test_int8_kv_cache_close_to_bf16():
    """Beyond-paper NMC extension: int8 KV cache (per-token/head scales)
    must track the bf16 cache's logits closely under teacher forcing."""
    import jax.numpy as jnp
    from repro.configs import base as cb
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    cfg8 = cfg.scaled(kv_cache_dtype="int8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)))
    forced = rng.integers(0, cfg.vocab_size, (5, 2)).astype(np.int32)
    logit_traces = {}
    for name, c in (("bf16", cfg), ("int8", cfg8)):
        lg, caches = lm.prefill(params, {"tokens": toks}, c, max_len=32)
        clen = jnp.full((2,), 13, jnp.int32)
        trace = [lg]
        for t in range(5):                      # same forced continuation
            tok = jnp.asarray(forced[t][:, None])
            lg, caches = lm.decode_step(params, tok, caches, clen, c)
            clen = clen + 1
            trace.append(lg)
        logit_traces[name] = jnp.stack(trace)
        if name == "int8":
            assert caches["layers"]["k"].dtype == jnp.int8
    scale = float(jnp.std(logit_traces["bf16"]))
    err = float(jnp.max(jnp.abs(logit_traces["bf16"]
                                - logit_traces["int8"])))
    assert err < 0.15 * scale, (err, scale)


def test_moe_expert_quantization():
    """NMC w8 on MoE expert banks: router stays fp (routing margins are
    below int8 noise), experts quantize per-(expert, out-channel)."""
    import jax.numpy as jnp
    from repro.configs import base as cb
    cfg = cb.get("moonshot-v1-16b-a3b", smoke=True).scaled(dtype=jnp.float32)
    p = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
    base, _ = lm.forward(p, batch, cfg)
    qp = quantize_params(p, cfg)
    flat = jax.tree_util.tree_flatten_with_path(qp)[0]
    assert any("wi_q" in str(path) for path, _ in flat)
    assert any("router" in str(path) and "'w'" in str(path)
               for path, _ in flat)          # router NOT quantized
    qcfg = cfg.scaled(nmc_mode="w8")
    qlog, _ = lm.forward(qp, batch, qcfg)
    agree = float((jnp.argmax(base, -1) == jnp.argmax(qlog, -1)).mean())
    assert agree > 0.85, agree
    # decode path runs with quantized experts
    lg, caches = lm.prefill(qp, batch, qcfg, max_len=32)
    lg2, _ = lm.decode_step(qp, jnp.argmax(lg, -1)[:, None].astype(jnp.int32),
                            caches, jnp.full((2,), 17, jnp.int32), qcfg)
    assert np.isfinite(np.asarray(lg2)).all()


def test_nmc_project_cache_keyed_on_full_shape():
    """Regression (PR 8): the projection kernel cache was keyed on (m, k)
    only — two weights with the same activation shape but different output
    widths n must not share a cache entry, and sew=32 exact-accumulation
    callers must not collide with the default wrap-at-8 path."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    eng = ServeEngine(cfg, qparams, n_slots=1, max_len=32, nmc_tiles=2)
    rng = np.random.default_rng(11)
    x8 = rng.integers(-128, 128, (4, 4), dtype=np.int8)
    w_wide = rng.integers(-128, 128, (4, 24), dtype=np.int8)
    w_narrow = rng.integers(-128, 128, (4, 8), dtype=np.int8)
    y_wide = eng.nmc_project(x8, w_wide)
    y_narrow = eng.nmc_project(x8, w_narrow)       # same (m, k), new n
    assert y_wide.shape == (4, 24) and y_narrow.shape == (4, 8)
    assert (y_wide ==
            (x8.astype(np.int64) @ w_wide.astype(np.int64))
            .astype(np.int8)).all()
    assert (y_narrow ==
            (x8.astype(np.int64) @ w_narrow.astype(np.int64))
            .astype(np.int8)).all()
    assert (4, 4, 24, 8) in eng._nmc_proj and (4, 4, 8, 8) in eng._nmc_proj
    # sew=32: exact int32 accumulation (true W8A8 GEMM), own cache entry
    y32 = eng.nmc_project(x8, w_wide, sew=32)
    assert (y32 == x8.astype(np.int64) @ w_wide.astype(np.int64)).all()
    assert (4, 4, 24, 32) in eng._nmc_proj


def test_max_new_exact_token_counts():
    """Regression (PR 8): a max_new=1 request used to ride one decode step
    after its prefill and emit two tokens — exhausted slots must retire at
    admission time."""
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    for max_new in (1, 2, 16):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=128)
        eng.submit(Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new=max_new))
        done = eng.run()
        assert len(done) == 1
        assert len(done[0].out) == max_new, (max_new, done[0].out)


def test_single_layer_cache_slot_insert():
    """Regression (PR 8): slot insertion sniffed the batch axis from leaf
    shapes, which misreads a single-layer stack (layer dim of 1 looks like
    a batch dim of 1) — axes now come from lm.cache_batch_axes."""
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32,
                                                       n_layers=1)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]
    eng = ServeEngine(cfg, params, n_slots=3, max_len=64)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=4))
    done = eng.run()
    assert len(done) == 3
    for req in done:
        ref = _greedy_reference(cfg, params, req.prompt, 4)
        assert req.out == ref, (req.rid, req.out, ref)


def test_continuous_batching_invariants():
    """PR 8 coverage: FIFO admission order, slot reuse after retirement,
    and run() draining both the request queue and every slot."""
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=64)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new=2))
    done = eng.run()
    # one slot served all three requests (reused after each retirement),
    # completing in submission order
    assert [r.rid for r in done] == [0, 1, 2]
    assert all(len(r.out) == 2 for r in done)
    # run() drains: no queued requests, no occupied slots
    assert not eng.queue and not any(eng.slot_req)


def test_max_len_truncates_generation():
    """PR 8 coverage: a slot retires when its sequence hits max_len, so a
    request can emit at most max_len - len(prompt) tokens."""
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new=16))
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].out) == 8 - len(prompt)


def test_max_prefills_bounds_admission():
    """PR 8: admission control — at most max_prefills prefills launch per
    step even with more free slots and queued requests."""
    import pytest
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64, max_prefills=1)
    for i in range(4):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new=3))
    eng._admit()
    assert sum(r is not None for r in eng.slot_req) == 1
    assert len(eng.queue) == 3
    # the bound is per step, not global: everything still completes, in
    # FIFO order, bit-identical to unbounded admission
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    eng_ref = ServeEngine(cfg, params, n_slots=4, max_len=64)
    for i, req in enumerate(sorted(done, key=lambda r: r.rid)):
        eng_ref.submit(Request(rid=i, prompt=req.prompt, max_new=3))
    ref = eng_ref.run()
    for a, b in zip(sorted(done, key=lambda r: r.rid),
                    sorted(ref, key=lambda r: r.rid)):
        assert a.out == b.out
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, n_slots=1, max_len=32, max_prefills=0)


def test_dispatch_queue_counters_mixed_traffic():
    """PR 8 coverage: DispatchQueue counter invariants under mixed
    submit (tile programs) and submit_call (generic device work) traffic
    through one private queue."""
    from repro import nmc
    cfg = cb.get("qwen1.5-0.5b", smoke=True).scaled(nmc_mode="w8a8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    own = nmc.DispatchQueue(pool=nmc.ResidentPool(
        pool=nmc.default_runtime().bucketed))
    eng = ServeEngine(cfg, qparams, n_slots=2, max_len=32,
                      nmc_queue=own, nmc_tiles=2)
    rng = np.random.default_rng(9)
    x8 = rng.integers(-128, 128, (3, 4), dtype=np.int8)
    w8 = rng.integers(-128, 128, (4, 16), dtype=np.int8)
    y = eng.nmc_project(x8, w8)                     # 2-shard tile wave
    assert (y == (x8.astype(np.int64) @ w8.astype(np.int64))
            .astype(np.int8)).all()
    eng.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
        max_new=3))
    eng.run()                                       # submit_call traffic
    own.drain()
    # lifecycle conservation: everything submitted launched and resolved
    assert own.submitted == own.launched == own.resolved == 2
    assert own.waves >= 1
    # generic device work is counted separately: 1 prefill + decode steps
    assert own.calls >= 3
    # a second projection through the same queue keeps the books balanced
    eng.nmc_project(x8, w8)
    assert own.submitted == own.launched == own.resolved == 4
