"""Distribution: sharding rules, multi-device execution (subprocess with 8
fake devices so the main test process keeps its single-device view)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs import base as cb
from repro.distributed import sharding
from jax.sharding import PartitionSpec as P


def test_param_specs_cover_all_archs():
    for arch in cb.ARCH_IDS:
        cfg = cb.get(arch, smoke=True)
        from repro.models import lm
        params = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        specs = sharding.param_specs(params)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        assert flat, arch
        for path, spec in flat:
            assert isinstance(spec, P), (arch, path)


def test_col_row_rules():
    params = {"wq": {"w": np.zeros((64, 128))},
              "wo": {"w": np.zeros((128, 64)), "b": np.zeros(64)},
              "norm": {"g": np.zeros(64)},
              "moe": {"wi": np.zeros((8, 64, 96)),
                      "router": {"w": np.zeros((64, 8))}}}
    specs = sharding.param_specs(params)
    assert specs["wq"]["w"] == P(None, "model")
    assert specs["wo"]["w"] == P("model", None)
    assert specs["wo"]["b"] == P(None)
    assert specs["norm"]["g"] == P(None)
    assert specs["moe"]["wi"] == P("model", None, None)
    assert specs["moe"]["router"]["w"] == P(None, None)


def test_divisibility_guard():
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # 51865 not divisible by anything > 1 relevant — spec survives on a
    # 1-sized axis
    fixed = sharding.fix_divisibility(P("model", None), (51865, 384), mesh)
    assert fixed == P("model", None)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    import sys
    sys.path.insert(0, "src")
    from repro.configs import base as cb
    from repro.distributed import context, sharding
    from repro.launch.mesh import make_mesh
    from repro.optim import adamw
    from repro.train import step as step_lib

    arch = sys.argv[1]
    cfg = cb.get(arch, smoke=True)
    mesh = make_mesh(np.array(jax.devices()).reshape(2, 4),
                     ("data", "model"))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 32)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(4, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(rng.normal(
            size=(4, cfg.n_img_tokens, cfg.d_model)).astype(np.float32))
        batch["tokens"] = batch["tokens"][:, : 32 - cfg.n_img_tokens]

    with context.use_mesh(mesh):
        params, opt = step_lib.init_train_state(jax.random.PRNGKey(0), cfg)
        pshard = sharding.param_shardings(params, mesh)
        params = jax.device_put(params, pshard)
        fn = jax.jit(step_lib.make_train_step(
            cfg, adamw.AdamWConfig(total_steps=10)))
        p2, o2, m = fn(params, opt, batch)
        loss1 = float(m["loss"])
        p2, o2, m = fn(p2, o2, batch)

    # single-device reference of step 1
    context.set_mesh(None)
    params1, opt1 = step_lib.init_train_state(jax.random.PRNGKey(0), cfg)
    fn1 = jax.jit(step_lib.make_train_step(
        cfg, adamw.AdamWConfig(total_steps=10)))
    _, _, m1 = fn1(params1, opt1, batch)
    print(json.dumps({"loss_mesh": loss1, "loss_single": float(m1["loss"]),
                      "loss2": float(m["loss"])}))
""")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "moonshot-v1-16b-a3b",
                                  "zamba2-2.7b", "deepseek-v2-lite-16b"])
def test_multidevice_train_step_matches_single(arch):
    """2x4 mesh (DP x TP incl. MoE expert parallelism) must reproduce the
    single-device loss — run in a subprocess so the fake-device XLA flag
    doesn't leak into this process."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT, arch],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_mesh"] - res["loss_single"]) < 0.05, res
    assert np.isfinite(res["loss2"])
