"""Property-based differential fuzzer: engine backends vs a numpy oracle.

Random unified-IR programs (hypothesis-generated, or the deterministic
vendored shim offline) execute on **every registered engine backend** —
the ``lax.scan`` interpreters and the fused Pallas kernels (interpret mode
on CPU, so this fuzz coverage needs no accelerator) — and are checked
**bit-exact** against independent numpy interpreters built on the
``repro.core.alu`` numpy mirrors (``lane_binop_np`` & co.) — an entirely
separate evaluation path: no JAX, no tracing, plain int64 arithmetic with
truncation at pack time.  Three properties, each across SEW in {8, 16, 32}
and backend in {scan, pallas}:

* random NM-Caesar bus-op programs (all binops + MAC/DOT accumulator chains
  + NOPs, random addresses) match the numpy memory-image interpreter;
* random NM-Carus xvnmc traces (arith vv/vx/vi, vmacc, vmv, vsetvl with
  dynamic VL, VL-masked tail-undisturbed writeback, NOPs) match the numpy
  VRF interpreter;
* one abstract elementwise op chain lowered to BOTH engines produces the
  same elements, equal to the shared numpy lane chain (the cross-engine
  differential: ops expressible on both ISAs must agree).

Programs NOP-pad to fixed instruction buckets so each engine traces once
per SEW for the whole fuzz run (the bucketed-scheduler property the suite
already proves).  Indirect addressing, slides and EMVV/EMVX are exercised
by tests/test_engines.py; they are out of the expressible-on-both subset
fuzzed here.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import alu, isa
from repro.core.carus import CarusConfig
from repro.core.isa import CaesarOp, VOp
from repro.nmc.engine import BACKENDS, get_engine
from repro.nmc.program import Program, caesar_entry, carus_entry

SEWS = (8, 16, 32)

CAESAR_MEM_WORDS = 8192
CAESAR_BUCKET = 16          # fuzzed streams pad here: one trace per SEW
CARUS_BUCKET = 16

# Independent op tables (deliberately restated, not imported from the
# engines, so a transcription bug in either side is caught).
CAESAR_BINOPS = {
    CaesarOp.AND: "and", CaesarOp.OR: "or", CaesarOp.XOR: "xor",
    CaesarOp.ADD: "add", CaesarOp.SUB: "sub", CaesarOp.MUL: "mul",
    CaesarOp.SLL: "sll", CaesarOp.SLR: "srl", CaesarOp.SRA: "sra",
    CaesarOp.MIN: "min", CaesarOp.MAX: "max",
}
CARUS_ARITH = {
    VOp.VADD: "add", VOp.VSUB: "sub", VOp.VMUL: "mul", VOp.VAND: "and",
    VOp.VOR: "or", VOp.VXOR: "xor", VOp.VMIN: "min", VOp.VMINU: "minu",
    VOp.VMAX: "max", VOp.VMAXU: "maxu", VOp.VSLL: "sll", VOp.VSRL: "srl",
    VOp.VSRA: "sra",
}
# ops expressible on both ISAs, as (caesar, carus, lane-op) triples
COMMON_OPS = [(c, {"add": VOp.VADD, "sub": VOp.VSUB, "mul": VOp.VMUL,
                   "and": VOp.VAND, "or": VOp.VOR, "xor": VOp.VXOR,
                   "min": VOp.VMIN, "max": VOp.VMAX, "sll": VOp.VSLL,
                   "srl": VOp.VSRL, "sra": VOp.VSRA}[name], name)
              for c, name in CAESAR_BINOPS.items()]


# ---------------------------------------------------------------------------
# numpy reference interpreters (the oracle side of the differential)
# ---------------------------------------------------------------------------

def caesar_oracle(mem: np.ndarray, prog: Program) -> np.ndarray:
    """Walk a Caesar IR program over a numpy memory image (word at a time),
    carrying the packed MAC and scalar DOT accumulators."""
    mem = np.array(mem, dtype=np.int32).copy()
    sew = prog.sew
    mac = np.int32(0)
    dot = 0
    for e in prog.entries:
        op = CaesarOp(int(e["op"]))
        d, s1, s2 = int(e["dest"]), int(e["src1"]), int(e["src2"])
        if op == CaesarOp.NOP:
            continue
        a, b = mem[s1], mem[s2]
        if op in CAESAR_BINOPS:
            mem[d] = alu.word_binop_np(CAESAR_BINOPS[op], a, b, sew)
        elif op == CaesarOp.MAC_INIT:
            mac = alu.word_macc_np(np.int32(0), a, b, sew)
        elif op == CaesarOp.MAC:
            mac = alu.word_macc_np(mac, a, b, sew)
        elif op == CaesarOp.MAC_STORE:
            mac = alu.word_macc_np(mac, a, b, sew)
            mem[d] = mac
        elif op == CaesarOp.DOT_INIT:
            dot = alu.word_dot_np(0, a, b, sew)
        elif op == CaesarOp.DOT:
            dot = alu.word_dot_np(dot, a, b, sew)
        elif op == CaesarOp.DOT_STORE:
            dot = alu.word_dot_np(dot, a, b, sew)
            mem[d] = dot
        else:
            raise AssertionError(op)
    return mem


def carus_oracle(vrf: np.ndarray, prog: Program) -> np.ndarray:
    """Walk a Carus IR trace over a numpy VRF with dynamic VL and the
    VL-masked (tail-undisturbed) writeback of the scanned VPU."""
    cfg = CarusConfig()
    vrf = np.array(vrf, dtype=np.int32).reshape(cfg.n_regs,
                                                cfg.reg_words).copy()
    sew = prog.sew
    L = 32 // sew
    n_elems = cfg.reg_words * L
    vlmax = cfg.vlmax(sew)
    vl = vlmax
    for e in prog.entries:
        vop = isa.VOP_COMPACT[int(e["op"])]
        if vop == VOp.VNOP:
            continue
        if vop == VOp.VSETVL:
            vl = min(int(e["sval1"]), vlmax)
            continue
        opmode = int(e["mode"]) & 0x3
        vd = int(e["dest"]) % cfg.n_regs
        vs2 = int(e["src2"]) % cfg.n_regs
        vs1 = int(e["src1"]) % cfg.n_regs
        dst = alu.unpack_lanes_np(vrf[vd], sew).reshape(-1)
        s2 = alu.unpack_lanes_np(vrf[vs2], sew).reshape(-1)
        if opmode == isa.MODE_VV:
            b = alu.unpack_lanes_np(vrf[vs1], sew).reshape(-1)
        else:
            scalar = (int(e["imm"]) if opmode == isa.MODE_VI
                      else int(e["sval1"]))
            b = np.full(n_elems, scalar, np.int64)
        if vop in CARUS_ARITH:
            r = alu.lane_binop_np(CARUS_ARITH[vop], s2, b, sew)
        elif vop == VOp.VMACC:
            r = dst + s2 * b
        elif vop == VOp.VMV:
            r = b
        else:
            raise AssertionError(vop)
        sel = np.where(np.arange(n_elems) < vl, r, dst)
        vrf[vd] = alu.pack_lanes_np(sel.reshape(cfg.reg_words, L), sew)
    return vrf


def _run_engine(prog: Program, state: np.ndarray,
                backend: str = "scan") -> np.ndarray:
    eng = get_engine(prog.engine, backend)
    return np.asarray(eng.run(eng.init_state(state), prog))


# ---------------------------------------------------------------------------
# numpy-mirror unit sanity: the mirrors match the JAX ALU on random words
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", SEWS)
def test_numpy_alu_mirrors_match_jax(sew):
    rng = np.random.default_rng(3)
    words_a = rng.integers(-2**31, 2**31, 64, dtype=np.int64).astype(np.int32)
    words_b = rng.integers(-2**31, 2**31, 64, dtype=np.int64).astype(np.int32)
    import jax.numpy as jnp
    ja, jb = jnp.asarray(words_a), jnp.asarray(words_b)
    for op in alu.BINOPS:
        got = alu.word_binop_np(op, words_a, words_b, sew)
        exp = np.asarray(alu.word_binop(op, ja, jb, sew))
        assert (got == exp).all(), op
    got = alu.word_macc_np(words_a, words_b, words_a, sew)
    exp = np.asarray(alu.word_macc(ja, jb, ja, sew))
    assert (got == exp).all()
    assert alu.word_dot_np(7, words_a, words_b, sew) \
        == int(alu.word_dot(jnp.int32(7), ja, jb, sew))


# ---------------------------------------------------------------------------
# engine-specific fuzzers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sew", SEWS)
@given(n_instr=st.integers(1, CAESAR_BUCKET - 1), seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_caesar_random_programs_match_oracle(sew, backend, n_instr, seed):
    rng = np.random.default_rng(seed)
    ops = list(CAESAR_BINOPS) + [CaesarOp.MAC_INIT, CaesarOp.MAC,
                                 CaesarOp.MAC_STORE, CaesarOp.DOT_INIT,
                                 CaesarOp.DOT, CaesarOp.DOT_STORE,
                                 CaesarOp.NOP]
    entries = [caesar_entry(ops[rng.integers(len(ops))],
                            int(rng.integers(CAESAR_MEM_WORDS)),
                            int(rng.integers(CAESAR_MEM_WORDS)),
                            int(rng.integers(CAESAR_MEM_WORDS)))
               for _ in range(n_instr)]
    prog = Program.from_entries("caesar", sew, entries) \
        .pad_to(CAESAR_BUCKET)                 # one trace per SEW
    mem = rng.integers(-2**31, 2**31, CAESAR_MEM_WORDS,
                       dtype=np.int64).astype(np.int32)
    got = _run_engine(prog, mem, backend)
    exp = caesar_oracle(mem, prog)
    assert (got == exp).all(), \
        (sew, backend, seed, np.flatnonzero(got != exp)[:8])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sew", SEWS)
@given(n_instr=st.integers(1, CARUS_BUCKET - 1), seed=st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_carus_random_traces_match_oracle(sew, backend, n_instr, seed):
    rng = np.random.default_rng(seed)
    cfg = CarusConfig()
    vlmax = cfg.vlmax(sew)
    arith = list(CARUS_ARITH)
    kinds = arith + [VOp.VMACC, VOp.VMV, VOp.VSETVL, VOp.VNOP]
    entries = []
    for _ in range(n_instr):
        vop = kinds[rng.integers(len(kinds))]
        mode = int(rng.integers(3))             # vv / vx / vi, direct only
        entries.append(carus_entry(
            vop, vd=int(rng.integers(cfg.n_regs)),
            vs1=int(rng.integers(cfg.n_regs)),
            vs2=int(rng.integers(cfg.n_regs)),
            sval1=int(rng.integers(0, vlmax + 17)) if vop == VOp.VSETVL
            else int(rng.integers(-2**31, 2**31)),
            imm=int(rng.integers(-16, 16)), mode=mode))
    prog = Program.from_entries("carus", sew, entries).pad_to(CARUS_BUCKET)
    vrf = rng.integers(-2**31, 2**31, (cfg.n_regs, cfg.reg_words),
                       dtype=np.int64).astype(np.int32)
    got = _run_engine(prog, vrf, backend)
    exp = carus_oracle(vrf, prog)
    assert (got == exp).all(), \
        (sew, backend, seed, np.argwhere(got != exp)[:8])


# ---------------------------------------------------------------------------
# cross-engine differential: one abstract chain, both engines, one oracle
# ---------------------------------------------------------------------------

N_ELEMS = 32          # differential vector length (nw words = N*sew/32)

@pytest.mark.parametrize("sew", SEWS)
@given(n_ops=st.integers(1, 4), seed=st.integers(0, 2**16))
@settings(max_examples=6, deadline=None)
def test_cross_engine_chain_agrees(sew, n_ops, seed):
    """c_0 = a op_0 b; c_k = c_{k-1} op_k b — lowered to both engines from
    one spec, both must equal the shared numpy lane chain bit-exactly."""
    rng = np.random.default_rng(seed)
    chain = [COMMON_OPS[rng.integers(len(COMMON_OPS))] for _ in range(n_ops)]
    dt = alu.NP_DTYPES[sew]
    info = np.iinfo(dt)
    a = rng.integers(info.min, info.max + 1, N_ELEMS, dtype=dt)
    b = rng.integers(info.min, info.max + 1, N_ELEMS, dtype=dt)
    nw = N_ELEMS * sew // 32

    # shared numpy expectation: lanes chain, truncated at SEW each step
    cur = np.asarray(a, np.int64)
    b_l = np.asarray(b, np.int64)
    for _, _, name in chain:
        cur = alu.trunc_lanes_np(alu.lane_binop_np(name, cur, b_l, sew), sew)

    # NM-Caesar: a @ word 0 (bank 0), b @ 4096 (bank 1), chain results at
    # 1024 + k*nw; each abstract op is nw word-ops
    mem = np.zeros(CAESAR_MEM_WORDS, np.int32)
    mem[:nw] = alu.pack_np(a)
    mem[4096:4096 + nw] = alu.pack_np(b)
    centries, src = [], 0
    for k, (cop, _, _) in enumerate(chain):
        dst = 1024 + k * nw
        centries += [caesar_entry(cop, dst + i, src + i, 4096 + i)
                     for i in range(nw)]
        src = dst
    cprog = Program.from_entries("caesar", sew, centries).pad_to(128)
    cfinal = _run_engine(cprog, mem)
    caesar_out = alu.unpack_np(cfinal[src:src + nw], dt)

    # NM-Carus: a -> v1, b -> v2, chain in v3, v4, ...; vl = N_ELEMS
    cfg = CarusConfig()
    vrf = np.zeros((cfg.n_regs, cfg.reg_words), np.int32)
    vrf[1, :nw] = alu.pack_np(a)
    vrf[2, :nw] = alu.pack_np(b)
    kentries = [carus_entry(VOp.VSETVL, sval1=N_ELEMS)]
    vsrc = 1
    for k, (_, vop, _) in enumerate(chain):
        vd = 3 + k
        kentries.append(carus_entry(vop, vd=vd, vs1=2, vs2=vsrc,
                                    mode=isa.MODE_VV))
        vsrc = vd
    kprog = Program.from_entries("carus", sew, kentries).pad_to(8)
    kfinal = _run_engine(kprog, vrf)
    carus_out = alu.unpack_np(kfinal[vsrc][:nw], dt)

    exp = cur.astype(dt)
    assert (caesar_out == exp).all(), (sew, seed, chain)
    assert (carus_out == exp).all(), (sew, seed, chain)
    assert (caesar_out == carus_out).all()


# ---------------------------------------------------------------------------
# dispatch-path differential: the Pallas backend through the pools and the
# async queue must match the numpy oracles exactly like direct engine runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", SEWS)
def test_backend_parity_sync_and_async_dispatch(sew):
    """Random Caesar/Carus waves through ``ResidentPool.dispatch`` (sync)
    and ``DispatchQueue.submit`` (async, double-buffered) on backend
    "pallas", checked against the numpy oracles and the scan dispatch
    path — the whole scheduler stack, not just ``Engine.run``."""
    from repro.nmc.pool import ResidentPool
    from repro.nmc.runtime import DispatchQueue

    rng = np.random.default_rng(sew)
    ops = list(CAESAR_BINOPS) + [CaesarOp.MAC_INIT, CaesarOp.MAC,
                                 CaesarOp.MAC_STORE]
    cprogs, cmems = [], []
    for _ in range(3):
        entries = [caesar_entry(ops[rng.integers(len(ops))],
                                int(rng.integers(CAESAR_MEM_WORDS)),
                                int(rng.integers(CAESAR_MEM_WORDS)),
                                int(rng.integers(CAESAR_MEM_WORDS)))
                   for _ in range(CAESAR_BUCKET - 1)]
        cprogs.append(Program.from_entries("caesar", sew, entries)
                      .pad_to(CAESAR_BUCKET))
        cmems.append(rng.integers(-2**31, 2**31, CAESAR_MEM_WORDS,
                                  dtype=np.int64).astype(np.int32))
    cfg = CarusConfig()
    kentries = [carus_entry(VOp.VSETVL, sval1=int(cfg.vlmax(sew) // 2))] + [
        carus_entry(list(CARUS_ARITH)[rng.integers(len(CARUS_ARITH))],
                    vd=int(rng.integers(cfg.n_regs)),
                    vs1=int(rng.integers(cfg.n_regs)),
                    vs2=int(rng.integers(cfg.n_regs)),
                    sval1=int(rng.integers(-2**31, 2**31)),
                    imm=int(rng.integers(-16, 16)),
                    mode=int(rng.integers(3)))
        for _ in range(CARUS_BUCKET - 2)]
    kprog = Program.from_entries("carus", sew, kentries).pad_to(CARUS_BUCKET)
    kvrf = rng.integers(-2**31, 2**31, (cfg.n_regs, cfg.reg_words),
                        dtype=np.int64).astype(np.int32)

    oracles = [caesar_oracle(m, p) for p, m in zip(cprogs, cmems)] \
        + [carus_oracle(kvrf, kprog)]
    progs = cprogs + [kprog]
    images = cmems + [kvrf]

    for backend in BACKENDS:
        # sync: one resident wave across 4 tiles
        rp = ResidentPool(backend=backend)
        for t, (p, img) in enumerate(zip(progs, images)):
            rp.load(("t", t), p.engine, img)
        rp.dispatch([(("t", t), p) for t, p in enumerate(progs)])
        sync = [np.asarray(rp.state(("t", t)))
                for t in range(len(progs))]
        # async: same wave through the double-buffered queue
        q = DispatchQueue(pool=ResidentPool(backend=backend))
        futs = [q.submit(("t", t), p, image=img, backend=backend)
                for t, (p, img) in enumerate(zip(progs, images))]
        asyn = [np.asarray(f.state()) for f in futs]
        for got_s, got_a, exp, p in zip(sync, asyn, oracles, progs):
            exp = exp.reshape(got_s.shape)
            assert (got_s == exp).all(), (backend, "sync", p.engine, sew)
            assert (got_a == exp).all(), (backend, "async", p.engine, sew)
