"""Pallas kernels vs pure-jnp oracles (interpret=True shape/dtype sweeps)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.nmc_matmul import nmc_matmul
from repro.kernels.vrf_alu import make_prog, vrf_alu

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# nmc_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 64, 64, 128),
    (256, 512, 256, 128, 256, 256),
    (64, 128, 512, 64, 128, 64),
])
@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_nmc_matmul_shapes(m, k, n, bm, bn, bk, act):
    x = jnp.asarray(RNG.integers(-127, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-127, 128, (k, n), dtype=np.int8))
    s = jnp.asarray(RNG.uniform(1e-3, 1e-2, n).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=n).astype(np.float32))
    got = nmc_matmul(x, w, s, b, act=act, bm=bm, bn=bn, bk=bk,
                     interpret=True)
    exp = ref.nmc_matmul(x, w, s, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-4)


def test_nmc_matmul_int32_accumulation_exact():
    """Accumulation must be exact int32 (the paper's 32-bit MAC rule):
    worst-case +-127*127*K must not saturate or lose precision."""
    k = 1024
    x = jnp.full((128, k), 127, jnp.int8)
    w = jnp.full((k, 128), 127, jnp.int8)
    s = jnp.ones((128,), jnp.float32)
    got = nmc_matmul(x, w, s, None, bm=128, bn=128, bk=256, interpret=True)
    assert float(got[0, 0]) == 127 * 127 * k


def test_nmc_matmul_extreme_int8_epilogue_parity():
    """Worst-case int8 operands through the full epilogue (scale + bias +
    silu): accumulation stays exact int32 and the fused epilogue matches
    the reference within float tolerance."""
    k = 512
    x = jnp.asarray(RNG.choice(np.array([-128, -1, 127], np.int8), (64, k)))
    w = jnp.asarray(RNG.choice(np.array([-128, -1, 127], np.int8), (k, 128)))
    s = jnp.asarray(RNG.uniform(1e-4, 1e-3, 128).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=128).astype(np.float32))
    got = nmc_matmul(x, w, s, b, act="silu", bm=64, bn=128, bk=128,
                     interpret=True)
    exp = ref.nmc_matmul(x, w, s, b, act="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-4)


def test_nmc_matmul_quantized_linear_accuracy():
    """End-to-end W8A8 path keeps ~1% relative error on typical weights."""
    rng = np.random.default_rng(42)
    d_in, d_out = 256, 512
    w = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32)) * 0.05
    x = jnp.asarray(rng.normal(size=(64, d_in)).astype(np.float32))
    wq, sw = ref.quantize_rowwise(w)
    xq, sx = ref.quantize_dynamic(x)
    y = nmc_matmul(xq, wq, sw * sx, None, interpret=True, bm=64, bn=128,
                   bk=256)
    exact = x @ w
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    assert rel < 0.015, rel


# ---------------------------------------------------------------------------
# vrf_alu
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
@pytest.mark.parametrize("block_vl", [128, 512])
def test_vrf_alu_program(dtype, block_vl):
    vrf = jnp.asarray(RNG.integers(-100, 100, (16, 512)).astype(dtype))
    prog = make_prog([
        ("add", 3, 1, 2, 0, ref.VRF_MODE_VV),
        ("mul", 4, 3, 3, 0, ref.VRF_MODE_VV),
        ("max", 5, 0, 4, 0, ref.VRF_MODE_VX),
        ("sra", 6, 0, 5, 3, ref.VRF_MODE_VX),
        ("xor", 7, 6, 5, 0, ref.VRF_MODE_VV),
        ("sub", 8, 7, 3, 0, ref.VRF_MODE_VV),
        ("mv", 9, 0, 0, -5, ref.VRF_MODE_VX),
        ("min", 10, 9, 8, 0, ref.VRF_MODE_VV),
    ])
    got = vrf_alu(vrf, prog, block_vl=block_vl, interpret=True)
    pd = {k: np.asarray(prog[:, i]) for i, k in
          enumerate(("op", "vd", "vs1", "vs2", "scalar", "mode"))}
    exp = ref.vrf_alu(vrf, pd)
    assert (np.asarray(got) == np.asarray(exp)).all()


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
def test_vrf_alu_wraparound_extremes(dtype):
    """Interpret-mode parity at the integer extremes: mul/add/sub/shift on
    saturating-looking inputs must wrap two's-complement, bit-exact with
    the reference (the NMC 'standard data types' contract)."""
    info = np.iinfo(dtype)
    vrf = np.zeros((16, 256), dtype)
    vrf[1, :] = info.min
    vrf[2, :] = info.max
    vrf[3, :] = np.tile(np.array([info.min, info.max, -1, 1], dtype), 64)
    prog = make_prog([
        ("mul", 4, 1, 2, 0, ref.VRF_MODE_VV),    # min*max wraps
        ("add", 5, 2, 4, 0, ref.VRF_MODE_VV),
        ("sub", 6, 1, 5, 0, ref.VRF_MODE_VV),
        ("mul", 7, 3, 3, 0, ref.VRF_MODE_VV),    # min^2 wraps to 0 at int8
        ("sll", 8, 0, 7, info.bits - 1, ref.VRF_MODE_VX),
        ("srl", 9, 0, 1, 1, ref.VRF_MODE_VX),
        ("sra", 10, 0, 1, 1, ref.VRF_MODE_VX),
        ("add", 11, 0, 2, 1, ref.VRF_MODE_VX),   # max+1 wraps to min
    ])
    got = vrf_alu(jnp.asarray(vrf), prog, block_vl=128, interpret=True)
    pd = {k: np.asarray(prog[:, i]) for i, k in
          enumerate(("op", "vd", "vs1", "vs2", "scalar", "mode"))}
    exp = ref.vrf_alu(jnp.asarray(vrf), pd)
    assert (np.asarray(got) == np.asarray(exp)).all()
    assert np.asarray(got)[11].flat[0] == info.min   # really wrapped


@given(n_instr=st.integers(1, 12), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_vrf_alu_random_programs(n_instr, seed):
    """Property: arbitrary programs (program = data) match the oracle."""
    r = np.random.default_rng(seed)
    ops = list(ref.VRF_OPS)
    entries = [(ops[r.integers(len(ops))], int(r.integers(16)),
                int(r.integers(16)), int(r.integers(16)),
                int(r.integers(-100, 100)), int(r.integers(2)))
               for _ in range(n_instr)]
    vrf = jnp.asarray(r.integers(-100, 100, (16, 256)).astype(np.int16))
    prog = make_prog(entries)
    got = vrf_alu(vrf, prog, block_vl=128, interpret=True)
    pd = {k: np.asarray(prog[:, i]) for i, k in
          enumerate(("op", "vd", "vs1", "vs2", "scalar", "mode"))}
    exp = ref.vrf_alu(vrf, pd)
    assert (np.asarray(got) == np.asarray(exp)).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,win", [
    (2, 4, 2, 256, 256, 64, True, None),
    (1, 8, 2, 128, 512, 64, True, 128),
    (1, 4, 4, 128, 256, 32, False, None),
    (2, 2, 1, 64, 384, 128, True, None),
])
def test_flash_attention_configs(b, hq, hkv, sq, skv, d, causal, win):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, window=win, bq=64, bk=128,
                          interpret=True)
    exp = ref.attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_flash_attention_gqa_window_combined():
    """GQA + sliding window + causal in one config (the serving attention
    shape), interpret-mode vs the plain-softmax reference."""
    q = jnp.asarray(RNG.normal(size=(2, 8, 192, 64)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 2, 384, 64)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 2, 384, 64)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, window=96, bq=64, bk=64,
                          interpret=True)
    exp = ref.attention(q, k, v, causal=True, window=96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_flash_attention_mla_dv_neq_dq():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 192)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 4, 128, 192)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, 4, 128, 128)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_chunked_fallback_matches_flash():
    from repro.kernels import ops
    q = jnp.asarray(RNG.normal(size=(2, 8, 256, 64)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)).astype(np.float32))
    a = ops.chunked_attention(q, k, v, causal=True, kv_chunk=64)
    b2 = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=2e-5)
