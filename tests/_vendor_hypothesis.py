"""Minimal deterministic fallback for the `hypothesis` API surface we use.

Installed as ``sys.modules["hypothesis"]`` by ``tests/conftest.py`` *only*
when the real package is absent (the offline CI image cannot pip-install).
It implements just what the test-suite touches — ``@given`` / ``@settings``
and the ``integers`` / ``sampled_from`` / ``lists`` / ``composite``
strategies — by running each property test over ``max_examples``
deterministically sampled inputs (seeded per test name).  No shrinking, no
database, no adaptive search: a sampled property check, not a replacement
for real hypothesis.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__version__ = "0.0-vendored-shim"


class SearchStrategy:
    """A strategy is just a sampling function rng -> value."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred, _tries: int = 100):
        def sample(rng):
            for _ in range(_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(sample)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int | None = None) -> SearchStrategy:
    def sample(rng):
        # unbounded lists still need size variety for the property to bite
        hi = min_size + 10 if max_size is None else max_size
        n = rng.randint(min_size, hi)
        return [elements.sample(rng) for _ in range(n)]
    return SearchStrategy(sample)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.randrange(2)))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.sample(rng) for s in strats))


def composite(fn):
    """@st.composite — `fn(draw, *args)` becomes a strategy factory."""
    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def sample(rng):
            return fn(lambda s: s.sample(rng), *args, **kwargs)
        return SearchStrategy(sample)
    return factory


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records max_examples on the test function for @given to pick up."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats: SearchStrategy, **kw_strats):
    """Deterministic sampled @given.

    Positional strategies right-align onto the test's parameters (matching
    hypothesis' convention); parameters supplied by pytest (fixtures,
    parametrize) are preserved in the wrapper's visible signature.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        pos_names = names[len(names) - len(arg_strats):] if arg_strats else []
        supplied = dict(zip(pos_names, arg_strats))
        overlap = set(supplied) & set(kw_strats)
        assert not overlap, f"duplicate strategies for {overlap}"
        supplied.update(kw_strats)
        remaining = [p for p in sig.parameters.values()
                     if p.name not in supplied]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read at call time: honors @settings whether it sits above
            # @given (attribute lands on `wrapper` via functools.wraps /
            # direct decoration) or below it (attribute lands on `fn`,
            # copied onto `wrapper` by functools.wraps)
            max_examples = getattr(wrapper, "_shim_max_examples",
                                   _DEFAULT_MAX_EXAMPLES)
            # stable per-test seed => reproducible example stream
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(max_examples):
                drawn = {name: s.sample(rng) for name, s in supplied.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


# `from hypothesis import strategies as st` resolves this attribute; it is
# also registered as the "hypothesis.strategies" module by conftest.py.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("SearchStrategy", "integers", "sampled_from", "lists",
              "booleans", "just", "tuples", "composite"):
    setattr(strategies, _name, globals()[_name])
