"""NM-Caesar / NM-Carus functional engines: bit-exact kernel verification,
indirect register addressing, VL masking, and eCPU programmability."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import alu, carus, caesar, ecpu, isa, programs
from repro.core.isa import CaesarOp, VOp


@pytest.mark.parametrize("name", programs.ALL_KERNELS)
@pytest.mark.parametrize("sew", [8, 16, 32])
def test_kernel_bit_exact_both_engines(name, sew):
    # reduced sizes keep the scanned engines fast in CI
    kw = {}
    if name in ("xor", "add", "mul", "relu", "leaky_relu", "maxpool"):
        kw = {"caesar_bytes": 2048, "carus_bytes": 4096}
    kb = programs.build(name, sew, **kw)
    res = programs.verify(kb)
    assert res["caesar"], f"{name}/{sew}: Caesar mismatch"
    assert res["carus"], f"{name}/{sew}: Carus mismatch"


def test_indirect_equals_direct():
    """The paper's indirect register addressing: same instruction template
    with indices in a GPR must produce identical results to direct encoding."""
    vpu = carus.CarusVPU()
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, 1024, dtype=np.int8)
    b = rng.integers(-128, 128, 1024, dtype=np.int8)
    vrf = np.zeros((32, 256), np.int32)
    vrf[1], vrf[2] = alu.pack_np(a), alu.pack_np(b)
    direct = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=1024),
        carus.trace_entry(VOp.VADD, vd=3, vs1=1, vs2=2, mode=isa.MODE_VV)])
    indirect = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=1024),
        carus.trace_entry(VOp.VADD, sval2=isa.pack_indices(3, 2, 1),
                          mode=isa.MODE_VV | isa.MODE_INDIRECT)])
    out1, _, _ = vpu.run_trace(jnp.asarray(vrf), direct, 8)
    out2, _, _ = vpu.run_trace(jnp.asarray(vrf), indirect, 8)
    assert (np.asarray(out1) == np.asarray(out2)).all()


@given(vl=st.integers(1, 512), sew=st.sampled_from([8, 16, 32]))
@settings(max_examples=12, deadline=None)
def test_vl_tail_undisturbed(vl, sew):
    """Elements at index >= VL must keep their previous value."""
    vpu = carus.CarusVPU()
    vlmax = vpu.cfg.vlmax(sew)
    vl = min(vl, vlmax)
    rng = np.random.default_rng(vl)
    vrf = rng.integers(-2**31, 2**31, (32, 256)).astype(np.int32)
    before = vrf[5].copy()
    tr = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=vl),
        carus.trace_entry(VOp.VXOR, vd=5, vs1=1, vs2=2, mode=isa.MODE_VV)])
    out, _, _ = vpu.run_trace(jnp.asarray(vrf), tr, sew)
    got = alu.unpack_np(np.asarray(out[5]), np.int8 if sew == 8 else
                        np.int16 if sew == 16 else np.int32)
    prev = alu.unpack_np(before, got.dtype)
    assert (got[vl:] == prev[vl:]).all()


def test_caesar_bus_encoding_roundtrip():
    data, addr = isa.caesar_encode(CaesarOp.ADD, dest=7, src1=100, src2=4196)
    op, dest, s1, s2 = isa.caesar_decode(data, addr)
    assert (op, dest, s1, s2) == (CaesarOp.ADD, 7, 100, 4196)


def test_xvnmc_encoding_roundtrip():
    i = isa.VInstr(VOp.VMACC, True, 5, 3, isa.F3.OPIVX, 7)
    d = isa.xvnmc_decode(isa.xvnmc_encode(i))
    assert (d.funct6, d.indirect, d.vs2_f, d.vs1_f, d.funct3, d.vd_f) == \
        (VOp.VMACC, True, 5, 3, isa.F3.OPIVX, 7)


def test_ecpu_runs_assembled_indirect_loop():
    """Full programmability: the Section III-B1 loop as real RV32E+xvnmc."""
    src = """
        li   a0, 4
        li   t0, 1024
        vsetvli t1, t0, e8
        li   t2, 0x00140A00
        li   a1, 0x00010101
        li   t1, 0
    loop:
        xvnmc.vaddr.vv t2
        add  t2, t2, a1
        addi t1, t1, 1
        blt  t1, a0, loop
        halt
    """
    words = ecpu.assemble(src)
    vpu = carus.CarusVPU()
    rng = np.random.default_rng(1)
    a = rng.integers(-128, 128, 4096, dtype=np.int8)
    b = rng.integers(-128, 128, 4096, dtype=np.int8)
    vrf = np.zeros((32, 256), np.int32)
    for i in range(4):
        vrf[i] = alu.pack_np(a[i * 1024:(i + 1) * 1024])
        vrf[10 + i] = alu.pack_np(b[i * 1024:(i + 1) * 1024])
    cpu = ecpu.ECpu(vpu, jnp.asarray(vrf))
    cpu.load_program(words)
    cpu.run()
    got = np.concatenate([alu.unpack_np(np.asarray(cpu.vrf[20 + i]), np.int8)
                          for i in range(4)])
    assert (got == a + b).all()
    assert cpu.vector_retired == 5   # vsetvli + 4 vadd


def test_caesar_same_bank_timing_penalty():
    from repro.core import timing
    from repro.core.programs import EngineBuild
    both_diff = EngineBuild([(CaesarOp.ADD, 10, 0, 4096)] * 10,
                            np.zeros(8192, np.int32), (10, 1))
    both_same = EngineBuild([(CaesarOp.ADD, 10, 0, 1)] * 10,
                            np.zeros(8192, np.int32), (10, 1))
    t1 = timing.caesar_cycles(both_diff)
    t2 = timing.caesar_cycles(both_same)
    assert t2.cycles - t1.cycles == 10  # +1 cycle per same-bank op