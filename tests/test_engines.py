"""NM-Caesar / NM-Carus functional engines: bit-exact kernel verification,
indirect register addressing, VL masking, eCPU programmability, and the
engine-protocol conformance matrix (every opcode through every registered
backend implementation)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import alu, carus, caesar, ecpu, isa, programs
from repro.core.isa import CaesarOp, VOp
from repro.nmc import engine as nmc_engine
from repro.nmc.program import Program, caesar_entry, carus_entry


@pytest.mark.parametrize("name", programs.ALL_KERNELS)
@pytest.mark.parametrize("sew", [8, 16, 32])
def test_kernel_bit_exact_both_engines(name, sew):
    # reduced sizes keep the scanned engines fast in CI
    kw = {}
    if name in ("xor", "add", "mul", "relu", "leaky_relu", "maxpool"):
        kw = {"caesar_bytes": 2048, "carus_bytes": 4096}
    kb = programs.build(name, sew, **kw)
    res = programs.verify(kb)
    assert res["caesar"], f"{name}/{sew}: Caesar mismatch"
    assert res["carus"], f"{name}/{sew}: Carus mismatch"


def test_indirect_equals_direct():
    """The paper's indirect register addressing: same instruction template
    with indices in a GPR must produce identical results to direct encoding."""
    vpu = carus.CarusVPU()
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, 1024, dtype=np.int8)
    b = rng.integers(-128, 128, 1024, dtype=np.int8)
    vrf = np.zeros((32, 256), np.int32)
    vrf[1], vrf[2] = alu.pack_np(a), alu.pack_np(b)
    direct = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=1024),
        carus.trace_entry(VOp.VADD, vd=3, vs1=1, vs2=2, mode=isa.MODE_VV)])
    indirect = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=1024),
        carus.trace_entry(VOp.VADD, sval2=isa.pack_indices(3, 2, 1),
                          mode=isa.MODE_VV | isa.MODE_INDIRECT)])
    out1, _, _ = vpu.run_trace(jnp.asarray(vrf), direct, 8)
    out2, _, _ = vpu.run_trace(jnp.asarray(vrf), indirect, 8)
    assert (np.asarray(out1) == np.asarray(out2)).all()


@given(vl=st.integers(1, 512), sew=st.sampled_from([8, 16, 32]))
@settings(max_examples=12, deadline=None)
def test_vl_tail_undisturbed(vl, sew):
    """Elements at index >= VL must keep their previous value."""
    vpu = carus.CarusVPU()
    vlmax = vpu.cfg.vlmax(sew)
    vl = min(vl, vlmax)
    rng = np.random.default_rng(vl)
    vrf = rng.integers(-2**31, 2**31, (32, 256)).astype(np.int32)
    before = vrf[5].copy()
    tr = carus.trace_to_arrays([
        carus.trace_entry(VOp.VSETVL, sval1=vl),
        carus.trace_entry(VOp.VXOR, vd=5, vs1=1, vs2=2, mode=isa.MODE_VV)])
    out, _, _ = vpu.run_trace(jnp.asarray(vrf), tr, sew)
    got = alu.unpack_np(np.asarray(out[5]), np.int8 if sew == 8 else
                        np.int16 if sew == 16 else np.int32)
    prev = alu.unpack_np(before, got.dtype)
    assert (got[vl:] == prev[vl:]).all()


def test_caesar_bus_encoding_roundtrip():
    data, addr = isa.caesar_encode(CaesarOp.ADD, dest=7, src1=100, src2=4196)
    op, dest, s1, s2 = isa.caesar_decode(data, addr)
    assert (op, dest, s1, s2) == (CaesarOp.ADD, 7, 100, 4196)


def test_xvnmc_encoding_roundtrip():
    i = isa.VInstr(VOp.VMACC, True, 5, 3, isa.F3.OPIVX, 7)
    d = isa.xvnmc_decode(isa.xvnmc_encode(i))
    assert (d.funct6, d.indirect, d.vs2_f, d.vs1_f, d.funct3, d.vd_f) == \
        (VOp.VMACC, True, 5, 3, isa.F3.OPIVX, 7)


def test_ecpu_runs_assembled_indirect_loop():
    """Full programmability: the Section III-B1 loop as real RV32E+xvnmc."""
    src = """
        li   a0, 4
        li   t0, 1024
        vsetvli t1, t0, e8
        li   t2, 0x00140A00
        li   a1, 0x00010101
        li   t1, 0
    loop:
        xvnmc.vaddr.vv t2
        add  t2, t2, a1
        addi t1, t1, 1
        blt  t1, a0, loop
        halt
    """
    words = ecpu.assemble(src)
    vpu = carus.CarusVPU()
    rng = np.random.default_rng(1)
    a = rng.integers(-128, 128, 4096, dtype=np.int8)
    b = rng.integers(-128, 128, 4096, dtype=np.int8)
    vrf = np.zeros((32, 256), np.int32)
    for i in range(4):
        vrf[i] = alu.pack_np(a[i * 1024:(i + 1) * 1024])
        vrf[10 + i] = alu.pack_np(b[i * 1024:(i + 1) * 1024])
    cpu = ecpu.ECpu(vpu, jnp.asarray(vrf))
    cpu.load_program(words)
    cpu.run()
    got = np.concatenate([alu.unpack_np(np.asarray(cpu.vrf[20 + i]), np.int8)
                          for i in range(4)])
    assert (got == a + b).all()
    assert cpu.vector_retired == 5   # vsetvli + 4 vadd


def test_caesar_same_bank_timing_penalty():
    from repro.core import timing
    from repro.core.programs import EngineBuild
    both_diff = EngineBuild([(CaesarOp.ADD, 10, 0, 4096)] * 10,
                            np.zeros(8192, np.int32), (10, 1))
    both_same = EngineBuild([(CaesarOp.ADD, 10, 0, 1)] * 10,
                            np.zeros(8192, np.int32), (10, 1))
    t1 = timing.caesar_cycles(both_diff)
    t2 = timing.caesar_cycles(both_same)
    assert t2.cycles - t1.cycles == 10  # +1 cycle per same-bank op

# ---------------------------------------------------------------------------
# Engine-protocol conformance matrix (DESIGN.md §10): one small golden
# program per opcode, executed by every registered (engine, backend)
# implementation; every implementation must produce the scan reference's
# memory image bit-exactly.  Future backends get these checks for free by
# registering in repro.nmc.engine.implementations().
# ---------------------------------------------------------------------------

CONF_BUCKET = 16    # all golden programs pad here: one compile per variant


def _caesar_golden_cases():
    """(label, entries) per NM-Caesar opcode; addresses span both banks."""
    cases = []
    for op in (CaesarOp.AND, CaesarOp.OR, CaesarOp.XOR, CaesarOp.ADD,
               CaesarOp.SUB, CaesarOp.MUL, CaesarOp.SLL, CaesarOp.SLR,
               CaesarOp.SRA, CaesarOp.MIN, CaesarOp.MAX):
        cases.append((op.name.lower(), [
            caesar_entry(op, 100 + i, 7 * i, 4096 + 11 * i)
            for i in range(4)]))
    cases.append(("mac_chain", [
        caesar_entry(CaesarOp.MAC_INIT, 0, 3, 4096),
        caesar_entry(CaesarOp.MAC, 0, 5, 4098),
        caesar_entry(CaesarOp.MAC_STORE, 200, 9, 4100)]))
    cases.append(("dot_chain", [
        caesar_entry(CaesarOp.DOT_INIT, 0, 4, 4097),
        caesar_entry(CaesarOp.DOT, 0, 6, 4099),
        caesar_entry(CaesarOp.DOT_STORE, 201, 8, 4101)]))
    # CSRW and NOP must leave memory untouched (an ADD proves the stream
    # still executed around them)
    cases.append(("csrw_nop", [
        caesar_entry(CaesarOp.CSRW, 0, 1, 0),
        caesar_entry(CaesarOp.NOP, 0, 0, 0),
        caesar_entry(CaesarOp.ADD, 300, 1, 4097)]))
    return [("caesar", label, entries) for label, entries in cases]


def _carus_golden_cases():
    """(label, entries) per NM-Carus vector opcode, VL-restricted so the
    tail-undisturbed writeback is part of every golden image."""
    pre = [carus_entry(VOp.VSETVL, sval1=777)]    # < vlmax at every SEW
    cases = []
    for vop in (VOp.VADD, VOp.VSUB, VOp.VMUL, VOp.VAND, VOp.VOR, VOp.VXOR,
                VOp.VMIN, VOp.VMINU, VOp.VMAX, VOp.VMAXU, VOp.VSLL,
                VOp.VSRL, VOp.VSRA, VOp.VMACC):
        cases.append((vop.name.lower(), pre + [
            carus_entry(vop, vd=4, vs1=1, vs2=2, mode=isa.MODE_VV),
            carus_entry(vop, vd=5, vs2=2, sval1=-3, mode=isa.MODE_VX),
            carus_entry(vop, vd=6, vs2=3, imm=7, mode=isa.MODE_VI)]))
    cases.append(("vmv", pre + [
        carus_entry(VOp.VMV, vd=7, vs1=1, mode=isa.MODE_VV),
        carus_entry(VOp.VMV, vd=8, sval1=-120, mode=isa.MODE_VX)]))
    cases.append(("vslideup", pre + [
        carus_entry(VOp.VSLIDEUP, vd=9, vs2=2, sval1=5, mode=isa.MODE_VX),
        carus_entry(VOp.VSLIDEUP, vd=10, vs2=2, sval1=42,
                    mode=isa.MODE_VX | isa.MODE_SLIDE1)]))
    cases.append(("vslidedown", pre + [
        carus_entry(VOp.VSLIDEDOWN, vd=11, vs2=2, sval1=3, mode=isa.MODE_VX),
        carus_entry(VOp.VSLIDEDOWN, vd=12, vs2=2, sval1=-9,
                    mode=isa.MODE_VX | isa.MODE_SLIDE1)]))
    cases.append(("emvv_emvx", pre + [
        carus_entry(VOp.EMVV, vd=13, sval1=99, sval2=17),
        carus_entry(VOp.EMVX, vd=0, vs2=2, sval1=5)]))
    cases.append(("indirect", pre + [
        carus_entry(VOp.VADD, sval2=isa.pack_indices(14, 2, 1),
                    mode=isa.MODE_VV | isa.MODE_INDIRECT)]))
    cases.append(("vsetvl_vnop", [
        carus_entry(VOp.VSETVL, sval1=3),
        carus_entry(VOp.VNOP),
        carus_entry(VOp.VXOR, vd=15, vs1=1, vs2=2, mode=isa.MODE_VV)]))
    return [("carus", label, entries) for label, entries in cases]


CONFORMANCE_CASES = _caesar_golden_cases() + _carus_golden_cases()


def _conformance_state(engine_name: str, sew: int) -> np.ndarray:
    rng = np.random.default_rng(sew)
    if engine_name == "caesar":
        return rng.integers(-2**31, 2**31, 8192,
                            dtype=np.int64).astype(np.int32)
    return rng.integers(-2**31, 2**31, (32, 256),
                        dtype=np.int64).astype(np.int32)


@pytest.mark.parametrize("engine_name,label,entries", CONFORMANCE_CASES,
                         ids=[f"{e}-{l}" for e, l, _ in CONFORMANCE_CASES])
@pytest.mark.parametrize("backend", nmc_engine.BACKENDS)
@pytest.mark.parametrize("sew", [8, 16, 32])
def test_engine_conformance(engine_name, label, entries, backend, sew):
    prog = Program.from_entries(engine_name, sew, entries) \
        .pad_to(CONF_BUCKET)
    state = _conformance_state(engine_name, sew)
    ref_eng = nmc_engine.get_engine(engine_name, "scan")
    ref = np.asarray(ref_eng.run(ref_eng.init_state(state), prog))
    eng = nmc_engine.get_engine(engine_name, backend)
    assert isinstance(eng, nmc_engine.Engine)
    got = np.asarray(eng.run(eng.init_state(state), prog))
    assert got.shape == ref.shape
    assert (got == ref).all(), \
        (engine_name, backend, label, sew,
         np.argwhere(got != ref)[:8].tolist())


def test_implementations_registry_is_complete():
    impls = nmc_engine.implementations()
    assert set(impls) == {(n, b) for n in ("caesar", "carus")
                          for b in nmc_engine.BACKENDS}
    for name, backend in impls:
        eng = nmc_engine.get_engine(name, backend)
        assert eng.name == name
        assert isinstance(eng, nmc_engine.Engine)
