"""Trainer, checkpointing, data pipeline, optimizer tests."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.train import step as step_lib
from repro.train.trainer import Trainer, TrainerConfig


def small_cfg():
    return cb.get("qwen1.5-0.5b", smoke=True)


def test_microbatched_grads_match_full_batch():
    cfg = small_cfg()
    params, opt = step_lib.init_train_state(jax.random.PRNGKey(0), cfg)
    oc = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}
    f1 = jax.jit(step_lib.make_train_step(cfg, oc, n_microbatches=1))
    f2 = jax.jit(step_lib.make_train_step(cfg, oc, n_microbatches=2))
    p1, _, m1 = f1(params, opt, batch)
    p2, _, m2 = f2(params, opt, batch)
    # same update within numerical tolerance of bf16 accumulation
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3)


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adamw.init_state(params)
    oc = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                           weight_decay=0.0)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(params, g, state, oc)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    # a torn write: directory without DONE marker
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_data_pipeline_determinism_and_host_sharding():
    cfg = small_cfg()
    d1 = SyntheticLM(DataConfig(global_batch=8, seq_len=16, seed=3,
                                n_hosts=2, host_id=0), cfg)
    d2 = SyntheticLM(DataConfig(global_batch=8, seq_len=16, seed=3,
                                n_hosts=2, host_id=1), cfg)
    a, b = d1.batch_at(5), d2.batch_at(5)
    assert not (a["tokens"] == b["tokens"]).all()      # hosts disjoint
    assert (d1.batch_at(5)["tokens"] == a["tokens"]).all()  # deterministic
    # resume-from-step reproduces the stream
    it = d1.iterate(start_step=5)
    assert (next(it)["tokens"] == a["tokens"]).all()


def test_packed_file_dataset(tmp_path):
    toks = np.arange(0, 4096, dtype=np.uint16) % 100
    path = str(tmp_path / "toks.bin")
    toks.tofile(path)
    from repro.data.pipeline import PackedFileDataset
    ds = PackedFileDataset(path, DataConfig(global_batch=4, seq_len=15))
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 15)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert (ds.batch_at(0)["tokens"] == b["tokens"]).all()


def test_trainer_end_to_end(tmp_path):
    cfg = small_cfg()
    tc = TrainerConfig(total_steps=6, ckpt_every=3, log_every=100,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(cfg, tc, data_cfg=DataConfig(global_batch=4, seq_len=32))
    out = tr.run()
    assert out["final_step"] == 6
    assert ckpt.latest_step(str(tmp_path / "ck")) == 6
    tr.checkpointer.close()


def test_grad_compression_roundtrip():
    from repro.distributed import compress as gc
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 64)).astype(np.float32))}
    err = gc.init_error_state(g)
    total = jnp.zeros_like(g["w"])
    # over many steps error feedback keeps the accumulated bias ~0
    acc_true = jnp.zeros_like(g["w"])
    for _ in range(20):
        cg, err = gc.compress_tree(g, err)
        dg = gc.decompress_tree(cg)
        total = total + dg["w"]
        acc_true = acc_true + g["w"]
    rel = float(jnp.linalg.norm(total - acc_true) /
                jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel
