"""Pipeline parallelism: GPipe schedule equivalence with sequential apply."""

import json
import os
import subprocess
import sys
import textwrap

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == 3 / 15
    assert bubble_fraction(1, 8) == 0.0


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    import sys
    sys.path.insert(0, "src")
    from repro.distributed.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    n_stages, n_micro, b, d = 4, 8, 16, 32
    mesh = make_mesh(np.array(jax.devices()).reshape(4), ("pod",))
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(
        size=(n_stages, d, d)).astype(np.float32)) * 0.3,
        "b": jnp.asarray(rng.normal(size=(n_stages, d)).astype(np.float32))}
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    with mesh:
        y = jax.jit(lambda pp, xx: pipeline_apply(
            stage_fn, pp, xx, mesh=mesh, axis="pod",
            n_microbatches=n_micro))(params, x)

    # sequential reference
    ref = x
    for i in range(n_stages):
        ref = stage_fn(jax.tree.map(lambda p: p[i], params), ref)
    err = float(jnp.max(jnp.abs(y - ref)))
    print(json.dumps({"err": err}))
""")


def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
