"""IR optimizer tests (DESIGN.md §13): every rewrite rule on a kernel
that exhibits its slack, the translation-validation gate on an injected
miscompile, the nmc.jit(opt=...) wiring with per-call override, opt/check
memo behavior (including LRU eviction + re-verification), the residency
hazard pass, and the ``python -m repro.nmc.check`` CLI exit codes and
JSON report schema.
"""

import copy
import json

import numpy as np
import pytest

from repro import nmc
from repro.core import alu, programs, timing
from repro.core.isa import CaesarOp, VOp
from repro.nmc import check, opt
from repro.nmc.engine import get_engine
from repro.nmc.opt import rules
from repro.nmc.opt.rules import Work
from repro.nmc.opt.validate import OptError, reference_output, validate
from repro.nmc.program import (PROG_DTYPE, Program, caesar_entry,
                               carus_entry, nop_entry)

ALL_SEWS = (8, 16, 32)
RNG = np.random.default_rng(11)

_RT = nmc.NmcRuntime()


def _rand(n, sew):
    info = np.iinfo(alu.NP_DTYPES[sew])
    return RNG.integers(info.min, info.max + 1, n,
                        dtype=alu.NP_DTYPES[sew])


def _run_direct(lk):
    eng = get_engine(lk.engine)
    final = eng.run(eng.init_state(lk.mem), lk.program)
    return lk.post(eng.extract(final, lk.out_slice, lk.sew))


def axpy(t, c0, w, x):
    # written naively: the multi-use accumulator and unhinted bank
    # placement carry exactly the slack the optimizer reclaims
    t.store(nmc.mac(t.load(c0), t.load(w), t.load(x)))


def _axpy_args(sew, n=256):
    return tuple(_rand(n, sew) for _ in range(3))


def _cycles(lk):
    return timing.program_cycles(lk.program).cycles


# ---------------------------------------------------------------------------
# End-to-end: opt="O1" beats opt="off" on kernels with slack, bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", ALL_SEWS)
def test_axpy_carus_copy_coalesce(sew):
    args = _axpy_args(sew)
    k = nmc.jit(axpy, engine="carus", sew=sew, runtime=_RT)
    off = k.lower(*args, opt="off")
    o1 = k.lower(*args)                 # default level is O1
    assert o1.opt_report is not None and off.opt_report is None
    assert "copy-coalesce" in {r.rule for r in o1.opt_report.rewrites}
    assert o1.program.n_instr < off.program.n_instr
    assert _cycles(o1) < _cycles(off)
    assert np.array_equal(_run_direct(o1), off.oracle)


@pytest.mark.parametrize("sew", ALL_SEWS)
def test_axpy_caesar_rebank(sew):
    args = _axpy_args(sew)
    k = nmc.jit(axpy, engine="caesar", sew=sew, runtime=_RT)
    off = k.lower(*args, opt="off")
    o1 = k.lower(*args)
    rep = o1.opt_report
    assert rep is not None and rep.moved > 0
    assert _cycles(o1) < _cycles(off)
    # rebank kills the same-bank penalty entirely on this kernel
    assert timing.program_cycles(o1.program).detail["same_bank_ops"] == 0
    assert np.array_equal(_run_direct(o1), off.oracle)


@pytest.mark.parametrize("sew", ALL_SEWS)
@pytest.mark.parametrize("backend", ("scan", "pallas"))
@pytest.mark.parametrize("engine", ("caesar", "carus"))
def test_opt_bit_exact_both_backends(engine, backend, sew):
    """Optimized and unoptimized programs agree through the full dispatch
    stack on both engines x both executors x every SEW (the acceptance
    matrix)."""
    args = _axpy_args(sew)
    k = nmc.jit(axpy, engine=engine, sew=sew, runtime=_RT)
    got_off = np.asarray(k(*args, opt="off", backend=backend))
    got_o1 = np.asarray(k(*args, backend=backend))
    assert np.array_equal(got_off, got_o1)
    assert np.array_equal(got_o1, k.oracle(*args))


def test_gemm_registry_rebank_five_percent():
    """The paper's GEMM (Table V) lowers with its splat epilogue constants
    in the accumulator bank: bank-aware placement wins >= 5% modeled
    cycles with bit-exact output."""
    eb = programs.build("gemm", 8).caesar
    lk = copy.deepcopy(eb.lowered)
    before = timing.program_cycles(lk.program).cycles
    rep = opt.optimize(lk)
    assert rep is not None and rep.validated >= 1
    after = timing.program_cycles(lk.program).cycles
    assert after <= 0.95 * before
    assert np.array_equal(_run_direct(lk), eb.oracle)


def test_wave_shards_optimize_before_bucket():
    args = _axpy_args(8)
    k = nmc.jit(axpy, engine="carus", sew=8, runtime=_RT, tiles=2)
    _, lks_off = k.lower_wave(*args, opt="off")
    _, lks_o1 = k.lower_wave(*args)
    assert all(lk.opt_report is not None for lk in lks_o1)
    assert max(lk.program.n_instr for lk in lks_o1) \
        <= max(lk.program.n_instr for lk in lks_off)
    got = np.asarray(k(*args, tiles=2))
    assert np.array_equal(got, k.oracle(*args))


def test_opt_kwarg_validates_eagerly():
    with pytest.raises(ValueError, match="opt level 'O9'"):
        nmc.jit(axpy, opt="O9")
    k = nmc.jit(axpy, runtime=_RT)
    with pytest.raises(ValueError, match="opt level 'O2'"):
        k.lower(*_axpy_args(8), opt="O2")


def test_optimized_lowering_metadata_consistent():
    args = _axpy_args(8)
    k = nmc.jit(axpy, engine="carus", sew=8, runtime=_RT)
    lk = k.lower(*args)
    assert lk.opt_report is not None
    assert lk.prov is None or len(lk.prov) == len(lk.stream)
    assert not check.verify_lowered(lk).errors
    assert lk.program.n_instr == len(lk.stream)


# ---------------------------------------------------------------------------
# Rule units on hand-built Work items
# ---------------------------------------------------------------------------

def _caesar_work(entries, out_slice=(16, 4), init_spans=(), mem_words=64,
                 used_words=64):
    mem = np.zeros(mem_words, np.int32)
    return Work(engine="caesar", sew=8,
                entries=np.array(entries, dtype=PROG_DTYPE), mem=mem,
                out_slice=out_slice, init_spans=list(init_spans),
                cpool_spans=(), used_words=used_words, prov=None)


def test_dead_write_elim_drops_unobserved_store():
    w = _caesar_work([
        caesar_entry(CaesarOp.XOR, dest=40, src1=0, src2=1),   # dead
        caesar_entry(CaesarOp.ADD, dest=16, src1=0, src2=1),   # out word
    ])
    stats = rules.dead_write_elim(w)
    assert stats == {"removed": 1}
    assert len(w.entries) == 1 and int(w.entries["op"][0]) == int(CaesarOp.ADD)


def test_dead_write_elim_overwritten_store_dies():
    w = _caesar_work([
        caesar_entry(CaesarOp.ADD, dest=16, src1=0, src2=1),   # overwritten
        caesar_entry(CaesarOp.XOR, dest=16, src1=2, src2=3),   # survives
    ])
    assert rules.dead_write_elim(w) == {"removed": 1}
    assert int(w.entries["op"][0]) == int(CaesarOp.XOR)


def test_dead_write_elim_trims_whole_mac_cone():
    """A MAC chain whose store nobody observes is removed as a unit — a
    partial trim would change the accumulator for surviving stores."""
    w = _caesar_work([
        caesar_entry(CaesarOp.MAC_INIT, dest=0, src1=0, src2=1),
        caesar_entry(CaesarOp.MAC, dest=0, src1=2, src2=3),
        caesar_entry(CaesarOp.MAC_STORE, dest=48, src1=4, src2=5),  # dead
        caesar_entry(CaesarOp.ADD, dest=16, src1=0, src2=1),
    ])
    assert rules.dead_write_elim(w) == {"removed": 3}
    assert len(w.entries) == 1


def test_dead_write_elim_keeps_live_mac_cone():
    w = _caesar_work([
        caesar_entry(CaesarOp.MAC_INIT, dest=0, src1=0, src2=1),
        caesar_entry(CaesarOp.MAC_STORE, dest=16, src1=2, src2=3),  # out
    ])
    assert rules.dead_write_elim(w) is None
    assert len(w.entries) == 2


def test_dead_write_elim_carus_dead_final():
    ents = [carus_entry(VOp.VADD, vd=5, vs2=1, vs1=2),       # dead final
            carus_entry(VOp.VADD, vd=0, vs2=1, vs1=2)]       # output reg
    w = Work(engine="carus", sew=8,
             entries=np.array(ents, dtype=PROG_DTYPE),
             mem=np.zeros(32 * 32, np.int32), out_slice=(0, 4),
             init_spans=[], cpool_spans=(), used_words=0, prov=None)
    assert rules.dead_write_elim(w) == {"removed": 1}
    assert int(w.entries["dest"][0]) == 0


def test_nop_compact_strips_neutral_rows():
    w = _caesar_work([
        nop_entry("caesar"),
        caesar_entry(CaesarOp.ADD, dest=16, src1=0, src2=1),
        nop_entry("caesar"),
    ])
    assert rules.nop_compact(w) == {"removed": 2}
    assert len(w.entries) == 1


def test_vsetvl_dedup():
    from repro.core import constants as C
    vlmax = C.CARUS_REG_WORDS * (32 // 8)
    ents = [carus_entry(VOp.VSETVL, sval1=vlmax),            # re-requests VLMAX
            carus_entry(VOp.VADD, vd=0, vs2=1, vs1=2),
            carus_entry(VOp.VSETVL, sval1=8),                # observed: kept
            carus_entry(VOp.VADD, vd=0, vs2=1, vs1=2),
            carus_entry(VOp.VSETVL, sval1=4)]                # unobserved
    w = Work(engine="carus", sew=8,
             entries=np.array(ents, dtype=PROG_DTYPE),
             mem=np.zeros(32 * C.CARUS_REG_WORDS, np.int32), out_slice=(0, 4),
             init_spans=[], cpool_spans=(), used_words=0, prov=None)
    assert rules.vsetvl_dedup(w) == {"removed": 2}
    assert len(w.entries) == 3
    kept = w.entries[w.entries["op"] == w.entries["op"][1]]
    assert int(kept["sval1"][0]) == 8


def test_rebank_respects_cpool_and_out_spans():
    """Patched (cpool) spans and the output window never move, even when
    moving would win cycles — residency depends on their addresses."""
    ents = [caesar_entry(CaesarOp.ADD, dest=16, src1=0, src2=1)] * 4
    w = _caesar_work(ents, out_slice=(16, 4), init_spans=[(0, 1), (1, 1)],
                     mem_words=8192, used_words=32)
    w.cpool_spans = ((0, 1), (1, 1))
    assert rules.rebank(w) is None
    assert w.init_spans == [(0, 1), (1, 1)]


# ---------------------------------------------------------------------------
# Translation-validation gate: an optimizer bug must fail loudly
# ---------------------------------------------------------------------------

def _live_caesar_work():
    ents = [caesar_entry(CaesarOp.ADD, dest=16 + i, src1=i, src2=8 + i)
            for i in range(4)]
    w = _caesar_work(ents, out_slice=(16, 4), init_spans=[(0, 4), (8, 4)])
    w.mem[0:4] = [3, 5, 7, 9]           # values with carries, so an
    w.mem[8:12] = [1, 3, 5, 7]          # ADD->XOR tamper changes outputs
    return w


def test_validate_catches_semantic_tamper():
    w = _live_caesar_work()
    ref = reference_output("caesar", w.mem, w.entries, 8, w.out_slice)
    w.entries["op"][0] = int(CaesarOp.XOR)      # ADD -> XOR: miscompile
    with pytest.raises(OptError, match="miscompiled"):
        validate(w, ref, "tampered", "evil-rule")


def test_validate_catches_structurally_broken_rewrite():
    w = _live_caesar_work()
    ref = reference_output("caesar", w.mem, w.entries, 8, w.out_slice)
    w.entries["op"][0] = 63                     # not an opcode at all
    with pytest.raises(OptError, match="static verification"):
        validate(w, ref, "tampered", "evil-rule")


def test_injected_buggy_rule_raises_through_optimize(monkeypatch):
    """A rule that silently changes semantics is caught by the gate inside
    optimize() — the optimized artifact can never escape."""
    def evil(w):
        w.entries["src2"][0] += 1               # reads the wrong word
        return {"removed": 0}

    monkeypatch.setitem(rules.PIPELINE, "caesar",
                        (("evil-rule", evil),))
    opt.clear_memo()
    args = _axpy_args(8)
    k = nmc.jit(axpy, engine="caesar", sew=8, runtime=_RT)
    with pytest.raises(OptError, match="evil-rule"):
        k.lower(*args)
    opt.clear_memo()


# ---------------------------------------------------------------------------
# Memo behavior: optimizer LRU + check-memo eviction (satellite)
# ---------------------------------------------------------------------------

def test_optimize_memo_reuses_artifact():
    opt.clear_memo()
    args = _axpy_args(8)
    k = nmc.jit(axpy, engine="carus", sew=8, runtime=_RT)
    a = k.lower(*args)
    b = k.lower(*args)                  # memo hit: same content key
    assert a.opt_report == b.opt_report
    assert np.array_equal(np.array(a.stream, dtype=PROG_DTYPE),
                          np.array(b.stream, dtype=PROG_DTYPE))
    assert np.array_equal(np.asarray(a.mem), np.asarray(b.mem))


def test_check_memo_lru_eviction_and_reverify(monkeypatch):
    """verify_lowered's blake2b memo is LRU-bounded: filling past the cap
    evicts the oldest entry, and re-verifying an evicted lowering
    recomputes a correct (equal) report rather than serving stale or
    missing results."""
    monkeypatch.setattr(check, "_MEMO_CAP", 2)
    check.clear_memo()
    lks = [nmc.jit(axpy, engine="caesar", sew=8, runtime=_RT)
           .lower(*_axpy_args(8, n=n), opt="off", check="off")
           for n in (64, 128, 192)]   # distinct streams: distinct memo keys
    first = check.verify_lowered(lks[0])
    check.verify_lowered(lks[1])
    check.verify_lowered(lks[2])        # evicts lks[0]'s entry
    assert len(check._report_memo) == 2
    assert check._lowered_key(lks[0], lks[0].kernel or "k", None) \
        not in check._report_memo
    again = check.verify_lowered(lks[0])    # recomputed, not cached
    assert again is not first
    assert [d.rule for d in again.diagnostics] \
        == [d.rule for d in first.diagnostics]
    assert not again.errors
    check.clear_memo()


# ---------------------------------------------------------------------------
# Residency hazard pass
# ---------------------------------------------------------------------------

class _FakeLowered:
    def __init__(self, engine, entries, cpool_spans=(), init_spans=(),
                 sew=8):
        self.program = Program.from_entries(engine, sew, entries)
        self.cpool_spans = cpool_spans
        self.init_spans = init_spans
        self.kernel = "fake"
        self.prov = None


def test_verify_resident_rejects_carus():
    lk = _FakeLowered("carus", [carus_entry(VOp.VADD, vd=0, vs2=1, vs1=2)])
    rep = check.verify_resident(lk)
    assert rep.by_rule("engine-not-resident")


def test_verify_resident_patch_alias():
    lk = _FakeLowered(
        "caesar", [caesar_entry(CaesarOp.ADD, dest=64, src1=0, src2=8)],
        cpool_spans=((0, 8),), init_spans=((0, 8), (4, 8)))
    rep = check.verify_resident(lk)
    assert rep.by_rule("patch-aliases-weights")


def test_verify_resident_write_hazard():
    lk = _FakeLowered(
        "caesar", [caesar_entry(CaesarOp.ADD, dest=10, src1=0, src2=20)],
        init_spans=((8, 8),))
    rep = check.verify_resident(lk)
    d = rep.by_rule("resident-write-hazard")
    assert d and d[0].instr == 0


def test_verify_resident_clean():
    lk = _FakeLowered(
        "caesar", [caesar_entry(CaesarOp.ADD, dest=64, src1=0, src2=8)],
        cpool_spans=((0, 4),), init_spans=((0, 4), (8, 8)))
    assert not check.verify_resident(lk).diagnostics


def test_verify_chained_waves():
    ok = check.verify_chained_waves([[("r", 0, 0), ("r", 1, 0)],
                                     [("r", 2, 0)]])
    assert not ok.errors
    dup = check.verify_chained_waves([[7, 7]])
    assert dup.by_rule("war-hazard")
    shared = check.verify_chained_waves([[1, 2], [2, 3]])
    assert shared.by_rule("war-hazard")


def test_resident_projection_carries_hazard_reports():
    from repro.serve.block import ResidentProjection
    from repro.nmc.runtime import DispatchQueue
    from repro.nmc.pool import ResidentPool
    w8 = RNG.integers(-100, 100, (8, 16), dtype=np.int8)
    proj = ResidentProjection("t", w8, DispatchQueue(ResidentPool()),
                              rows=2, tiles=1)
    assert proj.hazard_reports and all(not r.errors
                                       for r in proj.hazard_reports)


# ---------------------------------------------------------------------------
# CLI: exit codes + JSON report schema (satellite)
# ---------------------------------------------------------------------------

def _run_cli(args):
    return check.main(args)


def test_cli_clean_sweep_exit_zero(tmp_path, capsys):
    out = tmp_path / "rep.json"
    rc = _run_cli(["--kernel", "xor", "--sew", "8", "--no-waves",
                   "--report", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "0 error(s)" in text
    doc = json.loads(out.read_text())
    assert doc["schema"] == check.REPORT_SCHEMA == 1
    assert set(doc) == {"schema", "strict", "targets", "summary"}
    assert doc["summary"]["status"] == "ok"
    for t in doc["targets"]:
        assert set(t) == {"kernel", "sew", "engine", "n_instr", "errors",
                          "warnings", "status", "diagnostics"}
        assert t["status"] == "ok" and t["errors"] == 0


def test_cli_injected_error_exit_one(tmp_path, monkeypatch):
    """A corrupted registry build must flip the exit code to 1 and mark
    the target (and summary) as failed in the JSON report."""
    real_build = programs.build

    def corrupt(name, sew, **kw):
        kb = real_build(name, sew, **kw)
        kb.caesar.lowered.program.entries["op"][0] = 63   # bad opcode
        return kb

    monkeypatch.setattr(programs, "build", corrupt)
    check.clear_memo()
    out = tmp_path / "rep.json"
    rc = _run_cli(["--kernel", "xor", "--sew", "8", "--no-waves",
                   "--report", str(out)])
    assert rc == 1
    doc = json.loads(out.read_text())
    assert doc["summary"]["status"] == "fail"
    bad = [t for t in doc["targets"] if t["status"] == "fail"]
    assert bad and bad[0]["errors"] >= 1
    diags = bad[0]["diagnostics"]
    assert diags and set(diags[0]) == {"severity", "pass", "rule",
                                       "message", "kernel", "instr",
                                       "op_index"}
    assert any(d["rule"] == "bad-opcode" for d in diags)
    check.clear_memo()
