"""Resident W8A8 transformer-block serving (PR 8, DESIGN.md §12).

Covers the tentpole contract: a decoder block's quantized weights DMA onto
the tile array once (ResidentPool ``loads``), every subsequent token step
patches only activation words (``patches``/``patch_bytes``), and the
resident path is bit-exact against both the per-projection
``ServeEngine.nmc_project`` path and the pure-JAX int32 matmul reference.
"""

import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro import nmc  # noqa: E402
from repro.configs import base as cb  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.nmc import frontend  # noqa: E402
from repro.serve.block import (  # noqa: E402
    ResidentProjection,
    quantize_rows,
    splat_words,
)
from repro.serve.engine import ServeEngine, quantize_params  # noqa: E402


def _own_queue():
    """Private queue over a private ResidentPool (isolated residency
    counters) sharing the process-wide bucketed jit cache."""
    return nmc.DispatchQueue(pool=nmc.ResidentPool(
        pool=nmc.default_runtime().bucketed))


def _tiny_cfg():
    return cb.get("qwen1.5-0.5b", smoke=True).scaled(
        d_model=16, n_heads=2, n_kv_heads=2, d_ff=32, nmc_mode="w8a8")


def _tiny_engine(queue, n_slots=4, tiles=2):
    cfg = _tiny_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params, cfg)
    return ServeEngine(cfg, qparams, n_slots=n_slots, max_len=32,
                       nmc_queue=queue, nmc_tiles=tiles)


# ---------------------------------------------------------------------------
# splat_words: the patch payload must be exactly what lowering would write
# ---------------------------------------------------------------------------

def test_splat_words_matches_frontend_splat_word():
    rng = np.random.default_rng(0)
    for sew in (8, 16, 32):
        lo, hi = -(1 << (sew - 1)), (1 << (sew - 1))
        vals = rng.integers(lo, hi, 64, dtype=np.int64).astype(np.int32)
        got = splat_words(vals, sew)
        want = np.array([frontend.splat_word(int(v), sew) for v in vals],
                        np.int32)
        assert np.array_equal(got, want), sew


def test_quantize_rows_roundtrip_bounds():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(5, 24)).astype(np.float32) * 3.0
    q, s = quantize_rows(x)
    assert q.dtype == np.int8 and np.abs(q.astype(np.int32)).max() <= 127
    err = np.abs(q.astype(np.float32) * s[:, None] - x)
    assert err.max() <= 0.5 * s.max() + 1e-6


# ---------------------------------------------------------------------------
# ResidentProjection: bit-exactness + residency counters
# ---------------------------------------------------------------------------

def test_resident_projection_bit_exact_and_resident():
    own = _own_queue()
    rng = np.random.default_rng(2)
    w8 = rng.integers(-128, 128, (8, 12), dtype=np.int8)
    rp = ResidentProjection("p", w8, own, rows=3, tiles=2)
    assert rp.static, "value-independence proof must hold for the proj kernel"
    assert rp.n_shards == 2
    loads_after_first = None
    for it in range(3):
        x8 = rng.integers(-128, 128, (3, 8), dtype=np.int8)
        y = rp(x8)
        assert np.array_equal(
            y, x8.astype(np.int64) @ w8.astype(np.int64)), it
        if it == 0:
            loads_after_first = own.pool.loads
            assert loads_after_first == rp.n_shards
    # weights crossed the bus exactly once per shard — later calls are
    # patch-only
    assert own.pool.loads == loads_after_first
    assert own.pool.patches == 3 * rp.n_shards
    assert own.pool.patch_bytes == 3 * rp.patch_bytes_per_call


def test_resident_projection_rejects_carus():
    w8 = np.zeros((4, 4), np.int8)
    with pytest.raises(nmc.LoweringError):
        ResidentProjection("p", w8, _own_queue(), rows=2, tiles=1,
                           engine="carus")


# ---------------------------------------------------------------------------
# ResidentBlock: three-way bit-exactness over chained steps
# ---------------------------------------------------------------------------

def test_resident_block_three_way_bit_exact():
    own = _own_queue()
    eng = _tiny_engine(own, n_slots=4, tiles=2)
    blk = eng.resident_block(layer=0, tiles=2)
    assert blk.static
    assert blk.n_shards == 14          # 7 projections x 2 shards
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, eng.cfg.d_model)).astype(np.float32)
    x_res, x_prj, x_jax = x.copy(), x.copy(), x.copy()
    st_res, st_prj, st_jax = (blk.init_state(8) for _ in range(3))
    for _ in range(3):
        x_res, st_res = blk.step(x_res, st_res, mm=None)
        x_prj, st_prj = blk.step(x_prj, st_prj, mm=blk.project_mm(eng))
        x_jax, st_jax = blk.step(x_jax, st_jax, mm=blk.jax_mm)
        # int32 GEMMs are exact at SEW 32 and every host stage is shared,
        # so the three backends agree to the bit — not approximately
        assert np.array_equal(x_res, x_jax)
        assert np.array_equal(x_prj, x_jax)
        assert np.array_equal(st_res["k"], st_jax["k"])
    assert st_res["len"] == 3


def test_resident_block_weights_dma_once():
    own = _own_queue()
    eng = _tiny_engine(own, n_slots=4, tiles=2)
    blk = eng.resident_block(layer=0, tiles=2)
    rng = np.random.default_rng(4)
    st = blk.init_state(8)
    x = rng.normal(size=(4, eng.cfg.d_model)).astype(np.float32)
    x, st = blk.step(x, st)            # cold: ships every weight image
    loads0 = own.pool.loads
    assert loads0 == blk.n_shards
    pb0 = own.pool.patch_bytes
    for _ in range(2):                 # steady: activation patches only
        x, st = blk.step(x, st)
    assert own.pool.loads == loads0
    assert own.pool.patches == 3 * blk.n_shards
    assert own.pool.patch_bytes - pb0 == 2 * blk.patch_bytes_per_call


def test_resident_block_steady_cheaper_than_cold():
    own = _own_queue()
    eng = _tiny_engine(own, tiles=2)
    blk = eng.resident_block(layer=0, tiles=2)
    steady = blk.step_cycles(steady=True)
    cold = blk.step_cycles(steady=False)
    assert steady < cold
    # steady saves exactly on the input DMA leg; compute and output legs
    # are identical per stage
    for ws, wc in zip(blk.step_waves(True), blk.step_waves(False)):
        for s, c in zip(ws, wc):
            assert s.compute_cycles == c.compute_cycles
            assert s.dma_out_cycles == c.dma_out_cycles
            assert s.dma_in_cycles <= c.dma_in_cycles


def test_resident_block_rejects_non_dense_family():
    cfg = cb.get("moonshot-v1-16b-a3b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.resident_block()
