"""JAX version-compat shims: mesh axis_types + cost_analysis shape.

The repo supports both JAX 0.4.x and newer:
* ``jax.sharding.AxisType`` does not exist on 0.4.x — ``launch.mesh`` only
  passes ``axis_types`` when it does (``make_mesh`` is the single compat
  constructor everything builds meshes through),
* ``compiled.cost_analysis()`` returns a one-element list of dicts on 0.4.x
  and a plain dict on newer JAX — ``hlo_analysis.normalize_cost_analysis``
  hides the difference.

Both API shapes are exercised here via monkeypatching, plus the real
installed-JAX path for each shim.
"""

import numpy as np
import jax
import jax.numpy as jnp
import jax.sharding

from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_mod


# ---------------------------------------------------------------------------
# axis_types feature detection
# ---------------------------------------------------------------------------

def test_axis_types_kw_without_axistype(monkeypatch):
    """JAX 0.4.x shape: no AxisType attribute -> no axis_types kwarg."""
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert mesh_mod._axis_types_kw(2) == {}


def test_axis_types_kw_with_axistype(monkeypatch):
    """Newer-JAX shape: AxisType present -> one Auto entry per axis."""
    class FakeAxisType:
        Auto = "auto"

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                        raising=False)
    assert mesh_mod._axis_types_kw(3) == {"axis_types": ("auto",) * 3}


def test_make_mesh_on_installed_jax():
    """The compat constructor must build a usable Mesh on whatever JAX is
    installed (this is the call the subprocess test scripts make)."""
    mesh = mesh_mod.make_mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)


def test_make_local_mesh_on_installed_jax():
    mesh = mesh_mod.make_local_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size >= 1


# ---------------------------------------------------------------------------
# shard_map compat (jax.shard_map vs jax.experimental.shard_map)
# ---------------------------------------------------------------------------

def test_shard_map_compat_runs_on_installed_jax():
    """context.shard_map must dispatch a psum on whatever JAX is installed
    (the call the MoE layer and the pipeline schedule make)."""
    from repro.distributed import context

    mesh = mesh_mod.make_mesh(np.asarray(jax.devices()[:1]), ("data",))
    fn = context.shard_map(
        lambda x: jax.lax.psum(x, "data"), mesh=mesh,
        in_specs=jax.sharding.PartitionSpec("data"),
        out_specs=jax.sharding.PartitionSpec())
    out = fn(jnp.arange(4, dtype=jnp.float32))
    assert out.shape == (4,)


def test_shard_map_compat_prefers_public_api(monkeypatch):
    """When jax.shard_map exists (newer JAX) it is used with check_vma."""
    from repro.distributed import context

    calls = {}

    def fake_shard_map(fn, *, mesh, in_specs, out_specs, check_vma):
        calls["check_vma"] = check_vma
        return lambda *a: "new-api"

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    out = context.shard_map(lambda x: x, mesh=None, in_specs=(),
                            out_specs=())()
    assert out == "new-api" and calls["check_vma"] is False


# ---------------------------------------------------------------------------
# cost_analysis normalization
# ---------------------------------------------------------------------------

def test_normalize_cost_analysis_dict_shape():
    """Newer-JAX shape: dict passes through (copied)."""
    src = {"flops": 10.0, "bytes accessed": 5.0}
    out = hlo_analysis.normalize_cost_analysis(src)
    assert out == src and out is not src


def test_normalize_cost_analysis_list_shape():
    """JAX 0.4.x shape: one-element list of dicts unwraps to the dict."""
    out = hlo_analysis.normalize_cost_analysis([{"flops": 7.0}])
    assert out == {"flops": 7.0}


def test_normalize_cost_analysis_empty():
    assert hlo_analysis.normalize_cost_analysis([]) == {}
    assert hlo_analysis.normalize_cost_analysis(None) == {}


def test_normalize_cost_analysis_real_compiled():
    """End-to-end on the installed JAX: whatever cost_analysis() returns,
    the normalized view exposes positive matmul flops."""
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
    assert cost.get("flops", 0.0) >= 2 * 8 * 8 * 8
