"""Paper-validation as a test: the calibrated timing/energy models must
track Table V / Table VIII / Fig 12 within documented tolerances."""

import statistics

import pytest

from benchmarks import paper_data as PD
from benchmarks import table_v, table_viii, fig12
from repro.core import constants as C


@pytest.fixture(scope="module")
def table_v_rows():
    return table_v.run(verify_functional=False)


def test_table_v_aggregate_error(table_v_rows):
    errs = []
    for r in table_v_rows:
        for k in ("thr_caesar_err", "thr_carus_err", "en_caesar_err",
                  "en_carus_err"):
            if not (r["erratum_carus"] and k == "en_carus_err"):
                errs.append(abs(r[k]))
    assert statistics.mean(errs) < 0.10, statistics.mean(errs)
    assert statistics.median(errs) < 0.05


def test_table_v_headline_cells(table_v_rows):
    """The paper's headline claims: 28x/53.9x speedup, 25x/35.6x energy."""
    r = next(x for x in table_v_rows
             if x["kernel"] == "matmul" and x["sew"] == 8)
    assert abs(r["thr_caesar"] / 28.0 - 1) < 0.05
    assert abs(r["thr_carus"] / 53.9 - 1) < 0.06
    assert abs(r["en_caesar"] / 25.0 - 1) < 0.06
    assert abs(r["en_carus"] / 35.6 - 1) < 0.06


def test_table_viii_cycles_within_5pct():
    for r in table_viii.run():
        assert abs(r["caesar_cycles"] / r["caesar_cycles_paper"] - 1) < 0.05
        assert abs(r["carus_cycles"] / r["carus_cycles_paper"] - 1) < 0.05


def test_fig12_saturation_and_crossover():
    rows = fig12.run()
    sat = rows[-1]
    assert abs(sat["carus_out_per_cyc"] / PD.FIG12_CARUS_SAT_OUT_PER_CYC
               - 1) < 0.05
    assert abs(sat["caesar_out_per_cyc"] / PD.FIG12_CAESAR_SAT_OUT_PER_CYC
               - 1) < 0.02
    # eCPU bootstrap makes Carus lose at tiny sizes (Fig 12 discussion)
    small = rows[0]
    assert small["caesar_out_per_cyc"] > small["carus_out_per_cyc"]
    # monotone saturation
    thr = [r["carus_out_per_cyc"] for r in rows]
    assert all(b >= a - 1e-9 for a, b in zip(thr, thr[1:]))


def test_peak_throughput_identities():
    """Table VII peak GOPS fall out of the microarchitecture constants."""
    assert C.CARUS_PEAK_GOPS == pytest.approx(
        C.CARUS_N_LANES * 2 * C.F_CLK_MAX_HZ / 1e9, rel=0.01)
    # Caesar: one word-wise DOT (4 MACs) per 2 cycles = 2 MAC/cyc = 4 ops/cyc
    assert C.CAESAR_PEAK_GOPS == pytest.approx(
        4 * C.F_CLK_MAX_HZ / 1e9, rel=0.01)


# -- chained partitioned waves (PR 8, DESIGN.md §12) -------------------------

def _stage(i, dma_in=10.0, compute=100.0, dma_out=7.0):
    from repro.core import timing
    return timing.StageCost(f"s{i}", dma_in + i, compute - i, dma_out)


def test_chained_single_wave_degenerates_to_wave_cycles():
    from repro.core import timing
    stages = [_stage(i) for i in range(5)]
    for n in (1, 2, 4, 8):
        assert timing.chained_wave_cycles([stages], n) \
            == timing.wave_cycles(stages, n)


def test_chained_mode_delegates():
    from repro.core import timing
    waves = [[_stage(i) for i in range(3)], [_stage(i, 4, 30, 2)
                                             for i in range(2)]]
    assert timing.wave_cycles(waves, 2, mode="chained") \
        == timing.chained_wave_cycles(waves, 2)


def test_chained_wave_bounds():
    from repro.core import timing
    waves = [[_stage(i) for i in range(4)],
             [_stage(i, 3, 55, 9) for i in range(4)],
             [_stage(i, 20, 10, 1) for i in range(2)]]
    for n in (1, 2, 4):
        chain = timing.chained_wave_cycles(waves, n)
        # never cheaper than the longest constituent wave...
        assert chain >= max(timing.wave_cycles(w, n) for w in waves)
        # ...never costlier than running the waves with cold timelines
        assert chain <= sum(timing.wave_cycles(w, n) for w in waves) + 1e-9


def test_chained_wave_hand_example():
    from repro.core import timing
    # one tile, two dependent single-stage waves of (in=10, comp=20, out=5):
    # wave 1: bus 10, compute ends 30, output drains at 35
    # wave 2: input waits behind the drain -> bus 45, compute ends 65,
    #         output drains at 70
    w = timing.StageCost("w", 10.0, 20.0, 5.0)
    assert timing.chained_wave_cycles([[w], [w]], 1) == 70.0


# -- tile-assignment policies (PR 10, DESIGN.md §14) -------------------------

def test_greedy_assignment_hand_example():
    from repro.core import timing
    # three stages on two tiles: (in, comp, out) = (1,10,0) (1,1,0) (1,1,0)
    # roundrobin pins stage 2 back onto tile 0 (busy until 11):
    #   bus 1 -> t0 ends 11; bus 2 -> t1 ends 3; bus 3 -> t0 ends 12
    # greedy places stage 2 on the earliest-free tile 1 (free at 3):
    #   bus 3 -> t1 ends 4; the heavy tile 0 finishes at 11
    stages = [timing.StageCost("a", 1.0, 10.0, 0.0),
              timing.StageCost("b", 1.0, 1.0, 0.0),
              timing.StageCost("c", 1.0, 1.0, 0.0)]
    assert timing.wave_cycles(stages, 2, assign="roundrobin") == 12.0
    assert timing.wave_cycles(stages, 2, assign="greedy") == 11.0


def test_greedy_equals_roundrobin_when_stages_fit_tiles():
    from repro.core import timing
    # with stages <= tiles every stage lands on a fresh tile either way
    stages = [_stage(i) for i in range(4)]
    for n in (4, 6, 8):
        assert timing.wave_cycles(stages, n, assign="greedy") \
            == timing.wave_cycles(stages, n, assign="roundrobin")


def test_greedy_never_worse_than_roundrobin():
    from repro.core import timing
    stages = [_stage(i, 5.0 + 3 * (i % 3), 80.0 - 7 * i, 3.0)
              for i in range(7)]
    for n in (1, 2, 3, 5):
        assert timing.wave_cycles(stages, n, assign="greedy") \
            <= timing.wave_cycles(stages, n, assign="roundrobin")


def test_chained_wave_cycles_accepts_assign():
    from repro.core import timing
    waves = [[_stage(i) for i in range(5)], [_stage(i, 4, 30, 2)
                                             for i in range(3)]]
    rr = timing.chained_wave_cycles(waves, 2, assign="roundrobin")
    gd = timing.chained_wave_cycles(waves, 2, assign="greedy")
    assert gd <= rr
    assert timing.wave_cycles(waves, 2, mode="chained", assign="greedy") == gd


def test_unknown_assign_mode_rejected():
    from repro.core import timing
    with pytest.raises(AssertionError):
        timing.wave_cycles([_stage(0)], 2, assign="fifo")
