"""Fault tolerance: crash/restart, straggler detection, elastic reshard."""

import numpy as np
import jax

from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.data.pipeline import DataConfig
from repro.runtime.elastic import (HeartbeatMonitor, MembershipWatcher,
                                   make_mesh_for, reshard_state)
from repro.train.trainer import Trainer, TrainerConfig


def test_restart_from_checkpoint_after_injected_failure(tmp_path):
    """A node failure mid-run must restore the last committed step and
    finish; the synthetic pipeline replays the identical stream."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=8, ckpt_every=2, log_every=100,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(cfg, tc, data_cfg=DataConfig(global_batch=4, seq_len=32))
    out = tr.run(fail_at=5)
    assert out["final_step"] == 8
    assert out["restarts"] == 1
    tr.checkpointer.close()

    # bitwise-identical final params vs an uninterrupted run
    cfg2 = cfg
    tc2 = TrainerConfig(total_steps=8, ckpt_every=2, log_every=100,
                        ckpt_dir=str(tmp_path / "ck2"))
    tr2 = Trainer(cfg2, tc2, data_cfg=DataConfig(global_batch=4, seq_len=32))
    tr2.run()
    tr2.checkpointer.close()
    a = ckpt.restore(str(tmp_path / "ck"), 8,
                     {"params": tr.init_state()[0], "opt": tr.init_state()[1]})
    b = ckpt.restore(str(tmp_path / "ck2"), 8,
                     {"params": tr2.init_state()[0],
                      "opt": tr2.init_state()[1]})
    # failure at step 5 restores step 4 and replays 4..8 with the same data
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_straggler_detection():
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=1, straggler_factor=2.0)
    tr = Trainer(cfg, tc, data_cfg=DataConfig(global_batch=2, seq_len=16))
    for t in [0.1] * 10:
        tr._straggler_check(0, t)
    tr._straggler_check(11, 0.5)      # 5x median -> straggler
    assert tr.straggler_events == [11]


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(timeout_s=1.0)
    hb.beat(0.0)
    hb.beat(0.5)
    hb.beat(5.0)       # gap > timeout
    assert hb.failures == 1


def test_membership_watcher_and_mesh_rebuild():
    w = MembershipWatcher(events={3: 1})
    assert w.poll(1) is None
    v = w.poll(3)
    assert v is not None and v.generation == 1
    mesh = make_mesh_for(v.n_devices, model_parallel=1)
    assert mesh.devices.size == 1


def test_elastic_reshard_checkpoint(tmp_path):
    """A checkpoint written under one 'cluster' restores onto a new mesh
    (device_put onto fresh shardings) and training continues."""
    from repro.distributed import sharding
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    tc = TrainerConfig(total_steps=2, ckpt_every=2, log_every=100,
                       ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(cfg, tc, data_cfg=DataConfig(global_batch=2, seq_len=16))
    tr.run()
    tr.checkpointer.close()

    new_mesh = make_mesh_for(len(jax.devices()))
    params0, opt0, _ = tr.init_state()
    restored = ckpt.restore(str(tmp_path / "ck"), 2,
                            {"params": params0, "opt": opt0})
    resharded = reshard_state(
        restored["params"], new_mesh,
        lambda tree, m: sharding.param_shardings(tree, m))
    # values preserved bit-exactly across the reshard
    for x, y in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(resharded)):
        assert (np.asarray(x) == np.asarray(y)).all()
