"""Property tests: packed-SIMD ALU semantics vs numpy two's-complement."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import alu

DTYPES = {8: np.int8, 16: np.int16, 32: np.int32}


@st.composite
def words_and_sew(draw):
    sew = draw(st.sampled_from([8, 16, 32]))
    n = draw(st.integers(1, 64)) * (32 // sew)
    dt = DTYPES[sew]
    info = np.iinfo(dt)
    a = draw(st.lists(st.integers(info.min, info.max), min_size=n, max_size=n))
    b = draw(st.lists(st.integers(info.min, info.max), min_size=n, max_size=n))
    return sew, np.array(a, dt), np.array(b, dt)


@given(words_and_sew())
@settings(max_examples=60, deadline=None)
def test_pack_unpack_roundtrip(data):
    sew, a, _ = data
    w = jnp.asarray(alu.pack_np(a))
    back = alu.unpack_np(np.asarray(alu.pack(alu.unpack(w, sew), sew)),
                         DTYPES[sew])
    assert (back == a).all()


@pytest.mark.parametrize("op", alu.BINOPS)
@given(data=words_and_sew())
@settings(max_examples=12, deadline=None)
def test_binop_matches_numpy(op, data):
    sew, a, b = data
    dt = DTYPES[sew]
    got = alu.unpack_np(
        np.asarray(alu.word_binop(op, jnp.asarray(alu.pack_np(a)),
                                  jnp.asarray(alu.pack_np(b)), sew)), dt)
    ua, ub = a.astype(f"uint{sew}"), b.astype(f"uint{sew}")
    sh = (ub % sew)
    exp = {
        "add": a + b, "sub": a - b, "mul": a * b,
        "and": a & b, "or": a | b, "xor": a ^ b,
        "min": np.minimum(a, b), "max": np.maximum(a, b),
        "minu": np.where(ua <= ub, a, b), "maxu": np.where(ua >= ub, a, b),
        "sll": (ua << sh).astype(dt), "srl": (ua >> sh).astype(dt),
        "sra": a >> sh.astype(dt),
    }[op]
    assert (got == exp.astype(dt)).all()


@given(words_and_sew())
@settings(max_examples=30, deadline=None)
def test_dot_wraps_mod_2_32(data):
    sew, a, b = data
    acc = alu.word_dot(jnp.int32(0), jnp.asarray(alu.pack_np(a)),
                       jnp.asarray(alu.pack_np(b)), sew)
    exp = np.int32(np.sum(a.astype(np.int64) * b.astype(np.int64))
                   & 0xFFFFFFFF)
    assert np.int32(acc) == exp


def test_macc_accumulates_at_sew():
    a = np.array([100, -100, 127, -128], np.int8)
    b = np.array([100, 100, 2, 2], np.int8)
    acc = np.array([1, 2, 3, 4], np.int8)
    got = alu.unpack_np(
        np.asarray(alu.word_macc(jnp.asarray(alu.pack_np(acc)),
                                 jnp.asarray(alu.pack_np(a)),
                                 jnp.asarray(alu.pack_np(b)), 8)), np.int8)
    exp = (acc.astype(np.int64) + a.astype(np.int64) * b).astype(np.int8)
    assert (got == exp).all()
