"""Test-session bootstrap: vendored `hypothesis` fallback.

The offline CI container cannot pip-install hypothesis; without it the three
property-test modules fail at collection.  When the real package is absent
we register ``tests/_vendor_hypothesis.py`` (a deterministic sampled
implementation of the small API surface we use) under the ``hypothesis``
name *before* test modules import it.  With real hypothesis installed this
file is a no-op.
"""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ModuleNotFoundError:
    _path = pathlib.Path(__file__).parent / "_vendor_hypothesis.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
