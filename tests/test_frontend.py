"""Traced kernel frontend (DESIGN.md §7): engine auto-selection,
UnsupportedOnEngine diagnostics, and bit-exactness of traced kernels
against the pure-numpy oracle mirrors (``alu.*_np``) on both engines at
SEW 8/16/32, via both sync and async (DispatchQueue) call styles.

The kernel-library acceptance (all five legacy builders re-expressed
through the frontend, bit-exact on the full Table V sweep, both engines,
sync + async) is carried by tests/test_engines.py, tests/test_nmc_ir.py
and tests/test_runtime.py, which all consume the traced builders; this
file covers the frontend's own contract.
"""

import numpy as np
import pytest

from repro import nmc
from repro.core import alu, programs
from repro.nmc.engine import get_engine
from repro.nmc.frontend import LoweringError

ALL_SEWS = (8, 16, 32)
RNG = np.random.default_rng(42)

# one shared runtime for the module: sync + async share a jit cache
_RT = nmc.NmcRuntime()


def _rand(n, sew, shape=None):
    info = np.iinfo(alu.NP_DTYPES[sew])
    return RNG.integers(info.min, info.max + 1, shape or n,
                        dtype=alu.NP_DTYPES[sew])


def _run_direct(lk):
    """Run a LoweredKernel straight on its functional engine (no pool)."""
    eng = get_engine(lk.engine)
    final = eng.run(eng.init_state(lk.mem), lk.program)
    return lk.post(eng.extract(final, lk.out_slice, lk.sew))


# ---------------------------------------------------------------------------
# Bit-exactness vs the alu.*_np oracle mirrors, both engines, all SEWs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", ALL_SEWS)
@pytest.mark.parametrize("engine", ["caesar", "carus"])
def test_fused_kernel_bit_exact_vs_numpy_oracle(engine, sew):
    """A fused body exercising add/sub/mul/mac/shift/min/max/relu and
    scalar broadcast: the engine output must equal the alu.*_np evaluation
    the tracer performs (NmcValue.value / CompiledKernel.oracle)."""
    n = 256
    x, y = _rand(n, sew), _rand(n, sew)

    @nmc.jit(sew=sew, runtime=_RT)
    def fused(t, x, y):
        a, b = t.load(x, bank=0), t.load(y)
        s = (a * 3 + b).max(0)             # scalar mul, add, relu
        d = (a - b).min(s)                 # sub, vector min
        m = nmc.mac(d, 2, s)               # elementwise mac: d + 2*s
        t.store(m >> 1)                    # arithmetic shift epilogue

    # independent numpy mirror of the body (int64 lanes, wrap at SEW)
    def w(v):
        return alu.trunc_lanes_np(v, sew)
    xa, ya = x.astype(np.int64), y.astype(np.int64)
    s = np.maximum(w(w(xa * 3) + ya), 0)
    d = np.minimum(w(xa - ya), s)
    exp = w(d + 2 * s) >> 1

    lk = fused.lower(x, y, engine=engine)
    got = _run_direct(lk)
    assert (got.astype(np.int64) == exp).all(), (engine, sew)
    assert (np.asarray(fused.oracle(x, y)).astype(np.int64) == exp).all()


@pytest.mark.parametrize("sew", ALL_SEWS)
def test_sync_and_async_call_styles_bit_exact(sew):
    """CompiledKernel() vs call_async().result(): same engine path, same
    bucketed jit cache, bit-exact equal — on both engines."""
    x, y = _rand(128, sew), _rand(128, sew)

    @nmc.jit(sew=sew, runtime=_RT)
    def k(t, x, y):
        t.store((t.load(x, bank=0) ^ t.load(y)).max(1))

    for engine in ("caesar", "carus"):
        sync = k(x, y, engine=engine)
        fut = k.call_async(x, y, engine=engine)
        got = fut.result()
        assert (np.asarray(got) == np.asarray(sync)).all(), engine
        assert (np.asarray(sync) == k.oracle(x, y)).all(), engine


def test_unsigned_ops_and_slides_run_on_carus():
    x, y = _rand(64, 8), _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x, y):
        u = t.load(x).maxu(t.load(y))      # unsigned: Carus-only
        t.store(u.minu(100).slide_down(2))

    got = k(x, y)
    mask = (1 << 8) - 1
    xa, ya = x.astype(np.int64), y.astype(np.int64)
    u = np.where((xa & mask) >= (ya & mask), xa, ya)
    u = np.where((u & mask) <= 100, u, 100)
    exp = np.concatenate([u[2:], [0, 0]]).astype(np.int8)
    assert (np.asarray(got) == exp).all()


# ---------------------------------------------------------------------------
# Engine auto-selection + diagnostics
# ---------------------------------------------------------------------------

def test_auto_selects_caesar_for_bus_expressible_bodies():
    x = _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def busk(t, x):
        v = t.load(x)
        t.store(((v + 1) * 2).max(0).min(100) >> 1)

    assert busk.select_engine(x) == "caesar"
    assert busk.lower(x).engine == "caesar"


def test_auto_falls_back_to_carus_for_unsigned_and_computed_slides():
    x = _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def unsigned(t, x):
        t.store(t.load(x).maxu(0))

    @nmc.jit(runtime=_RT)
    def computed_slide(t, x):
        t.store((t.load(x) + 1).slide_down(1))

    assert unsigned.select_engine(x) == "carus"
    assert computed_slide.select_engine(x) == "carus"
    # slides of *loaded* values are bus-expressible (shifted data replicas)
    @nmc.jit(runtime=_RT)
    def loaded_slide(t, x):
        t.store(t.load(x).slide_down(1) + 0)

    assert loaded_slide.select_engine(x) == "caesar"


def test_unsupported_on_engine_names_the_offending_op():
    x = _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def unsigned(t, x):
        t.store(t.load(x).minu(5))

    with pytest.raises(nmc.UnsupportedOnEngine) as ei:
        unsigned.lower(x, engine="caesar")
    assert ei.value.op == "minu" and ei.value.engine == "caesar"
    assert "minu" in str(ei.value)

    @nmc.jit(runtime=_RT)
    def computed_slide(t, x):
        t.store((t.load(x) * 2).slide_down(3))

    with pytest.raises(nmc.UnsupportedOnEngine) as ei:
        computed_slide.lower(x, engine="caesar")
    assert ei.value.op == "slide_down"


def test_carus_register_spanning_slide_is_diagnosed():
    x = _rand(4096, 8)                      # 1024 words > 256-word register

    @nmc.jit(runtime=_RT)
    def k(t, x):
        t.store((t.load(x) + 1).slide_down(1))

    with pytest.raises(nmc.UnsupportedOnEngine) as ei:
        k.lower(x, engine="carus")
    assert ei.value.op == "slide_down" and ei.value.engine == "carus"


def test_lowering_errors_are_informative():
    x = _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def store_load(t, x):
        t.store(t.load(x))

    with pytest.raises(LoweringError, match="loaded value"):
        store_load.lower(x)

    @nmc.jit(runtime=_RT)
    def no_store(t, x):
        t.load(x)

    with pytest.raises(LoweringError, match="stored no"):
        no_store.lower(x)


# ---------------------------------------------------------------------------
# Lowering structure: the traced kernel library keeps the paper's shape
# ---------------------------------------------------------------------------

def test_traced_matmul_has_conflict_free_mac_chains():
    """The Table V matmul: every Caesar MAC reads the splatted tap from
    bank 0 and the B row from bank 1 — zero same-bank penalties."""
    kb = programs.build("matmul", 8)
    from repro.core import timing
    rep = timing.program_cycles(kb.caesar.program, 0.0)
    assert rep.detail["same_bank_ops"] == 0
    # Carus: one VSETVL + per-tap EMVX + VMUL/VMACC
    ops = kb.carus.program.vops()
    from repro.core.isa import VOp
    assert ops[0] == VOp.VSETVL
    assert ops.count(VOp.EMVX) == 64 and ops.count(VOp.VMACC) == 56


def test_store_trim_bounds_emission_and_output():
    """t.store(v, n=...) trims the logical output (conv2d's 'valid' region)
    and, on Caesar, the emitted word count."""
    x = _rand(64, 32)

    @nmc.jit(sew=32, runtime=_RT)
    def k(t, x):
        t.store(t.load(x) + 1, n=61)

    lk = k.lower(x, engine="caesar")
    assert lk.program.n_instr == 61         # demand-trimmed word loop
    assert lk.oracle.shape == (61,)
    got = _run_direct(lk)
    assert (got == (x[:61] + 1)).all()


def test_stored_slide_replica_lands_in_caesar_output_window():
    """A stored slide_down lowers on Caesar to a data replica placed
    directly in the output window (regression: the replica used to be
    re-allocated in bank 1, leaving the extracted window all-zero)."""
    x = _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x):
        t.store(t.load(x).slide_down(1))

    exp = np.concatenate([x[1:], [0]]).astype(np.int8)
    for engine in ("caesar", "carus"):
        got = np.asarray(k(x, engine=engine))
        assert (got == exp).all(), engine
    assert (k.oracle(x) == exp).all()


def test_mac_with_loaded_accumulator_copies_on_carus():
    """nmc.mac with a loaded (non-chain) accumulator is valid on both
    engines (regression: Carus used to raise 'accumulator and output
    block diverged' instead of emitting the VMV copy)."""
    c, a, b = _rand(64, 8), _rand(64, 8), _rand(64, 8)

    @nmc.jit(runtime=_RT)
    def axpy(t, c, a, b):
        t.store(nmc.mac(t.load(c, bank=0), t.load(a), t.load(b)))

    exp = (c.astype(np.int64) + a.astype(np.int64) * b.astype(np.int64)
           ).astype(np.int8)
    for engine in ("caesar", "carus"):
        got = np.asarray(axpy(c, a, b, engine=engine))
        assert (got == exp).all(), engine


def test_repeated_calls_keep_resident_state_bounded():
    """Kernel calls share the runtime's jit tile: N calls must not grow
    the resident pool by N tile memories (regression: every call used to
    leak one full tile buffer)."""
    rt = nmc.NmcRuntime()
    x = _rand(64, 8)

    @nmc.jit(runtime=rt)
    def k(t, x):
        t.store(t.load(x) + 1)

    before = len(rt.resident.tiles)
    outs = [np.asarray(k(x)) for _ in range(6)]
    futs = [k.call_async(x) for _ in range(3)]
    outs += [np.asarray(f.result()) for f in futs]
    assert len(rt.resident.tiles) == before + 1     # the shared jit tile
    exp = (x.astype(np.int64) + 1).astype(np.int8)
    assert all((o == exp).all() for o in outs)


def test_lowering_error_on_public_surface():
    assert nmc.LoweringError is LoweringError
    assert "LoweringError" in nmc.__all__


def test_jit_kwargs_validate_eagerly():
    """A typo'd engine string, an unsupported sew, an impossible tile
    count or an unknown partition strategy must raise a named ValueError
    at decoration time — not a deep-stack assertion at first call."""
    def body(t, x):
        t.store(t.load(x) + 1)

    with pytest.raises(ValueError, match="engine 'ceasar'"):
        nmc.jit(body, engine="ceasar")
    with pytest.raises(ValueError, match="sew 12"):
        nmc.jit(body, sew=12)
    with pytest.raises(ValueError, match="sew"):
        nmc.jit(body, sew="8x")
    with pytest.raises(ValueError, match="tiles"):
        nmc.jit(body, tiles=0)
    with pytest.raises(ValueError, match="tiles"):
        nmc.jit(body, tiles="many")
    with pytest.raises(ValueError, match="partition"):
        nmc.jit(body, partition="diagonal")
    # per-call overrides validate identically (no deep-stack KeyError /
    # bare int() failure)
    k = nmc.jit(body, runtime=_RT)
    with pytest.raises(ValueError, match="tiles"):
        k.call_async(np.zeros(8, np.int8), tiles=-2)
    with pytest.raises(ValueError, match="tiles must be an int"):
        k.call_async(np.zeros(8, np.int8), tiles="many")
    with pytest.raises(ValueError, match="engine 'ceasar'"):
        k(np.zeros(8, np.int8), engine="ceasar")
    with pytest.raises(ValueError, match="engine 'ceasar'"):
        k(np.zeros(8, np.int8), engine="ceasar", tiles=2)
    # valid kwargs still construct
    assert nmc.jit(body, engine="carus", sew=16, tiles=4,
                   partition="axis").tiles == 4


def test_backend_kwarg_validates_eagerly():
    """An unknown backend must raise a ValueError naming the valid set at
    decoration time, and identically for per-call overrides."""
    def body(t, x):
        t.store(t.load(x) + 1)

    with pytest.raises(ValueError, match="backend 'bogus'.*scan.*pallas"):
        nmc.jit(body, backend="bogus")
    with pytest.raises(ValueError, match="backend"):
        nmc.jit(body, backend=8)
    k = nmc.jit(body, runtime=_RT)
    with pytest.raises(ValueError, match="backend 'bogus'"):
        k(_rand(16, 8), backend="bogus")
    with pytest.raises(ValueError, match="backend 'bogus'"):
        k.call_async(_rand(16, 8), backend="bogus")
    # valid spellings construct; 'auto' resolves through the runtime
    assert nmc.jit(body, backend="pallas").backend == "pallas"
    assert nmc.jit(body, backend="auto", runtime=_RT).resolve_backend() \
        in nmc.BACKENDS


@pytest.mark.parametrize("sew", [8, 16, 32])
def test_backend_pallas_bit_exact_vs_scan(sew):
    """The same traced kernel through backend='pallas' must equal the
    scan reference bit-for-bit — sync call and per-call override."""
    x, y = _rand(128, sew), _rand(128, sew)

    @nmc.jit(sew=sew, runtime=_RT)
    def k(t, a, b):
        t.store((t.load(a, bank=0) + t.load(b)) * t.load(a, bank=0))

    ref = np.asarray(k(x, y, backend="scan"))
    via_kwarg = np.asarray(k(x, y, backend="pallas"))
    assert (via_kwarg == ref).all()
    kp = nmc.jit(k.fn, sew=sew, runtime=_RT, backend="pallas")
    assert (np.asarray(kp(x, y)) == ref).all()


def test_mac_rejects_scalar_accumulator():
    """Regression: a non-traced accumulator used to be silently dropped
    (mac(5, a, b) computed a*b); it must raise instead."""
    x = _rand(16, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x):
        v = t.load(x)
        t.store(nmc.mac(5, v, v))

    with pytest.raises(TypeError, match="accumulator"):
        k.lower(x)


def test_consts_indexing_normalizes_and_bounds_checks():
    """Regression: negative consts indices used to read outside the pool
    on the engines while the oracle indexed pythonically."""
    x = _rand(16, 8)

    @nmc.jit(runtime=_RT)
    def k(t, x):
        c = t.consts(np.array([2, 3], np.int8))
        t.store(t.load(x) * c[-1])

    exp = (x.astype(np.int64) * 3).astype(np.int8)
    for engine in ("caesar", "carus"):
        assert (np.asarray(k(x, engine=engine)) == exp).all(), engine

    @nmc.jit(runtime=_RT)
    def oob(t, x):
        c = t.consts(np.array([2, 3], np.int8))
        t.store(t.load(x) * c[2])

    with pytest.raises(IndexError):
        oob.lower(x)


def test_compiled_kernel_repr_and_value_introspection():
    x = _rand(8, 8)

    @nmc.kernel
    def k(t, x):
        v = t.load(x) + 0
        assert v.ne == 8
        assert (v.value == x).all()         # eager oracle evaluation
        t.store(v)

    assert "k" in repr(k)
    out = nmc.jit(k.fn, runtime=_RT)(x)
    assert (np.asarray(out) == x).all()
