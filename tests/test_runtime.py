"""Async dispatch runtime + pool-accounting invariants (DESIGN.md §5.2).

Covers the ISSUE-3 acceptance criteria:

* hand-computed counter invariants for :class:`BucketedPool`
  (``compiles`` / ``pad_waste`` / ``bytes_moved``) and
  :class:`ResidentPool` (load/dispatch/store byte accounting) over
  mixed-shape sweeps — exact equalities, not bounds;
* :class:`DispatchQueue` futures resolve **bit-exact equal** to synchronous
  ``ResidentPool`` dispatch on the full Table V sweep (all kernels x both
  engines x SEW in {8, 16, 32}), under both schedulers, including
  round-robin tile reuse (double buffering) and chained per-tile programs;
* the overlapped-DMA timing mode reports <= the serial mode's cycles on
  every kernel sweep, strictly less on the matmul sweep, with the pipeline
  makespan hand-computed on synthetic stages.
"""

import functools

import numpy as np
import pytest

from repro.core import programs, timing
from repro.nmc import (BucketedPool, DispatchQueue, Program, ResidentPool,
                       caesar_entry, carus_entry, instr_bucket, tile_bucket)
from repro.nmc.engine import get_engine
from repro.nmc.program import PROG_DTYPE
from repro.core.isa import CaesarOp, VOp
from repro.core.timing import StageCost, dispatch_cycles

SMALL = {"caesar_bytes": 2048, "carus_bytes": 4096}
ALL_SEWS = (8, 16, 32)

# one bucketed jit cache for the whole module: sync pools and queues share
# traces (the compile-once property the scheduler tests already prove), so
# the full-sweep differential below costs execution time, not compile time
_SHARED = BucketedPool(donate=True)


@functools.lru_cache(maxsize=None)
def _full_build(name: str, sew: int):
    return programs.build(name, sew)


def _small_builds(sew: int = 8):
    kbs = [programs.build(n, sew, **SMALL)
           for n in ("xor", "add", "mul", "relu")]
    return [getattr(kb, e) for kb in kbs for e in ("caesar", "carus")]


def _caesar_prog(n_instr: int, sew: int = 8) -> Program:
    return Program.from_entries(
        "caesar", sew, [caesar_entry(CaesarOp.ADD, 100 + i, i, 4096 + i)
                        for i in range(n_instr)])


def _carus_prog(n_instr: int, sew: int = 8) -> Program:
    return Program.from_entries(
        "carus", sew, [carus_entry(VOp.VADD, vd=3, vs1=1, vs2=2)
                       for _ in range(n_instr)])


# ---------------------------------------------------------------------------
# Pool-accounting invariants: exact hand-computed counter values
# ---------------------------------------------------------------------------

def test_bucketed_pool_counters_hand_computed():
    """Mixed-shape sweep: three caesar programs in the 8-bucket (3 tiles ->
    tile-bucket 4), one caesar and one carus program in the 4-bucket.
    Every counter is checked against the by-hand arithmetic."""
    progs = [_caesar_prog(5), _caesar_prog(6), _caesar_prog(7),
             _caesar_prog(3), _carus_prog(3)]
    states = [np.zeros(8192, np.int32)] * 4 + [np.zeros((32, 256), np.int32)]
    pool = BucketedPool()
    pool.run(progs, states)
    assert pool.compiles == 3            # (c,8,8)x4t, (c,8,4)x1t, (k,8,4)x1t
    assert pool.dispatches == 3 and pool.programs_run == 5
    # pad_waste: [4 tiles x bucket 8 - (5+6+7)] + [4 - 3] + [4 - 3]
    assert pool.pad_waste == (4 * 8 - 18) + 1 + 1 == 16
    e = PROG_DTYPE.itemsize              # 8 int32 fields = 32 B per entry
    assert e == 32
    state_b = 8192 * 4                   # every image is 8192 words
    expected = ((4 * 8 * e + 4 * state_b + 4 * state_b)    # 8-bucket group
                + 2 * (1 * 4 * e + state_b + state_b))     # two 4-buckets
    assert pool.bytes_moved == expected == 394496


def test_resident_pool_mixed_engine_accounting():
    """load = full image, dispatch = instruction bytes per bucket group,
    store = result words — exact values for a two-engine tile pair."""
    rp = ResidentPool()
    rp.load("c", "caesar", np.zeros(8192, np.int32))
    rp.load("k", "carus", np.zeros((32, 256), np.int32))
    assert rp.loads == 2 and rp.bytes_moved == 2 * 8192 * 4
    rp.dispatch([("c", _caesar_prog(5)), ("k", _carus_prog(3))])
    e = PROG_DTYPE.itemsize
    instr = 1 * instr_bucket(5) * e + 1 * instr_bucket(3) * e   # 256 + 128
    assert rp.dispatches == 2            # one group per engine bucket
    assert rp.bytes_moved == 2 * 8192 * 4 + instr
    rp.store("c", (100, 4), 8)
    rp.store("k", (0, 8), 8)
    assert rp.stores == 2
    assert rp.bytes_moved == 2 * 8192 * 4 + instr + (4 + 8) * 4


def test_tile_bucket_matches_instr_bucket_rule():
    assert [tile_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]


# ---------------------------------------------------------------------------
# Async futures vs synchronous dispatch: bit-exact (acceptance, Table V)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sew", ALL_SEWS)
def test_async_queue_bit_exact_full_table_v(sew):
    """The full Table V sweep through the overlapped DispatchQueue (with a
    4-tile round-robin array, so shadow-buffer staging actually happens)
    must equal synchronous ResidentPool dispatch bit-exactly."""
    kbs = [_full_build(name, sew) for name in programs.ALL_KERNELS]
    builds = [getattr(kb, e) for kb in kbs for e in ("caesar", "carus")]
    sync = ResidentPool(pool=_SHARED)
    ref = sync.run_builds(builds)
    queue = DispatchQueue(pool=ResidentPool(pool=_SHARED))
    got = queue.run_builds(builds, n_tiles=4)
    for eb, a, b in zip(builds, ref, got):
        assert (np.asarray(a) == np.asarray(b)).all(), (eb.engine, sew)
        exp = np.asarray(eb.oracle).reshape(-1)
        assert (np.asarray(b).reshape(-1)[:exp.size] == exp).all()
    assert queue.submitted == queue.launched == queue.resolved == len(builds)
    assert queue.staged_while_busy == len(builds) - 4   # all but first wave
    assert queue.waves == -(-len(builds) // 4)          # ceil(items / tiles)


def test_inorder_and_overlapped_schedulers_agree():
    builds = _small_builds()
    ref = ResidentPool(pool=_SHARED).run_builds(builds)
    qo = DispatchQueue(pool=ResidentPool(pool=_SHARED))
    qi = DispatchQueue(pool=ResidentPool(pool=_SHARED), mode="inorder")
    oo = qo.run_builds(builds, n_tiles=2)
    oi = qi.run_builds(builds, n_tiles=2)
    for a, b, c in zip(ref, oo, oi):
        assert (np.asarray(a) == np.asarray(b)).all()
        assert (np.asarray(a) == np.asarray(c)).all()
    # overlapped stages eagerly while tiles are busy; inorder never does
    assert qo.staged_while_busy == len(builds) - 2
    assert qi.staged_while_busy == 0
    assert qi.waves == len(builds)       # one single-item wave per submit
    assert qo.waves == len(builds) // 2


def test_chained_programs_single_tile_fifo():
    """Two chained submits on one tile (second without an image) equal the
    concatenated program, and land in consecutive waves."""
    mem = np.zeros(8192, np.int32)
    mem[0], mem[4096] = 5, 7
    pa = Program.from_entries(
        "caesar", 32, [caesar_entry(CaesarOp.ADD, 100, 0, 4096)])
    pb = Program.from_entries(
        "caesar", 32, [caesar_entry(CaesarOp.XOR, 101, 100, 4096)])
    queue = DispatchQueue(pool=ResidentPool(pool=_SHARED))
    f1 = queue.submit("t", pa, image=mem)
    f2 = queue.submit("t", pb, out_slice=(100, 2))
    assert not f1.launched and not f2.launched
    out = f2.result()                    # resolves lazily, flushing both
    assert queue.waves == 2
    assert f1.result() is None           # no out_slice: state stays resident
    eng = get_engine("caesar")
    both = Program.from_entries("caesar", 32,
                                list(pa.entries) + list(pb.entries))
    exp = eng.extract(eng.run(eng.init_state(mem), both), (100, 2), 32)
    assert (out == exp).all()


def test_drain_resolves_chained_futures():
    """drain() must resolve every outstanding future — including earlier
    chained submits on a tile, not just the per-tile FIFO tail."""
    mem = np.zeros(8192, np.int32)
    pa = _caesar_prog(1, sew=8)
    pb = _caesar_prog(2, sew=8)
    queue = DispatchQueue(pool=ResidentPool(pool=_SHARED))
    f1 = queue.submit("t", pa, image=mem, out_slice=(100, 1))
    f2 = queue.submit("t", pb, out_slice=(100, 2))
    queue.drain()
    assert f1.resolved and f2.resolved
    assert queue.resolved == 2
    assert queue.pool.stores == 2        # both results were extracted


def test_run_builds_queue_threading_and_pool_guard():
    builds = _small_builds()[:4]
    rp = ResidentPool(pool=_SHARED)
    queue = DispatchQueue(pool=rp)
    got = rp.run_builds(builds, queue=queue)
    ref = ResidentPool(pool=_SHARED).run_builds(builds)
    for a, b in zip(ref, got):
        assert (np.asarray(a) == np.asarray(b)).all()
    # a queue wrapping a different pool must be rejected
    with pytest.raises(AssertionError):
        ResidentPool(pool=_SHARED).run_builds(builds, queue=queue)


def test_queue_tile_ids_never_collide_with_pool_run_builds():
    """Anonymous queue tiles draw from the pool's id counter, so mixing
    sync and async run_builds on one pool never clobbers resident state."""
    builds = _small_builds()[:2]
    rp = ResidentPool(pool=_SHARED)
    rp.run_builds(builds)
    n_sync = len(rp.tiles)
    DispatchQueue(pool=rp).run_builds(builds)
    assert len(rp.tiles) == n_sync + len(builds)   # all tiles distinct


def test_submit_call_device_future():
    import jax.numpy as jnp
    queue = DispatchQueue(pool=ResidentPool(pool=_SHARED))
    fut = queue.submit_call(lambda a, b: a @ b, jnp.eye(4), jnp.arange(4.0))
    assert queue.calls == 1
    assert np.allclose(np.asarray(fut.result()), np.arange(4.0))


# ---------------------------------------------------------------------------
# Overlapped-DMA timing mode (acceptance: <= serial everywhere, < on matmul)
# ---------------------------------------------------------------------------

def test_dispatch_cycles_hand_computed():
    s = StageCost("s", 10, 100, 10)
    assert dispatch_cycles([], "overlapped") == 0.0
    # single stage: nothing to overlap with — modes agree exactly
    assert dispatch_cycles([s], "overlapped") \
        == dispatch_cycles([s], "serial") == 120
    # two compute-bound stages: the second load (10) hides under compute 0,
    # store 0 (10) hides under compute 1 — only the last store is exposed:
    # 10 + 100 + 100 + 10 = 220 vs serial 240
    assert dispatch_cycles([s, s], "serial") == 240
    assert dispatch_cycles([s, s], "overlapped") == 220
    # DMA-bound: computes hide under the DMA stream instead
    d = StageCost("d", 100, 10, 10)
    assert dispatch_cycles([d, d], "serial") == 240
    assert dispatch_cycles([d, d], "overlapped") == 220


def test_wave_cycles_hand_computed():
    """Multi-tile wave model: one shared bus serializes DMA, per-tile
    compute overlaps (DESIGN.md §9)."""
    s = StageCost("s", 10, 100, 10)
    assert timing.wave_cycles([], 4) == 0.0
    # one tile, one stage: identical to the serial sum
    assert timing.wave_cycles([s], 1) == 120
    assert timing.wave_cycles([s, s], 2, "serial") == 240
    # two tiles, two compute-bound stages: loads serialize on the bus
    # (end 10, 20), computes overlap (end 110, 120), stores drain after
    # their compute (110+10=120, then max(120,120)+10=130)
    assert timing.wave_cycles([s, s], 2) == 130
    # same two stages on ONE tile: computes serialize (10+100+100), the
    # second load hides, stores drain -> 220 (the double-buffered shape)
    assert timing.wave_cycles([s, s], 1) == 220
    # DMA-bound stages: adding tiles cannot beat the serialized bus —
    # loads end at 200, the second compute at 210, its store at 220
    d = StageCost("d", 100, 10, 10)
    assert timing.wave_cycles([d, d], 2) == 220
    assert timing.wave_cycles([d] * 4, 4) >= 4 * 100


def test_wave_speedup_saturates_when_bus_binds():
    """Scaling shape: compute-bound shards speed up with the tile count;
    once the serialized DMA stream exceeds the overlapped compute, adding
    tiles stops helping (the paper's system-level saturation)."""
    single = StageCost("w", 64, 4096, 8)

    def shard(n):
        return StageCost("p", 64 / n, 4096 / n, 8 / n)

    speed = [timing.wave_speedup(single, [shard(n)] * n, n)
             for n in (1, 2, 4, 8, 16, 64)]
    assert abs(speed[0] - 1.0) < 1e-9
    assert all(a < b for a, b in zip(speed[:4], speed[1:5]))  # rising
    # with a 64-cycle image split across 64 tiles the bus stream alone is
    # 64 cycles against 64-cycle shard computes: speedup is bus-capped far
    # below the tile count
    assert speed[-1] < 64 / 1.9


def test_store_accounting_word_granular_for_subword_tails():
    """ResidentPool.store / DispatchQueue._account_store count whole bus
    words: a sub-word element tail (gathered shards at SEW 8/16 make odd
    tails common) still moves its full last word.  Locks the audited
    behavior of the 32-bit-bus accounting model."""
    rp = ResidentPool(pool=_SHARED)
    rp.load(("acct", 0), "caesar", np.zeros(8192, np.int32))
    b0 = rp.bytes_moved
    elems = rp.store(("acct", 0), (0, 2), 8)     # 2 words @ SEW 8
    assert rp.bytes_moved - b0 == 8              # whole words, not 5 bytes
    assert elems.size == 8                       # 2 words x 4 lanes
    b1 = rp.bytes_moved
    rp.store(("acct", 0), (0, 3), 16)            # 3 words @ SEW 16
    assert rp.bytes_moved - b1 == 12
    # the async path accounts identically: a future resolving a 2-word
    # slice with a 5-element post trim still counts 8 bytes
    queue = DispatchQueue(pool=rp)
    fut = queue.submit(("acct", 1), _caesar_prog(4),
                       image=np.zeros(8192, np.int32), out_slice=(100, 2),
                       post=lambda e: e[:5])
    queue.flush()                 # launch: image install + instruction bytes
    b2 = rp.bytes_moved
    out = fut.result()            # resolution: only the store leg remains
    assert out.size == 5                          # trimmed elements
    assert rp.bytes_moved - b2 == 8               # word-granular bytes


@pytest.mark.parametrize("name", programs.ALL_KERNELS)
def test_overlapped_leq_serial_every_kernel(name):
    stages = [timing.stage_cost(getattr(_full_build(name, sew), e))
              for sew in ALL_SEWS for e in ("caesar", "carus")]
    ser = dispatch_cycles(stages, "serial")
    ovl = dispatch_cycles(stages, "overlapped")
    assert ovl <= ser, (name, ovl, ser)
    if name == "matmul":                 # acceptance: strictly less
        assert ovl < ser, (ovl, ser)


def test_overlapped_strictly_less_on_full_sweep():
    builds = [getattr(_full_build(name, sew), e)
              for name in programs.ALL_KERNELS for sew in ALL_SEWS
              for e in ("caesar", "carus")]
    ser = timing.sweep_dispatch_cycles(builds, "serial")
    ovl = timing.sweep_dispatch_cycles(builds, "overlapped")
    assert ovl < ser
    # steady-state floor: the pipeline can't beat its busiest resource
    total_dma = sum(timing.stage_cost(b).dma_in_cycles
                    + timing.stage_cost(b).dma_out_cycles for b in builds)
    total_comp = sum(timing.stage_cost(b).compute_cycles for b in builds)
    assert ovl >= max(total_dma, total_comp)
