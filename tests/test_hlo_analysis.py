"""HLO analyzer: trip-count expansion must recover known FLOP counts."""

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_expanded():
    """k scanned matmuls must count k * 2mnk, not 2mnk (the cost_analysis
    body-once bug this module exists to fix)."""
    m = n = kdim = 128
    k_steps = 7

    def fn(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=k_steps)
        return out

    x = jax.ShapeDtypeStruct((m, kdim), jnp.float32)
    w = jax.ShapeDtypeStruct((kdim, n), jnp.float32)
    txt = _compile_text(fn, x, w)
    r = hlo_analysis.analyze(txt)
    expected = k_steps * 2 * m * n * kdim
    assert abs(r["flops"] - expected) / expected < 0.01, \
        (r["flops"], expected)

    # and the body-once XLA number would be ~1/k of that (cost_analysis()
    # returns a list-of-dicts on JAX 0.4.x — normalized by the helper)
    cost = hlo_analysis.normalize_cost_analysis(
        jax.jit(fn).lower(x, w).compile().cost_analysis())
    assert cost["flops"] < r["flops"] / (k_steps - 1)


def test_plain_matmul_flops():
    def fn(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    r = hlo_analysis.analyze(_compile_text(fn, a, b))
    assert abs(r["flops"] - 2 * 64 * 256 * 32) / (2 * 64 * 256 * 32) < 0.01


def test_nested_scan_flops():
    def fn(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    r = hlo_analysis.analyze(_compile_text(fn, x, w))
    expected = 15 * 2 * 32 ** 3
    assert abs(r["flops"] - expected) / expected < 0.01, r["flops"]


def test_bytes_positive_and_bounded():
    def fn(a):
        return jnp.tanh(a) + 1.0
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    r = hlo_analysis.analyze(_compile_text(fn, a))
    nbytes = 1024 * 1024 * 4
    assert nbytes <= r["hbm_bytes"] <= 6 * nbytes
