"""Per-architecture smoke tests + family-specific equivalence checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, base as cb
from repro.models import layers as L
from repro.models import lm, moe, ssm, xlstm
from repro.models.config import ModelConfig

RNG = np.random.default_rng(0)


def make_batch(cfg, B=2, S=32):
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        b["images"] = jnp.asarray(RNG.normal(
            size=(B, cfg.n_img_tokens, cfg.d_model)).astype(np.float32))
        b["tokens"] = b["tokens"][:, : S - cfg.n_img_tokens]
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_decode(arch):
    """REDUCED config of the same family: one forward/train step on CPU,
    asserting output shapes + no NaNs, plus prefill + 2 decode steps."""
    cfg = cb.get(arch, smoke=True)
    p = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = lm.loss_fn(p, batch, cfg)
    assert np.isfinite(float(loss)), arch
    logits, aux = lm.forward(p, batch, cfg)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()

    lg, caches = lm.prefill(p, batch, cfg, max_len=64)
    assert lg.shape == (2, cfg.vocab_size)
    clen = jnp.full((2,), 33, jnp.int32)
    for _ in range(2):
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        lg, caches = lm.decode_step(p, tok, caches, clen, cfg)
        clen = clen + 1
        assert not np.isnan(np.asarray(lg, dtype=np.float32)).any()


def test_train_step_reduces_loss():
    from repro.optim import adamw
    from repro.train import step as step_lib
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    params, opt = step_lib.init_train_state(jax.random.PRNGKey(0), cfg)
    fn = jax.jit(step_lib.make_train_step(
        cfg, adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)))
    batch = make_batch(cfg, B=4, S=64)   # fixed batch -> loss must drop
    first = None
    for i in range(12):
        params, opt, m = fn(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.5, (first, float(m["loss"]))


def test_prefill_decode_consistency_dense():
    """Teacher-forced forward logits == prefill+decode logits stepwise."""
    cfg = cb.get("h2o-danube-1.8b", smoke=True).scaled(dtype=jnp.float32)
    p = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 16)))
    full_logits, _ = lm.forward(p, {"tokens": toks}, cfg)
    lg, caches = lm.prefill(p, {"tokens": toks[:, :8]}, cfg, max_len=32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, 7]),
                               atol=2e-2)
    clen = jnp.full((1,), 9, jnp.int32)
    for t in range(8, 12):
        lg, caches = lm.decode_step(p, toks[:, t:t + 1], caches, clen, cfg)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, t]), atol=2e-2)
        clen = clen + 1


def test_mamba2_chunked_equals_recurrent():
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      dtype=jnp.float32)
    p = ssm.mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64), jnp.float32)
    y_full, cache = ssm.mamba2_apply(p, x, cfg, return_state=True)
    st = ssm.mamba2_state_init(cfg, 2, jnp.float32)
    ys = []
    for t in range(32):
        yt, st = ssm.mamba2_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache["ssm"]),
                               np.asarray(st["ssm"]), atol=1e-4)


def test_mlstm_chunked_equals_step():
    cfg = ModelConfig(name="t", family="xlstm", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      ssm_chunk=8, dtype=jnp.float32)
    p = xlstm.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64), jnp.float32)
    y_full = xlstm.mlstm_apply(p, x, cfg)
    st = xlstm.mlstm_state_init(cfg, 2)
    ys = []
    for t in range(32):
        yt, st = xlstm.mlstm_apply(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-3)


def test_moe_matches_dense_reference():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=64,
                      moe=True, n_experts=8, top_k=2, moe_d_ff=96,
                      capacity_factor=4.0, dtype=jnp.float32)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    y, aux = moe.moe_apply(p, x, cfg)
    t = x.reshape(-1, 64)
    logits = t @ p["router"]["w"]
    pr = jax.nn.softmax(logits, -1)
    tp, te = jax.lax.top_k(pr, 2)
    tp = tp / tp.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", t, p["wi"]) * \
        jax.nn.silu(jnp.einsum("td,edf->tef", t, p["wg"]))
    eo = jnp.einsum("tef,efd->ted", h, p["wo"])
    yr = jnp.zeros_like(t)
    for kk in range(2):
        sel = jnp.take_along_axis(
            eo, te[:, kk][:, None, None].repeat(64, -1), 1)[:, 0]
        yr = yr + tp[:, kk:kk + 1] * sel
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr.reshape(x.shape)),
                               atol=1e-4)


def test_nmc_quantized_serving_close_to_fp():
    """The paper's technique as a framework feature: int8 NMC serving logits
    stay close to the bf16 ones (top-1 agreement on most positions)."""
    cfg = cb.get("qwen1.5-0.5b", smoke=True)
    p = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    base, _ = lm.forward(p, batch, cfg)
    qp = L.quantize_tree(p)
    qcfg = cfg.scaled(nmc_mode="w8a8")
    qlog, _ = lm.forward(qp, batch, qcfg)
    agree = (jnp.argmax(base, -1) == jnp.argmax(qlog, -1)).mean()
    assert float(agree) > 0.9, float(agree)


def test_param_count_sane():
    # rough published sizes (whisper-tiny is ~39M; moonshot/deepseek ~16B)
    for arch in ARCH_IDS:
        cfg = cb.get(arch)
        n = cfg.param_count()
        assert 3e7 < n < 3e10, (arch, n)
